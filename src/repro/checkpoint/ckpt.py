"""Checkpointing (no TensorStore/orbax available offline — built from scratch).

Format (directory per step):
    step_<N>/
      manifest.msgpack   — tree structure, leaf shapes/dtypes, crc32 per file
      leaf_<i>.npy       — full logical value of each leaf (np.save)

Design points (DESIGN.md §6):
* **Mesh-independent**: leaves are written as *global* logical arrays
  (device_get on addressable data — single-process here; the multi-host
  variant writes per-shard files keyed by global offset, same manifest), so
  a checkpoint saved on one mesh restores onto any other — the elastic
  resize path (tested: save on 8 devices, restore on 4).
* **Integrity**: crc32 per leaf file + atomic rename of the step directory;
  a partial save can never be mistaken for a complete one.
* **Async**: ``AsyncCheckpointer`` snapshots to host memory synchronously
  (cheap) and writes in a background thread, overlapping I/O with compute.
"""
from __future__ import annotations

import dataclasses
import os
import shutil
import threading
import zlib
from typing import Any, Optional

import jax
import jax.numpy as jnp
import msgpack
import numpy as np


def _tree_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten(tree)
    return flat, treedef


def save_checkpoint(directory: str, step: int, tree: Any) -> str:
    """Synchronous atomic save; returns the final path."""
    os.makedirs(directory, exist_ok=True)
    tmp = os.path.join(directory, f".tmp_step_{step}")
    final = os.path.join(directory, f"step_{step}")
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    flat, treedef = _tree_paths(tree)
    leaves_meta = []
    for i, leaf in enumerate(flat):
        arr = np.asarray(jax.device_get(leaf))
        fname = f"leaf_{i}.npy"
        np.save(os.path.join(tmp, fname), arr)
        with open(os.path.join(tmp, fname), "rb") as f:
            crc = zlib.crc32(f.read())
        leaves_meta.append({
            "file": fname,
            "shape": list(arr.shape),
            "dtype": str(arr.dtype),
            "crc32": crc,
        })
    manifest = {
        "step": step,
        "treedef": str(treedef),
        "n_leaves": len(flat),
        "leaves": leaves_meta,
        "format_version": 1,
    }
    with open(os.path.join(tmp, "manifest.msgpack"), "wb") as f:
        f.write(msgpack.packb(manifest))
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)  # atomic publish
    return final


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = []
    for name in os.listdir(directory):
        if name.startswith("step_") and os.path.exists(
                os.path.join(directory, name, "manifest.msgpack")):
            steps.append(int(name.split("_")[1]))
    return max(steps) if steps else None


def restore_checkpoint(directory: str, step: int, target: Any,
                       shardings: Any = None) -> Any:
    """Restore into the structure of ``target``; reshard via ``shardings``.

    ``shardings``: optional pytree of jax.sharding.Sharding (or None leaves)
    — this is the elastic-resize path: the stored global arrays are placed
    onto whatever mesh the restoring job runs.
    """
    path = os.path.join(directory, f"step_{step}")
    with open(os.path.join(path, "manifest.msgpack"), "rb") as f:
        manifest = msgpack.unpackb(f.read())
    flat_t, treedef = _tree_paths(target)
    assert manifest["n_leaves"] == len(flat_t), (
        f"checkpoint has {manifest['n_leaves']} leaves, target {len(flat_t)}")
    shard_flat = (jax.tree_util.tree_flatten(
                      shardings, is_leaf=lambda x: x is None)[0]
                  if shardings is not None else [None] * len(flat_t))
    out = []
    for i, (meta, tgt) in enumerate(zip(manifest["leaves"], flat_t)):
        fpath = os.path.join(path, meta["file"])
        with open(fpath, "rb") as f:
            crc = zlib.crc32(f.read())
        if crc != meta["crc32"]:
            raise IOError(f"checksum mismatch in {fpath}")
        arr = np.load(fpath)
        assert list(arr.shape) == list(np.shape(tgt)), (
            f"leaf {i}: ckpt {arr.shape} vs target {np.shape(tgt)}")
        sh = shard_flat[i] if i < len(shard_flat) else None
        if sh is not None:
            out.append(jax.device_put(arr, sh))
        else:
            out.append(jnp.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, out)


@dataclasses.dataclass
class AsyncCheckpointer:
    """Snapshot-then-write-in-background; ``wait()`` joins the writer."""

    directory: str
    _thread: Optional[threading.Thread] = None
    _error: Optional[BaseException] = None

    def save(self, step: int, tree: Any):
        self.wait()
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)

        def _write():
            try:
                save_checkpoint(self.directory, step, host_tree)
            except BaseException as e:  # surfaced on next wait()
                self._error = e

        self._thread = threading.Thread(target=_write, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err
