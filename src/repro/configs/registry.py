"""Config registry + reduced smoke configs.

``smoke_config`` shrinks every dimension while preserving the family traits
(MoE stays MoE, MLA stays MLA, hybrid keeps its pattern) so CPU smoke tests
exercise the same code paths the full dry-run compiles.
"""
from __future__ import annotations

import dataclasses
import importlib

from repro.configs.base import ArchConfig, MLAConfig, MoEConfig

_MODULES = {
    "deepseek-67b": "deepseek_67b",
    "internlm2-20b": "internlm2_20b",
    "granite-3-2b": "granite_3_2b",
    "phi3-mini-3.8b": "phi3_mini_3_8b",
    "internvl2-76b": "internvl2_76b",
    "zamba2-1.2b": "zamba2_1_2b",
    "whisper-large-v3": "whisper_large_v3",
    "llama4-scout-17b-a16e": "llama4_scout_17b_a16e",
    "deepseek-v2-lite-16b": "deepseek_v2_lite_16b",
    "rwkv6-1.6b": "rwkv6_1_6b",
}

ARCH_IDS = tuple(_MODULES)


def get_config(name: str) -> ArchConfig:
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_MODULES)}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[name]}")
    return mod.CONFIG


def smoke_config(name: str) -> ArchConfig:
    """Reduced same-family config: tiny dims, few layers, tiny vocab."""
    full = get_config(name)
    kw = dict(
        n_layers=min(full.n_layers, 4),
        d_model=128,
        n_heads=4,
        n_kv_heads=min(full.n_kv_heads, 2) if full.n_kv_heads < full.n_heads else 4,
        head_dim=32,
        d_ff=256,
        vocab=512,
        encoder_layers=2 if full.encoder_layers else 0,
        encoder_seq=16 if full.encoder_layers else full.encoder_seq,
        vision_patches=8 if full.frontend == "vision_stub" else full.vision_patches,
        ssm_state=16, ssm_head_dim=16, ssm_conv=4,
        shared_attn_every=2,
        sliding_window=16 if full.sliding_window else 0,
        loss_chunks=2,
        dtype="float32",  # CPU smoke tests check numerics in fp32
        remat="none",
    )
    if full.moe:
        kw["moe"] = MoEConfig(
            n_experts=4,
            top_k=min(full.moe.top_k, 2),
            d_ff_expert=64,
            n_shared=min(full.moe.n_shared, 1),
        )
    if full.mla:
        kw["mla"] = MLAConfig(kv_lora=32, qk_nope_dim=16, qk_rope_dim=8,
                              v_head_dim=16)
    if full.ffn_mode != "dense":
        kw["topk_k"] = 32
    return dataclasses.replace(full, **kw)
