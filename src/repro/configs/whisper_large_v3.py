"""whisper-large-v3 [audio] — enc-dec; conv frontend is a STUB
[arXiv:2212.04356]: ``input_specs`` provides precomputed frame embeddings
(B, encoder_seq, d_model)."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="whisper-large-v3", family="audio",
    n_layers=32, d_model=1280, n_heads=20, n_kv_heads=20,
    d_ff=5120, vocab=51866, head_dim=64,
    attention="gqa", rope_theta=10000.0,
    encoder_layers=32, encoder_seq=1500, frontend="audio_stub",
)
