"""llama4-scout-17b-a16e [moe] — 16 experts, top-1 routing
[hf:meta-llama/Llama-4-Scout-17B-16E]."""
from repro.configs.base import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="llama4-scout-17b-a16e", family="moe",
    n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8,
    d_ff=8192, vocab=202048, head_dim=128,
    attention="gqa", rope_theta=500000.0,
    moe=MoEConfig(n_experts=16, top_k=1, d_ff_expert=8192, n_shared=0),
)
