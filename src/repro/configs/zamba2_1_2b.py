"""zamba2-1.2b [hybrid] — Mamba2 backbone + shared attention block
[arXiv:2411.15242].  38 Mamba2 layers; one *weight-shared* attention+FFN
block applied every ``shared_attn_every`` layers (the Zamba trick).
Sub-quadratic: runs the long_500k shape (DESIGN.md §5)."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="zamba2-1.2b", family="hybrid",
    n_layers=38, d_model=2048, n_heads=32, n_kv_heads=32,
    d_ff=8192, vocab=32000, head_dim=64,
    attention="gqa", block_pattern="M", shared_attn_every=6,
    ssm_state=64, ssm_conv=4, ssm_expand=2, ssm_head_dim=64,
    sliding_window=4096,  # shared-attn block uses windowed attention at 500k
)
