"""internvl2-76b [vlm] — InternViT stub + InternLM2-like backbone
[arXiv:2404.16821].  The vision frontend is a STUB per the brief:
``input_specs`` provides precomputed patch embeddings that replace the
first ``vision_patches`` token positions."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="internvl2-76b", family="vlm",
    n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8,
    d_ff=28672, vocab=128256, head_dim=128,
    attention="gqa", rope_theta=1000000.0,
    frontend="vision_stub", vision_patches=256,
)
