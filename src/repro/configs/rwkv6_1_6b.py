"""rwkv6-1.6b [ssm] — Finch: attention-free, data-dependent decay
[arXiv:2404.05892].  Sub-quadratic: runs the long_500k shape."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="rwkv6-1.6b", family="ssm",
    n_layers=24, d_model=2048, n_heads=32, n_kv_heads=32,
    d_ff=7168, vocab=65536, head_dim=64,
    attention="none", block_pattern="R",
)
