"""deepseek-v2-lite-16b [moe] — MLA (kv_lora=512) + 64 routed experts
top-6 + 2 shared [arXiv:2405.04434].

Note (DESIGN.md §5): the pool row lists both "64e top-6" and "2 shared+160
routed"; 160 contradicts the Lite config in arXiv:2405.04434 (§Lite: 64
routed, 2 shared, top-6, expert d_ff 1408, first layer dense d_ff 10944),
so we follow the paper's 64.
"""
from repro.configs.base import ArchConfig, MLAConfig, MoEConfig

CONFIG = ArchConfig(
    name="deepseek-v2-lite-16b", family="moe",
    n_layers=27, d_model=2048, n_heads=16, n_kv_heads=16,
    d_ff=10944,  # first (dense) layer FFN width
    vocab=102400,
    attention="mla",
    mla=MLAConfig(kv_lora=512, qk_nope_dim=128, qk_rope_dim=64, v_head_dim=128),
    moe=MoEConfig(n_experts=64, top_k=6, d_ff_expert=1408, n_shared=2),
    first_layer_dense_ffn=True,
    rope_theta=10000.0,
)
