"""ArchConfig: one dataclass describing every supported architecture.

Field semantics follow the assigned-architecture table (see DESIGN.md §5).
``block_pattern`` drives heterogeneous stacks: a string of block codes that
tiles the depth — 'A' attention+FFN, 'M' Mamba2, 'R' RWKV6, 'S' shared-
attention insert (zamba2), e.g. zamba2 = 'MMMMMS' repeating.
"""
from __future__ import annotations

import dataclasses
from typing import Literal, Optional

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int = 0
    top_k: int = 1
    d_ff_expert: int = 0
    n_shared: int = 0          # always-on shared experts (DeepSeek style)
    capacity_factor: float = 1.25
    router_dtype: str = "float32"
    impl: str = "gspmd"   # "shard_map" = explicit-collective EP (§Perf)


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    kv_lora: int = 512
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: Literal["dense", "moe", "ssm", "hybrid", "vlm", "audio"]
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None            # default d_model // n_heads
    attention: Literal["gqa", "mla", "none"] = "gqa"
    mla: Optional[MLAConfig] = None
    moe: Optional[MoEConfig] = None
    block_pattern: str = "A"                   # tiles over depth
    first_layer_dense_ffn: bool = False        # deepseek-v2 style
    rope_theta: float = 10000.0
    norm_eps: float = 1e-5
    attn_p_dtype: str = "float32"   # "bfloat16" halves score HBM traffic
    # --- paper integration: TopK-SpGEMM FFN (Eq. 1-3) ---
    ffn_mode: Literal["dense", "topk", "block_topk"] = "dense"
    topk_k: int = 0                            # kept d_ff entries per token
    topk_block: int = 128                      # lanes per block (block_topk)
    # --- SSM blocks ---
    ssm_state: int = 64
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    rwkv_chunk: int = 32     # chunked parallel WKV (0 = per-token recurrence)
    shared_attn_every: int = 6                 # zamba2 shared block period
    sliding_window: int = 0                    # 0 = full causal
    # --- enc-dec / frontends ---
    encoder_layers: int = 0                    # >0 => enc-dec (whisper)
    encoder_seq: int = 1500                    # stub frame count
    frontend: Literal["none", "audio_stub", "vision_stub"] = "none"
    vision_patches: int = 256                  # stub patch count (vlm)
    dtype: str = "bfloat16"
    # train-time
    remat: Literal["none", "full"] = "full"
    remat_groups: int = 0   # >1 = sqrt-schedule nested-scan remat (§Perf lever)
    loss_chunks: int = 8
    # --- measurement mode (roofline accounting; see launch/dryrun.py) ---
    # XLA cost_analysis counts while-loop bodies ONCE (trip counts unknown to
    # it), so roofline measurement unrolls every loop on reduced-depth models
    # and extrapolates the per-layer marginal cost.  Production graphs keep
    # scan (depth-independent HLO / compile time).
    unroll_layers: bool = False
    unroll_inner: bool = False      # flash-attn chunks + loss chunks
    attn_chunk: int = 0             # override flash q/k chunk (measurement)

    @property
    def hd(self) -> int:
        return self.head_dim if self.head_dim else self.d_model // self.n_heads

    @property
    def activation_dtype(self):
        return jnp.bfloat16 if self.dtype == "bfloat16" else jnp.float32

    def pattern_at(self, layer: int) -> str:
        return self.block_pattern[layer % len(self.block_pattern)]

    def n_params(self) -> float:
        """Analytic parameter count (for 6·N·D roofline bookkeeping)."""
        d, v = self.d_model, self.vocab
        n = v * d  # embedding
        n += v * d  # lm head (untied)
        per_layer_attn = 0.0
        if self.attention == "gqa":
            hd = self.hd
            per_layer_attn = d * (self.n_heads * hd) + 2 * d * (self.n_kv_heads * hd) \
                + (self.n_heads * hd) * d
        elif self.attention == "mla":
            m = self.mla
            qd = self.n_heads * (m.qk_nope_dim + m.qk_rope_dim)
            per_layer_attn = d * qd + d * (m.kv_lora + m.qk_rope_dim) \
                + m.kv_lora * self.n_heads * (m.qk_nope_dim + m.v_head_dim) \
                + self.n_heads * m.v_head_dim * d
        def ffn_params(dff):
            return 3 * d * dff  # SwiGLU
        per_layer_ffn = ffn_params(self.d_ff)
        moe_active_ffn = per_layer_ffn
        if self.moe and self.moe.n_experts:
            e = self.moe
            per_layer_ffn = e.n_experts * ffn_params(e.d_ff_expert) \
                + e.n_shared * ffn_params(e.d_ff_expert) + self.d_model * e.n_experts
            moe_active_ffn = (e.top_k + e.n_shared) * ffn_params(e.d_ff_expert) \
                + self.d_model * e.n_experts
        ssm_per_layer = 0.0
        if "M" in self.block_pattern:
            di = self.ssm_expand * d
            heads = di // self.ssm_head_dim
            ssm_per_layer = d * 2 * di + di * self.ssm_conv \
                + di * 2 * self.ssm_state + heads + di * d
        rwkv_per_layer = 0.0
        if "R" in self.block_pattern:
            rwkv_per_layer = 4 * d * d + d * self.d_ff * 2 + 6 * d
        total_layers = self.n_layers + self.encoder_layers
        n_attn_layers = sum(
            1 for i in range(total_layers)
            if self.pattern_at(i) in ("A", "S") or self.encoder_layers
        ) if self.attention != "none" else 0
        n_ssm = sum(1 for i in range(self.n_layers) if self.pattern_at(i) == "M")
        n_rwkv = sum(1 for i in range(self.n_layers) if self.pattern_at(i) == "R")
        n_ffn = total_layers - n_ssm - n_rwkv
        n += n_attn_layers * per_layer_attn + n_ffn * per_layer_ffn
        n += n_ssm * ssm_per_layer + n_rwkv * rwkv_per_layer
        if self.encoder_layers:  # cross attention in decoder
            n += self.n_layers * per_layer_attn
        return float(n)

    def n_active_params(self) -> float:
        """Active (per-token) params for MoE 6·N_active·D accounting."""
        if not (self.moe and self.moe.n_experts):
            return self.n_params()
        d = self.d_model
        e = self.moe
        full_ffn = e.n_experts * 3 * d * e.d_ff_expert
        active_ffn = (e.top_k + e.n_shared) * 3 * d * e.d_ff_expert
        return self.n_params() - self.n_layers * (full_ffn - active_ffn)


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: Literal["train", "prefill", "decode"]


SHAPE_SETS = (
    ShapeSpec("train_4k", 4096, 256, "train"),
    ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    ShapeSpec("decode_32k", 32768, 128, "decode"),
    ShapeSpec("long_500k", 524288, 1, "decode"),
)
