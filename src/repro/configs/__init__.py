"""Architecture configs: one module per assigned architecture + the paper's
own GNN workloads.  ``get_config(name)`` returns the full published config;
``smoke_config(name)`` returns the reduced same-family config used by CPU
smoke tests (the full configs are exercised only via the dry-run)."""
from repro.configs.base import ArchConfig, SHAPE_SETS, ShapeSpec
from repro.configs.registry import ARCH_IDS, get_config, smoke_config

__all__ = ["ArchConfig", "ARCH_IDS", "get_config", "smoke_config",
           "SHAPE_SETS", "ShapeSpec"]
