"""Graph Contraction (paper Algorithm 7): C = S · G · Sᵀ via two SpGEMMs.

S is m×n with S[label[v], v] = 1 — left-multiplying merges rows that share
a label, right-multiplying by Sᵀ merges columns; merged edge weights add.
"""
from __future__ import annotations

import numpy as np

from repro.core import executor
from repro.core.spgemm import spgemm
from repro.sparse.formats import CSR, csr_from_coo
from repro.sparse.ops import csr_transpose


def label_matrix(labels: np.ndarray, n: int | None = None,
                 m: int | None = None) -> CSR:
    """S = sparse(labels, 1:n, 1, m, n) (Algorithm 7 line 3)."""
    labels = np.asarray(labels)
    n = n if n is not None else len(labels)
    m = m if m is not None else int(labels.max()) + 1
    return csr_from_coo(labels, np.arange(n), np.ones(n, np.float32), (m, n))


def graph_contraction(g: CSR, labels: np.ndarray, method: str = "sort",
                      gather: str = "auto", schedule: str = "grouped",
                      mesh=None, pipeline: str = "two_wave",
                      sizing: str = "auto"):
    """Returns (C, infos): contracted adjacency + per-SpGEMM counters.

    ``method``/``gather``/``schedule`` select the executor's engine, B-row
    gather backend, and Table-I scheduling (the paper's ablation axes);
    ``mesh`` runs both SpGEMMs through the sharded multi-device executor,
    ``pipeline`` picks the two-wave vs legacy sync structure, and
    ``sizing`` the measured-vs-planned output sizing (planned = zero
    blocking syncs per SpGEMM for fused engines).  ``method`` accepts any
    registered engine or ``"auto"`` (per-bin adaptive dispatch), validated
    up front.
    """
    method = executor.resolve_engine(method)
    s = label_matrix(labels, n=g.n_rows)
    st = csr_transpose(s)
    r1 = spgemm(s, g, engine=method, gather=gather, schedule=schedule,
                mesh=mesh, pipeline=pipeline, sizing=sizing)
    r2 = spgemm(r1.c, st, engine=method, gather=gather, schedule=schedule,
                mesh=mesh, pipeline=pipeline, sizing=sizing)
    return r2.c, [r1.info, r2.info]
