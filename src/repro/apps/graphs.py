"""Synthetic graph generators matched to the paper's workload tables.

The paper evaluates on UF Sparse Matrix Collection matrices (Table II) and
OGB/GraphSAINT datasets (Table III).  Those files are not available offline,
so we generate synthetic matrices *matched on the characteristics the paper
reports*: rows, nnz/row, max nnz/row (Table II) and nodes, avg degree
(Table III), at CPU-feasible scale.  RMAT gives the power-law degree tails
of web/citation graphs; uniform gives road-network-like flat degrees.
"""
from __future__ import annotations

import numpy as np

from repro.sparse.formats import CSR, csr_from_coo


def rmat_graph(n: int, avg_deg: float, seed: int = 0,
               a=0.57, b=0.19, c=0.19, values: str = "uniform") -> CSR:
    """R-MAT power-law digraph as CSR (self-loop-free, deduped)."""
    rng = np.random.default_rng(seed)
    scale = int(np.ceil(np.log2(max(n, 2))))
    n_pow = 1 << scale
    n_edges = int(n * avg_deg)
    rows = np.zeros(n_edges, np.int64)
    cols = np.zeros(n_edges, np.int64)
    for level in range(scale):
        r = rng.random(n_edges)
        half = 1 << (scale - level - 1)
        # quadrant probabilities a, b, c, d
        go_right = (r >= a) & (r < a + b) | (r >= a + b + c)
        go_down = r >= a + b
        rows += np.where(go_down, half, 0)
        cols += np.where(go_right, half, 0)
    keep = (rows < n) & (cols < n) & (rows != cols)
    rows, cols = rows[keep], cols[keep]
    if values == "uniform":
        vals = rng.random(len(rows)).astype(np.float32) + 0.1
    else:
        vals = np.ones(len(rows), np.float32)
    return csr_from_coo(rows, cols, vals, (n, n))


def uniform_graph(n: int, avg_deg: float, seed: int = 0,
                  values: str = "uniform") -> CSR:
    """Uniform random digraph (flat degree distribution, RoadTX-like)."""
    rng = np.random.default_rng(seed)
    n_edges = int(n * avg_deg)
    rows = rng.integers(0, n, n_edges)
    cols = rng.integers(0, n, n_edges)
    keep = rows != cols
    rows, cols = rows[keep], cols[keep]
    vals = (rng.random(len(rows)).astype(np.float32) + 0.1
            if values == "uniform" else np.ones(len(rows), np.float32))
    return csr_from_coo(rows, cols, vals, (n, n))


# Table II workloads, scaled to CPU feasibility while preserving the
# NNZ/row and skew characteristics the paper reports.  `kind` picks the
# generator that matches the degree distribution family.
TABLE_II_SCALED = {
    #  name            n      avg_deg  kind       paper: (rows, nnz/row, max/row)
    "RoadTX":        (8192,   2.8,  "uniform"),   # 1.39M, 2.8, 51
    "p2p-Gnutella04": (8192,  3.7,  "rmat"),      # 10.9k, 3.7, 497
    "amazon0601":    (8192,   8.4,  "rmat"),      # 403k, 8.4, 100
    "web-Google":    (8192,   5.6,  "rmat"),      # 916k, 5.6, 4334
    "scircuit":      (8192,   5.6,  "uniform"),   # 171k, 5.6, 353
    "cit-Patents":   (8192,   4.4,  "rmat"),      # 3.77M, 4.4, 770
    "Economics":     (8192,   6.2,  "uniform"),   # 206k, 6.2, 44
    "webbase-1M":    (8192,   3.1,  "rmat"),      # 1M, 3.1, 4700
    "wb-edu":        (8192,   5.8,  "rmat"),      # 9.8M, 5.8, 3841
    "cage15":        (8192,  19.2,  "uniform"),   # 5.2M, 19.2, 47
    "WindTunnel":    (4096,  53.4,  "uniform"),   # 218k, 53.4, 180
    "Protein":       (2048, 119.3,  "uniform"),   # 36k, 119.3, 204
}

# Table III GNN datasets, scaled (nodes, avg_deg, n_classes, kind).
TABLE_III_SCALED = {
    "Flickr":        (4096,  22.2, 7,  "rmat"),    # 89k nodes
    "ogbn-proteins": (2048, 100.0, 2,  "uniform"), # 133k, deg 1194 (capped)
    "ogbn-arxiv":    (4096,  15.8, 40, "rmat"),    # 169k
    "Reddit":        (2048, 100.0, 41, "rmat"),    # 233k, deg 986 (capped)
    "Yelp":          (8192,  38.9, 10, "rmat"),    # 717k
    "ogbn-products": (16384, 51.5, 47, "rmat"),    # 2.45M, deg 103 (capped)
}


def table_ii_matrix(name: str, seed: int = 0, n_override: int | None = None
                    ) -> CSR:
    n, deg, kind = TABLE_II_SCALED[name]
    if n_override:
        n = n_override
    gen = rmat_graph if kind == "rmat" else uniform_graph
    return gen(n, deg, seed=seed)
