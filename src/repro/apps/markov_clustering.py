"""Markov Clustering (paper Algorithm 6) on the SpGEMM pipeline.

Expansion (A^e) is the SpGEMM; pruning keeps top-k per column above θ;
inflation is a Hadamard power + column normalization.  Each iteration's
expansion runs through the full multi-phase pipeline (grouping →
allocation → accumulation), exactly the iterative-SpGEMM workload the
paper benchmarks in Fig. 7/8.
"""
from __future__ import annotations

import dataclasses
from typing import List

import numpy as np

from repro.core import executor
from repro.core.spgemm import PlanCache, spgemm, spgemm_streamed
from repro.sparse.formats import CSR, csr_from_coo
from repro.sparse.ops import (
    csr_column_normalize,
    csr_hadamard_power,
    csr_prune_columns,
)


@dataclasses.dataclass
class MCLResult:
    matrix: CSR
    clusters: np.ndarray  # cluster id per node
    n_iterations: int
    spgemm_info: List[dict]
    # Alg. 1 + Table-I setups skipped because the expansion's support was
    # unchanged from an earlier iteration (``reuse_plan=True``).
    plan_cache_hits: int = 0


def add_self_loops(g: CSR, weight: float = 1.0) -> CSR:
    """AddSelfLoops(G) — host-side structural edit."""
    indptr = np.asarray(g.indptr)
    indices = np.asarray(g.indices)
    data = np.asarray(g.data)
    nnz = int(indptr[-1])
    rows = np.repeat(np.arange(g.n_rows), indptr[1:] - indptr[:-1])
    rows = np.concatenate([rows, np.arange(g.n_rows)])
    cols = np.concatenate([indices[:nnz], np.arange(g.n_rows)])
    vals = np.concatenate([data[:nnz], np.full(g.n_rows, weight, data.dtype)])
    return csr_from_coo(rows, cols, vals, g.shape)


def _change(a: CSR, b: CSR) -> float:
    """Frobenius distance between two same-structure-capacity CSRs (densified)."""
    from repro.sparse.formats import csr_to_dense
    da = np.asarray(csr_to_dense(a), np.float64)
    db = np.asarray(csr_to_dense(b), np.float64)
    return float(np.abs(da - db).max())


def interpret_clusters(a: CSR) -> np.ndarray:
    """Connected components of the converged matrix's support (attractors)."""
    import networkx as nx
    indptr = np.asarray(a.indptr)
    indices = np.asarray(a.indices)
    data = np.asarray(a.data)
    g = nx.Graph()
    g.add_nodes_from(range(a.n_rows))
    for i in range(a.n_rows):
        for p in range(indptr[i], indptr[i + 1]):
            if data[p] > 1e-6:
                g.add_edge(i, int(indices[p]))
    labels = np.zeros(a.n_rows, np.int64)
    for cid, comp in enumerate(nx.connected_components(g)):
        for v in comp:
            labels[v] = cid
    return labels


def mcl(
    g: CSR,
    e: int = 2,
    r: float = 2.0,
    theta: float = 1e-4,
    k: int = 32,
    max_iters: int = 16,
    tol: float = 1e-4,
    method: str = "sort",
    gather: str = "auto",
    schedule: str = "grouped",
    mesh=None,
    reuse_plan: bool = True,
    pipeline: str = "two_wave",
    sizing: str = "auto",
    stream: int = None,
    prefetch: int = 2,
    on_budget: str = "error",
) -> MCLResult:
    """Algorithm 6.  ``e=2`` expansion = one SpGEMM self-product per iter.

    Each iteration's expansion goes through the plan-compiled executor;
    ``gather``/``schedule`` expose the paper's AIA ablation axes, and
    repeated iterations reuse the executor's program cache (no re-tracing).
    ``mesh`` shards every expansion's plan across the mesh's devices; the
    per-shard programs stay cache-warm across iterations.
    ``reuse_plan`` keeps a per-run ``PlanCache`` over the expansions: once
    the clustering's support stabilizes (the common case well before
    value convergence), every further iteration skips Algorithm 1 IP
    counting and Table-I binning entirely — the hit count is reported as
    ``MCLResult.plan_cache_hits``.
    ``pipeline`` selects the executor sync structure (``"two_wave"`` =
    one coalesced allocate sync + device-side reassembly per expansion;
    ``"legacy"`` = the per-chunk-sync reference path).
    ``sizing`` selects the executor's output sizing (``"planned"`` = the
    sync-free Alg. 1 bound path, the default for ``method="fused_hash"``;
    ``"measured"`` = the uniqueCount-sync escape hatch).
    ``method="auto"`` turns on per-bin adaptive dispatch — MCL's repeated
    same-support expansions are the ``AutotuneCache``'s convergence case;
    any method value is validated up front.
    ``stream`` routes every expansion through the out-of-core streamed
    lane (``spgemm_streamed``) with ``stream`` rows per tile and
    ``prefetch`` tiles in flight — bit-identical to the monolithic run,
    but with a per-tile device working set, so a graph whose monolithic
    expansion exceeds ``executor.set_device_budget`` still clusters end
    to end.  ``reuse_plan`` then caches *tile* plans: once the support
    stabilizes, every tile of every further expansion is a plan hit.
    ``stream=None`` (default) keeps the monolithic expansion.
    ``on_budget="stream"`` makes monolithic expansions degrade gracefully
    when an iteration's plan exceeds ``executor.set_device_budget``: that
    expansion re-routes through the streamed lane with auto-derived
    ``tile_rows`` (bit-identical) instead of raising
    ``DeviceBudgetExceeded`` — see docs/resilience.md.
    """
    method = executor.resolve_engine(method)
    stream = None if stream is None else executor.resolve_tile_rows(stream)
    on_budget = executor.resolve_on_budget(on_budget)
    a = add_self_loops(g)
    a = csr_column_normalize(a)
    plan_cache = PlanCache() if reuse_plan else None
    infos = []
    it = 0
    for it in range(1, max_iters + 1):
        prev = a
        # Expansion: B <- A^e  (e-1 SpGEMM products)
        b = a
        for _ in range(e - 1):
            if stream is not None:
                res = spgemm_streamed(
                    b, a, tile_rows=stream, prefetch=prefetch,
                    engine=method, gather=gather, schedule=schedule,
                    mesh=mesh, plan=plan_cache, pipeline=pipeline,
                    sizing=sizing)
            else:
                res = spgemm(b, a, engine=method, gather=gather,
                             schedule=schedule, mesh=mesh, plan=plan_cache,
                             pipeline=pipeline, sizing=sizing,
                             on_budget=on_budget)
            infos.append(res.info)
            b = res.c
        # Prune: drop < theta, keep top-k per column
        c = csr_prune_columns(b, theta, k)
        # Inflation: Hadamard power + column normalize
        c = csr_hadamard_power(c, r)
        a = csr_column_normalize(c)
        if a.shape == prev.shape and _change(a, prev) < tol:
            break
    clusters = interpret_clusters(a)
    return MCLResult(matrix=a, clusters=clusters, n_iterations=it,
                     spgemm_info=infos,
                     plan_cache_hits=plan_cache.hits if plan_cache else 0)
