"""GNN full-batch training with TopK structured pruning (paper §V-C, Eq. 1–3).

Three architectures (GCN, GIN, GraphSAGE — the paper's Fig. 10/11 set), each
with a pruning layer that sparsifies activations so the aggregation
``A · TopK(X) · W`` is an SpGEMM instead of an SpMM.  The TopK backward is
the paper's Eq. (3) winner-take-all mask (``topk_rows_st`` custom VJP).

``sparse_mode``:
  * "topk"  — Eq. (1): aggregation over TopK-masked features (the paper's
              AIA-accelerated path; the gather inside ``csr_spmm`` is the
              two-level indirection AIA serves).
  * "dense" — the cuSPARSE-role baseline: dense Â @ X @ W.

Mini-batch path (``train_gnn_minibatch``): each step trains on a
bulk-sampled subgraph chain from ``apps.sampling.bulk_sample`` — the
SpGEMM-expressed sampler whose per-batch probability patterns repeat every
epoch.  A shared ``PlanCache`` therefore amortizes the sampler's
Algorithm-1 setups across epochs, and an optional edge-weight ensemble
(``weight_sets``) routes the probability products through the *batched*
executor (one plan, many same-pattern value sets).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Literal, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.optim import adamw, apply_updates, clip_by_global_norm
from repro.sparse.formats import CSR
from repro.sparse.ops import csr_spmm
from repro.sparse.topk import topk_rows_st


@dataclasses.dataclass(frozen=True)
class GNNConfig:
    arch: Literal["gcn", "gin", "sage"] = "gcn"
    n_layers: int = 2
    d_in: int = 64
    d_hidden: int = 64
    n_classes: int = 7
    topk: int = 16  # k of Eq. (1); <= d_hidden
    sparse_mode: Literal["topk", "dense"] = "topk"
    # How the aggregation's two-level indirect gather is served: "aia" uses
    # the scalar-prefetch Pallas kernels (paper's accelerated path), "xla"
    # the software-only baseline, "auto" picks by backend (AIA on TPU).
    gather: Literal["auto", "xla", "aia"] = "auto"


def normalize_adjacency(a: CSR) -> CSR:
    """Â = D^{-1/2} (A+I) D^{-1/2} for GCN (built host-side once)."""
    from repro.apps.markov_clustering import add_self_loops
    from repro.sparse.ops import csr_scale_rows, csr_scale_columns
    a = add_self_loops(a)
    deg = np.asarray(a.row_nnz()).astype(np.float32)
    dinv = jnp.asarray(1.0 / np.sqrt(np.maximum(deg, 1.0)))
    return csr_scale_columns(csr_scale_rows(a, dinv), dinv)


def init_gnn(cfg: GNNConfig, key) -> Dict:
    dims = [cfg.d_in] + [cfg.d_hidden] * (cfg.n_layers - 1) + [cfg.n_classes]
    params = {}
    for layer in range(cfg.n_layers):
        key, k1, k2 = jax.random.split(key, 3)
        fan_in = dims[layer]
        w = jax.random.normal(k1, (fan_in, dims[layer + 1])) / np.sqrt(fan_in)
        params[f"w{layer}"] = w.astype(jnp.float32)
        if cfg.arch == "sage":
            params[f"w_self{layer}"] = (
                jax.random.normal(k2, (fan_in, dims[layer + 1])) / np.sqrt(fan_in)
            ).astype(jnp.float32)
        if cfg.arch == "gin":
            params[f"eps{layer}"] = jnp.zeros((), jnp.float32)
    return params


def _aggregate(a: CSR, x: jax.Array, mode: str, k: int,
               gather: str = "auto", mesh=None) -> jax.Array:
    """A · TopK(X) — Eq. (1)'s sparse aggregation (or dense baseline)."""
    if mode == "topk":
        xs = topk_rows_st(x, k)  # Eq. (2) fwd, Eq. (3) bwd
        return csr_spmm(a, xs, gather=gather, mesh=mesh)
    return csr_spmm(a, x, gather=gather, mesh=mesh)


def gnn_forward(cfg: GNNConfig, params: Dict, a: CSR, x: jax.Array,
                mesh=None) -> jax.Array:
    """Forward pass; ``mesh`` row-shards every layer's aggregation so GSPMD
    splits the SpMM across the mesh's first axis."""
    h = x
    for layer in range(cfg.n_layers):
        k = min(cfg.topk, h.shape[1])
        mode = cfg.sparse_mode if layer > 0 else "dense"  # input feats stay dense
        agg = _aggregate(a, h, mode, k, gather=cfg.gather, mesh=mesh)
        if cfg.arch == "gcn":
            h = agg @ params[f"w{layer}"]
        elif cfg.arch == "gin":
            h = ((1.0 + params[f"eps{layer}"]) * h + agg) @ params[f"w{layer}"]
        else:  # sage: self + mean-ish neighbor path
            h = h @ params[f"w_self{layer}"] + agg @ params[f"w{layer}"]
        if layer < cfg.n_layers - 1:
            h = jax.nn.relu(h)
    return h  # logits


def _loss_fn(cfg, params, a, x, labels, mask, mesh=None):
    logits = gnn_forward(cfg, params, a, x, mesh=mesh)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, labels[:, None], axis=1)[:, 0]
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)


def train_gnn(
    cfg: GNNConfig,
    a: CSR,
    x: np.ndarray,
    labels: np.ndarray,
    n_steps: int = 30,
    lr: float = 1e-2,
    seed: int = 0,
    mesh=None,
) -> Tuple[Dict, List[float]]:
    """Full-batch training loop; returns (params, loss history).

    ``mesh`` row-shards the per-layer aggregations (forward and backward)
    over the mesh's first axis via GSPMD sharding constraints.
    """
    key = jax.random.PRNGKey(seed)
    params = init_gnn(cfg, key)
    opt = adamw(lr, weight_decay=0.0)
    opt_state = opt.init(params)
    x = jnp.asarray(x)
    labels = jnp.asarray(labels)
    mask = jnp.ones(labels.shape[0], jnp.float32)

    @jax.jit
    def step(params, opt_state):
        loss, grads = jax.value_and_grad(
            lambda p: _loss_fn(cfg, p, a, x, labels, mask, mesh=mesh)
        )(params)
        grads, _ = clip_by_global_norm(grads, 1.0)
        updates, opt_state = opt.update(grads, opt_state, params)
        return apply_updates(params, updates), opt_state, loss

    history = []
    for _ in range(n_steps):
        params, opt_state, loss = step(params, opt_state)
        history.append(float(loss))
    return params, history


# ---------------------------------------------------------------------------
# Mini-batch path (bulk-sampled subgraphs, amortized SpGEMM planning)
# ---------------------------------------------------------------------------

def gnn_forward_minibatch(cfg: GNNConfig, params: Dict, adjs: Sequence[CSR],
                          frontiers: Sequence[np.ndarray], x: jax.Array,
                          mesh=None) -> jax.Array:
    """Layer-wise forward over one ``bulk_sample`` subgraph chain.

    ``adjs[l]`` maps frontier l+1's features onto frontier l
    (shape ``(|Q^l|, |Q^{l+1}|)``, frontiers[0] = the batch vertices).
    Features flow from the outermost frontier inwards: layer 0 (input
    features, dense mode as in the full-batch path) consumes the last
    adjacency, the final layer lands on the batch vertices.  Self features
    for GIN/SAGE are the restriction of the previous frontier's features
    (``Q^l ⊆ Q^{l+1}`` by construction, so it's a positional take).
    """
    n_layers = cfg.n_layers
    assert len(adjs) == n_layers, (len(adjs), n_layers)
    h = jnp.asarray(x)[jnp.asarray(frontiers[n_layers])]  # outermost feats
    for layer in range(n_layers):
        t = n_layers - 1 - layer  # chain position consumed by this layer
        a_l = adjs[t]
        rows, cols = np.asarray(frontiers[t]), np.asarray(frontiers[t + 1])
        k = min(cfg.topk, h.shape[1])
        mode = cfg.sparse_mode if layer > 0 else "dense"
        agg = _aggregate(a_l, h, mode, k, gather=cfg.gather, mesh=mesh)
        # cols is sorted-unique and contains rows: positional restriction
        h_self = h[jnp.asarray(np.searchsorted(cols, rows))]
        if cfg.arch == "gcn":
            h = agg @ params[f"w{layer}"]
        elif cfg.arch == "gin":
            h = ((1.0 + params[f"eps{layer}"]) * h_self + agg) @ params[f"w{layer}"]
        else:  # sage
            h = h_self @ params[f"w_self{layer}"] + agg @ params[f"w{layer}"]
        if layer < n_layers - 1:
            h = jax.nn.relu(h)
    return h  # logits for frontiers[0] (the batch vertices)


def train_gnn_minibatch(
    cfg: GNNConfig,
    a: CSR,
    x: np.ndarray,
    labels: np.ndarray,
    batch_size: int = 32,
    n_epochs: int = 2,
    fanout: int = 4,
    lr: float = 1e-2,
    seed: int = 0,
    engine: str = "sort",
    mesh=None,
    weight_sets: Optional[np.ndarray] = None,
    reuse_plan: bool = True,
    pipeline: str = "two_wave",
    sizing: str = "auto",
) -> Tuple[Dict, List[float], Dict[str, int]]:
    """Mini-batch training on ``bulk_sample`` subgraph chains.

    Returns (params, per-step loss history, amortization stats).  Each step
    samples a GraphSAGE-style L-layer neighborhood for its vertex batch
    (every SpGEMM in the chain goes through the plan-compiled executor,
    sharded under ``mesh=``) and trains on the sampled subgraphs.
    ``reuse_plan`` shares one ``PlanCache`` across all steps: each batch's
    neighborhood sampling is seeded per *batch* (not per epoch), so the
    same vertex batch re-appears every epoch with the same frontiers and
    the same probability pattern ``Q^l · A``, and from the second epoch on
    the sampler's planning cost is amortized away (hits reported in the
    stats).  ``weight_sets``
    forwards an edge-reweighting ensemble to ``bulk_sample``, turning each
    probability product into one batched SpGEMM.  ``pipeline`` forwards
    the executor sync structure to every sampling-chain SpGEMM, and
    ``sizing`` its output sizing (planned Alg. 1 bounds vs the measured
    uniqueCount sync).  ``a``
    should already be normalized as the architecture expects
    (e.g. ``normalize_adjacency``).  ``engine`` accepts any registered
    engine or ``"auto"`` (per-bin adaptive dispatch — epoch-revisited
    batches are the ``AutotuneCache``'s convergence case), validated up
    front.
    """
    from repro.apps.sampling import bulk_sample
    from repro.core import executor
    from repro.core.spgemm import PlanCache

    engine = executor.resolve_engine(engine)
    key = jax.random.PRNGKey(seed)
    params = init_gnn(cfg, key)
    opt = adamw(lr, weight_decay=0.0)
    opt_state = opt.init(params)
    x = jnp.asarray(x)
    labels_np = np.asarray(labels)
    n = a.n_rows
    order = np.random.default_rng(seed).permutation(n)
    batches = [np.sort(order[i: i + batch_size])
               for i in range(0, n, batch_size)]
    plan_cache = PlanCache(max_entries=256) if reuse_plan else None

    history: List[float] = []
    for epoch in range(n_epochs):
        for bi, batch in enumerate(batches):
            adjs, frontiers = bulk_sample(
                a, batch, fanout=fanout, n_layers=cfg.n_layers,
                # Per-batch (epoch-independent) seed: revisiting a batch
                # must reproduce its frontiers, or every deeper-layer
                # pattern re-fingerprints and the PlanCache never hits.
                seed=seed * 100_000 + bi,
                engine=engine, gather=cfg.gather, mesh=mesh,
                plan_cache=plan_cache, weight_sets=weight_sets,
                pipeline=pipeline, sizing=sizing,
            )
            y = jnp.asarray(labels_np[frontiers[0]])

            def loss_fn(p):
                logits = gnn_forward_minibatch(cfg, p, adjs, frontiers, x,
                                               mesh=mesh)
                logp = jax.nn.log_softmax(logits, axis=-1)
                return -jnp.mean(
                    jnp.take_along_axis(logp, y[:, None], axis=1))

            loss, grads = jax.value_and_grad(loss_fn)(params)
            grads, _ = clip_by_global_norm(grads, 1.0)
            updates, opt_state = opt.update(grads, opt_state, params)
            params = apply_updates(params, updates)
            history.append(float(loss))
    stats = {
        "plan_cache_hits": plan_cache.hits if plan_cache else 0,
        "plan_cache_misses": plan_cache.misses if plan_cache else 0,
    }
    return params, history, stats
