"""Paper applications (§V): Markov Clustering, Graph Contraction, GNN+TopK."""
from repro.apps.graphs import (
    rmat_graph, uniform_graph, table_ii_matrix, TABLE_II_SCALED, TABLE_III_SCALED,
)
from repro.apps.markov_clustering import mcl, MCLResult
from repro.apps.graph_contraction import graph_contraction
from repro.apps.gnn import GNNConfig, init_gnn, gnn_forward, train_gnn
from repro.apps.sampling import bulk_sample

__all__ = [
    "rmat_graph", "uniform_graph", "table_ii_matrix",
    "TABLE_II_SCALED", "TABLE_III_SCALED",
    "mcl", "MCLResult", "graph_contraction",
    "GNNConfig", "init_gnn", "gnn_forward", "train_gnn",
    "bulk_sample",
]
