"""Matrix-based bulk neighborhood sampling (paper §V-C, Tripathy et al.).

Mini-batch GNN sampling expressed as a chain of SpGEMM operations, per the
paper's three-step framework for each layer l = L..1:

  1. probabilities:  P   = Q^l · A          (SpGEMM — our pipeline)
  2. normalization:  NORM(P)                (row-stochastic for GraphSAGE)
  3. sampling:       Q^{l-1} = SAMPLE(P, s) (inverse-transform, s per row)
  4. extraction:     A^l = R · A · Cᵀ       (row/column extraction — itself
                                             two SpGEMMs with selection
                                             matrices, as the paper notes)

Returns the per-layer sampled adjacency list A^0..A^{L-1} used by layer-wise
aggregation in mini-batch training.  Sampling randomness is host-side
(deterministic per seed) — the data-dependent shapes make this the natural
split, mirroring the distributed implementations the paper cites.

Amortization hooks (the mini-batch regime is exactly where SpGEMM setup
cost repeats):

* ``plan_cache=`` — every SpGEMM in the chain consults one ``PlanCache``;
  epoch-revisited mini-batches re-issue the same probability patterns
  (Q^l is deterministic per batch), so their Algorithm-1 setups are
  skipped.
* ``weight_sets=`` — a stack of alternative A edge-value sets sharing A's
  support (DropEdge-style reweightings / importance ensembles).  The
  probability step P = Q^l · A then runs **one batched SpGEMM** over the
  ensemble (structure shared, values differ) and samples from the
  ensemble-averaged distribution.
"""
from __future__ import annotations

from typing import List, Optional, Tuple

import numpy as np

from repro.core import executor
from repro.core.spgemm import spgemm, spgemm_batched
from repro.sparse.formats import CSR, csr_from_coo
from repro.sparse.ops import csr_scale_rows, csr_transpose


def selection_matrix(vertices: np.ndarray, n: int) -> CSR:
    """R with R[i, vertices[i]] = 1 — row-extraction by SpGEMM."""
    vertices = np.asarray(vertices)
    b = len(vertices)
    return csr_from_coo(np.arange(b), vertices, np.ones(b, np.float32), (b, n))


def norm_rows(p: CSR) -> CSR:
    """GraphSAGE NORM: each row of P becomes a probability distribution."""
    import jax.numpy as jnp
    rowsum = np.zeros(p.n_rows, np.float32)
    rid = np.asarray(p.row_ids())
    data = np.asarray(p.data)
    valid = rid < p.n_rows
    np.add.at(rowsum, rid[valid], data[valid])
    inv = np.where(rowsum > 0, 1.0 / np.maximum(rowsum, 1e-12), 0.0)
    return csr_scale_rows(p, jnp.asarray(inv))


def sample_rows(p: CSR, s: int, rng: np.random.Generator) -> np.ndarray:
    """Inverse-transform sampling: ≤ s distinct columns per row of P."""
    indptr = np.asarray(p.indptr)
    indices = np.asarray(p.indices)
    data = np.asarray(p.data)
    picks = set()
    for i in range(p.n_rows):
        lo, hi = indptr[i], indptr[i + 1]
        cols = indices[lo:hi]
        w = np.maximum(data[lo:hi], 0)
        if len(cols) == 0 or w.sum() <= 0:
            continue
        k = min(s, len(cols))
        chosen = rng.choice(cols, size=k, replace=False, p=w / w.sum())
        picks.update(int(c) for c in chosen)
    return np.asarray(sorted(picks), np.int64)


def extract(a: CSR, rows: np.ndarray, cols: np.ndarray,
            engine: str = "sort", gather: str = "auto", mesh=None,
            plan_cache=None, pipeline: str = "two_wave",
            sizing: str = "auto") -> CSR:
    """A[rows, cols] via SpGEMM with selection matrices: R · A · Cᵀ.

    ``engine`` accepts any registered engine name or ``"auto"`` (per-bin
    adaptive dispatch), validated up front."""
    engine = executor.resolve_engine(engine)
    r = selection_matrix(rows, a.n_rows)
    c = selection_matrix(cols, a.n_cols)
    ra = spgemm(r, a, engine=engine, gather=gather, mesh=mesh,
                plan=plan_cache, pipeline=pipeline, sizing=sizing).c
    return spgemm(ra, csr_transpose(c), engine=engine, gather=gather,
                  mesh=mesh, plan=plan_cache, pipeline=pipeline,
                  sizing=sizing).c


def _weighted_members(a: CSR, weight_sets: np.ndarray) -> List[CSR]:
    """CSRs sharing ``a``'s support with per-member edge values.

    ``weight_sets``: (W, nnz) — one row of alternative values per member
    (e.g. DropEdge masks as 0/scale factors).
    """
    import jax.numpy as jnp

    weight_sets = np.asarray(weight_sets, np.asarray(a.data).dtype)
    nnz = int(np.asarray(a.indptr)[-1])
    if weight_sets.ndim != 2 or weight_sets.shape[1] != nnz:
        raise ValueError(
            f"weight_sets must be (n_members, nnz={nnz}), "
            f"got {weight_sets.shape}")
    cap = int(a.indices.shape[0])
    members = []
    for w in weight_sets:
        data = np.zeros(cap, weight_sets.dtype)
        data[:nnz] = w
        members.append(CSR(a.indptr, a.indices, jnp.asarray(data), a.shape))
    return members


def _ensemble_mean(cs: List[CSR]) -> CSR:
    """Average same-structure CSRs (batched-SpGEMM outputs share layout)."""
    import jax.numpy as jnp

    data = jnp.mean(jnp.stack([c.data for c in cs]), axis=0)
    t = cs[0]
    return CSR(t.indptr, t.indices, data, t.shape)


def bulk_sample(
    a: CSR,
    batch_vertices: np.ndarray,
    fanout: int,
    n_layers: int,
    seed: int = 0,
    engine: str = "sort",
    gather: str = "auto",
    mesh=None,
    plan_cache=None,
    weight_sets: Optional[np.ndarray] = None,
    pipeline: str = "two_wave",
    sizing: str = "auto",
) -> Tuple[List[CSR], List[np.ndarray]]:
    """GraphSAGE-style L-layer sampling for one minibatch.

    Returns (adjacencies A^{L-1}..A^0 outermost-first, frontier vertex lists
    Q^L..Q^0).  A^l has shape (|Q^{l+1}|, |Q^l|).  ``engine``/``gather``
    select the SpGEMM executor's accumulation engine and B-row gather;
    ``mesh`` runs every sampling-chain SpGEMM through the sharded executor.
    ``plan_cache`` (a ``core.spgemm.PlanCache``) amortizes the chain's
    Algorithm-1 setups across repeated calls (epochs revisit the same
    probability patterns).  ``weight_sets`` (W, nnz) supplies an ensemble
    of edge reweightings of A sharing its support: the probability step
    becomes one batched SpGEMM and sampling draws from the averaged
    distribution (``None`` = the single-matrix path, unchanged).
    ``pipeline`` selects the executor sync structure (two-wave coalesced
    allocate sync + device reassembly vs the legacy per-chunk path) and
    ``sizing`` the output sizing (planned Alg. 1 bounds = zero blocking
    syncs for fused engines, vs the measured uniqueCount sync); the
    chain's shared adjacency also makes every step after the first serve
    B's replicated buffers from the executor's ``OperandCache``.
    ``engine="auto"`` turns on the executor's per-bin adaptive dispatch
    (the chain's repeated patterns are what the ``AutotuneCache``
    converges on); any engine value is validated up front.
    """
    engine = executor.resolve_engine(engine)
    rng = np.random.default_rng(seed)
    frontiers = [np.asarray(batch_vertices, np.int64)]
    adjs: List[CSR] = []
    q_cur = frontiers[0]
    members = (None if weight_sets is None
               else _weighted_members(a, weight_sets))
    for _ in range(n_layers):
        q_mat = selection_matrix(q_cur, a.n_rows)
        if members is None:
            p = spgemm(q_mat, a, engine=engine, gather=gather,
                       mesh=mesh, plan=plan_cache,
                       pipeline=pipeline, sizing=sizing).c  # P = Q^l · A
        else:
            # P_w = Q^l · A_w for every reweighting, one planned run
            batch = spgemm_batched(q_mat, members, engine=engine,
                                   gather=gather, mesh=mesh, plan=plan_cache,
                                   pipeline=pipeline, sizing=sizing)
            p = _ensemble_mean(batch.cs)
        p = norm_rows(p)                            # NORM
        sampled = sample_rows(p, fanout, rng)       # SAMPLE
        q_next = np.unique(np.concatenate([q_cur, sampled]))  # self + nbrs
        adjs.append(extract(a, q_cur, q_next, engine=engine, gather=gather,
                            mesh=mesh, plan_cache=plan_cache,
                            pipeline=pipeline, sizing=sizing))
        frontiers.append(q_next)
        q_cur = q_next
    return adjs, frontiers
