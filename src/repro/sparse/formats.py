"""Sparse matrix formats with static (padded) capacities.

JAX requires static shapes under ``jit``; every format therefore separates
the *capacity* (static, shape-defining) from the *occupancy* (dynamic, data).

Conventions
-----------
* ``CSR``: ``indptr[(n_rows+1,)] int32``; ``indices[(cap,)] int32`` and
  ``data[(cap,)]`` padded beyond ``indptr[-1]`` with ``indices = 0`` and
  ``data = 0``.  Validity of slot ``p`` is ``p < indptr[-1]``; row ids are
  recovered with ``row_ids()``.
* ``ELL``: ``indices[(n_rows, k_cap)]`` padded with ``-1``;
  ``data[(n_rows, k_cap)]`` padded with ``0``.  Per-row occupancy is
  ``(indices >= 0).sum(-1)``.
* ``BSR``: block-CSR; ``indptr[(n_brows+1,)]``, ``indices[(bcap,)]`` block
  column ids, ``blocks[(bcap, bs_r, bs_c)]``.
* ``TopKRows``: the paper's Eq. (2) sparsified activation — exactly ``k``
  entries per row (``values[(n, k)]``, ``indices[(n, k)]``), no padding.

All containers are registered pytrees: array fields are leaves, the logical
``shape`` is static aux data, so they pass through ``jit``/``vmap``/``scan``.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np


def _register(cls):
    fields = [f.name for f in dataclasses.fields(cls) if not f.metadata.get("static")]
    static = [f.name for f in dataclasses.fields(cls) if f.metadata.get("static")]

    def flatten(x):
        return tuple(getattr(x, n) for n in fields), tuple(getattr(x, n) for n in static)

    def unflatten(aux, leaves):
        return cls(**dict(zip(fields, leaves)), **dict(zip(static, aux)))

    jax.tree_util.register_pytree_node(cls, flatten, unflatten)
    return cls


def _static(**kw):
    return dataclasses.field(metadata={"static": True}, **kw)


@_register
@dataclasses.dataclass(frozen=True)
class CSR:
    """Compressed sparse row with static capacity ``indices.shape[0]``."""

    indptr: jax.Array
    indices: jax.Array
    data: jax.Array
    shape: Tuple[int, int] = _static()

    @property
    def n_rows(self) -> int:
        return self.shape[0]

    @property
    def n_cols(self) -> int:
        return self.shape[1]

    @property
    def capacity(self) -> int:
        return self.indices.shape[0]

    @property
    def nnz(self) -> jax.Array:
        """Dynamic occupancy (a traced scalar under jit)."""
        return self.indptr[-1]

    def row_ids(self) -> jax.Array:
        """Row id of every slot (capacity,); padding slots get ``n_rows``."""
        p = jnp.arange(self.capacity, dtype=jnp.int32)
        rid = jnp.searchsorted(self.indptr, p, side="right").astype(jnp.int32) - 1
        return jnp.where(p < self.nnz, rid, self.n_rows)

    def valid_mask(self) -> jax.Array:
        return jnp.arange(self.capacity) < self.nnz

    def row_nnz(self) -> jax.Array:
        return (self.indptr[1:] - self.indptr[:-1]).astype(jnp.int32)


@_register
@dataclasses.dataclass(frozen=True)
class ELL:
    """Padded row-major sparse rows: fixed ``k_cap`` slots per row."""

    indices: jax.Array  # (n_rows, k_cap) int32, -1 padded
    data: jax.Array  # (n_rows, k_cap)
    shape: Tuple[int, int] = _static()

    @property
    def n_rows(self) -> int:
        return self.shape[0]

    @property
    def n_cols(self) -> int:
        return self.shape[1]

    @property
    def k_cap(self) -> int:
        return self.indices.shape[1]

    def valid_mask(self) -> jax.Array:
        return self.indices >= 0

    def row_nnz(self) -> jax.Array:
        return self.valid_mask().sum(-1).astype(jnp.int32)


@_register
@dataclasses.dataclass(frozen=True)
class BSR:
    """Block-CSR with dense ``(bs_r, bs_c)`` blocks (MXU-aligned on TPU)."""

    indptr: jax.Array  # (n_brows + 1,)
    indices: jax.Array  # (bcap,) block-column ids, 0-padded
    blocks: jax.Array  # (bcap, bs_r, bs_c)
    shape: Tuple[int, int] = _static()  # element shape (rows, cols)

    @property
    def block_shape(self) -> Tuple[int, int]:
        return (self.blocks.shape[1], self.blocks.shape[2])

    @property
    def n_brows(self) -> int:
        return self.indptr.shape[0] - 1

    @property
    def n_bcols(self) -> int:
        return self.shape[1] // self.blocks.shape[2]

    @property
    def nnzb(self) -> jax.Array:
        return self.indptr[-1]


@_register
@dataclasses.dataclass(frozen=True)
class TopKRows:
    """Eq. (2) of the paper: exactly-k-per-row sparse activations."""

    values: jax.Array  # (n, k)
    indices: jax.Array  # (n, k) int32
    shape: Tuple[int, int] = _static()  # (n, d_full)

    @property
    def k(self) -> int:
        return self.values.shape[1]

    def to_dense(self) -> jax.Array:
        n, d = self.shape
        out = jnp.zeros((n, d), self.values.dtype)
        rows = jnp.arange(n)[:, None]
        return out.at[rows, self.indices].add(self.values)


# ---------------------------------------------------------------------------
# Constructors / converters.  Dense-side constructors are host/test helpers;
# they accept a static ``capacity`` so results stay jit-compatible.
# ---------------------------------------------------------------------------

def csr_from_dense(x, capacity: int | None = None) -> CSR:
    """Dense (n, m) -> CSR.  Host-side helper (uses numpy for compaction)."""
    x = np.asarray(x)
    n, m = x.shape
    rows, cols = np.nonzero(x)
    vals = x[rows, cols]
    nnz = len(rows)
    cap = capacity if capacity is not None else max(nnz, 1)
    if nnz > cap:
        raise ValueError(f"capacity {cap} < nnz {nnz}")
    indptr = np.zeros(n + 1, np.int32)
    np.add.at(indptr[1:], rows, 1)
    indptr = np.cumsum(indptr).astype(np.int32)
    indices = np.zeros(cap, np.int32)
    data = np.zeros(cap, x.dtype)
    indices[:nnz] = cols
    data[:nnz] = vals
    return CSR(jnp.asarray(indptr), jnp.asarray(indices), jnp.asarray(data), (n, m))


def csr_from_coo(rows, cols, vals, shape, capacity: int | None = None) -> CSR:
    """COO triplets (host numpy) -> CSR, sorting by (row, col) and merging dups."""
    rows = np.asarray(rows, np.int64)
    cols = np.asarray(cols, np.int64)
    vals = np.asarray(vals)
    n, m = shape
    order = np.lexsort((cols, rows))
    rows, cols, vals = rows[order], cols[order], vals[order]
    # merge duplicates
    if len(rows):
        key = rows * m + cols
        uniq, inv = np.unique(key, return_inverse=True)
        merged = np.zeros(len(uniq), vals.dtype)
        np.add.at(merged, inv, vals)
        rows, cols, vals = (uniq // m).astype(np.int64), (uniq % m).astype(np.int64), merged
    nnz = len(rows)
    cap = capacity if capacity is not None else max(nnz, 1)
    indptr = np.zeros(n + 1, np.int32)
    np.add.at(indptr[1:], rows, 1)
    indptr = np.cumsum(indptr).astype(np.int32)
    indices = np.zeros(cap, np.int32)
    data = np.zeros(cap, vals.dtype)
    indices[:nnz] = cols
    data[:nnz] = vals
    return CSR(jnp.asarray(indptr), jnp.asarray(indices), jnp.asarray(data), (n, m))


def csr_to_dense(a: CSR) -> jax.Array:
    out = jnp.zeros(a.shape, a.data.dtype)
    rid = a.row_ids()
    # padding slots have rid == n_rows -> scattered into a dropped row
    out = jnp.zeros((a.n_rows + 1, a.n_cols), a.data.dtype).at[rid, a.indices].add(
        jnp.where(a.valid_mask(), a.data, 0)
    )
    return out[: a.n_rows]


def ell_from_dense(x, k_cap: int | None = None) -> ELL:
    x = np.asarray(x)
    n, m = x.shape
    per_row = (x != 0).sum(axis=1)
    k = k_cap if k_cap is not None else max(int(per_row.max(initial=0)), 1)
    indices = -np.ones((n, k), np.int32)
    data = np.zeros((n, k), x.dtype)
    for i in range(n):
        cols = np.nonzero(x[i])[0][:k]
        indices[i, : len(cols)] = cols
        data[i, : len(cols)] = x[i, cols]
    return ELL(jnp.asarray(indices), jnp.asarray(data), (n, m))


def ell_to_dense(a: ELL) -> jax.Array:
    n, m = a.shape
    mask = a.valid_mask()
    safe_idx = jnp.where(mask, a.indices, m)  # scatter padding into a dropped col
    out = jnp.zeros((n, m + 1), a.data.dtype)
    rows = jnp.arange(n)[:, None]
    out = out.at[rows, safe_idx].add(jnp.where(mask, a.data, 0))
    return out[:, :m]


def csr_to_ell(a: CSR, k_cap: int) -> ELL:
    """CSR -> ELL with static per-row capacity ``k_cap`` (jit-compatible)."""
    n = a.n_rows
    rid = a.row_ids()
    p = jnp.arange(a.capacity, dtype=jnp.int32)
    # slot's position within its row
    within = p - jnp.take(a.indptr, jnp.clip(rid, 0, n), mode="clip")
    valid = a.valid_mask() & (within < k_cap)
    srow = jnp.where(valid, rid, n)
    scol = jnp.where(valid, within, 0)
    indices = jnp.full((n + 1, k_cap), -1, jnp.int32).at[srow, scol].set(
        jnp.where(valid, a.indices, -1)
    )[:n]
    data = jnp.zeros((n + 1, k_cap), a.data.dtype).at[srow, scol].set(
        jnp.where(valid, a.data, 0)
    )[:n]
    return ELL(indices, data, a.shape)


def ell_to_csr(a: ELL, capacity: int | None = None) -> CSR:
    """ELL -> CSR (jit-compatible; capacity defaults to n*k_cap)."""
    n, m = a.shape
    cap = capacity if capacity is not None else a.n_rows * a.k_cap
    counts = a.row_nnz()
    indptr = jnp.concatenate([jnp.zeros(1, jnp.int32), jnp.cumsum(counts)]).astype(jnp.int32)
    mask = a.valid_mask()
    # compact valid entries left within each row, then scatter to flat offsets
    order = jnp.argsort(~mask, axis=1, stable=True)  # valid first
    rows = jnp.arange(n)[:, None]
    cidx = jnp.take_along_axis(a.indices, order, axis=1)
    cdat = jnp.take_along_axis(a.data, order, axis=1)
    within = jnp.arange(a.k_cap)[None, :]
    flat_pos = indptr[:-1][:, None] + within
    ok = within < counts[:, None]
    flat_pos = jnp.where(ok, flat_pos, cap)
    indices = jnp.zeros(cap + 1, jnp.int32).at[flat_pos].set(jnp.where(ok, cidx, 0))[:cap]
    data = jnp.zeros(cap + 1, a.data.dtype).at[flat_pos].set(jnp.where(ok, cdat, 0))[:cap]
    return CSR(indptr, indices, data, a.shape)


def bsr_from_dense(x, block_shape: Tuple[int, int], capacity: int | None = None) -> BSR:
    """Dense -> BSR keeping blocks with any nonzero (host-side helper)."""
    x = np.asarray(x)
    n, m = x.shape
    br, bc = block_shape
    assert n % br == 0 and m % bc == 0, (n, m, block_shape)
    nbr, nbc = n // br, m // bc
    blocks4 = x.reshape(nbr, br, nbc, bc).transpose(0, 2, 1, 3)
    nz = np.abs(blocks4).sum(axis=(2, 3)) != 0
    rows, cols = np.nonzero(nz)
    nnzb = len(rows)
    cap = capacity if capacity is not None else max(nnzb, 1)
    indptr = np.zeros(nbr + 1, np.int32)
    np.add.at(indptr[1:], rows, 1)
    indptr = np.cumsum(indptr).astype(np.int32)
    indices = np.zeros(cap, np.int32)
    blocks = np.zeros((cap, br, bc), x.dtype)
    indices[:nnzb] = cols
    blocks[:nnzb] = blocks4[rows, cols]
    return BSR(jnp.asarray(indptr), jnp.asarray(indices), jnp.asarray(blocks), (n, m))


def bsr_to_dense(a: BSR) -> jax.Array:
    br, bc = a.block_shape
    nbr = a.n_brows
    nbc = a.shape[1] // bc
    cap = a.indices.shape[0]
    p = jnp.arange(cap, dtype=jnp.int32)
    rid = jnp.searchsorted(a.indptr, p, side="right").astype(jnp.int32) - 1
    valid = p < a.nnzb
    rid = jnp.where(valid, rid, nbr)
    out = jnp.zeros((nbr + 1, nbc, br, bc), a.blocks.dtype)
    out = out.at[rid, a.indices].add(jnp.where(valid[:, None, None], a.blocks, 0))
    return out[:nbr].transpose(0, 2, 1, 3).reshape(a.shape)
