"""Static-shape sparse formats and primitives for JAX/TPU.

Everything here is jit/vmap-compatible: formats carry *static* capacities
(padded arrays) so they can flow through ``jax.jit``/``pjit`` unchanged.
"""
from repro.sparse.formats import (
    CSR,
    ELL,
    BSR,
    TopKRows,
    csr_from_dense,
    csr_to_dense,
    ell_from_dense,
    ell_to_dense,
    csr_to_ell,
    ell_to_csr,
    bsr_from_dense,
    bsr_to_dense,
    csr_from_coo,
)
from repro.sparse.ops import (
    csr_transpose,
    csr_row_nnz,
    csr_spmm,
    csr_spmv,
    csr_scale_columns,
    csr_scale_rows,
    csr_hadamard_power,
    csr_column_sums,
    csr_column_normalize,
    csr_prune_columns,
    csr_permute_rows,
)
from repro.sparse.topk import (
    topk_rows,
    topk_mask,
    block_topk_rows,
    topk_rows_st,
)

__all__ = [
    "CSR", "ELL", "BSR", "TopKRows",
    "csr_from_dense", "csr_to_dense", "ell_from_dense", "ell_to_dense",
    "csr_to_ell", "ell_to_csr", "bsr_from_dense", "bsr_to_dense",
    "csr_from_coo",
    "csr_transpose", "csr_row_nnz", "csr_spmm", "csr_spmv",
    "csr_scale_columns", "csr_scale_rows", "csr_hadamard_power",
    "csr_column_sums", "csr_column_normalize", "csr_prune_columns",
    "csr_permute_rows",
    "topk_rows", "topk_mask", "block_topk_rows", "topk_rows_st",
]
