"""TopK sparsification (paper Eq. 1–3) and block-structured variant.

``topk_rows`` implements Eq. (2): keep the k largest-magnitude entries per
row.  ``topk_rows_st`` wires the paper's Eq. (3) backward pass — gradients
flow *only* through the selected entries (winner-take-all routing) — as a
``custom_vjp`` so the sparse structure is reused in the backward SpGEMM.

``block_topk_rows`` is the beyond-paper TPU adaptation: selection at the
granularity of contiguous ``block`` lanes so the downstream gather is
MXU-tile aligned (see DESIGN.md §4).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.sparse.formats import TopKRows


def topk_mask(x: jax.Array, k: int) -> jax.Array:
    """Binary mask M_k of Eq. (2): 1 where x is among the row's top-k |values|."""
    _, idx = jax.lax.top_k(jnp.abs(x), k)
    mask = jnp.zeros_like(x, dtype=bool)
    rows = jnp.arange(x.shape[0])[:, None]
    return mask.at[rows, idx].set(True)


def topk_rows(x: jax.Array, k: int) -> TopKRows:
    """Eq. (2) as an explicit sparse container (values may include zeros)."""
    vals_abs, idx = jax.lax.top_k(jnp.abs(x), k)
    del vals_abs
    rows = jnp.arange(x.shape[0])[:, None]
    vals = x[rows, idx]
    return TopKRows(vals, idx.astype(jnp.int32), x.shape)


def block_topk_rows(x: jax.Array, k_blocks: int, block: int = 128) -> TopKRows:
    """Keep the ``k_blocks`` highest-energy *blocks* of ``block`` lanes per row.

    Returns a TopKRows whose ``indices`` are block ids (0..d/block) and whose
    ``values`` are the dense (n, k_blocks*block) kept lanes reshaped to
    (n, k_blocks, block) flattened — callers treat entry (i, t) as the whole
    block ``indices[i, t]``.
    """
    n, d = x.shape
    assert d % block == 0, (d, block)
    nb = d // block
    xb = x.reshape(n, nb, block)
    energy = jnp.sum(xb * xb, axis=-1)
    _, bidx = jax.lax.top_k(energy, k_blocks)  # (n, k_blocks)
    rows = jnp.arange(n)[:, None]
    kept = xb[rows, bidx]  # (n, k_blocks, block)
    return TopKRows(kept.reshape(n, k_blocks * block), bidx.astype(jnp.int32), (n, d))


import functools


@functools.partial(jax.custom_vjp, nondiff_argnums=(1,))
def topk_rows_st(x: jax.Array, k: int):
    """TopK with the paper's Eq. (3) gradient: dL/dx = M_k ⊙ upstream."""
    m = topk_mask(x, k)
    return jnp.where(m, x, 0)


def _topk_fwd(x, k):
    m = topk_mask(x, k)
    return jnp.where(m, x, 0), m


def _topk_bwd(k, m, g):
    return (jnp.where(m, g, 0),)


topk_rows_st.defvjp(_topk_fwd, _topk_bwd)
