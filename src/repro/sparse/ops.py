"""CSR primitives (jit-compatible, static capacities).

These are the substrate ops the paper's applications are built on:
Markov Clustering needs column normalization, Hadamard powers and top-k
column pruning (Algorithm 6); Graph Contraction needs transposes
(Algorithm 7); GNNs need SpMM.  All ops preserve the static capacity of
their inputs so they compose under ``jit``/``scan``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.sparse.formats import CSR


def csr_row_nnz(a: CSR) -> jax.Array:
    return a.row_nnz()


@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def _take_rows(x: jax.Array, idx: jax.Array, gather: str = "xla") -> jax.Array:
    """rows_of_x = x[idx] with a pluggable gather backend.

    ``gather="aia"`` serves the indirection through the scalar-prefetch
    Pallas kernels (``kernels.aia_gather``, backend auto-detected); the
    backward pass is always an XLA scatter-add, so the op stays
    differentiable either way (Pallas kernels have no AD rules).
    """
    if gather == "aia":
        from repro.kernels.aia_gather import gather_rows_any
        return gather_rows_any(x, idx)
    return jnp.take(x, idx, axis=0, mode="clip")


def _take_rows_fwd(x, idx, gather):
    return _take_rows(x, idx, gather), (idx, x.shape[0])


def _take_rows_bwd(gather, res, ct):
    idx, n = res
    safe = jnp.clip(idx, 0, n - 1)
    return jnp.zeros((n, ct.shape[1]), ct.dtype).at[safe].add(ct), None


_take_rows.defvjp(_take_rows_fwd, _take_rows_bwd)


def csr_transpose(a: CSR, capacity: int | None = None) -> CSR:
    """CSR transpose via stable sort on column ids (jit-compatible).

    Padding slots sort to the end because their key is ``n_cols``.
    """
    cap = capacity if capacity is not None else a.capacity
    valid = a.valid_mask()
    key = jnp.where(valid, a.indices, a.n_cols)
    order = jnp.argsort(key, stable=True)
    new_rows = key[order]  # transposed row id per slot (n_cols for padding)
    rid = a.row_ids()  # original row = transposed col
    new_cols = jnp.where(valid, rid, 0)[order]
    new_data = jnp.where(valid, a.data, 0)[order]
    counts = jnp.zeros(a.n_cols + 1, jnp.int32).at[new_rows].add(
        valid[order].astype(jnp.int32)
    )[: a.n_cols]
    indptr = jnp.concatenate([jnp.zeros(1, jnp.int32), jnp.cumsum(counts)]).astype(jnp.int32)
    if cap == a.capacity:
        indices, data = new_cols, new_data
    elif cap > a.capacity:
        indices = jnp.zeros(cap, jnp.int32).at[: a.capacity].set(new_cols)
        data = jnp.zeros(cap, a.data.dtype).at[: a.capacity].set(new_data)
    else:
        indices, data = new_cols[:cap], new_data[:cap]
    return CSR(indptr, indices, data, (a.n_cols, a.n_rows))


def csr_spmv(a: CSR, x: jax.Array) -> jax.Array:
    """y = A @ x for dense vector x: gather + segment-sum."""
    valid = a.valid_mask()
    contrib = jnp.where(valid, a.data * jnp.take(x, a.indices, mode="clip"), 0)
    rid = a.row_ids()
    return jnp.zeros(a.n_rows + 1, contrib.dtype).at[rid].add(contrib)[: a.n_rows]


def csr_spmm(a: CSR, x: jax.Array, gather: str = "xla", mesh=None) -> jax.Array:
    """Y = A @ X for dense X (n_cols, d): the GNN aggregation primitive.

    This is the *two-level indirect access* the paper targets: ``indices``
    selects rows of ``X`` (ranged access of length d), results are
    segment-summed by row.  ``gather="aia"`` serves that gather with the
    scalar-prefetch Pallas kernels (Fig. 7 ablation); ``"auto"`` picks AIA
    on TPU and XLA elsewhere.  ``mesh`` constrains the output to be
    row-sharded over the mesh's first axis so GSPMD partitions the gather +
    segment-sum across devices (jit-safe: constraint only, no placement).
    """
    from repro.core.executor import resolve_gather  # lazy: avoids pkg cycle
    gather = resolve_gather(gather)  # validates + honors REPRO_KERNEL_BACKEND
    valid = a.valid_mask()
    rows_of_x = _take_rows(x, a.indices, gather)  # (cap, d)
    contrib = jnp.where(valid[:, None], a.data[:, None] * rows_of_x, 0)
    rid = a.row_ids()
    out = jnp.zeros((a.n_rows + 1, x.shape[1]), contrib.dtype).at[rid].add(contrib)
    out = out[: a.n_rows]
    if mesh is not None:
        from repro.launch.sharding import row_sharding
        out = jax.lax.with_sharding_constraint(out, row_sharding(mesh, out.ndim))
    return out


def csr_scale_rows(a: CSR, s: jax.Array) -> CSR:
    """diag(s) @ A."""
    rid = a.row_ids()
    sv = jnp.take(s, jnp.clip(rid, 0, a.n_rows - 1), mode="clip")
    return CSR(a.indptr, a.indices, jnp.where(a.valid_mask(), a.data * sv, 0), a.shape)


def csr_scale_columns(a: CSR, s: jax.Array) -> CSR:
    """A @ diag(s)."""
    sv = jnp.take(s, a.indices, mode="clip")
    return CSR(a.indptr, a.indices, jnp.where(a.valid_mask(), a.data * sv, 0), a.shape)


def csr_hadamard_power(a: CSR, r: float) -> CSR:
    """Elementwise power on stored entries (MCL inflation, Alg. 6 line 12)."""
    valid = a.valid_mask()
    d = jnp.where(valid, a.data, 1.0)
    return CSR(a.indptr, a.indices, jnp.where(valid, jnp.power(d, r), 0), a.shape)


def csr_column_sums(a: CSR) -> jax.Array:
    valid = a.valid_mask()
    return jnp.zeros(a.n_cols, a.data.dtype).at[a.indices].add(
        jnp.where(valid, a.data, 0)
    )


def csr_column_normalize(a: CSR, eps: float = 1e-12) -> CSR:
    """Make columns sum to one (MCL's ColumnNormalize)."""
    s = csr_column_sums(a)
    inv = jnp.where(s > eps, 1.0 / jnp.maximum(s, eps), 0.0)
    return csr_scale_columns(a, inv)


def csr_prune_columns(a: CSR, theta: float, k: int) -> CSR:
    """MCL Prune (Alg. 6 lines 6–10): drop entries < theta, keep top-k per column.

    Keeps the CSR layout (entries are zeroed in place, structure retained) —
    the *values* become exactly the pruned matrix; callers needing compaction
    re-build via ``ell_to_csr``/host utilities.
    """
    valid = a.valid_mask()
    vals = jnp.where(valid, a.data, 0)
    vals = jnp.where(vals >= theta, vals, 0)
    # top-k per column with a fixed number of sort passes:
    # rank entries within each column by value (descending) via sort on
    # (col, -val); entries with per-column rank >= k are dropped.
    col_key = jnp.where(valid, a.indices, a.n_cols)
    order = jnp.lexsort((-vals, col_key))  # sort by col, then value desc
    sorted_cols = col_key[order]
    # rank within column = position - first position of this column
    pos = jnp.arange(a.capacity)
    is_start = jnp.concatenate([jnp.ones(1, bool), sorted_cols[1:] != sorted_cols[:-1]])
    start_pos = jnp.where(is_start, pos, 0)
    start_pos = jax.lax.associative_scan(jnp.maximum, start_pos)
    rank = pos - start_pos
    keep_sorted = rank < k
    keep = jnp.zeros(a.capacity, bool).at[order].set(keep_sorted)
    new_data = jnp.where(keep, vals, 0)
    return CSR(a.indptr, a.indices, new_data, a.shape)


def csr_permute_rows(a: CSR, perm: jax.Array, inverse: bool = False) -> CSR:
    """Reorder rows by ``perm`` (Map from the paper's row-grouping phase).

    ``perm[i]`` = original row id placed at new position i.  Only the
    *logical* order changes; used to build locality-friendly schedules.
    """
    if inverse:
        perm = jnp.argsort(perm)
    counts = a.row_nnz()[perm]
    new_indptr = jnp.concatenate(
        [jnp.zeros(1, jnp.int32), jnp.cumsum(counts)]
    ).astype(jnp.int32)
    # scatter each old slot to its new flat position
    old_starts = a.indptr[:-1][perm]  # start of source row for each new row
    k_cap = a.capacity
    # build via gather: for each new flat slot, find its (new_row, within) and
    # read from old_starts[new_row] + within.
    p = jnp.arange(k_cap, dtype=jnp.int32)
    new_rid = jnp.searchsorted(new_indptr, p, side="right").astype(jnp.int32) - 1
    valid = p < new_indptr[-1]
    new_rid_c = jnp.clip(new_rid, 0, a.n_rows - 1)
    within = p - new_indptr[new_rid_c]
    src = jnp.take(old_starts, new_rid_c, mode="clip") + within
    src = jnp.where(valid, src, 0)
    indices = jnp.where(valid, a.indices[src], 0)
    data = jnp.where(valid, a.data[src], 0)
    return CSR(new_indptr, indices, data, a.shape)
