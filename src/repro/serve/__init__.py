"""Serving: batched KV-cache decode loop."""
from repro.serve.engine import ServeEngine, greedy_generate

__all__ = ["ServeEngine", "greedy_generate"]
