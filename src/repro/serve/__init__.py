"""Serving: batched LM decode loop + multi-tenant SpGEMM service."""
from repro.serve.engine import Request, ServeEngine, greedy_generate
from repro.serve.spgemm_service import (
    DeadlineExceeded, QueueFull, ServeKnobs, SpGEMMService, Ticket)

__all__ = ["ServeEngine", "Request", "greedy_generate",
           "SpGEMMService", "ServeKnobs", "Ticket", "QueueFull",
           "DeadlineExceeded"]
