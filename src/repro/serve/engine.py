"""Batched serving engine over ``decode_step``.

Continuous-batching-lite: a fixed-slot batch where finished sequences are
replaced by queued requests between steps (slot swap is a host-side cache
row reset — O(1) bookkeeping, no recompile).  Prefill is teacher-forced
through the decode path one token at a time for correctness parity with
training; the prefill_32k dry-run cells lower the fused full-sequence
prefill instead (launch/dryrun.py).
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.launch.sharding import Shardings, UNSHARDED
from repro.models.transformer import decode_step, init_decode_cache


@dataclasses.dataclass
class Request:
    """One queued generation request (prompt in, greedy tokens out)."""

    prompt: np.ndarray          # (prompt_len,) int32
    max_new_tokens: int = 16
    out_tokens: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


class ServeEngine:
    """Fixed-slot continuous-batching LM decode engine over ``decode_step``."""

    def __init__(self, cfg: ArchConfig, params, batch_slots: int,
                 max_seq: int, sh: Shardings = UNSHARDED):
        self.cfg = cfg
        self.params = params
        self.slots = batch_slots
        self.max_seq = max_seq
        self.sh = sh
        self.cache = init_decode_cache(cfg, batch_slots, max_seq)
        self._step = jax.jit(
            lambda p, c, t: decode_step(cfg, p, c, t, sh))
        self.active: List[Optional[Request]] = [None] * batch_slots
        self.queue: List[Request] = []

    def submit(self, req: Request):
        """Queue a request; it claims a batch slot as one frees up."""
        self.queue.append(req)

    def _fill_slots(self):
        for i in range(self.slots):
            if self.active[i] is None and self.queue:
                self.active[i] = self.queue.pop(0)

    def run(self, max_steps: int = 256):
        """Drive all requests to completion (greedy decoding)."""
        self._fill_slots()
        # simple batched prefill: feed prompts token-by-token (ragged fronts
        # padded with token 0; their logits are discarded)
        maxp = max((len(r.prompt) for r in self.active if r), default=0)
        for t in range(maxp):
            toks = np.zeros((self.slots, 1), np.int32)
            for i, r in enumerate(self.active):
                if r is not None and t < len(r.prompt):
                    toks[i, 0] = r.prompt[t]
            logits, self.cache = self._step(self.params, self.cache,
                                            jnp.asarray(toks))
        nxt = np.asarray(jnp.argmax(logits[:, 0], axis=-1)) if maxp else \
            np.zeros(self.slots, np.int64)
        for _ in range(max_steps):
            live = [i for i, r in enumerate(self.active) if r and not r.done]
            if not live:
                break
            toks = np.zeros((self.slots, 1), np.int32)
            for i in live:
                tok = int(nxt[i])
                self.active[i].out_tokens.append(tok)
                if len(self.active[i].out_tokens) >= self.active[i].max_new_tokens:
                    self.active[i].done = True
                toks[i, 0] = tok
            logits, self.cache = self._step(self.params, self.cache,
                                            jnp.asarray(toks))
            nxt = np.asarray(jnp.argmax(logits[:, 0], axis=-1))
        return [r for r in self.active if r is not None]


def greedy_generate(cfg: ArchConfig, params, prompt: np.ndarray,
                    n_new: int, max_seq: int = 128) -> np.ndarray:
    """Single-sequence greedy generation (example/test helper)."""
    cache = init_decode_cache(cfg, 1, max_seq)
    step = jax.jit(lambda p, c, t: decode_step(cfg, p, c, t))
    logits = None
    for t in prompt:
        logits, cache = step(params, cache,
                             jnp.asarray([[int(t)]], jnp.int32))
    out = []
    for _ in range(n_new):
        nxt = int(jnp.argmax(logits[0, 0]))
        out.append(nxt)
        logits, cache = step(params, cache, jnp.asarray([[nxt]], jnp.int32))
    return np.asarray(out, np.int32)
