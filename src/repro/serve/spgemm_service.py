"""Multi-tenant SpGEMM serving: a pattern-coalescing micro-batcher.

The production shape the ROADMAP names — millions of users issuing small
*same-structure* sparse queries (GNN inference on per-user subgraphs,
repeated MCL steps) — is exactly what the executor's amortization layer
was built for: ``spgemm_batched`` runs one planned pipeline for a whole
batch of same-pattern operands (3.5× over a per-call loop in CI), the
``PlanCache`` skips Alg. 1 + Table-I binning on repeated patterns, and
the ``OperandCache``/``AutotuneCache`` amortize B placement and per-bin
engine choice.  ``SpGEMMService`` turns those library mechanisms into a
servable system:

* ``submit(tenant_id, a, b, **knobs)`` fingerprints both operand patterns
  (``executor.pattern_fingerprint`` — the ``PlanCache`` key) and enqueues
  the request under ``(fingerprint_a, fingerprint_b, knob signature)``.
  Same-pattern traffic from *any* tenant lands in the same micro-batch —
  the cross-tenant coalescing is the point (nsparse-style batched
  hash-table scheduling per workload class, arXiv:1804.01698; OpSparse,
  arXiv:2206.07244, motivates attacking dispatch overhead rather than the
  kernels).
* A micro-batch dispatches through ``spgemm_batched`` the moment it
  reaches ``max_batch``, or when its oldest request has waited
  ``max_wait`` seconds (checked on every ``submit``/``poll``).  A
  singleton group falls back to plain ``spgemm`` — no vmap overhead for
  patterns nobody else is sending.  Results are **bit-exact** vs a
  per-request loop (the batched lane's standing guarantee).
* The queue is bounded (``max_queue``): a submit beyond the bound is shed
  with ``QueueFull`` and counted in ``stats()["requests_shed"]`` — an
  overloaded service degrades loudly, never silently.
* Every tenant gets its own quota'd ``PlanCache`` / ``OperandCache`` /
  ``AutotuneCache`` (LRU eviction accounted per tenant, via the
  executor's cache-scoping hooks: ``operand_cache=``/``autotune=``
  threading and ``PlanCache.plan_for(supplier=)``).  One tenant's churn
  can never evict another tenant's plans or placed operands.  When a
  coalesced batch spans tenants, the lead (first-submitting) tenant's
  caches drive execution and every participating tenant's ``PlanCache``
  accounts the pattern against its own quota without re-planning.
* ``stats()`` is the metrics surface: p50/p99 latency, queue depth,
  coalescing ratio (requests per dispatch), shed counts, and per-tenant
  cache hit rates — everything the open-loop bench
  (``benchmarks/bench_serve.py``) and the CI serve gate read.

The service is deliberately synchronous and single-threaded: dispatch
happens inside ``submit``/``poll``/``flush`` on the caller's thread, the
clock is injectable, and there is no background flusher — which makes
latency accounting deterministic and the whole layer testable without
sleeps.  An async front-end can drive ``submit``/``poll`` from an event
loop; the executor underneath already overlaps device work via JAX's
async dispatch.
"""
from __future__ import annotations

import dataclasses
import time
from collections import OrderedDict, deque
from typing import Callable, Deque, Dict, List, Optional

import numpy as np

from repro.core import faults
from repro.core.executor import (
    AutotuneCache, OperandCache, PlanCache, pattern_fingerprint,
    resolve_engine, resolve_gather, resolve_operands)
from repro.core.spgemm import SpGEMMResult, spgemm, spgemm_batched
from repro.sparse.formats import CSR


class QueueFull(RuntimeError):
    """Raised by ``submit`` when the bounded request queue is at capacity.

    The request is *shed*, not queued: the caller decides whether to
    retry, back off, or drop — or pass ``submit(..., retries=, backoff=)``
    to have the service retry with exponential backoff before shedding.
    Shed counts surface in ``SpGEMMService.stats()`` (globally and per
    tenant).
    """


class DeadlineExceeded(RuntimeError):
    """A request's ``deadline=`` elapsed before its micro-batch dispatched.

    Raised by ``Ticket.result()`` (the request is expired at dispatch
    time, never executed) and counted in
    ``SpGEMMService.stats()['deadline_exceeded']`` — a late answer to a
    caller that stopped waiting is work the service refuses to do.
    """


# Base backoff (seconds) for submit's shed-retry loop; attempt *k* sleeps
# ``backoff * 2**k`` through the injectable ``sleep`` hook.
DEFAULT_BACKOFF = 0.05


def resolve_deadline(deadline) -> Optional[float]:
    """Validate a request's ``deadline=`` (seconds; ``None`` = no deadline).

    The deadline is relative to submit time and enforced at dispatch: a
    request whose deadline elapsed while queued is expired with
    ``DeadlineExceeded`` instead of executed.
    """
    if deadline is None:
        return None
    if isinstance(deadline, bool) or not isinstance(
            deadline, (int, float, np.integer, np.floating)):
        raise ValueError(
            f"deadline must be a positive number of seconds or None; "
            f"got {deadline!r}")
    if float(deadline) <= 0:
        raise ValueError(f"deadline must be > 0 seconds; got {deadline!r}")
    return float(deadline)


def resolve_retries(retries) -> int:
    """Validate ``submit``'s ``retries=`` (shed-retry attempts; default 0).

    ``0`` (the default) preserves the shed-loudly contract: a full queue
    raises ``QueueFull`` immediately.  ``k > 0`` lets submit back off and
    re-poll up to ``k`` times before shedding.
    """
    if retries is None:
        return 0
    if isinstance(retries, bool) or not isinstance(retries, (int, np.integer)):
        raise ValueError(f"retries must be an int >= 0; got {retries!r}")
    if int(retries) < 0:
        raise ValueError(f"retries must be >= 0; got {int(retries)}")
    return int(retries)


def resolve_backoff(backoff) -> float:
    """Validate ``submit``'s ``backoff=`` (base seconds; ``None`` = the
    ``DEFAULT_BACKOFF``).  Retry attempt *k* sleeps ``backoff * 2**k``."""
    if backoff is None:
        return DEFAULT_BACKOFF
    if isinstance(backoff, bool) or not isinstance(
            backoff, (int, float, np.integer, np.floating)):
        raise ValueError(
            f"backoff must be a positive number of seconds; got {backoff!r}")
    if float(backoff) <= 0:
        raise ValueError(f"backoff must be > 0 seconds; got {backoff!r}")
    return float(backoff)


@dataclasses.dataclass(frozen=True)
class ServeKnobs:
    """The executor knobs a request is dispatched with.

    Requests coalesce only when their knob signatures match exactly — a
    tenant asking for ``engine="hash"`` never rides a ``"sort"`` batch.
    Every field is validated eagerly at ``submit`` time through the
    executor's ``resolve_*`` hooks, so a typo fails the submitting caller
    immediately instead of poisoning a whole micro-batch at dispatch.
    ``mesh`` participates in the signature by identity (meshes are
    long-lived objects, not per-request values).
    """

    engine: str = "sort"
    gather: str = "auto"
    schedule: str = "grouped"
    row_chunk: int = 4096
    pipeline: str = "two_wave"
    sizing: str = "auto"
    operands: str = "auto"
    mesh: object = None

    def validate(self) -> "ServeKnobs":
        """Fail fast on any invalid knob value (returns self)."""
        resolve_engine(self.engine)
        resolve_gather(self.gather)
        resolve_operands(self.operands)
        if self.schedule not in ("grouped", "natural"):
            raise ValueError(f"unknown schedule {self.schedule!r}")
        if self.pipeline not in ("two_wave", "legacy"):
            raise ValueError(f"unknown pipeline {self.pipeline!r}")
        if self.sizing not in ("auto", "planned", "measured"):
            raise ValueError(f"unknown sizing {self.sizing!r}")
        return self

    def signature(self) -> tuple:
        """Hashable coalescing key component (mesh by identity)."""
        return (self.engine, self.gather, self.schedule, int(self.row_chunk),
                self.pipeline, self.sizing, self.operands,
                None if self.mesh is None else id(self.mesh))

    def call_kwargs(self) -> dict:
        """The kwargs forwarded to ``spgemm``/``spgemm_batched``."""
        return dict(engine=self.engine, gather=self.gather,
                    schedule=self.schedule, row_chunk=self.row_chunk,
                    pipeline=self.pipeline, sizing=self.sizing,
                    operands=self.operands, mesh=self.mesh)


@dataclasses.dataclass
class Ticket:
    """Handle for one submitted request.

    ``result()`` returns the request's ``SpGEMMResult``; if the request is
    still queued it forces its micro-batch to dispatch first (a caller
    blocking on a result should not wait out ``max_wait``).  ``done`` is
    True once the batch containing this request has executed;
    ``coalesced_with`` is the number of requests that shared its dispatch
    (1 = singleton fallback).  A request that failed — its ``deadline=``
    elapsed while queued, or it was quarantined as the poison member of a
    failed micro-batch — re-raises its recorded error from ``result()``.
    """

    tenant_id: str
    submitted_at: float
    done: bool = False
    coalesced_with: int = 0
    latency_s: float = -1.0
    deadline_at: Optional[float] = None
    _result: Optional[SpGEMMResult] = None
    _error: Optional[Exception] = None
    _service: Optional["SpGEMMService"] = None
    _group_key: Optional[tuple] = None

    def result(self) -> SpGEMMResult:
        """The request's product, dispatching its micro-batch if needed.

        Raises ``DeadlineExceeded`` if the request expired while queued,
        or the quarantined request's own error if it was the member that
        failed an isolated replay (docs/resilience.md).
        """
        if not self.done:
            self._service._dispatch_key(self._group_key)
        if self._error is not None:
            raise self._error
        return self._result


@dataclasses.dataclass
class _QueuedRequest:
    tenant_id: str
    a: CSR
    b: CSR
    ticket: Ticket
    submitted_at: float
    deadline_at: Optional[float] = None


@dataclasses.dataclass
class _PendingGroup:
    """One open micro-batch: same (pattern-pair, knob signature)."""

    knobs: ServeKnobs
    requests: List[_QueuedRequest] = dataclasses.field(default_factory=list)

    @property
    def oldest(self) -> float:
        return self.requests[0].submitted_at


class _TenantState:
    """Per-tenant cache scope + accounting.

    Each tenant owns quota'd ``PlanCache``/``OperandCache``/
    ``AutotuneCache`` instances — the LRU bound is *per tenant*, so a
    noisy tenant cycling through many patterns evicts only its own
    entries (``tests/test_serve.py`` holds that bar).
    """

    def __init__(self, plan_quota: int, operand_quota: int,
                 autotune_quota: int):
        self.plans = PlanCache(max_entries=plan_quota)
        self.operands = OperandCache(max_entries=operand_quota)
        self.autotune = AutotuneCache(max_entries=autotune_quota)
        self.submitted = 0
        self.completed = 0
        self.shed = 0

    def stats(self) -> Dict[str, object]:
        """Per-tenant metrics: traffic counts + cache occupancy/hit rates."""
        plan = self.plans.stats()
        lookups = plan["hits"] + plan["misses"]
        return {
            "submitted": self.submitted,
            "completed": self.completed,
            "shed": self.shed,
            "plan_entries": plan["entries"],
            "plan_hits": plan["hits"],
            "plan_misses": plan["misses"],
            "plan_hit_rate": plan["hits"] / lookups if lookups else 0.0,
            "operand_entries": len(self.operands),
            "autotune_entries": len(self.autotune),
        }


class SpGEMMService:
    """Multi-tenant SpGEMM serving engine (pattern-coalescing micro-batcher).

    Parameters
    ----------
    max_batch:
        Micro-batch size that triggers an immediate dispatch of a group.
    max_wait:
        Seconds the oldest request of a group may wait before the group is
        flushed (enforced on every ``submit``/``poll``; there is no
        background thread — an idle service flushes on the next call, or
        via an explicit ``flush()``).
    max_queue:
        Bound on the total number of queued (undispatched) requests;
        submits beyond it raise ``QueueFull`` and count as shed.
    tenant_plan_quota / tenant_operand_quota / tenant_autotune_quota:
        Per-tenant LRU bounds of the scoped caches.
    clock:
        Injectable time source (seconds, monotonic); tests drive a fake
        clock, production uses ``time.monotonic``.
    sleep:
        Injectable sleep used by submit's shed-retry backoff; tests pass
        a fake that advances the fake clock, production uses
        ``time.sleep``.
    latency_window:
        How many recent request latencies the p50/p99 estimate keeps.
    """

    def __init__(self, max_batch: int = 16, max_wait: float = 0.01,
                 max_queue: int = 1024, tenant_plan_quota: int = 32,
                 tenant_operand_quota: int = 8,
                 tenant_autotune_quota: int = 16,
                 clock: Callable[[], float] = time.monotonic,
                 sleep: Callable[[float], None] = time.sleep,
                 latency_window: int = 4096):
        if max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {max_batch}")
        if max_queue < 1:
            raise ValueError(f"max_queue must be >= 1, got {max_queue}")
        self.max_batch = max_batch
        self.max_wait = float(max_wait)
        self.max_queue = max_queue
        self._quotas = (tenant_plan_quota, tenant_operand_quota,
                        tenant_autotune_quota)
        self._clock = clock
        self._sleep = sleep
        self._groups: "OrderedDict[tuple, _PendingGroup]" = OrderedDict()
        self._tenants: Dict[str, _TenantState] = {}
        self._latencies: Deque[float] = deque(maxlen=latency_window)
        self._submitted = 0
        self._completed = 0
        self._shed = 0
        self._dispatches = 0
        self._batched_dispatches = 0
        self._singleton_dispatches = 0
        self._coalesced_requests = 0
        self._deadline_exceeded = 0
        self._retries = 0
        self._quarantined = 0

    # ------------------------------------------------------------------
    # Request path
    # ------------------------------------------------------------------

    def submit(self, tenant_id: str, a: CSR, b: CSR, *,
               deadline: Optional[float] = None, retries: int = 0,
               backoff: Optional[float] = None, **knobs) -> Ticket:
        """Enqueue one ``a @ b`` request for ``tenant_id``.

        Knobs (``engine=``, ``gather=``, ``sizing=``, ... — see
        ``ServeKnobs``) are validated immediately; the request coalesces
        with queued requests whose operands share both sparsity patterns
        *and* whose knob signature matches.  Returns a ``Ticket``; raises
        ``QueueFull`` (and counts the request as shed) when the bounded
        queue is at capacity.  Overdue groups are flushed on the way in,
        so a steadily-submitting caller honors ``max_wait`` without a
        background thread.

        ``deadline`` (seconds from now, ``None`` = unbounded) expires the
        request if it is still queued when its micro-batch dispatches:
        ``result()`` then raises ``DeadlineExceeded`` instead of returning
        a stale answer.  ``retries``/``backoff`` soften the ``QueueFull``
        edge: a submit finding the queue full sleeps ``backoff * 2**k``
        (injectable ``sleep``) and re-polls, up to ``retries`` times,
        before shedding — each attempt counted in ``stats()['retries']``.
        """
        deadline_s = resolve_deadline(deadline)
        n_retries = resolve_retries(retries)
        backoff_s = resolve_backoff(backoff)
        kn = ServeKnobs(**knobs).validate()
        now = self._clock()
        self.poll(now)
        tenant = self._tenant(tenant_id)
        attempt = 0
        while self.queue_depth() >= self.max_queue:
            if attempt >= n_retries:
                self._shed += 1
                tenant.shed += 1
                raise QueueFull(
                    f"serving queue at capacity ({self.max_queue} queued "
                    f"requests); request from tenant {tenant_id!r} shed"
                    + (f" after {attempt} retries" if attempt else ""))
            # bounded retry-with-backoff: overdue groups may drain on the
            # re-poll, turning a would-be shed into a served request
            self._retries += 1
            self._sleep(backoff_s * (2 ** attempt))
            attempt += 1
            now = self._clock()
            self.poll(now)
        self._submitted += 1
        tenant.submitted += 1
        key = (pattern_fingerprint(a), pattern_fingerprint(b),
               kn.signature())
        deadline_at = None if deadline_s is None else now + deadline_s
        ticket = Ticket(tenant_id=tenant_id, submitted_at=now,
                        deadline_at=deadline_at, _service=self,
                        _group_key=key)
        group = self._groups.get(key)
        if group is None:
            group = self._groups[key] = _PendingGroup(knobs=kn)
        group.requests.append(
            _QueuedRequest(tenant_id, a, b, ticket, now,
                           deadline_at=deadline_at))
        if len(group.requests) >= self.max_batch:
            self._dispatch_key(key)
        return ticket

    def poll(self, now: Optional[float] = None) -> int:
        """Dispatch every group whose oldest request exceeded ``max_wait``.

        Returns the number of dispatches performed.  Call this from an
        idle loop (or rely on ``submit``, which polls on entry).
        """
        now = self._clock() if now is None else now
        due = [k for k, g in self._groups.items()
               if now - g.oldest >= self.max_wait]
        for key in due:
            self._dispatch_key(key)
        return len(due)

    def flush(self) -> int:
        """Dispatch every queued group regardless of age/size; returns the
        number of dispatches."""
        keys = list(self._groups)
        for key in keys:
            self._dispatch_key(key)
        return len(keys)

    def queue_depth(self) -> int:
        """Total queued (undispatched) requests across all groups."""
        return sum(len(g.requests) for g in self._groups.values())

    # ------------------------------------------------------------------
    # Dispatch
    # ------------------------------------------------------------------

    def _tenant(self, tenant_id: str) -> _TenantState:
        st = self._tenants.get(tenant_id)
        if st is None:
            st = self._tenants[tenant_id] = _TenantState(*self._quotas)
        return st

    def _run_isolated(self, req: _QueuedRequest, plan, lead: _TenantState,
                      kwargs: dict):
        """Execute one request alone; an Exception return means quarantine.

        The batch-isolation replay path: when a coalesced dispatch fails,
        each member re-runs individually through this, so the poison
        request collects its own error and every innocent member still
        completes (docs/resilience.md).
        """
        try:
            faults.fire("dispatch_fail")
            return spgemm(req.a, req.b, plan=plan, autotune=lead.autotune,
                          operand_cache=lead.operands, **kwargs)
        except Exception as e:  # noqa: BLE001 — any member failure isolates
            return e

    def _dispatch_key(self, key: tuple) -> None:
        group = self._groups.pop(key, None)
        if group is None:
            return  # already dispatched (e.g. result() raced a poll)
        now = self._clock()
        reqs = []
        for r in group.requests:
            if r.deadline_at is not None and now > r.deadline_at:
                # expired while queued: refuse the work, surface the error
                t = r.ticket
                t._error = DeadlineExceeded(
                    f"request from tenant {r.tenant_id!r} queued "
                    f"{now - r.submitted_at:.3f}s, past its "
                    f"{r.deadline_at - r.submitted_at:.3f}s deadline")
                t.done = True
                t.latency_s = now - r.submitted_at
                self._deadline_exceeded += 1
            else:
                reqs.append(r)
        if not reqs:
            return
        lead = self._tenant(reqs[0].tenant_id)
        # Plan once through the lead tenant's cache; every other tenant in
        # the batch accounts the same plan against its own quota without
        # re-planning (PlanCache.plan_for(supplier=...) — the executor's
        # multi-tenant scoping hook).
        a0, b0 = reqs[0].a, reqs[0].b
        plan = lead.plans.plan_for(a0, b0)
        for tid in dict.fromkeys(r.tenant_id for r in reqs):
            if tid != reqs[0].tenant_id:
                self._tenant(tid).plans.plan_for(a0, b0,
                                                 supplier=lambda: plan)
        kwargs = group.knobs.call_kwargs()
        self._dispatches += 1
        if len(reqs) == 1:
            # Singleton-pattern fallback: no batch to amortize, skip the
            # vmapped value planes entirely.
            self._singleton_dispatches += 1
            results = [self._run_isolated(reqs[0], plan, lead, kwargs)]
        else:
            self._batched_dispatches += 1
            self._coalesced_requests += len(reqs)
            try:
                faults.fire("dispatch_fail")
                batch = spgemm_batched(
                    [r.a for r in reqs], [r.b for r in reqs], plan=plan,
                    autotune=lead.autotune, operand_cache=lead.operands,
                    **kwargs)
                results = [
                    SpGEMMResult(c=c, plan=batch.plan,
                                 info={**batch.info, "batch": len(reqs)})
                    for c in batch.cs
                ]
            except Exception:  # noqa: BLE001 — isolate, don't fail the batch
                # Batch-failure isolation: one poison member must never
                # fail a whole micro-batch.  Replay every member alone;
                # innocents complete (bit-exact — the per-request loop is
                # the batched lane's reference), the poison request is
                # quarantined with its own error.
                results = [self._run_isolated(r, plan, lead, kwargs)
                           for r in reqs]
        now = self._clock()
        for req, res in zip(reqs, results):
            t = req.ticket
            t.done = True
            t.coalesced_with = len(reqs)
            t.latency_s = now - req.submitted_at
            if isinstance(res, Exception):
                t._error = res
                self._quarantined += 1
                continue
            t._result = res
            self._latencies.append(t.latency_s)
            self._completed += 1
            self._tenant(req.tenant_id).completed += 1

    # ------------------------------------------------------------------
    # Metrics
    # ------------------------------------------------------------------

    def stats(self) -> Dict[str, object]:
        """The service metrics surface, one flat dict plus a per-tenant map.

        * ``requests_submitted`` / ``requests_completed`` /
          ``requests_shed`` — lifetime traffic counters (shed = rejected
          by the ``max_queue`` bound, never executed).
        * ``queue_depth`` / ``queued_groups`` — current undispatched
          requests and the open micro-batches holding them.
        * ``dispatches`` / ``batched_dispatches`` /
          ``singleton_dispatches`` — executor calls made, split by lane.
        * ``coalescing_ratio`` — completed requests per dispatch (1.0 =
          no coalescing; ``max_batch`` = perfect).
        * ``coalesced_fraction`` — fraction of completed requests that
          rode a multi-request batch.
        * ``latency_p50_ms`` / ``latency_p99_ms`` — percentiles over the
          trailing ``latency_window`` completed requests (queue wait +
          dispatch, by the service clock).
        * ``deadline_exceeded`` — requests whose ``deadline=`` elapsed
          while queued (expired at dispatch, never executed).
        * ``retries`` — shed-retry backoff attempts submit made before
          queueing or shedding (``submit(..., retries=)``).
        * ``quarantined`` — requests that failed an isolated replay after
          a micro-batch dispatch failure and carry their own error
          (docs/resilience.md).
        * ``tenants`` — ``{tenant_id: per-tenant stats}`` with traffic
          counts, plan hit rates, and cache occupancies (see
          ``_TenantState.stats``).
        """
        lat = np.asarray(self._latencies, np.float64)
        p50, p99 = (float(np.percentile(lat, 50)) * 1e3,
                    float(np.percentile(lat, 99)) * 1e3) if lat.size else \
            (0.0, 0.0)
        return {
            "requests_submitted": self._submitted,
            "requests_completed": self._completed,
            "requests_shed": self._shed,
            "queue_depth": self.queue_depth(),
            "queued_groups": len(self._groups),
            "dispatches": self._dispatches,
            "batched_dispatches": self._batched_dispatches,
            "singleton_dispatches": self._singleton_dispatches,
            "coalescing_ratio": (self._completed / self._dispatches
                                 if self._dispatches else 0.0),
            "coalesced_fraction": (self._coalesced_requests / self._completed
                                   if self._completed else 0.0),
            "latency_p50_ms": p50,
            "latency_p99_ms": p99,
            "deadline_exceeded": self._deadline_exceeded,
            "retries": self._retries,
            "quarantined": self._quarantined,
            "tenants": {tid: st.stats()
                        for tid, st in sorted(self._tenants.items())},
        }
