"""ZeRO-1: shard optimizer moments over the data axis (DESIGN.md §6).

Adam's ``mu``/``nu`` are elementwise; any dimension may be sharded without
changing math.  ``zero1_state_specs`` takes the parameter PartitionSpecs and
returns moment specs with the ``data`` axis added to the first dimension not
already sharded (falling back to the param spec when no dim is free), so
moment memory scales 1/|data| like ZeRO stage 1.
"""
from __future__ import annotations

import jax
from jax.sharding import PartitionSpec as P


def _add_data_axis(spec: P, shape, data_axis="data") -> P:
    parts = list(spec) if spec is not None else []
    # pad to rank
    while len(parts) < len(shape):
        parts.append(None)
    for i, p in enumerate(parts):
        if p is None and shape[i] > 1:
            parts[i] = data_axis
            return P(*parts)
        # don't double-shard a dim that already carries an axis
    return P(*parts)


def zero1_state_specs(param_specs, param_shapes, data_axis: str = "data"):
    """Moment PartitionSpecs for AdamWState given param specs/shapes."""
    def one(spec, shape):
        return _add_data_axis(spec, shape, data_axis)
    mu = jax.tree.map(one, param_specs, param_shapes)
    return mu
