"""Optimizers + distributed-optimization tricks (no external deps).

Optax-style API: ``opt.init(params) -> state``; ``opt.update(grads, state,
params) -> (updates, state)``; apply with ``apply_updates``.

Distributed features (used by repro.train):
* ZeRO-1: ``zero1_state_specs`` shards Adam moments over the ``data`` axis.
* Gradient compression: int8 quantize → psum → dequantize with per-tensor
  scales (cross-pod all-reduce cost ÷4), optional error feedback.
"""
from repro.optim.adamw import adamw, sgd, apply_updates, global_norm, clip_by_global_norm
from repro.optim.schedules import cosine_schedule, linear_warmup_cosine
from repro.optim.compression import int8_compress, int8_decompress, compressed_psum
from repro.optim.zero import zero1_state_specs

__all__ = [
    "adamw", "sgd", "apply_updates", "global_norm", "clip_by_global_norm",
    "cosine_schedule", "linear_warmup_cosine",
    "int8_compress", "int8_decompress", "compressed_psum",
    "zero1_state_specs",
]
