"""Int8 gradient compression for cross-pod all-reduce (DESIGN.md §6).

Quantize per-tensor symmetric int8 → ``psum`` int32 accumulate → dequantize.
Wire bytes per gradient element drop 4× (fp32) / 2× (bf16); the scale is a
second tiny psum.  ``compressed_psum`` is shard_map/pjit-compatible.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def int8_compress(x: jax.Array):
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)))
    scale = jnp.where(amax > 0, amax / 127.0, 1.0)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127).astype(jnp.int8)
    return q, scale


def int8_decompress(q: jax.Array, scale: jax.Array, dtype=jnp.float32):
    return (q.astype(jnp.float32) * scale).astype(dtype)


def compressed_psum(x: jax.Array, axis_name: str, dtype=None):
    """all-reduce(x) with int8 wire format.

    Each participant quantizes with its own scale; scales are all-maxed first
    so the shared scale bounds every shard (no overflow in the int32 psum:
    worst case n·127 ≪ 2³¹ for n ≤ 2²⁴ participants).
    """
    dtype = dtype or x.dtype
    amax_local = jnp.max(jnp.abs(x.astype(jnp.float32)))
    amax = jax.lax.pmax(amax_local, axis_name)
    scale = jnp.where(amax > 0, amax / 127.0, 1.0)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127).astype(jnp.int32)
    total = jax.lax.psum(q, axis_name)
    return (total.astype(jnp.float32) * scale).astype(dtype)
