"""AdamW / SGD with fp32 master moments over bf16 params (pure JAX)."""
from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple, Union

import jax
import jax.numpy as jnp

Schedule = Union[float, Callable[[jax.Array], jax.Array]]


def _lr_at(lr: Schedule, step):
    return lr(step) if callable(lr) else jnp.asarray(lr, jnp.float32)


class AdamWState(NamedTuple):
    step: jax.Array
    mu: dict
    nu: dict


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable
    update: Callable


def adamw(lr: Schedule, b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
          weight_decay: float = 0.1, mu_dtype=jnp.float32) -> Optimizer:
    """AdamW with decoupled weight decay; moments in fp32 regardless of params."""

    def init(params):
        mu = jax.tree.map(lambda p: jnp.zeros(p.shape, mu_dtype), params)
        nu = jax.tree.map(lambda p: jnp.zeros(p.shape, mu_dtype), params)
        return AdamWState(step=jnp.zeros((), jnp.int32), mu=mu, nu=nu)

    def update(grads, state, params):
        step = state.step + 1
        lr_t = _lr_at(lr, step)
        b1t = 1 - b1 ** step.astype(jnp.float32)
        b2t = 1 - b2 ** step.astype(jnp.float32)

        def upd(g, m, v, p):
            g32 = g.astype(mu_dtype)
            m = b1 * m + (1 - b1) * g32
            v = b2 * v + (1 - b2) * g32 * g32
            mhat = m / b1t
            vhat = v / b2t
            u = mhat / (jnp.sqrt(vhat) + eps) + weight_decay * p.astype(mu_dtype)
            return (-lr_t * u).astype(p.dtype), m, v

        g_flat, treedef = jax.tree.flatten(grads)
        m_flat = treedef.flatten_up_to(state.mu)
        v_flat = treedef.flatten_up_to(state.nu)
        p_flat = treedef.flatten_up_to(params)
        out = [upd(g, m, v, p) for g, m, v, p in zip(g_flat, m_flat, v_flat, p_flat)]
        updates = treedef.unflatten([t[0] for t in out])
        mu = treedef.unflatten([t[1] for t in out])
        nu = treedef.unflatten([t[2] for t in out])
        return updates, AdamWState(step=step, mu=mu, nu=nu)

    return Optimizer(init=init, update=update)


class SGDState(NamedTuple):
    step: jax.Array
    momentum: dict


def sgd(lr: Schedule, momentum: float = 0.0) -> Optimizer:
    def init(params):
        if momentum == 0.0:
            return SGDState(step=jnp.zeros((), jnp.int32), momentum={})
        m = jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)
        return SGDState(step=jnp.zeros((), jnp.int32), momentum=m)

    def update(grads, state, params):
        step = state.step + 1
        lr_t = _lr_at(lr, step)
        if momentum == 0.0:
            updates = jax.tree.map(lambda g, p: (-lr_t * g).astype(p.dtype),
                                   grads, params)
            return updates, SGDState(step=step, momentum={})
        m = jax.tree.map(lambda mm, g: momentum * mm + g.astype(jnp.float32),
                         state.momentum, grads)
        updates = jax.tree.map(lambda mm, p: (-lr_t * mm).astype(p.dtype), m, params)
        return updates, SGDState(step=step, momentum=m)

    return Optimizer(init=init, update=update)


def apply_updates(params, updates):
    return jax.tree.map(lambda p, u: p + u, params, updates)


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype),
                        grads), norm
