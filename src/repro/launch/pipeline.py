"""GPipe-style pipeline parallelism via shard_map + ppermute (DESIGN.md §6).

Stage weights are sharded over the ``pipe`` mesh axis (one stage per device
group); microbatches flow stage-to-stage through ``lax.ppermute``.  The
schedule is the classic GPipe fill-drain: M + S − 1 ticks for M microbatches
over S stages (bubble fraction (S−1)/(M+S−1)).  Every device computes every
tick; in-flight garbage during fill/drain is masked at the output, which is
exactly how SPMD pipelining is expressed on TPU (no dynamic control flow).

``pipeline_apply`` is the generic schedule; models opt in by passing their
block as ``stage_fn`` with per-stage stacked weights.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

try:  # JAX moved shard_map out of experimental in newer releases
    from jax import shard_map as _shard_map_mod  # type: ignore
    shard_map = _shard_map_mod  # pragma: no cover
except ImportError:
    from jax.experimental.shard_map import shard_map


def pipeline_apply(mesh, stage_weights, microbatches, stage_fn: Callable,
                   n_microbatches: int, axis: str = "pipe"):
    """Run ``stage_fn`` as an S-stage pipeline.

    stage_weights: (S, ...) pytree leaves stacked on the stage axis.
    microbatches:  (M, ...) inputs.
    Returns (M, ...) outputs, replicated across the pipe axis.
    """
    s_stages = mesh.shape[axis]
    m = n_microbatches

    def body(w_local, x_all):
        stage = jax.lax.axis_index(axis)
        w = jax.tree.map(lambda a: a[0], w_local)  # drop sharded stage dim
        state = jnp.zeros_like(x_all[0])
        outputs = jnp.zeros_like(x_all)
        perm = [(i, (i + 1) % s_stages) for i in range(s_stages)]
        for t in range(m + s_stages - 1):
            m_in = min(t, m - 1)
            inp = jnp.where(stage == 0, x_all[m_in], state)
            out = stage_fn(w, inp)
            m_out = t - (s_stages - 1)
            if 0 <= m_out < m:
                is_last = stage == s_stages - 1
                outputs = outputs.at[m_out].set(
                    jnp.where(is_last, out, outputs[m_out]))
            state = jax.lax.ppermute(out, axis, perm)
        # replicate the last stage's outputs everywhere
        is_last = (stage == s_stages - 1)
        return jax.lax.psum(jnp.where(is_last, outputs, 0.0), axis)

    fn = shard_map(body, mesh=mesh,
                   in_specs=(P(axis), P(*([None] * microbatches.ndim))),
                   out_specs=P(*([None] * microbatches.ndim)))
    return fn(stage_weights, microbatches)
