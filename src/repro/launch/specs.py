"""ShapeDtypeStruct stand-ins + shardings for every (arch × shape) cell.

``input_specs`` builds the exact pytrees the dry-run lowers against: no
device allocation ever happens for the full configs (the brief's contract).
Param/optimizer specs come from ``param_specs`` (+ ZeRO-1 over ``data``);
decode caches shard their *sequence* dim over ``model`` (flash-decoding).
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig, ShapeSpec
from repro.models import transformer as tf


def _batch_axes(mesh) -> Tuple[str, ...]:
    return tuple(n for n in ("pod", "data") if n in mesh.axis_names)


def _data_size(mesh) -> int:
    return int(np.prod([mesh.shape[a] for a in _batch_axes(mesh)]))


def _model_size(mesh) -> int:
    return mesh.shape.get("model", 1)


def _sds(shape, dtype, mesh, spec: P):
    return jax.ShapeDtypeStruct(shape, dtype,
                                sharding=NamedSharding(mesh, spec))


def batch_specs(cfg: ArchConfig, shape: ShapeSpec, mesh) -> Dict[str, Any]:
    """Training/prefill batch ShapeDtypeStructs."""
    b, s = shape.global_batch, shape.seq_len
    ba = _batch_axes(mesh)
    bspec = ba if (ba and b % _data_size(mesh) == 0) else None
    out = {
        "tokens": _sds((b, s), jnp.int32, mesh, P(bspec, None)),
        "labels": _sds((b, s), jnp.int32, mesh, P(bspec, None)),
    }
    if cfg.frontend == "vision_stub":
        out["vision_embeds"] = _sds((b, cfg.vision_patches, cfg.d_model),
                                    jnp.bfloat16, mesh, P(bspec, None, None))
    if cfg.encoder_layers:
        out["frames"] = _sds((b, cfg.encoder_seq, cfg.d_model), jnp.bfloat16,
                             mesh, P(bspec, None, None))
    return out


def param_sds(cfg: ArchConfig, mesh):
    """ShapeDtypeStruct tree of params with NamedShardings (no allocation)."""
    shapes = jax.eval_shape(
        lambda k: tf.init_transformer(cfg, k)[0], jax.random.PRNGKey(0))
    specs = tf.param_specs(cfg, shapes, model_size=_model_size(mesh))
    sds = jax.tree.map(
        lambda s, sp: jax.ShapeDtypeStruct(s.shape, s.dtype,
                                           sharding=NamedSharding(mesh, sp)),
        shapes, specs)
    return sds, specs


def train_state_sds(cfg: ArchConfig, mesh, zero1: bool = True):
    """TrainState ShapeDtypeStructs: params + AdamW moments (ZeRO-1)."""
    from repro.train.step import TrainState
    from repro.optim.adamw import AdamWState

    p_sds, p_specs = param_sds(cfg, mesh)
    data_axes = _batch_axes(mesh)
    data_axis = data_axes[-1] if data_axes else None

    def moment_spec(spec, sds):
        if not zero1 or data_axis is None:
            return spec
        parts = list(spec) + [None] * (len(sds.shape) - len(spec))
        dsize = mesh.shape[data_axis]
        for i, pp in enumerate(parts):
            if pp is None and sds.shape[i] % dsize == 0 and sds.shape[i] >= dsize:
                parts[i] = data_axis
                break
        return P(*parts)

    m_specs = jax.tree.map(moment_spec, p_specs, p_sds)
    m_sds = jax.tree.map(
        lambda s, sp: jax.ShapeDtypeStruct(s.shape, jnp.float32,
                                           sharding=NamedSharding(mesh, sp)),
        p_sds, m_specs)
    scalar = jax.ShapeDtypeStruct((), jnp.int32,
                                  sharding=NamedSharding(mesh, P()))
    state = TrainState(
        step=scalar, params=p_sds,
        opt=AdamWState(step=scalar, mu=m_sds, nu=m_sds))
    return state


def cache_specs(cfg: ArchConfig, shape: ShapeSpec, mesh) -> Dict[str, Any]:
    """Decode cache ShapeDtypeStructs; sequence dims sharded over `model`."""
    b, s = shape.global_batch, shape.seq_len
    ba = _batch_axes(mesh)
    bspec = ba if (ba and b % _data_size(mesh) == 0) else None
    ms = _model_size(mesh)
    seq_ax = "model" if (ms > 1 and s % ms == 0) else None
    shapes = jax.eval_shape(
        functools.partial(tf.init_decode_cache, cfg, b, s))
    rules = {
        "pos": P(),
        "k": P(None, bspec, seq_ax, None, None),
        "v": P(None, bspec, seq_ax, None, None),
        "latent": P(None, bspec, seq_ax, None),
        "krope": P(None, bspec, seq_ax, None),
        "p_latent": P(None, bspec, seq_ax, None),
        "p_krope": P(None, bspec, seq_ax, None),
        "cross_k": P(None, bspec, None, None, None),
        "cross_v": P(None, bspec, None, None, None),
        "ssm": P(None, bspec, "model" if ms > 1 else None, None, None),
        "conv": P(None, bspec, None, None),
        "shared_k": P(None, bspec, seq_ax, None, None),
        "shared_v": P(None, bspec, seq_ax, None, None),
        "wkv": P(None, bspec, "model" if ms > 1 and cfg.n_heads % ms == 0
                 else None, None, None),
        "shift1": P(None, bspec, None),
        "shift2": P(None, bspec, None),
    }
    out = {}
    for k, sds in shapes.items():
        spec = rules[k]
        # drop axes that don't divide their dim evenly
        fixed = []
        for dim, ax in zip(sds.shape, tuple(spec)):
            if ax is None:
                fixed.append(None)
                continue
            if isinstance(ax, tuple):
                size = int(np.prod([mesh.shape[a] for a in ax]))
            else:
                size = mesh.shape.get(ax, 1)
            fixed.append(ax if dim % size == 0 else None)
        out[k] = _sds(sds.shape, sds.dtype, mesh, P(*fixed))
    return out


def decode_token_specs(cfg: ArchConfig, shape: ShapeSpec, mesh):
    b = shape.global_batch
    ba = _batch_axes(mesh)
    bspec = ba if (ba and b % _data_size(mesh) == 0) else None
    return _sds((b, 1), jnp.int32, mesh, P(bspec, None))


def cell_is_runnable(cfg: ArchConfig, shape: ShapeSpec) -> Tuple[bool, str]:
    """The DESIGN.md §5 skip matrix (long_500k on full-attention archs)."""
    if shape.name == "long_500k":
        if cfg.family in ("ssm", "hybrid"):
            return True, ""
        return False, ("skipped: pure full-attention arch — 500k decode "
                       "needs sub-quadratic mixing (DESIGN.md §5)")
    return True, ""
