"""Named-axis sharding rules shared by models and launchers.

``Shardings`` abstracts over single-pod ``("data","model")`` and multi-pod
``("pod","data","model")`` meshes: models ask for logical placements
("activation batch", "heads", "ffn hidden", …) and get mesh-appropriate
``PartitionSpec``s.  Constraints are applied with
``jax.lax.with_sharding_constraint`` and are no-ops outside a mesh context,
so the same model code runs on 1 CPU device and on 512 chips.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
from jax.sharding import PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class Shardings:
    """Logical→physical axis rules.

    batch_axes: mesh axes carrying data parallelism (("pod","data") or
    ("data",) or () for unsharded smoke tests).
    model_axis: the tensor/expert/sequence-parallel axis (None to disable).
    """

    batch_axes: Tuple[str, ...] = ()
    model_axis: Optional[str] = None
    # sequence parallelism: shard activation seq dim over model axis in
    # between attention/FFN blocks (beyond-paper perf feature).
    sequence_parallel: bool = False
    # concrete mesh (needed by shard_map-based layers, e.g. the EP MoE path)
    mesh: object = None

    # ---- PartitionSpecs for common layouts ----
    @property
    def batch(self):
        return tuple(self.batch_axes) if self.batch_axes else None

    def spec(self, *names):
        """names use tokens: 'b'=batch, 'm'=model, '-'=replicated."""
        out = []
        for n in names:
            if n == "b":
                out.append(self.batch)
            elif n == "m":
                out.append(self.model_axis)
            else:
                out.append(None)
        return P(*out)

    # activations
    def act_btd(self, x):  # (batch, seq, d_model)
        if self.sequence_parallel and self.model_axis:
            return constrain(x, self.spec("b", "m", "-"))
        return constrain(x, self.spec("b", "-", "-"))

    def act_bthd(self, x):  # (batch, seq, heads, head_dim) — heads on model
        return constrain(x, self.spec("b", "-", "m", "-"))

    def act_btf(self, x):  # (batch, seq, d_ff) — hidden on model
        return constrain(x, self.spec("b", "-", "m"))

    def act_btv(self, x):  # logits (batch, seq, vocab) — vocab on model
        return constrain(x, self.spec("b", "-", "m"))

    def act_ecd(self, x):  # MoE dispatched (experts, cap, d) — EP over model,
        # capacity rows over data (keeps dispatch buffers 1/|data| per chip)
        return constrain(x, self.spec("m", "b", "-"))


# ``constrain()`` fallback activations, folded into the executor's
# ``cache_stats()`` (lives here, not in executor.py, because the executor
# imports from this module — the reverse import would be circular).
SHARDING_STATS = {"sharding_fallbacks": 0}


def constrain(x, spec):
    """Apply a sharding constraint, degrading to a no-op outside a mesh.

    Only jax's "no mesh context" rejection is the benign single-device
    case (unit tests on 1 device) — it is counted in
    ``cache_stats()["sharding_fallbacks"]`` so silent degradation stays
    observable.  Any other error is a real sharding bug and propagates.
    """
    try:
        return jax.lax.with_sharding_constraint(x, spec)
    except (ValueError, RuntimeError) as e:
        # jax raises RuntimeError ("... requires a non-empty mesh ...") on
        # current versions, ValueError on some older ones — but always
        # naming the mesh.  Anything else propagates.
        if "mesh" not in str(e).lower():
            raise
        SHARDING_STATS["sharding_fallbacks"] += 1
        return x


UNSHARDED = Shardings()


# ---------------------------------------------------------------------------
# SpGEMM executor shard placement (1-D ("shard",) meshes, but any mesh works)
# ---------------------------------------------------------------------------

def shard_devices(mesh) -> list:
    """Flat device list a sharded executor round-robins work over.

    ``mesh=None`` → ``[None]``: one logical shard on the default device, so
    the single- and multi-device code paths are the same loop.
    """
    if mesh is None:
        return [None]
    import numpy as np

    return list(np.asarray(mesh.devices).reshape(-1))


def replicate_to(x, device):
    """Place ``x`` on ``device`` (the per-shard B replication / all-gather
    analogue); identity for the unsharded ``device=None`` path.

    Also the executor epilogue's device-to-device move: shard outputs are
    ``replicate_to``'d onto the merge device *without* a host round-trip
    (``jax.device_put`` between devices is an async transfer, not a sync).
    """
    if device is None:
        return x
    return jax.device_put(x, device)


def merge_device(devices):
    """The device that accumulates the reassembled CSR buffers (the
    device-side epilogue's merge point): the first shard device, or
    ``None`` (uncommitted default placement) on the unsharded path."""
    return devices[0] if devices else None


def place_operand_block(b_idx, b_val, rows, device):
    """Place the footprint-gathered B operand block for one shard.

    ``rows`` are the (sorted, unique) global B-row ids the shard's work
    items will gather; only those ELL rows travel to ``device``, together
    with an int32 ``remap`` of length ``n_rows(B)`` translating global row
    ids to block-local ones (``-1`` = row absent from the block, which the
    executor's remapped gathers treat exactly like A-column padding).
    Returns ``(idx_block, val_block, remap)``, all on ``device`` — the
    communication-avoiding alternative to replicating the full ELL.
    """
    import numpy as np

    rows_np = np.asarray(rows, np.int64)
    n_total = int(b_idx.shape[0])
    remap = np.full(n_total, -1, np.int32)
    remap[rows_np] = np.arange(len(rows_np), dtype=np.int32)
    import jax.numpy as jnp

    sel = jnp.asarray(rows_np.astype(np.int32))
    return (replicate_to(jnp.take(b_idx, sel, axis=0), device),
            replicate_to(jnp.take(b_val, sel, axis=0), device),
            replicate_to(jnp.asarray(remap), device))


def stage_tile(arrays, device):
    """Stage one streamed A-tile's operand arrays host→device.

    ``jax.device_put`` is an asynchronous transfer, so staging tile *k+1*
    while tile *k*'s programs are still executing overlaps the H2D copy
    with compute — the streamed executor's double buffering
    (``prefetch=``).  Under a mesh the tile lands on the merge/lead shard
    device and the per-tile ``execute_plan`` fans it out device-to-device
    like any other A operand; ``device=None`` (no mesh) targets the
    default device.  Returns the placed arrays in input order.
    """
    if device is None:
        return tuple(jax.device_put(x) for x in arrays)
    return tuple(jax.device_put(x, device) for x in arrays)


def row_sharding(mesh, ndim: int = 2):
    """NamedSharding splitting dim 0 (rows) over the mesh's first axis,
    replicating the rest — the layout for SpMM outputs and CSR row work."""
    from jax.sharding import NamedSharding

    return NamedSharding(mesh, P(mesh.axis_names[0], *([None] * (ndim - 1))))


def make_shardings(mesh, sequence_parallel: bool = False) -> Shardings:
    names = mesh.axis_names
    batch_axes = tuple(n for n in ("pod", "data") if n in names)
    model_axis = "model" if "model" in names else None
    return Shardings(batch_axes=batch_axes, model_axis=model_axis,
                     sequence_parallel=sequence_parallel, mesh=mesh)
