"""Serving launcher: batched greedy decoding with the ServeEngine.

    PYTHONPATH=src python -m repro.launch.serve --arch granite-3-2b --smoke \
        --requests 4 --new-tokens 8
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.configs import get_config, smoke_config
from repro.models.transformer import init_transformer
from repro.serve import ServeEngine
from repro.serve.engine import Request


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--new-tokens", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-seq", type=int, default=64)
    args = ap.parse_args()

    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    params, _ = init_transformer(cfg, jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, params, batch_slots=args.slots,
                      max_seq=args.max_seq)
    rng = np.random.default_rng(0)
    for i in range(args.requests):
        eng.submit(Request(prompt=rng.integers(0, cfg.vocab, 4 + i % 3),
                           max_new_tokens=args.new_tokens))
    done = eng.run()
    for i, r in enumerate(done):
        print(f"[serve] req{i}: prompt={list(r.prompt)} -> {r.out_tokens}")


if __name__ == "__main__":
    main()
