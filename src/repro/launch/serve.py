"""Serving launcher: LM decode engine or the multi-tenant SpGEMM service.

LM mode (batched greedy decoding with the ServeEngine):

    PYTHONPATH=src python -m repro.launch.serve --arch granite-3-2b --smoke \
        --requests 4 --new-tokens 8

SpGEMM mode (pattern-coalescing micro-batcher over synthetic traffic):

    PYTHONPATH=src python -m repro.launch.serve --spgemm \
        --requests 64 --tenants 4 --patterns 6 --max-batch 8
"""
from __future__ import annotations

import argparse

import numpy as np


def run_lm(args) -> None:
    """Drive the fixed-slot LM ServeEngine over random prompts."""
    import jax

    from repro.configs import get_config, smoke_config
    from repro.models.transformer import init_transformer
    from repro.serve import Request, ServeEngine

    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    params, _ = init_transformer(cfg, jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, params, batch_slots=args.slots,
                      max_seq=args.max_seq)
    rng = np.random.default_rng(0)
    for i in range(args.requests):
        eng.submit(Request(prompt=rng.integers(0, cfg.vocab, 4 + i % 3),
                           max_new_tokens=args.new_tokens))
    done = eng.run()
    for i, r in enumerate(done):
        print(f"[serve] req{i}: prompt={list(r.prompt)} -> {r.out_tokens}")


def run_spgemm(args) -> None:
    """Drive the SpGEMMService over Zipf-popular synthetic patterns."""
    from repro.serve import SpGEMMService
    from repro.sparse.formats import csr_from_dense

    rng = np.random.default_rng(args.seed)
    n = args.n
    masks = [rng.random((n, n)) < args.density for _ in range(args.patterns)]
    b_side = [csr_from_dense((m * rng.standard_normal((n, n)))
                             .astype(np.float32)) for m in masks]

    def fresh(pid):
        vals = rng.standard_normal((n, n)).astype(np.float32)
        return csr_from_dense((masks[pid] * vals).astype(np.float32))

    svc = SpGEMMService(max_batch=args.max_batch, max_wait=args.max_wait,
                        max_queue=args.max_queue)
    # Zipf-distributed pattern popularity: a few hot patterns dominate,
    # which is what makes coalescing pay.
    ranks = np.arange(1, args.patterns + 1, dtype=np.float64)
    popularity = ranks ** -args.zipf
    popularity /= popularity.sum()
    for i in range(args.requests):
        pid = int(rng.choice(args.patterns, p=popularity))
        tenant = f"tenant{i % args.tenants}"
        svc.submit(tenant, fresh(pid), b_side[pid])
    svc.flush()
    s = svc.stats()
    print(f"[spgemm-serve] {s['requests_completed']} requests in "
          f"{s['dispatches']} dispatches "
          f"(coalescing ratio {s['coalescing_ratio']:.2f}, "
          f"{s['batched_dispatches']} batched / "
          f"{s['singleton_dispatches']} singleton)")
    print(f"[spgemm-serve] latency p50={s['latency_p50_ms']:.2f}ms "
          f"p99={s['latency_p99_ms']:.2f}ms shed={s['requests_shed']}")
    for tid, ten in s["tenants"].items():
        print(f"[spgemm-serve]   {tid}: {ten['completed']} done, "
              f"plan hit rate {ten['plan_hit_rate']:.2f} "
              f"({ten['plan_entries']} plans cached)")


def main():
    """Parse args and dispatch to the LM or SpGEMM serving mode."""
    ap = argparse.ArgumentParser()
    ap.add_argument("--spgemm", action="store_true",
                    help="serve SpGEMM requests instead of LM decoding")
    ap.add_argument("--arch", help="LM mode: architecture name")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--new-tokens", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-seq", type=int, default=64)
    # SpGEMM-service knobs
    ap.add_argument("--tenants", type=int, default=4)
    ap.add_argument("--patterns", type=int, default=6)
    ap.add_argument("--n", type=int, default=64)
    ap.add_argument("--density", type=float, default=0.1)
    ap.add_argument("--zipf", type=float, default=1.2)
    ap.add_argument("--max-batch", type=int, default=8)
    ap.add_argument("--max-wait", type=float, default=0.01)
    ap.add_argument("--max-queue", type=int, default=1024)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    if args.spgemm:
        run_spgemm(args)
    else:
        if not args.arch:
            ap.error("--arch is required unless --spgemm is given")
        run_lm(args)


if __name__ == "__main__":
    main()
