"""Production training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch granite-3-2b \
        --steps 100 --batch 8 --seq 256 [--ffn-mode topk] [--smoke]

On a real fleet this process runs per-host under `jax.distributed`
(initialize() from env); on this container it runs the same code path on
the local device(s).  ``--smoke`` swaps in the reduced config so the full
loop (data → step → checkpoint → restore) is exercised on CPU.
"""
from __future__ import annotations

import argparse
import dataclasses
import tempfile

import jax

from repro.configs import get_config, smoke_config
from repro.data.pipeline import TokenPipeline
from repro.launch.sharding import UNSHARDED
from repro.optim import adamw, linear_warmup_cosine
from repro.train import Trainer, TrainerConfig, init_train_state, make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ffn-mode", default=None,
                    choices=[None, "dense", "topk", "block_topk"])
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-feasible)")
    args = ap.parse_args()

    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    if args.ffn_mode:
        k = cfg.topk_k or max(cfg.d_ff // 8, 1)
        cfg = dataclasses.replace(cfg, ffn_mode=args.ffn_mode, topk_k=k)

    print(f"[train] {cfg.name}: ~{cfg.n_params()/1e9:.2f}B params "
          f"(active {cfg.n_active_params()/1e9:.2f}B), ffn={cfg.ffn_mode}")
    opt = adamw(linear_warmup_cosine(args.lr, 20, args.steps))
    state = init_train_state(cfg, jax.random.PRNGKey(0), opt)
    step = jax.jit(make_train_step(cfg, opt, UNSHARDED, args.microbatches))
    pipe = TokenPipeline(vocab=cfg.vocab, seq_len=args.seq,
                         global_batch=args.batch, seed=0)
    ckpt_dir = args.ckpt_dir or tempfile.mkdtemp(prefix=f"ckpt_{cfg.name}_")
    tcfg = TrainerConfig(total_steps=args.steps,
                         checkpoint_every=args.ckpt_every,
                         checkpoint_dir=ckpt_dir)
    trainer = Trainer(tcfg, step, state, pipe)
    trainer.run()
    losses = [m["loss"] for m in trainer.history]
    print(f"[train] done: loss {losses[0]:.3f} -> {losses[-1]:.3f}; "
          f"ckpts in {ckpt_dir}")


if __name__ == "__main__":
    main()
