import os
os.environ["XLA_FLAGS"] = (os.environ.get("REPRO_EXTRA_XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512").strip()

"""Multi-pod dry-run driver (deliverable e).

For every (architecture × input shape) cell, on both the single-pod
16×16 mesh and the 2×16×16 multi-pod mesh:

    lowered  = jax.jit(step, in_shardings=…).lower(*input_specs(cell))
    compiled = lowered.compile()
    print(compiled.memory_analysis(), compiled.cost_analysis())

Train cells lower the full ``train_step`` (loss → grads → AdamW); decode
cells lower ``serve_step`` (one token against a seq_len KV cache); prefill
cells lower the forward+last-logits step.  Failures here (sharding
mismatch, unsupported collective) are bugs in the system.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch granite-3-2b \
        --shape train_4k [--multi-pod] [--all] [--json out.json]

NOTE: the XLA_FLAGS line above MUST run before any other jax import.
"""
import argparse
import json
import re
import time
from typing import Dict

import jax

from repro.configs import ARCH_IDS, SHAPE_SETS, get_config
from repro.configs.base import ArchConfig, ShapeSpec
from repro.launch import specs as sp
from repro.launch.mesh import make_production_mesh, use_mesh
from repro.launch.sharding import make_shardings
from repro.models import transformer as tf
from repro.optim import adamw
from repro.train.step import make_train_step

COLLECTIVE_RE = re.compile(
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"[^=]*=\s*\(?([a-z0-9]+)\[([0-9,]*)\]")


def collective_bytes_from_hlo(hlo: str) -> Dict[str, float]:
    """Sum output-shape bytes of every collective op in the post-SPMD HLO.

    Wire-cost weighting (ring algorithms) is applied in benchmarks/roofline:
    here we report raw per-op tensor bytes by collective kind.
    """
    dtype_bytes = {"f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4,
                   "s8": 1, "u8": 1, "pred": 1, "f64": 8, "s64": 8, "c64": 8}
    out: Dict[str, float] = {}
    for m in COLLECTIVE_RE.finditer(hlo):
        kind, dt, dims = m.group(1), m.group(2), m.group(3)
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        b = n * dtype_bytes.get(dt, 4)
        out[kind] = out.get(kind, 0.0) + b
    return out


def shape_for(cfg: ArchConfig, shape: ShapeSpec) -> ShapeSpec:
    return shape


def lower_cell(cfg: ArchConfig, shape: ShapeSpec, mesh, verbose=True):
    """Lower + compile one cell; returns the analysis record."""
    sh = make_shardings(mesh)
    t0 = time.monotonic()
    with use_mesh(mesh):
        if shape.kind == "train":
            step = make_train_step(cfg, adamw(3e-4), sh)
            state = sp.train_state_sds(cfg, mesh)
            batch = sp.batch_specs(cfg, shape, mesh)
            lowered = jax.jit(step).lower(state, batch)
        elif shape.kind == "prefill":
            def prefill(params, batch):
                h, _ = tf.forward_hidden(
                    cfg, params, batch["tokens"], sh,
                    vision_embeds=batch.get("vision_embeds"),
                    frames=batch.get("frames"), remat=False)
                logits = h[:, -1:, :] @ params["lm_head"]
                return sh.act_btv(logits)
            params, _ = sp.param_sds(cfg, mesh)
            batch = sp.batch_specs(cfg, shape, mesh)
            batch.pop("labels")
            lowered = jax.jit(prefill).lower(params, batch)
        else:  # decode
            def serve_step(params, cache, tokens):
                return tf.decode_step(cfg, params, cache, tokens, sh)
            params, _ = sp.param_sds(cfg, mesh)
            cache = sp.cache_specs(cfg, shape, mesh)
            tokens = sp.decode_token_specs(cfg, shape, mesh)
            lowered = jax.jit(serve_step).lower(params, cache, tokens)
        t_lower = time.monotonic() - t0
        t0 = time.monotonic()
        compiled = lowered.compile()
        t_compile = time.monotonic() - t0

    ma = compiled.memory_analysis()
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):  # jax <= 0.4.x: one dict per program
        ca = ca[0] if ca else {}
    hlo = compiled.as_text()
    coll = collective_bytes_from_hlo(hlo)
    rec = {
        "arch": cfg.name,
        "shape": shape.name,
        "mesh": dict(mesh.shape),
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "flops_per_device": float(ca.get("flops", 0.0)),
        "bytes_accessed_per_device": float(ca.get("bytes accessed", 0.0)),
        "collective_bytes": coll,
        "memory": {
            "argument_bytes": ma.argument_size_in_bytes,
            "output_bytes": ma.output_size_in_bytes,
            "temp_bytes": ma.temp_size_in_bytes,
            "alias_bytes": ma.alias_size_in_bytes,
        },
    }
    if verbose:
        print(f"[dryrun] {cfg.name} × {shape.name} × mesh{tuple(mesh.shape.values())}"
              f" lower={t_lower:.1f}s compile={t_compile:.1f}s")
        print(f"  memory_analysis: args={ma.argument_size_in_bytes/2**30:.2f}GiB"
              f" temp={ma.temp_size_in_bytes/2**30:.2f}GiB"
              f" out={ma.output_size_in_bytes/2**30:.2f}GiB (per device)")
        print(f"  cost_analysis: flops/dev={rec['flops_per_device']:.3e}"
              f" bytes/dev={rec['bytes_accessed_per_device']:.3e}")
        print(f"  collectives: { {k: f'{v:.3e}' for k, v in coll.items()} }")
    return rec


# ---------------------------------------------------------------------------
# Measurement mode — exact trip-count accounting (see DESIGN.md §7).
#
# XLA's cost_analysis counts while-loop bodies ONCE (trip counts are opaque
# to it), so scan-over-layers graphs report per-superstep costs.  For the
# roofline we therefore lower *unrolled* reduced-depth variants at 1 and 2
# depth units, and extrapolate:  corrected = C(1) + (C(2) − C(1))·(U − 1)
# where U = true depth in units.  Embedding/loss/optimizer costs live in
# C(1) once (correct); per-layer costs appear in the marginal term.  The
# production scan graphs remain the compile/memory artifact.
# ---------------------------------------------------------------------------

import dataclasses as _dc


def _unit_plan(cfg: ArchConfig):
    """Returns (cfg_at_1_unit, cfg_at_2_units, units_true)."""
    meas = dict(unroll_layers=True, unroll_inner=True, attn_chunk=4096,
                remat_groups=0, rwkv_chunk=64)
    if cfg.encoder_layers:  # whisper: one unit = 1 enc + 1 dec layer
        c1 = _dc.replace(cfg, n_layers=1, encoder_layers=1, **meas)
        c2 = _dc.replace(cfg, n_layers=2, encoder_layers=2, **meas)
        return c1, c2, float(cfg.n_layers)
    if cfg.block_pattern == "M" and cfg.shared_attn_every:  # zamba2 segment
        u = cfg.shared_attn_every
        c1 = _dc.replace(cfg, n_layers=u, **meas)
        c2 = _dc.replace(cfg, n_layers=2 * u, **meas)
        return c1, c2, cfg.n_layers / u
    if cfg.first_layer_dense_ffn:  # prefix stays in the fixed part
        c1 = _dc.replace(cfg, n_layers=2, **meas)
        c2 = _dc.replace(cfg, n_layers=3, **meas)
        return c1, c2, float(cfg.n_layers - 1)
    c1 = _dc.replace(cfg, n_layers=1, **meas)
    c2 = _dc.replace(cfg, n_layers=2, **meas)
    return c1, c2, float(cfg.n_layers)


def measure_cell(cfg: ArchConfig, shape: ShapeSpec, mesh, verbose=True):
    """Corrected per-step flops / bytes / collective-bytes for one cell."""
    c1, c2, units = _unit_plan(cfg)
    r1 = lower_cell(c1, shape, mesh, verbose=False)
    r2 = lower_cell(c2, shape, mesh, verbose=False)

    def extrap(k1, k2):
        return k1 + (k2 - k1) * (units - 1.0)

    coll = {}
    for kind in set(r1["collective_bytes"]) | set(r2["collective_bytes"]):
        coll[kind] = max(extrap(r1["collective_bytes"].get(kind, 0.0),
                                r2["collective_bytes"].get(kind, 0.0)), 0.0)
    rec = {
        "arch": cfg.name,
        "shape": shape.name,
        "mesh": dict(mesh.shape),
        "measured": True,
        "units_true": units,
        "flops_per_device": extrap(r1["flops_per_device"],
                                   r2["flops_per_device"]),
        "bytes_accessed_per_device": extrap(r1["bytes_accessed_per_device"],
                                            r2["bytes_accessed_per_device"]),
        "collective_bytes": coll,
        "memory": r2["memory"],  # production memory comes from the scan graph
        "unit_records": [r1, r2],
    }
    if verbose:
        print(f"[measure] {cfg.name} × {shape.name}: "
              f"flops/dev={rec['flops_per_device']:.3e} "
              f"bytes/dev={rec['bytes_accessed_per_device']:.3e} "
              f"coll={ {k: f'{v:.2e}' for k, v in coll.items()} }")
    return rec


def run(arch_ids, shape_names, multi_pod: bool, out_json=None,
        also_single=True):
    records = []
    meshes = []
    if also_single:
        meshes.append(make_production_mesh(multi_pod=False))
    if multi_pod:
        meshes.append(make_production_mesh(multi_pod=True))
    for arch in arch_ids:
        cfg = get_config(arch)
        for shape in SHAPE_SETS:
            if shape_names and shape.name not in shape_names:
                continue
            ok, why = sp.cell_is_runnable(cfg, shape)
            if not ok:
                print(f"[dryrun] {arch} × {shape.name}: {why}")
                records.append({"arch": arch, "shape": shape.name,
                                "skipped": why})
                continue
            for mesh in meshes:
                records.append(lower_cell(cfg, shape, mesh))
    if out_json:
        with open(out_json, "w") as f:
            json.dump(records, f, indent=1)
        print(f"[dryrun] wrote {len(records)} records to {out_json}")
    return records


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="arch id (default: all)")
    ap.add_argument("--shape", default=None, help="shape name (default: all)")
    ap.add_argument("--multi-pod", action="store_true",
                    help="also run the 2×16×16 multi-pod mesh")
    ap.add_argument("--single-only", action="store_true")
    ap.add_argument("--json", default=None)
    args = ap.parse_args()
    archs = [args.arch] if args.arch else list(ARCH_IDS)
    shapes = [args.shape] if args.shape else None
    run(archs, shapes, multi_pod=args.multi_pod and not args.single_only,
        out_json=args.json)


if __name__ == "__main__":
    main()
