"""Mesh construction + version-compat helpers (multi-pod dry-run contract).

``make_production_mesh`` is a FUNCTION (importing this module never touches
jax device state): single-pod = (16, 16) chips over ("data", "model");
multi-pod = (2, 16, 16) over ("pod", "data", "model") — 2 × 256-chip v5e
pods.  The ``pod`` axis carries only data parallelism + the cross-pod
gradient all-reduce (optionally int8-compressed).

``make_spgemm_mesh`` builds the 1-D ``("shard",)`` mesh the sharded SpGEMM
executor partitions ``GroupPlan`` row ranges over; ``use_mesh`` papers over
the ``jax.set_mesh`` (jax >= 0.6) vs legacy ``with mesh:`` context split so
the same sharded code runs on every supported jax.
"""
from __future__ import annotations

import jax


def compat_make_mesh(shape, axes, devices=None):
    """``jax.make_mesh`` where it exists (jax >= 0.4.35); otherwise the
    ``mesh_utils`` + ``Mesh`` construction every earlier jax supports."""
    if hasattr(jax, "make_mesh"):
        return jax.make_mesh(shape, axes, devices=devices)
    import numpy as np
    from jax.experimental import mesh_utils
    from jax.sharding import Mesh

    if devices is not None:
        return Mesh(np.asarray(devices).reshape(shape), axes)
    return Mesh(mesh_utils.create_device_mesh(shape), axes)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat_make_mesh(shape, axes)


def make_test_mesh(shape=(2, 2), axes=("data", "model")):
    """Small mesh for multi-device unit tests (host platform)."""
    return compat_make_mesh(shape, axes)


def make_spgemm_mesh(n_devices: int | None = None):
    """1-D ``("shard",)`` mesh for the sharded SpGEMM executor.

    Uses the first ``n_devices`` visible devices (all of them by default).
    On a host platform, force the device count with
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` *before* jax is
    imported (``benchmarks/run.py --devices N`` does this for you).
    """
    devs = jax.devices()
    n = len(devs) if n_devices is None else int(n_devices)
    if not 1 <= n <= len(devs):
        raise ValueError(
            f"requested {n} shard devices but only {len(devs)} are visible; "
            "set XLA_FLAGS=--xla_force_host_platform_device_count before "
            "importing jax")
    return compat_make_mesh((n,), ("shard",), devices=devs[:n])


def use_mesh(mesh):
    """Ambient-mesh context manager across jax versions.

    ``jax.set_mesh`` where it exists (jax >= 0.6); otherwise the legacy
    ``with mesh:`` resource-env context, under which
    ``with_sharding_constraint`` resolves bare ``PartitionSpec``s the same
    way.  Always enter the mesh through this helper so sharded code paths
    (and ``tests/test_distributed.py``) run on every supported jax.
    """
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    return mesh  # jax.sharding.Mesh is itself a context manager
