"""Production mesh construction (multi-pod dry-run contract).

``make_production_mesh`` is a FUNCTION (importing this module never touches
jax device state): single-pod = (16, 16) chips over ("data", "model");
multi-pod = (2, 16, 16) over ("pod", "data", "model") — 2 × 256-chip v5e
pods.  The ``pod`` axis carries only data parallelism + the cross-pod
gradient all-reduce (optionally int8-compressed).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(shape=(2, 2), axes=("data", "model")):
    """Small mesh for multi-device unit tests (host platform)."""
    return jax.make_mesh(shape, axes)
