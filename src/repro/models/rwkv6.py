"""RWKV-6 "Finch" block: data-dependent decay WKV + channel mix
[arXiv:2404.05892].

Per head:  S_t = diag(w_t)·S_{t-1} + k_tᵀ v_t ;  o_t = r_t·(S_{t-1} + diag(u)·k_tᵀ v_t)
with the Finch hallmark w_t = exp(−exp(w0 + LoRA(x_t))) *data-dependent* per
channel.  Token-shift mixing uses the static learned μ (the RWKV-6 dynamic
token-shift LoRA is omitted — noted in DESIGN.md §5); decay retains the full
data dependence.

Train uses a chunked scan (sequential depth S/chunk, chunk math in matmuls);
decode is O(1) per token.  Attention-free ⇒ runs long_500k.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models.common import dense_init


class RWKV6Params(NamedTuple):
    mu_r: jax.Array  # (D,) token-shift mixes
    mu_k: jax.Array
    mu_v: jax.Array
    mu_w: jax.Array
    wr: jax.Array    # (D, D)
    wk: jax.Array
    wv: jax.Array
    wg: jax.Array
    w0: jax.Array    # (D,) decay base
    w_lora_a: jax.Array  # (D, 64)
    w_lora_b: jax.Array  # (64, D)
    u: jax.Array     # (H, P) bonus
    ln_w: jax.Array  # (D,) group-norm-ish scale on output
    wo: jax.Array    # (D, D)
    # channel mix
    mu_ck: jax.Array
    mu_cr: jax.Array
    ck: jax.Array    # (D, F)
    cv: jax.Array    # (F, D)
    cr: jax.Array    # (D, D)


def rwkv6_init(key, d_model, d_ff, n_heads, dtype) -> RWKV6Params:
    p = d_model // n_heads
    ks = jax.random.split(key, 10)
    mk = lambda i, a, b: dense_init(ks[i], a, b, dtype)
    return RWKV6Params(
        mu_r=jnp.full((d_model,), 0.5, dtype), mu_k=jnp.full((d_model,), 0.5, dtype),
        mu_v=jnp.full((d_model,), 0.5, dtype), mu_w=jnp.full((d_model,), 0.5, dtype),
        wr=mk(0, d_model, d_model), wk=mk(1, d_model, d_model),
        wv=mk(2, d_model, d_model), wg=mk(3, d_model, d_model),
        w0=jnp.full((d_model,), -2.0, jnp.float32),
        w_lora_a=mk(4, d_model, 64), w_lora_b=mk(5, 64, d_model),
        u=jnp.zeros((n_heads, p), jnp.float32),
        ln_w=jnp.ones((d_model,), dtype),
        wo=mk(6, d_model, d_model),
        mu_ck=jnp.full((d_model,), 0.5, dtype), mu_cr=jnp.full((d_model,), 0.5, dtype),
        ck=mk(7, d_model, d_ff), cv=mk(8, d_ff, d_model), cr=mk(9, d_model, d_model),
    )


def _token_shift(x, mu, x_prev=None):
    """lerp(x_{t-1}, x_t, mu); x_prev is the carry for decode/chunk edges."""
    if x_prev is None:
        prev = jnp.pad(x[:, :-1], ((0, 0), (1, 0), (0, 0)))
    else:
        prev = jnp.concatenate([x_prev[:, None, :], x[:, :-1]], axis=1)
    return prev + mu * (x - prev)


def _wkv_chunk(r, k, v, w, u, s0):
    """Sequential WKV inside one chunk via scan over time.

    r,k,v: (B,Q,H,P); w: (B,Q,H,P) decay in (0,1); s0: (B,H,P,P).
    Returns (out (B,Q,H,P), s_final).
    """
    def step(s, inp):
        rt, kt, vt, wt = inp  # (B,H,P)
        kv = jnp.einsum("bhp,bhq->bhpq", kt, vt)  # key-major outer
        out = jnp.einsum("bhp,bhpq->bhq", rt, s + u[None, :, :, None] * kv)
        s = wt[..., None] * s + kv
        return s, out

    rT = jnp.moveaxis(r, 1, 0)
    kT = jnp.moveaxis(k, 1, 0)
    vT = jnp.moveaxis(v, 1, 0)
    wT = jnp.moveaxis(w, 1, 0)
    s, outs = jax.lax.scan(step, s0, (rT, kT, vT, wT))
    return jnp.moveaxis(outs, 0, 1), s


def _wkv_chunked(r, k, v, w, u, s0, chunk: int, unroll: bool = False):
    """Chunked parallel WKV (the TPU-native form; DESIGN.md §7).

    Within a chunk of Q steps, all decay products are bounded in (0,1], so
    the quadratic form M[j,t,p] = r_j[p]·k_t[p]·exp(cl_{j-1}[p] − cl_t[p])
    (t < j) is computed directly in log space with no overflow; the state is
    carried across chunks.  Sequential depth drops S → S/Q and the inner
    work is MXU-shaped einsums.
    """
    b, s, h, p_dim = r.shape
    q = min(chunk, s)
    assert s % q == 0, (s, q)
    nc = s // q

    def reshape(x):
        return x.reshape(b, nc, q, h, p_dim)

    rc, kc, vc, wc = map(reshape, (r, k, v, w))
    logw = jnp.log(jnp.maximum(wc, 1e-38))
    cl = jnp.cumsum(logw, axis=2)  # inclusive (B,nc,Q,H,P)

    def chunk_step(s_prev, ins):
        rj, kj, vj, clj = ins  # (B,Q,H,P)
        # cl_{j-1}: exclusive cumsum (cl_0 = 0)
        cl_excl = jnp.pad(clj[:, :-1], ((0, 0), (1, 0), (0, 0), (0, 0)))
        # intra-chunk quadratic form, strictly lower triangular in (j, t);
        # exponents are ≤ 0 inside the mask, so exp never overflows
        diff = cl_excl[:, :, None] - clj[:, None, :]  # (B,Q_j,Q_t,H,P)
        mask = jnp.tril(jnp.ones((q, q), bool), k=-1)
        m = jnp.exp(jnp.where(mask[None, :, :, None, None], diff, -jnp.inf))
        m = m * rj[:, :, None] * kj[:, None, :]
        intra = jnp.einsum("bjthp,bthq->bjhq", m, vj)
        # bonus diagonal term
        bonus = jnp.einsum("bjhp,hp,bjhp->bjh", rj, u, kj)
        intra = intra + bonus[..., None] * vj
        # inter-chunk: state from previous chunks
        inter = jnp.einsum("bjhp,bhpq->bjhq", rj * jnp.exp(cl_excl), s_prev)
        # state update to end of chunk
        tail = jnp.exp(clj[:, -1:, :] - clj)  # decay from t to chunk end
        s_new = s_prev * jnp.exp(clj[:, -1])[..., None] + \
            jnp.einsum("bthp,bthq->bhpq", kj * tail, vj)
        return s_new, intra + inter

    xs = (jnp.moveaxis(rc, 1, 0), jnp.moveaxis(kc, 1, 0),
          jnp.moveaxis(vc, 1, 0), jnp.moveaxis(cl, 1, 0))
    if unroll:
        outs = []
        s_cur = s0
        for i in range(nc):
            s_cur, o = chunk_step(s_cur, jax.tree.map(lambda a: a[i], xs))
            outs.append(o)
        s_final = s_cur
        out = jnp.stack(outs)
    else:
        s_final, out = jax.lax.scan(chunk_step, s0, xs)
    out = jnp.moveaxis(out, 0, 1).reshape(b, s, h, p_dim)
    return out, s_final


def rwkv6_time_mix(p: RWKV6Params, x, *, n_heads, state=None, x_prev=None,
                   sh=None, chunk: int = 0, unroll: bool = False):
    """x: (B,S,D).  state: (B,H,P,P) carried WKV state (decode/continuation).

    ``chunk > 0`` selects the chunked parallel WKV (train path on TPU);
    ``chunk == 0`` uses the per-token recurrence (decode / reference).
    """
    b, s, d = x.shape
    hp = d // n_heads
    xr = _token_shift(x, p.mu_r, x_prev)
    xk = _token_shift(x, p.mu_k, x_prev)
    xv = _token_shift(x, p.mu_v, x_prev)
    xw = _token_shift(x, p.mu_w, x_prev)
    r = (xr @ p.wr).reshape(b, s, n_heads, hp).astype(jnp.float32)
    k = (xk @ p.wk).reshape(b, s, n_heads, hp).astype(jnp.float32)
    v = (xv @ p.wv).reshape(b, s, n_heads, hp).astype(jnp.float32)
    g = jax.nn.silu(xr @ p.wg)
    # Finch data-dependent decay
    wlog = p.w0 + (jnp.tanh(xw.astype(jnp.float32) @ p.w_lora_a.astype(jnp.float32))
                   @ p.w_lora_b.astype(jnp.float32))
    w = jnp.exp(-jnp.exp(wlog)).reshape(b, s, n_heads, hp)  # (0,1)
    s0 = state if state is not None else jnp.zeros((b, n_heads, hp, hp), jnp.float32)
    if chunk and s > 1:
        out, s_final = _wkv_chunked(r, k, v, w, p.u, s0, chunk, unroll=unroll)
    else:
        out, s_final = _wkv_chunk(r, k, v, w, p.u, s0)
    out = out.reshape(b, s, d).astype(x.dtype)
    from repro.models.common import rms_norm
    out = rms_norm(out, p.ln_w) * g
    return out @ p.wo, s_final, x[:, -1, :]


def rwkv6_channel_mix(p: RWKV6Params, x, x_prev=None):
    xk = _token_shift(x, p.mu_ck, x_prev)
    xr = _token_shift(x, p.mu_cr, x_prev)
    k = jnp.square(jax.nn.relu(xk @ p.ck))
    return jax.nn.sigmoid(xr @ p.cr) * (k @ p.cv), x[:, -1, :]
