"""LM model zoo: dense GQA/MLA transformers, MoE, Mamba2 hybrid, RWKV6,
Whisper enc-dec, VLM — all with the paper's TopK-SpGEMM FFN as a
first-class option (DESIGN.md §4/§5)."""
from repro.models.transformer import (
    Transformer, init_transformer, train_loss, decode_step, init_decode_cache,
)

__all__ = [
    "Transformer", "init_transformer", "train_loss", "decode_step",
    "init_decode_cache",
]
