"""FFNs: dense SwiGLU, the paper's TopK-SpGEMM FFN (Eq. 1–3), and MoE.

``ffn_mode``:
* "dense"      — published architecture (baseline for §Perf).
* "topk"       — Eq. (1): h is TopK-masked; backward is Eq. (3).  In-graph
                 XLA form keeps dense FLOPs (mask ⊙ h) — it validates
                 semantics; the FLOP/byte win appears in
* "block_topk" — the TPU-native SpGEMM form: per 8-token tile, keep
                 ``topk_k/topk_block`` blocks of 128 d_ff lanes, gather only
                 the selected W2 row-blocks (the AIA ranged access), and
                 contract — compiled HLO FLOPs drop to k/d_ff of dense.
                 Served by the ``block_topk_spmm`` Pallas kernel on TPU.

MoE: token-choice top-k with capacity, sort-based dispatch (no T×E×C
tensors), experts shardable over the ``model`` axis (EP) — itself a
dispatch-as-SpGEMM instance (DESIGN.md §4).
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.common import dense_init
from repro.sparse.topk import topk_rows_st


class FFNParams(NamedTuple):
    w1: jax.Array  # gate (D, F)
    w3: jax.Array  # up   (D, F)
    w2: jax.Array  # down (F, D)


def ffn_init(key, d_model, d_ff, dtype) -> FFNParams:
    k1, k2, k3 = jax.random.split(key, 3)
    return FFNParams(
        w1=dense_init(k1, d_model, d_ff, dtype),
        w3=dense_init(k2, d_model, d_ff, dtype),
        w2=dense_init(k3, d_ff, d_model, dtype),
    )


def swiglu(p: FFNParams, x, sh=None):
    h = jax.nn.silu(x @ p.w1) * (x @ p.w3)
    if sh is not None:
        h = sh.act_btf(h)
    return h @ p.w2


def topk_ffn(p: FFNParams, x, k: int, sh=None):
    """Eq. (1): y = TopK(act(xW1)⊙(xW3)) @ W2 with Eq. (3) backward."""
    h = jax.nn.silu(x @ p.w1) * (x @ p.w3)
    if sh is not None:
        h = sh.act_btf(h)
    b, s, f = h.shape
    hs = topk_rows_st(h.reshape(b * s, f), k).reshape(b, s, f)
    return hs @ p.w2


def block_topk_ffn(p: FFNParams, x, k: int, block: int = 128, tile: int = 8,
                   sh=None):
    """MXU-native SpGEMM FFN: tile-shared block TopK + W2 block gather.

    Compiled FLOPs of the second matmul drop from S·F·D to S·k·D; the W2
    gather is the ranged indirect access the AIA kernel serves on TPU.
    """
    h = jax.nn.silu(x @ p.w1) * (x @ p.w3)
    if sh is not None:
        h = sh.act_btf(h)
    b, s, f = h.shape
    kb = max(k // block, 1)
    nb = f // block
    assert s % tile == 0, (s, tile)
    nt = (b * s) // tile
    hb = h.reshape(nt, tile, nb, block)
    energy = jnp.sum(jnp.square(hb.astype(jnp.float32)), axis=(1, 3))  # (nt, nb)
    _, bidx = jax.lax.top_k(energy, kb)  # (nt, kb)
    tiles = jnp.arange(nt)[:, None]
    h_kept = jnp.moveaxis(hb, 2, 1)[tiles, bidx]  # (nt, kb, tile, block)
    w2b = p.w2.reshape(nb, block, p.w2.shape[1])
    w2_sel = w2b[bidx]  # (nt, kb, block, D) — the AIA ranged gather
    y = jnp.einsum("nktb,nkbd->ntd", h_kept, w2_sel)
    return y.reshape(b, s, p.w2.shape[1])


# ---------------------------------------------------------------------------
# MoE
# ---------------------------------------------------------------------------

class MoEParams(NamedTuple):
    router: jax.Array    # (D, E)
    w1: jax.Array        # (E, D, Fe)
    w3: jax.Array        # (E, D, Fe)
    w2: jax.Array        # (E, Fe, D)
    shared: Optional[FFNParams]  # fused shared experts (or None)


def moe_init(key, d_model, cfg, dtype) -> MoEParams:
    e, fe = cfg.n_experts, cfg.d_ff_expert
    ks = jax.random.split(key, 5)
    s1 = 1.0 / np.sqrt(d_model)
    s2 = 1.0 / np.sqrt(fe)
    shared = None
    if cfg.n_shared:
        shared = ffn_init(ks[4], d_model, cfg.n_shared * fe, dtype)
    return MoEParams(
        router=dense_init(ks[0], d_model, e, jnp.float32),
        w1=(jax.random.normal(ks[1], (e, d_model, fe), jnp.float32) * s1).astype(dtype),
        w3=(jax.random.normal(ks[2], (e, d_model, fe), jnp.float32) * s1).astype(dtype),
        w2=(jax.random.normal(ks[3], (e, fe, d_model), jnp.float32) * s2).astype(dtype),
        shared=shared,
    )


def moe_ffn_shard_map(p: MoEParams, x, cfg, sh):
    """EP MoE with explicit collectives (§Perf iteration for the MoE cells).

    Baseline diagnosis: GSPMD cannot shard the data-dependent dispatch
    gather/scatter, so it replicates full token buffers — measured ~73 GB of
    all-reduce per layer per chip on llama4-scout.  Restructure: tokens stay
    replicated over ``model``; each model shard routes the *local data
    shard's* tokens to its *local experts only* (zero-comm dispatch, since
    x is already model-replicated), computes its experts, and the combine is
    ONE bf16 psum of (T_local, d) over ``model`` — per-layer collective
    bytes drop ~100×.
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    mesh = sh.mesh
    e, k = cfg.n_experts, cfg.top_k
    b, s, d = x.shape
    names = mesh.axis_names
    model_size = mesh.shape.get("model", 1)
    assert e % model_size == 0, (e, model_size)
    e_loc = e // model_size
    bspec = sh.batch

    def local_moe(router, w1, w3, w2, xl):
        # xl: (B_loc, S, D); w*: (E_loc, ...) — this model shard's experts
        j = jax.lax.axis_index("model") if model_size > 1 else 0
        bl = xl.shape[0]
        t = bl * s
        xt = xl.reshape(t, d)
        cap = max(8, min(int(np.ceil(t * k / e * cfg.capacity_factor)), t))
        logits = xt.astype(jnp.float32) @ router
        gate_logits, expert_idx = jax.lax.top_k(logits, k)
        gates = jax.nn.softmax(gate_logits, axis=-1)
        flat_e = expert_idx.reshape(-1)
        flat_t = jnp.repeat(jnp.arange(t), k)
        flat_g = gates.reshape(-1)
        order = jnp.argsort(flat_e, stable=True)
        e_sorted = flat_e[order]
        t_sorted = flat_t[order]
        g_sorted = flat_g[order]
        counts = jnp.zeros(e, jnp.int32).at[e_sorted].add(1)
        starts = jnp.concatenate([jnp.zeros(1, jnp.int32),
                                  jnp.cumsum(counts)[:-1]]).astype(jnp.int32)
        pos_in_e = jnp.arange(t * k, dtype=jnp.int32) - starts[e_sorted]
        e_local = e_sorted - j * e_loc
        mine = (e_local >= 0) & (e_local < e_loc) & (pos_in_e < cap)
        slot = jnp.where(mine, e_local * cap + pos_in_e, e_loc * cap)
        buf = jnp.zeros((e_loc * cap + 1, d), xl.dtype).at[slot].set(xt[t_sorted])
        buf = buf[:-1].reshape(e_loc, cap, d)
        h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, w1)) * \
            jnp.einsum("ecd,edf->ecf", buf, w3)
        y = jnp.einsum("ecf,efd->ecd", h, w2).reshape(e_loc * cap, d)
        y_slot = jnp.concatenate([y, jnp.zeros((1, d), y.dtype)])[
            jnp.where(mine, slot, e_loc * cap)]
        contrib = y_slot * g_sorted[:, None].astype(y.dtype)
        out = jnp.zeros((t, d), y.dtype).at[t_sorted].add(contrib)
        if model_size > 1:
            out = jax.lax.psum(out, "model")
        # aux loss (identical across model shards; mean over batch shards)
        me = jnp.mean(jax.nn.softmax(logits, axis=-1), axis=0)
        ce = counts.astype(jnp.float32) / jnp.maximum(jnp.sum(counts), 1)
        aux = e * jnp.sum(me * ce)
        if bspec:
            aux = jax.lax.pmean(aux, bspec)
        return out.reshape(bl, s, d), aux

    espec = P("model", None, None) if model_size > 1 else P(None, None, None)
    out, aux = shard_map(
        local_moe, mesh=mesh,
        in_specs=(P(None, None), espec, espec, espec, P(bspec, None, None)),
        out_specs=(P(bspec, None, None), P()),
        check_rep=False,
    )(p.router, p.w1, p.w3, p.w2, x)
    if p.shared is not None:
        out = out + swiglu(p.shared, x, sh=sh)
    return out, aux


def moe_ffn(p: MoEParams, x, cfg, sh=None):
    """Token-choice top-k with capacity; sort-based dispatch (static shapes)."""
    b, s, d = x.shape
    t = b * s
    xt = x.reshape(t, d)
    e, k = cfg.n_experts, cfg.top_k
    cap = int(np.ceil(t * k / e * cfg.capacity_factor))
    cap = max(8, min(cap, t))

    logits = (xt.astype(jnp.float32) @ p.router)  # (T, E)
    gate_logits, expert_idx = jax.lax.top_k(logits, k)  # (T, k)
    gates = jax.nn.softmax(gate_logits, axis=-1)

    # ---- sort-based dispatch: group (token, slot) pairs by expert ----
    flat_e = expert_idx.reshape(-1)            # (T*k,)
    flat_t = jnp.repeat(jnp.arange(t), k)      # token of each slot
    flat_g = gates.reshape(-1)
    order = jnp.argsort(flat_e, stable=True)
    e_sorted = flat_e[order]
    t_sorted = flat_t[order]
    g_sorted = flat_g[order]
    # position within expert group
    counts = jnp.zeros(e, jnp.int32).at[e_sorted].add(1)
    starts = jnp.concatenate([jnp.zeros(1, jnp.int32),
                              jnp.cumsum(counts)[:-1]]).astype(jnp.int32)
    pos_in_e = jnp.arange(t * k, dtype=jnp.int32) - starts[e_sorted]
    keep = pos_in_e < cap
    slot = jnp.where(keep, e_sorted * cap + pos_in_e, e * cap)  # overflow slot

    # gather tokens into (E*cap, D) expert-major buffer
    buf = jnp.zeros((e * cap + 1, d), x.dtype).at[slot].set(xt[t_sorted])
    buf = buf[:-1].reshape(e, cap, d)
    if sh is not None:
        buf = sh.act_ecd(buf)  # experts on the model axis (EP all-to-all)

    # expert computation (grouped einsum over stacked weights)
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, p.w1)) * \
        jnp.einsum("ecd,edf->ecf", buf, p.w3)
    y = jnp.einsum("ecf,efd->ecd", h, p.w2)
    if sh is not None:
        y = sh.act_ecd(y)
    y = y.reshape(e * cap, d)

    # combine: read back each kept slot, weight by gate
    y_slot = jnp.concatenate([y, jnp.zeros((1, d), y.dtype)])[
        jnp.where(keep, slot, e * cap)]
    contrib = y_slot * g_sorted[:, None].astype(y.dtype)
    out = jnp.zeros((t, d), y.dtype).at[t_sorted].add(contrib)
    out = out.reshape(b, s, d)

    if p.shared is not None:
        out = out + swiglu(p.shared, x, sh=sh)

    # load-balance auxiliary loss (Switch style), returned for logging
    me = jnp.mean(jax.nn.softmax(logits, axis=-1), axis=0)
    ce = counts.astype(jnp.float32) / jnp.maximum(jnp.sum(counts), 1)
    aux = e * jnp.sum(me * ce)
    return out, aux
