"""Shared model substrate: norms, RoPE, inits, chunked losses."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def rms_norm(x: jax.Array, w: jax.Array, eps: float = 1e-5) -> jax.Array:
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps)).astype(x.dtype) * w


def layer_norm(x: jax.Array, w: jax.Array, b: jax.Array,
               eps: float = 1e-5) -> jax.Array:
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    return ((x32 - mu) * jax.lax.rsqrt(var + eps)).astype(x.dtype) * w + b


def dense_init(key, d_in: int, d_out: int, dtype=jnp.bfloat16,
               scale: float | None = None) -> jax.Array:
    s = scale if scale is not None else 1.0 / np.sqrt(d_in)
    return (jax.random.normal(key, (d_in, d_out), jnp.float32) * s).astype(dtype)


def rope_frequencies(head_dim: int, theta: float = 10000.0) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float = 10000.0
               ) -> jax.Array:
    """x: (..., seq, heads, head_dim); positions: (..., seq)."""
    hd = x.shape[-1]
    freqs = rope_frequencies(hd, theta)  # (hd/2,)
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (..., seq, hd/2)
    cos = jnp.cos(angles)[..., :, None, :]
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def cross_entropy_chunked(logits_fn, h: jax.Array, labels: jax.Array,
                          w_out: jax.Array, n_chunks: int = 8,
                          unroll: bool = False) -> jax.Array:
    """Memory-safe LM loss: computes vocab logits in sequence chunks.

    h: (B, S, D) final hidden; labels: (B, S) int32 (-1 = masked);
    w_out: (D, V).  Never materializes the full (B, S, V) logits — essential
    for vocab≈100–200k at 1M-token global batches (DESIGN.md §6).
    """
    b, s, d = h.shape
    assert s % n_chunks == 0, (s, n_chunks)
    cs = s // n_chunks
    hc = h.reshape(b, n_chunks, cs, d).swapaxes(0, 1)  # (n_chunks, B, cs, D)
    lc = labels.reshape(b, n_chunks, cs).swapaxes(0, 1)

    def chunk_loss(carry, xs):
        hh, ll = xs
        logits = logits_fn(hh, w_out).astype(jnp.float32)  # (B, cs, V)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(
            logits, jnp.maximum(ll, 0)[..., None], axis=-1
        )[..., 0]
        mask = (ll >= 0).astype(jnp.float32)
        nll = (logz - gold) * mask
        return carry + jnp.sum(nll), jnp.sum(mask)

    if unroll:  # measurement mode (exact trip counts; launch/dryrun.py)
        total = jnp.zeros((), jnp.float32)
        counts = []
        for i in range(n_chunks):
            total, cnt = chunk_loss(total, (hc[i], lc[i]))
            counts.append(cnt)
        return total / jnp.maximum(sum(counts), 1.0)
    total, counts = jax.lax.scan(chunk_loss, jnp.zeros((), jnp.float32), (hc, lc))
    return total / jnp.maximum(jnp.sum(counts), 1.0)


def causal_mask(sq: int, sk: int, offset: int = 0) -> jax.Array:
    """(sq, sk) bool: query i attends key j iff j <= i + offset."""
    qi = jnp.arange(sq)[:, None] + offset
    kj = jnp.arange(sk)[None, :]
    return kj <= qi


def sliding_window_mask(sq: int, sk: int, window: int, offset: int = 0
                        ) -> jax.Array:
    qi = jnp.arange(sq)[:, None] + offset
    kj = jnp.arange(sk)[None, :]
    return (kj <= qi) & (kj > qi - window)
