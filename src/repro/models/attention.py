"""Attention: GQA (chunked online-softmax) + MLA, train and decode paths.

Train/prefill use a flash-style double-chunked attention (pure JAX scan with
running max/denominator) so the S×S score matrix is never materialized —
required for the 32k-prefill shapes at 1M-token global batch.

Decode reads a KV cache whose *sequence* dimension may be sharded over the
``model`` axis (flash-decoding): scores are computed on local KV shards and
combined through the softmax's max/sum reductions, which GSPMD lowers to
cheap collectives — this is how kv_heads < |model| and the 500k cache stay
memory-feasible (DESIGN.md §6).
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.models.common import apply_rope

NEG_INF = -1e30


def _mask_val(qpos, kpos, causal: bool, window: int):
    ok = kpos <= qpos if causal else jnp.ones((), bool) & (kpos == kpos)
    if window:
        ok = ok & (kpos > qpos - window)
    return ok


def flash_attention(
    q: jax.Array,            # (B, Sq, H, D)
    k: jax.Array,            # (B, Sk, KV, D)
    v: jax.Array,            # (B, Sk, KV, D)
    causal: bool = True,
    window: int = 0,
    q_offset: int = 0,
    q_chunk: int = 512,
    k_chunk: int = 1024,
    kv_valid_len: Optional[jax.Array] = None,
    unroll: bool = False,
    p_dtype=None,
) -> jax.Array:
    """Online-softmax attention; O(S·chunk) memory.  GQA via head groups.

    ``p_dtype=jnp.bfloat16`` stores softmax probabilities in bf16 between the
    two matmuls (halves score-tensor HBM traffic; §Perf iteration).
    """
    b, sq, h, d = q.shape
    sk, kv = k.shape[1], k.shape[2]
    dv = v.shape[3]  # value dim may differ from qk dim (MLA)
    g = h // kv
    scale = 1.0 / jnp.sqrt(jnp.asarray(d, jnp.float32))
    q_chunk = min(q_chunk, sq)
    k_chunk = min(k_chunk, sk)
    # pad ragged sequence lengths up to the chunk grid (whisper's 1500-frame
    # encoder etc.); padded keys are masked via kv_valid_len, padded queries
    # are sliced off the output.
    sq_orig, sk_orig = sq, sk
    if sq % q_chunk or sk % k_chunk:
        sq_pad = (-sq) % q_chunk
        sk_pad = (-sk) % k_chunk
        q = jnp.pad(q, ((0, 0), (0, sq_pad), (0, 0), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, sk_pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, sk_pad), (0, 0), (0, 0)))
        sq, sk = sq + sq_pad, sk + sk_pad
        if kv_valid_len is None:
            kv_valid_len = jnp.asarray(sk_orig, jnp.int32)
        else:
            kv_valid_len = jnp.minimum(kv_valid_len, sk_orig)
    nq, nk = sq // q_chunk, sk // k_chunk

    qr = q.reshape(b, nq, q_chunk, kv, g, d)
    kr = k.reshape(b, nk, k_chunk, kv, d)
    vr = v.reshape(b, nk, k_chunk, kv, dv)

    def q_step(qi, qc):
        # qc: (B, q_chunk, KV, G, D)
        m0 = jnp.full((b, q_chunk, kv, g), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, q_chunk, kv, g), jnp.float32)
        a0 = jnp.zeros((b, q_chunk, kv, g, dv), jnp.float32)

        def kv_step(carry, kj):
            m, l, acc = carry
            ks = kr[:, kj]  # (B, k_chunk, KV, D)
            vs = vr[:, kj]
            s = jnp.einsum("bqkgd,bckd->bqkgc", qc.astype(jnp.float32),
                           ks.astype(jnp.float32)) * scale
            qpos = q_offset + qi * q_chunk + jnp.arange(q_chunk)
            kpos = kj * k_chunk + jnp.arange(k_chunk)
            ok = _mask_val(qpos[:, None], kpos[None, :], causal, window)
            if kv_valid_len is not None:
                ok = ok & (kpos[None, :] < kv_valid_len)
            s = jnp.where(ok[None, :, None, None, :], s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + jnp.sum(p, axis=-1)
            if p_dtype is not None:
                p = p.astype(p_dtype)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bqkgc,bckd->bqkgd", p, vs.astype(p.dtype),
                preferred_element_type=jnp.float32)
            return (m_new, l_new, acc_new), None

        if unroll:  # measurement mode: exact trip counts in HLO
            carry = (m0, l0, a0)
            for kj in range(nk):
                carry, _ = kv_step(carry, kj)
            m, l, acc = carry
        else:
            (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0), jnp.arange(nk))
        out = acc / jnp.maximum(l[..., None], 1e-30)
        return out.astype(q.dtype)  # (B, q_chunk, KV, G, D)

    if unroll:
        outs = jnp.stack([q_step(i, qr[:, i]) for i in range(nq)])
    else:
        outs = jax.lax.map(lambda i: q_step(i, qr[:, i]), jnp.arange(nq))
    # (nq, B, q_chunk, KV, G, Dv) -> (B, Sq, H, Dv)
    out = jnp.moveaxis(outs, 0, 1).reshape(b, sq, kv, g, dv)
    out = out.reshape(b, sq, h, dv)
    if sq != sq_orig:
        out = out[:, :sq_orig]
    return out


def decode_attention(
    q: jax.Array,          # (B, 1, H, D)
    k_cache: jax.Array,    # (B, S_max, KV, D)
    v_cache: jax.Array,
    cache_len: jax.Array,  # () current length INCLUDING the new token
    window: int = 0,
) -> jax.Array:
    """Single-token attention over the cache (flash-decoding under GSPMD)."""
    b, smax, kv, d = k_cache.shape
    h = q.shape[2]
    g = h // kv
    scale = 1.0 / jnp.sqrt(jnp.asarray(d, jnp.float32))
    qr = q.reshape(b, kv, g, d).astype(jnp.float32)
    s = jnp.einsum("bkgd,bskd->bkgs", qr, k_cache.astype(jnp.float32)) * scale
    kpos = jnp.arange(smax)
    ok = kpos < cache_len
    if window:
        ok = ok & (kpos >= cache_len - window)
    s = jnp.where(ok[None, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", p, v_cache.astype(jnp.float32))
    return out.reshape(b, 1, h, d).astype(q.dtype)


# ---------------------------------------------------------------------------
# GQA block-level wrappers
# ---------------------------------------------------------------------------

class AttnParams(NamedTuple):
    wq: jax.Array  # (D, H*hd)
    wk: jax.Array  # (D, KV*hd)
    wv: jax.Array  # (D, KV*hd)
    wo: jax.Array  # (H*hd, D)


def gqa_init(key, d_model, n_heads, n_kv, hd, dtype) -> AttnParams:
    from repro.models.common import dense_init
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return AttnParams(
        wq=dense_init(k1, d_model, n_heads * hd, dtype),
        wk=dense_init(k2, d_model, n_kv * hd, dtype),
        wv=dense_init(k3, d_model, n_kv * hd, dtype),
        wo=dense_init(k4, n_heads * hd, d_model, dtype),
    )


def gqa_forward(p: AttnParams, x, *, n_heads, n_kv, hd, rope_theta,
                causal=True, window=0, positions=None, sh=None,
                cross_kv=None, attn_chunk=0, unroll=False, p_dtype=None):
    """Train/prefill attention.  cross_kv=(k,v) switches to cross-attention."""
    b, s, d = x.shape
    q = (x @ p.wq).reshape(b, s, n_heads, hd)
    if positions is None:
        positions = jnp.arange(s)[None, :]
    g = n_heads // n_kv
    if cross_kv is None:
        k = (x @ p.wk).reshape(b, s, n_kv, hd)
        v = (x @ p.wv).reshape(b, s, n_kv, hd)
        q = apply_rope(q, positions, rope_theta)
        k = apply_rope(k, positions, rope_theta)
        if g > 1:
            # expand KV to full head count so the head dim shards uniformly
            # over `model` (avoids GSPMD's (KV,G) mixed-factor resharding)
            k = jnp.repeat(k, g, axis=2)
            v = jnp.repeat(v, g, axis=2)
        if sh is not None:
            q, k, v = sh.act_bthd(q), sh.act_bthd(k), sh.act_bthd(v)
        kw = dict(q_chunk=attn_chunk, k_chunk=attn_chunk) if attn_chunk else {}
        out = flash_attention(q, k, v, causal=causal, window=window,
                              unroll=unroll, p_dtype=p_dtype, **kw)
    else:
        k, v = cross_kv
        if g > 1:
            k = jnp.repeat(k, g, axis=2)
            v = jnp.repeat(v, g, axis=2)
        if sh is not None:
            q = sh.act_bthd(q)
            k, v = sh.act_bthd(k), sh.act_bthd(v)
        kw = dict(q_chunk=attn_chunk, k_chunk=attn_chunk) if attn_chunk else {}
        out = flash_attention(q, k, v, causal=False, unroll=unroll,
                              p_dtype=p_dtype, **kw)
    return out.reshape(b, s, n_heads * hd) @ p.wo


def gqa_cross_kv(p: AttnParams, enc: jax.Array, n_kv, hd):
    """Precompute encoder K/V once per sequence (whisper decode)."""
    b, s, _ = enc.shape
    k = (enc @ p.wk).reshape(b, s, n_kv, hd)
    v = (enc @ p.wv).reshape(b, s, n_kv, hd)
    return k, v


def gqa_decode(p: AttnParams, x, k_cache, v_cache, pos, *, n_heads, n_kv,
               hd, rope_theta, window=0):
    """One decode step: append to cache, attend.  pos: () int32 index."""
    b = x.shape[0]
    q = (x @ p.wq).reshape(b, 1, n_heads, hd)
    k = (x @ p.wk).reshape(b, 1, n_kv, hd)
    v = (x @ p.wv).reshape(b, 1, n_kv, hd)
    posb = jnp.full((b, 1), pos, jnp.int32)
    q = apply_rope(q, posb, rope_theta)
    k = apply_rope(k, posb, rope_theta)
    k_cache = jax.lax.dynamic_update_slice_in_dim(k_cache, k.astype(k_cache.dtype), pos, axis=1)
    v_cache = jax.lax.dynamic_update_slice_in_dim(v_cache, v.astype(v_cache.dtype), pos, axis=1)
    out = decode_attention(q, k_cache, v_cache, pos + 1, window=window)
    return out.reshape(b, 1, n_heads * hd) @ p.wo, k_cache, v_cache


# ---------------------------------------------------------------------------
# MLA — Multi-head Latent Attention (DeepSeek-V2)
# ---------------------------------------------------------------------------

class MLAParams(NamedTuple):
    wq: jax.Array       # (D, H*(nope+rope))
    w_dkv: jax.Array    # (D, kv_lora)
    w_kr: jax.Array     # (D, rope_dim) shared rope key
    w_uk: jax.Array     # (kv_lora, H*nope)
    w_uv: jax.Array     # (kv_lora, H*v_dim)
    wo: jax.Array       # (H*v_dim, D)
    norm_kv: jax.Array  # (kv_lora,)


def mla_init(key, d_model, n_heads, mla, dtype) -> MLAParams:
    from repro.models.common import dense_init
    ks = jax.random.split(key, 6)
    qd = n_heads * (mla.qk_nope_dim + mla.qk_rope_dim)
    return MLAParams(
        wq=dense_init(ks[0], d_model, qd, dtype),
        w_dkv=dense_init(ks[1], d_model, mla.kv_lora, dtype),
        w_kr=dense_init(ks[2], d_model, mla.qk_rope_dim, dtype),
        w_uk=dense_init(ks[3], mla.kv_lora, n_heads * mla.qk_nope_dim, dtype),
        w_uv=dense_init(ks[4], mla.kv_lora, n_heads * mla.v_head_dim, dtype),
        wo=dense_init(ks[5], n_heads * mla.v_head_dim, d_model, dtype),
        norm_kv=jnp.ones((mla.kv_lora,), dtype),
    )


def mla_forward(p: MLAParams, x, *, n_heads, mla, rope_theta, sh=None,
                attn_chunk=0, unroll=False, p_dtype=None):
    """Train/prefill MLA (expanded form)."""
    from repro.models.common import rms_norm
    b, s, d = x.shape
    nd, rd, vd = mla.qk_nope_dim, mla.qk_rope_dim, mla.v_head_dim
    q = (x @ p.wq).reshape(b, s, n_heads, nd + rd)
    q_nope, q_rope = q[..., :nd], q[..., nd:]
    pos = jnp.arange(s)[None, :]
    q_rope = apply_rope(q_rope, pos, rope_theta)
    latent = rms_norm(x @ p.w_dkv, p.norm_kv)  # (B,S,kv_lora)
    k_rope = apply_rope((x @ p.w_kr)[:, :, None, :], pos, rope_theta)  # (B,S,1,rd)
    k_nope = (latent @ p.w_uk).reshape(b, s, n_heads, nd)
    v = (latent @ p.w_uv).reshape(b, s, n_heads, vd)
    # assemble full-dim q/k: concat nope + rope (k_rope broadcast over heads)
    qf = jnp.concatenate([q_nope, q_rope], axis=-1)
    kf = jnp.concatenate([k_nope, jnp.broadcast_to(k_rope, (b, s, n_heads, rd))],
                         axis=-1)
    if sh is not None:
        qf, kf, v = sh.act_bthd(qf), sh.act_bthd(kf), sh.act_bthd(v)
    kw = dict(q_chunk=attn_chunk, k_chunk=attn_chunk) if attn_chunk else {}
    out = flash_attention(qf, kf, v, causal=True, unroll=unroll,
                          p_dtype=p_dtype, **kw)
    return out.reshape(b, s, n_heads * vd) @ p.wo


def mla_decode(p: MLAParams, x, latent_cache, krope_cache, pos, *,
               n_heads, mla, rope_theta):
    """Absorbed-form decode: cache is (latent, k_rope) only — the MLA win."""
    from repro.models.common import rms_norm
    b = x.shape[0]
    nd, rd, vd = mla.qk_nope_dim, mla.qk_rope_dim, mla.v_head_dim
    lora = mla.kv_lora
    q = (x @ p.wq).reshape(b, 1, n_heads, nd + rd)
    q_nope, q_rope = q[..., :nd], q[..., nd:]
    posb = jnp.full((b, 1), pos, jnp.int32)
    q_rope = apply_rope(q_rope, posb, rope_theta)
    lat = rms_norm(x @ p.w_dkv, p.norm_kv)              # (B,1,lora)
    kr = apply_rope((x @ p.w_kr)[:, :, None, :], posb, rope_theta)[:, :, 0, :]
    latent_cache = jax.lax.dynamic_update_slice_in_dim(
        latent_cache, lat.astype(latent_cache.dtype), pos, axis=1)
    krope_cache = jax.lax.dynamic_update_slice_in_dim(
        krope_cache, kr.astype(krope_cache.dtype), pos, axis=1)
    # absorb W_uk into q: q_lat[h] = q_nope[h] @ W_uk[h]^T  -> (B,1,H,lora)
    wuk = p.w_uk.reshape(lora, n_heads, nd)
    q_lat = jnp.einsum("bqhn,lhn->bqhl", q_nope.astype(jnp.float32),
                       wuk.astype(jnp.float32))
    smax = latent_cache.shape[1]
    scale = 1.0 / jnp.sqrt(jnp.asarray(nd + rd, jnp.float32))
    s_lat = jnp.einsum("bqhl,bsl->bhqs", q_lat,
                       latent_cache.astype(jnp.float32))
    s_rope = jnp.einsum("bqhr,bsr->bhqs", q_rope.astype(jnp.float32),
                        krope_cache.astype(jnp.float32))
    s = (s_lat + s_rope) * scale
    ok = jnp.arange(smax) < (pos + 1)
    s = jnp.where(ok[None, None, None, :], s, NEG_INF)
    pattn = jax.nn.softmax(s, axis=-1)
    ctx_lat = jnp.einsum("bhqs,bsl->bqhl", pattn,
                         latent_cache.astype(jnp.float32))  # (B,1,H,lora)
    wuv = p.w_uv.reshape(lora, n_heads, vd)
    out = jnp.einsum("bqhl,lhv->bqhv", ctx_lat, wuv.astype(jnp.float32))
    out = out.reshape(b, 1, n_heads * vd).astype(x.dtype)
    return out @ p.wo, latent_cache, krope_cache
