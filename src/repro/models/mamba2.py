"""Mamba2 (SSD) block — chunked dual form for the MXU [arXiv:2405.21060].

State update  h_t = exp(a_h·dt_t)·h_{t-1} + dt_t·B_t x_t^T ;  y_t = C_t·h_t.
The chunked algorithm computes intra-chunk terms as (Q×Q) matmuls and
carries the (H, P, N) state across chunks with a scan — sequential depth
S/Q instead of S, and all heavy ops are MXU-shaped (DESIGN.md §5: the scan
itself has no indirection, so the paper's technique applies only to this
block's surrounding projections).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.common import dense_init


class Mamba2Params(NamedTuple):
    in_proj: jax.Array   # (D, 2*di + 2*N + H)  -> z, x, B, C, dt
    conv_w: jax.Array    # (conv, di + 2*N) depthwise causal conv
    a_log: jax.Array     # (H,)
    d_skip: jax.Array    # (H,)
    dt_bias: jax.Array   # (H,)
    norm_w: jax.Array    # (di,) gated RMSNorm
    out_proj: jax.Array  # (di, D)


def mamba2_dims(d_model, expand, head_dim, state):
    di = expand * d_model
    heads = di // head_dim
    return di, heads


def mamba2_init(key, d_model, *, expand, head_dim, state, conv, dtype
                ) -> Mamba2Params:
    di, heads = mamba2_dims(d_model, expand, head_dim, state)
    ks = jax.random.split(key, 3)
    return Mamba2Params(
        in_proj=dense_init(ks[0], d_model, 2 * di + 2 * state + heads, dtype),
        conv_w=(jax.random.normal(ks[1], (conv, di + 2 * state), jnp.float32)
                / np.sqrt(conv)).astype(dtype),
        a_log=jnp.zeros((heads,), jnp.float32),
        d_skip=jnp.ones((heads,), jnp.float32),
        dt_bias=jnp.zeros((heads,), jnp.float32),
        norm_w=jnp.ones((di,), dtype),
        out_proj=dense_init(ks[2], di, d_model, dtype),
    )


def _causal_conv(x, w, state=None):
    """Depthwise causal conv along seq.  x: (B,S,C); w: (K,C).

    With ``state`` (B, K-1, C) the conv continues from a previous chunk and
    the new state is returned (used in decode).
    """
    k = w.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)
    out = sum(xp[:, i:i + x.shape[1], :] * w[i][None, None, :] for i in range(k))
    new_state = xp[:, -(k - 1):, :] if k > 1 else jnp.zeros_like(pad)
    return out, new_state


def _split_proj(p, x, di, state, heads):
    zxbcdt = x @ p.in_proj
    z = zxbcdt[..., :di]
    rest = zxbcdt[..., di:]
    xbc = rest[..., : di + 2 * state]
    dt = rest[..., di + 2 * state:]
    return z, xbc, dt


def mamba2_forward(p: Mamba2Params, x, *, expand, head_dim, state, conv,
                   chunk: int = 64, sh=None):
    """Train/prefill SSD.  x: (B, S, D) -> (B, S, D)."""
    from repro.models.common import rms_norm
    b, s, d = x.shape
    di, heads = mamba2_dims(d, expand, head_dim, state)
    pdim = head_dim
    z, xbc, dt = _split_proj(p, x, di, state, heads)
    xbc, _ = _causal_conv(xbc, p.conv_w)
    xbc = jax.nn.silu(xbc)
    xin = xbc[..., :di].reshape(b, s, heads, pdim)
    bmat = xbc[..., di:di + state]          # (B,S,N)
    cmat = xbc[..., di + state:]            # (B,S,N)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p.dt_bias)   # (B,S,H)
    a = -jnp.exp(p.a_log)                                      # (H,)
    la = a[None, None, :] * dt                                 # log decay (B,S,H)

    q = min(chunk, s)
    assert s % q == 0, (s, q)
    nc = s // q
    xin = xin.reshape(b, nc, q, heads, pdim).astype(jnp.float32)
    bmat = bmat.reshape(b, nc, q, state).astype(jnp.float32)
    cmat = cmat.reshape(b, nc, q, state).astype(jnp.float32)
    dt = dt.reshape(b, nc, q, heads)
    la = la.reshape(b, nc, q, heads)
    cum = jnp.cumsum(la, axis=2)  # (B,nc,Q,H) inclusive log-decay

    # ---- intra-chunk (dual quadratic form) ----
    # L[b,c,h,i,j] = exp(cum_i - cum_j) for j<=i
    li = cum[:, :, :, None, :]      # i
    lj = cum[:, :, None, :, :]      # j
    mask = jnp.tril(jnp.ones((q, q), bool))
    ldiff = jnp.where(mask[None, None, :, :, None], li - lj, -jnp.inf)
    decay = jnp.exp(ldiff)          # (B,nc,Q,Q,H)
    scores = jnp.einsum("bcin,bcjn->bcij", cmat, bmat)  # (B,nc,Q,Q)
    m = scores[..., None] * decay * dt[:, :, None, :, :]  # j-indexed dt
    y_intra = jnp.einsum("bcijh,bcjhp->bcihp", m, xin)

    # ---- chunk states + inter-chunk scan ----
    tail = jnp.exp(cum[:, :, -1:, :] - cum)  # decay from j to chunk end
    chunk_state = jnp.einsum("bcjn,bcjh,bcjhp->bchpn",
                             bmat, dt * tail, xin)  # (B,nc,H,P,N)
    chunk_decay = jnp.exp(cum[:, :, -1, :])         # (B,nc,H)

    def carry_step(h, ins):
        cs, cd = ins
        h_new = h * cd[..., None, None] + cs
        return h_new, h  # emit state *before* this chunk

    h0 = jnp.zeros((b, heads, pdim, state), jnp.float32)
    _, h_prev = jax.lax.scan(
        carry_step,
        h0,
        (jnp.moveaxis(chunk_state, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)),
    )
    h_prev = jnp.moveaxis(h_prev, 0, 1)  # (B,nc,H,P,N)
    y_inter = jnp.einsum("bcin,bchpn,bcih->bcihp",
                         cmat, h_prev, jnp.exp(cum))
    y = (y_intra + y_inter).reshape(b, s, heads, pdim)
    y = y + p.d_skip[None, None, :, None] * xin.reshape(b, s, heads, pdim)
    y = y.reshape(b, s, di).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), p.norm_w)
    return y @ p.out_proj


def mamba2_decode(p: Mamba2Params, x, ssm_state, conv_state, *, expand,
                  head_dim, state, conv):
    """One token: O(1) state update.  x: (B,1,D)."""
    from repro.models.common import rms_norm
    b, _, d = x.shape
    di, heads = mamba2_dims(d, expand, head_dim, state)
    pdim = head_dim
    z, xbc, dt = _split_proj(p, x, di, state, heads)
    xbc, conv_state = _causal_conv(xbc, p.conv_w, conv_state)
    xbc = jax.nn.silu(xbc)
    xin = xbc[..., :di].reshape(b, heads, pdim)
    bmat = xbc[:, 0, di:di + state].astype(jnp.float32)   # (B,N)
    cmat = xbc[:, 0, di + state:].astype(jnp.float32)
    dt = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + p.dt_bias)  # (B,H)
    a = -jnp.exp(p.a_log)
    decay = jnp.exp(a[None, :] * dt)  # (B,H)
    upd = jnp.einsum("bh,bn,bhp->bhpn", dt, bmat, xin.astype(jnp.float32))
    ssm_state = ssm_state * decay[..., None, None] + upd
    y = jnp.einsum("bn,bhpn->bhp", cmat, ssm_state)
    y = y + p.d_skip[None, :, None] * xin.astype(jnp.float32)
    y = y.reshape(b, 1, di).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), p.norm_w)
    return y @ p.out_proj, ssm_state, conv_state
