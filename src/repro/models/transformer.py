"""The LM backbone: every assigned architecture through one scan-based stack.

Design (DESIGN.md §5/§6):
* **scan-over-layers** — per-layer params are stacked on a leading axis and
  consumed by ``lax.scan`` so HLO size is depth-independent (95-layer models
  compile like 1-layer ones); heterogeneous stacks (zamba2 shared blocks,
  deepseek-v2's dense first layer) are expressed as *segments*: python-level
  sequence of (scanned span, optional eager block).
* **remat** — the scan body is wrapped in ``jax.checkpoint`` for train.
* block codes: 'A' attention+FFN • 'M' Mamba2 • 'R' RWKV6; whisper adds an
  encoder stack + per-layer cross-attention; internvl2 replaces the first
  ``vision_patches`` embeddings with stub patch embeddings.
* the paper's TopK-SpGEMM FFN (Eq. 1–3) is selected by ``cfg.ffn_mode``.

Public API: ``init_transformer`` (params + PartitionSpecs), ``train_loss``,
``init_decode_cache``, ``decode_step``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.launch.sharding import Shardings, UNSHARDED
from repro.models import attention as attn
from repro.models import ffn as ffn_mod
from repro.models import mamba2 as m2
from repro.models import rwkv6 as rk
from repro.models.common import cross_entropy_chunked, dense_init, rms_norm


class Transformer(NamedTuple):
    cfg: ArchConfig
    params: Dict[str, Any]


# ---------------------------------------------------------------------------
# Segments: contiguous scanned spans + eager inserts (zamba2 / ds-v2-lite)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Segment:
    start: int
    length: int
    shared_after: bool  # apply the weight-shared attn block after this span


def segments(cfg: ArchConfig) -> List[Segment]:
    if cfg.block_pattern == "M" and cfg.shared_attn_every:
        segs = []
        i = 0
        while i < cfg.n_layers:
            ln = min(cfg.shared_attn_every, cfg.n_layers - i)
            segs.append(Segment(i, ln, shared_after=(ln == cfg.shared_attn_every)))
            i += ln
        return segs
    return [Segment(0, cfg.n_layers, shared_after=False)]


def n_shared_apps(cfg: ArchConfig) -> int:
    return sum(1 for s in segments(cfg) if s.shared_after)


# ---------------------------------------------------------------------------
# Per-layer init (one layer), then vmapped to a stacked pytree
# ---------------------------------------------------------------------------

def _layer_init(cfg: ArchConfig, key, kind: str, cross: bool = False):
    dtype = cfg.activation_dtype
    d = cfg.d_model
    out: Dict[str, Any] = {}
    ks = iter(jax.random.split(key, 8))
    if kind == "A":
        out["ln1"] = jnp.ones((d,), dtype)
        if cfg.attention == "mla":
            out["attn"] = attn.mla_init(next(ks), d, cfg.n_heads, cfg.mla, dtype)
        else:
            out["attn"] = attn.gqa_init(next(ks), d, cfg.n_heads,
                                        cfg.n_kv_heads, cfg.hd, dtype)
        if cross:
            out["ln_cross"] = jnp.ones((d,), dtype)
            out["cross"] = attn.gqa_init(next(ks), d, cfg.n_heads,
                                         cfg.n_kv_heads, cfg.hd, dtype)
        out["ln2"] = jnp.ones((d,), dtype)
        if cfg.moe and cfg.moe.n_experts:
            out["ffn"] = ffn_mod.moe_init(next(ks), d, cfg.moe, dtype)
        else:
            out["ffn"] = ffn_mod.ffn_init(next(ks), d, cfg.d_ff, dtype)
    elif kind == "M":
        out["ln1"] = jnp.ones((d,), dtype)
        out["mamba"] = m2.mamba2_init(
            next(ks), d, expand=cfg.ssm_expand, head_dim=cfg.ssm_head_dim,
            state=cfg.ssm_state, conv=cfg.ssm_conv, dtype=dtype)
    elif kind == "R":
        out["ln1"] = jnp.ones((d,), dtype)
        out["ln2"] = jnp.ones((d,), dtype)
        out["rwkv"] = rk.rwkv6_init(next(ks), d, cfg.d_ff, cfg.n_heads, dtype)
    else:
        raise ValueError(kind)
    return out


def _dense_layer_init(cfg: ArchConfig, key):
    """Plain attention+dense-FFN layer (deepseek-v2-lite layer 0)."""
    dtype = cfg.activation_dtype
    d = cfg.d_model
    ks = jax.random.split(key, 2)
    out = {"ln1": jnp.ones((d,), dtype), "ln2": jnp.ones((d,), dtype)}
    if cfg.attention == "mla":
        out["attn"] = attn.mla_init(ks[0], d, cfg.n_heads, cfg.mla, dtype)
    else:
        out["attn"] = attn.gqa_init(ks[0], d, cfg.n_heads, cfg.n_kv_heads,
                                    cfg.hd, dtype)
    out["ffn"] = ffn_mod.ffn_init(ks[1], d, cfg.d_ff, dtype)
    return out


def init_transformer(cfg: ArchConfig, key) -> Tuple[Dict, Dict]:
    """Returns (params, partition_specs) — specs mirror the param tree."""
    dtype = cfg.activation_dtype
    keys = jax.random.split(key, 8)
    params: Dict[str, Any] = {}
    params["embed"] = (jax.random.normal(keys[0], (cfg.vocab, cfg.d_model),
                                         jnp.float32) * 0.02).astype(dtype)
    params["out_norm"] = jnp.ones((cfg.d_model,), dtype)
    params["lm_head"] = dense_init(keys[1], cfg.d_model, cfg.vocab, dtype)

    kind = cfg.block_pattern[0] if len(set(cfg.block_pattern)) == 1 else "A"
    n_prefix = 1 if cfg.first_layer_dense_ffn else 0
    n_scan = cfg.n_layers - n_prefix
    cross = cfg.encoder_layers > 0

    layer_keys = jax.random.split(keys[2], max(n_scan, 1))
    params["layers"] = jax.vmap(
        lambda k: _layer_init(cfg, k, kind, cross=cross)
    )(layer_keys)

    if n_prefix:
        params["prefix_layers"] = [
            _dense_layer_init(cfg, k) for k in jax.random.split(keys[3], n_prefix)
        ]
    if cfg.block_pattern == "M" and cfg.shared_attn_every:
        params["shared_attn"] = _layer_init(cfg, keys[4], "A")
    if cfg.encoder_layers:
        enc_keys = jax.random.split(keys[5], cfg.encoder_layers)
        params["encoder"] = jax.vmap(
            lambda k: _layer_init(cfg, k, "A")
        )(enc_keys)
        params["enc_norm"] = jnp.ones((cfg.d_model,), dtype)

    specs = param_specs(cfg, params)
    return params, specs


# ---------------------------------------------------------------------------
# Parameter PartitionSpecs (megatron-style TP over the `model` axis)
# ---------------------------------------------------------------------------

def _spec_for(path: str, shape: Tuple[int, ...], model_size: int) -> P:
    """TP rules by param name.  Column-parallel: out dim on `model`;
    row-parallel (down/out projections): in dim on `model`."""
    def ok(dim):  # only shard divisible dims
        return dim % model_size == 0 if model_size > 1 else False

    last = path.split("/")[-1]
    col = {"wq", "wk", "wv", "w1", "w3", "ck", "w_uk", "w_uv", "in_proj",
           "lm_head", "wr", "wk2", "wg", "router"}
    row = {"wo", "w2", "cv", "out_proj", "cr"}
    if last == "embed":
        return P("model" if ok(shape[0]) else None, None)
    if last in col:
        d_out = shape[-1]
        return P(*([None] * (len(shape) - 1)), "model" if ok(d_out) else None)
    if last in row:
        d_in = shape[-2] if len(shape) >= 2 else shape[0]
        spec = [None] * len(shape)
        if ok(d_in):
            spec[-2] = "model"
        return P(*spec)
    return P(*([None] * len(shape)))


def _moe_spec(path: str, shape, model_size) -> Optional[P]:
    """Experts dim (first after layer-stack) on `model` (EP)."""
    last = path.split("/")[-1]
    if last in ("w1", "w3", "w2") and len(shape) >= 3:
        # (L, E, D, F) stacked or (E, D, F) unstacked
        e_dim = len(shape) - 3
        if shape[e_dim] % model_size == 0 and shape[e_dim] >= model_size:
            spec = [None] * len(shape)
            spec[e_dim] = "model"
            return P(*spec)
    return None


def param_specs(cfg: ArchConfig, params, model_size: int = 16) -> Dict:
    is_moe = bool(cfg.moe and cfg.moe.n_experts)

    def one(path_tuple, leaf):
        path = "/".join(str(getattr(p, "key", getattr(p, "name", getattr(p, "idx", p))))
                        for p in path_tuple)
        shape = leaf.shape
        if is_moe and "layers" in path and "ffn" in path and "shared" not in path:
            s = _moe_spec(path, shape, model_size)
            if s is not None:
                return s
        base = _spec_for(path, shape, model_size)
        # stacked layers: never shard the leading layer axis; pad spec rank
        if path.startswith(("layers", "encoder")) and len(base) < len(shape):
            return P(*([None] * (len(shape) - len(base))), *base)
        return base

    return jax.tree_util.tree_map_with_path(one, params)


# ---------------------------------------------------------------------------
# Blocks (train/prefill)
# ---------------------------------------------------------------------------

def _ffn_apply(cfg: ArchConfig, lp, x, sh: Shardings):
    if cfg.moe and cfg.moe.n_experts and "router" in lp["ffn"]._fields:
        if cfg.moe.impl == "shard_map" and sh.mesh is not None:
            return ffn_mod.moe_ffn_shard_map(lp["ffn"], x, cfg.moe, sh)
        y, aux = ffn_mod.moe_ffn(lp["ffn"], x, cfg.moe, sh=sh)
        return y, aux
    if cfg.ffn_mode == "topk" and cfg.topk_k:
        return ffn_mod.topk_ffn(lp["ffn"], x, cfg.topk_k, sh=sh), 0.0
    if cfg.ffn_mode == "block_topk" and cfg.topk_k:
        return ffn_mod.block_topk_ffn(lp["ffn"], x, cfg.topk_k,
                                      block=cfg.topk_block, sh=sh), 0.0
    return ffn_mod.swiglu(lp["ffn"], x, sh=sh), 0.0


def _attn_block(cfg: ArchConfig, lp, x, sh: Shardings, *, causal=True,
                window=0, enc=None, dense_ffn=False):
    h = rms_norm(x, lp["ln1"], cfg.norm_eps)
    meas = dict(attn_chunk=cfg.attn_chunk, unroll=cfg.unroll_inner,
                p_dtype=jnp.bfloat16 if cfg.attn_p_dtype == "bfloat16" else None)
    if cfg.attention == "mla":
        a = attn.mla_forward(lp["attn"], h, n_heads=cfg.n_heads, mla=cfg.mla,
                             rope_theta=cfg.rope_theta, sh=sh, **meas)
    else:
        a = attn.gqa_forward(lp["attn"], h, n_heads=cfg.n_heads,
                             n_kv=cfg.n_kv_heads, hd=cfg.hd,
                             rope_theta=cfg.rope_theta, causal=causal,
                             window=window, sh=sh, **meas)
    x = x + a
    if enc is not None and "cross" in lp:
        h = rms_norm(x, lp["ln_cross"], cfg.norm_eps)
        kv = attn.gqa_cross_kv(lp["cross"], enc, cfg.n_kv_heads, cfg.hd)
        c = attn.gqa_forward(lp["cross"], h, n_heads=cfg.n_heads,
                             n_kv=cfg.n_kv_heads, hd=cfg.hd,
                             rope_theta=cfg.rope_theta, sh=sh, cross_kv=kv,
                             **meas)
        x = x + c
    h = rms_norm(x, lp["ln2"], cfg.norm_eps)
    if dense_ffn:
        y, aux = ffn_mod.swiglu(lp["ffn"], h, sh=sh), 0.0
    else:
        y, aux = _ffn_apply(cfg, lp, h, sh)
    return x + y, aux


def _mamba_block(cfg: ArchConfig, lp, x, sh: Shardings):
    h = rms_norm(x, lp["ln1"], cfg.norm_eps)
    y = m2.mamba2_forward(
        lp["mamba"], h, expand=cfg.ssm_expand, head_dim=cfg.ssm_head_dim,
        state=cfg.ssm_state, conv=cfg.ssm_conv)
    return x + y, 0.0


def _rwkv_block(cfg: ArchConfig, lp, x, sh: Shardings):
    h = rms_norm(x, lp["ln1"], cfg.norm_eps)
    y, _, _ = rk.rwkv6_time_mix(lp["rwkv"], h, n_heads=cfg.n_heads, sh=sh,
                                chunk=cfg.rwkv_chunk, unroll=cfg.unroll_inner)
    x = x + y
    h = rms_norm(x, lp["ln2"], cfg.norm_eps)
    y, _ = rk.rwkv6_channel_mix(lp["rwkv"], h)
    return x + y, 0.0


def _block(cfg: ArchConfig, kind: str, lp, x, sh, enc=None):
    if kind == "A":
        return _attn_block(cfg, lp, x, sh, causal=True,
                           window=cfg.sliding_window if cfg.family == "hybrid" else 0,
                           enc=enc)
    if kind == "M":
        return _mamba_block(cfg, lp, x, sh)
    if kind == "R":
        return _rwkv_block(cfg, lp, x, sh)
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# Forward (train / prefill)
# ---------------------------------------------------------------------------

def _scan_layers(cfg, stacked, x, sh, kind, enc=None, remat=True):
    def body(carry, lp):
        h, aux = carry
        h = sh.act_btd(h)
        h, a = _block(cfg, kind, lp, h, sh, enc=enc)
        return (h, aux + a), None

    n_layers = jax.tree.leaves(stacked)[0].shape[0]
    if cfg.unroll_layers:
        # measurement mode: python loop so HLO carries every layer and
        # cost_analysis trip counts are exact (see launch/dryrun.py)
        bodyc = jax.checkpoint(body) if (remat and cfg.remat == "full") else body
        carry = (x, jnp.zeros((), jnp.float32))
        for i in range(n_layers):
            lp = jax.tree.map(lambda a: a[i], stacked)
            carry, _ = bodyc(carry, lp)
        return carry
    g = cfg.remat_groups
    if remat and cfg.remat == "full" and g > 1 and n_layers % g == 0:
        # sqrt-schedule remat: outer scan over G checkpointed groups, inner
        # scan over L/G layers — backward stores G carries instead of L
        # (the memory-term §Perf lever; see EXPERIMENTS.md).
        inner_n = n_layers // g
        grouped = jax.tree.map(
            lambda a: a.reshape(g, inner_n, *a.shape[1:]), stacked)

        def outer(carry, gp):
            out, _ = jax.lax.scan(body, carry, gp)
            return out, None

        outer = jax.checkpoint(outer)
        (x, aux), _ = jax.lax.scan(outer, (x, jnp.zeros((), jnp.float32)),
                                   grouped)
        return x, aux
    if remat and cfg.remat == "full":
        body = jax.checkpoint(body)
    (x, aux), _ = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)), stacked)
    return x, aux


def _slice_layers(stacked, start, length):
    return jax.tree.map(lambda a: jax.lax.slice_in_dim(a, start, start + length,
                                                       axis=0), stacked)


def encode(cfg: ArchConfig, params, frames, sh: Shardings):
    """Whisper encoder over stub frame embeddings (B, T_enc, D)."""
    x = frames.astype(cfg.activation_dtype)

    def body(carry, lp):
        h, _ = carry
        h, _ = _attn_block(cfg, lp, h, sh, causal=False, dense_ffn=False)
        return (h, jnp.zeros((), jnp.float32)), None

    if cfg.remat == "full":
        body = jax.checkpoint(body)
    if cfg.unroll_layers:
        carry = (x, jnp.zeros((), jnp.float32))
        n = jax.tree.leaves(params["encoder"])[0].shape[0]
        for i in range(n):
            lp = jax.tree.map(lambda a: a[i], params["encoder"])
            carry, _ = body(carry, lp)
        x = carry[0]
        return rms_norm(x, params["enc_norm"], cfg.norm_eps)
    (x, _), _ = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)),
                             params["encoder"])
    return rms_norm(x, params["enc_norm"], cfg.norm_eps)


def forward_hidden(cfg: ArchConfig, params, tokens, sh: Shardings = UNSHARDED,
                   vision_embeds=None, frames=None, remat=True):
    """tokens (B,S) -> final hidden (B,S,D); plus MoE aux loss."""
    x = jnp.take(params["embed"], tokens, axis=0)
    if cfg.frontend == "vision_stub" and vision_embeds is not None:
        pv = vision_embeds.shape[1]
        x = jax.lax.dynamic_update_slice_in_dim(
            x, vision_embeds.astype(x.dtype), 0, axis=1)
        del pv
    enc = None
    if cfg.encoder_layers and frames is not None:
        enc = encode(cfg, params, frames, sh)
    x = sh.act_btd(x)
    aux_total = jnp.zeros((), jnp.float32)

    for lp in params.get("prefix_layers", []):
        x, aux = _attn_block(cfg, lp, x, sh, dense_ffn=True, enc=enc)
        aux_total += aux

    kind = cfg.block_pattern[0] if len(set(cfg.block_pattern)) == 1 else "A"
    if kind == "M" and cfg.shared_attn_every:
        stacked = params["layers"]
        for seg in segments(cfg):
            span = _slice_layers(stacked, seg.start, seg.length)
            x, aux = _scan_layers(cfg, span, x, sh, "M", remat=remat)
            aux_total += aux
            if seg.shared_after:
                x, aux = _attn_block(cfg, params["shared_attn"], x, sh,
                                     window=cfg.sliding_window)
                aux_total += aux
    else:
        x, aux = _scan_layers(cfg, params["layers"], x, sh, kind, enc=enc,
                              remat=remat)
        aux_total += aux
    return rms_norm(x, params["out_norm"], cfg.norm_eps), aux_total


def train_loss(cfg: ArchConfig, params, batch, sh: Shardings = UNSHARDED):
    """batch: {"tokens": (B,S), "labels": (B,S)} (+ stub modality inputs)."""
    h, aux = forward_hidden(
        cfg, params, batch["tokens"], sh,
        vision_embeds=batch.get("vision_embeds"),
        frames=batch.get("frames"),
    )
    def logits_fn(hh, w):
        out = hh @ w
        return sh.act_btv(out)
    loss = cross_entropy_chunked(logits_fn, h, batch["labels"],
                                 params["lm_head"], cfg.loss_chunks,
                                 unroll=cfg.unroll_inner)
    return loss + 0.01 * aux


# ---------------------------------------------------------------------------
# Decode (serve_step)
# ---------------------------------------------------------------------------

def init_decode_cache(cfg: ArchConfig, batch: int, max_seq: int,
                      dtype=None) -> Dict:
    """Cache pytree (all stacked on a leading per-layer axis)."""
    dtype = dtype or cfg.activation_dtype
    kind = cfg.block_pattern[0] if len(set(cfg.block_pattern)) == 1 else "A"
    n_prefix = 1 if cfg.first_layer_dense_ffn else 0
    n_scan = cfg.n_layers - n_prefix
    cache: Dict[str, Any] = {"pos": jnp.zeros((), jnp.int32)}
    if kind == "A":
        if cfg.attention == "mla":
            m = cfg.mla
            cache["latent"] = jnp.zeros((n_scan, batch, max_seq, m.kv_lora), dtype)
            cache["krope"] = jnp.zeros((n_scan, batch, max_seq, m.qk_rope_dim), dtype)
            if n_prefix:
                cache["p_latent"] = jnp.zeros((n_prefix, batch, max_seq, m.kv_lora), dtype)
                cache["p_krope"] = jnp.zeros((n_prefix, batch, max_seq, m.qk_rope_dim), dtype)
        else:
            kv, hd = cfg.n_kv_heads, cfg.hd
            cache["k"] = jnp.zeros((n_scan, batch, max_seq, kv, hd), dtype)
            cache["v"] = jnp.zeros((n_scan, batch, max_seq, kv, hd), dtype)
        if cfg.encoder_layers:
            cache["cross_k"] = jnp.zeros(
                (n_scan, batch, cfg.encoder_seq, cfg.n_kv_heads, cfg.hd), dtype)
            cache["cross_v"] = jnp.zeros_like(cache["cross_k"])
    elif kind == "M":
        di, heads = m2.mamba2_dims(cfg.d_model, cfg.ssm_expand,
                                   cfg.ssm_head_dim, cfg.ssm_state)
        cache["ssm"] = jnp.zeros((n_scan, batch, heads, cfg.ssm_head_dim,
                                  cfg.ssm_state), jnp.float32)
        cache["conv"] = jnp.zeros((n_scan, batch, cfg.ssm_conv - 1,
                                   di + 2 * cfg.ssm_state), dtype)
        if cfg.shared_attn_every:
            napp = n_shared_apps(cfg)
            kv, hd = cfg.n_kv_heads, cfg.hd
            cache["shared_k"] = jnp.zeros((napp, batch, max_seq, kv, hd), dtype)
            cache["shared_v"] = jnp.zeros_like(cache["shared_k"])
    elif kind == "R":
        hp = cfg.d_model // cfg.n_heads
        cache["wkv"] = jnp.zeros((n_scan, batch, cfg.n_heads, hp, hp), jnp.float32)
        cache["shift1"] = jnp.zeros((n_scan, batch, cfg.d_model), dtype)
        cache["shift2"] = jnp.zeros((n_scan, batch, cfg.d_model), dtype)
    return cache


def _maybe_scan(cfg, body, carry, xs):
    """lax.scan, or an unrolled python loop in measurement mode."""
    if not cfg.unroll_layers:
        return jax.lax.scan(body, carry, xs)
    n = jax.tree.leaves(xs)[0].shape[0]
    ys = []
    for i in range(n):
        xi = jax.tree.map(lambda a: a[i], xs)
        carry, y = body(carry, xi)
        ys.append(y)
    stacked = jax.tree.map(lambda *a: jnp.stack(a), *ys)
    return carry, stacked


def _decode_attn_layer(cfg, lp, x, kc, vc, pos, window=0):
    h = rms_norm(x, lp["ln1"], cfg.norm_eps)
    a, kc, vc = attn.gqa_decode(lp["attn"], h, kc, vc, pos,
                                n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads,
                                hd=cfg.hd, rope_theta=cfg.rope_theta,
                                window=window)
    return x + a, kc, vc


def _decode_ffn(cfg, lp, x, sh, dense_ffn=False):
    h = rms_norm(x, lp["ln2"], cfg.norm_eps)
    if dense_ffn:
        return x + ffn_mod.swiglu(lp["ffn"], h, sh=sh)
    y, _ = _ffn_apply(cfg, lp, h, sh)
    return x + y


def decode_step(cfg: ArchConfig, params, cache: Dict, tokens,
                sh: Shardings = UNSHARDED):
    """One serve step: tokens (B,1) -> logits (B,1,V); updates cache."""
    pos = cache["pos"]
    x = jnp.take(params["embed"], tokens, axis=0)
    x = sh.act_btd(x)
    kind = cfg.block_pattern[0] if len(set(cfg.block_pattern)) == 1 else "A"
    new_cache = dict(cache)

    for i, lp in enumerate(params.get("prefix_layers", [])):
        # prefix layers exist only for MLA archs (deepseek-v2-lite layer 0)
        assert cfg.attention == "mla", "prefix layers require MLA"
        h = rms_norm(x, lp["ln1"], cfg.norm_eps)
        a, lat, krp = attn.mla_decode(
            lp["attn"], h, cache["p_latent"][i], cache["p_krope"][i], pos,
            n_heads=cfg.n_heads, mla=cfg.mla, rope_theta=cfg.rope_theta)
        x = x + a
        new_cache["p_latent"] = new_cache["p_latent"].at[i].set(lat)
        new_cache["p_krope"] = new_cache["p_krope"].at[i].set(krp)
        x = _decode_ffn(cfg, lp, x, sh, dense_ffn=True)

    if kind == "A":
        if cfg.attention == "mla":
            def body(carry, xs):
                h = carry
                lp, lat, krp = xs
                hh = rms_norm(h, lp["ln1"], cfg.norm_eps)
                a, lat, krp = attn.mla_decode(
                    lp["attn"], hh, lat, krp, pos, n_heads=cfg.n_heads,
                    mla=cfg.mla, rope_theta=cfg.rope_theta)
                h = h + a
                h = _decode_ffn(cfg, lp, h, sh)
                return h, (lat, krp)
            x, (lat, krp) = _maybe_scan(
                cfg, body, x, (params["layers"], cache["latent"], cache["krope"]))
            new_cache["latent"], new_cache["krope"] = lat, krp
        else:
            has_cross = cfg.encoder_layers > 0
            def body(carry, xs):
                h = carry
                if has_cross:
                    lp, kc, vc, ck, cv = xs
                else:
                    lp, kc, vc = xs
                hh = rms_norm(h, lp["ln1"], cfg.norm_eps)
                a, kc, vc = attn.gqa_decode(
                    lp["attn"], hh, kc, vc, pos, n_heads=cfg.n_heads,
                    n_kv=cfg.n_kv_heads, hd=cfg.hd, rope_theta=cfg.rope_theta)
                h = h + a
                if has_cross:
                    hh = rms_norm(h, lp["ln_cross"], cfg.norm_eps)
                    b = hh.shape[0]
                    q = (hh @ lp["cross"].wq).reshape(b, 1, cfg.n_heads, cfg.hd)
                    o = attn.decode_attention(q, ck, cv,
                                              jnp.asarray(ck.shape[1], jnp.int32))
                    h = h + o.reshape(b, 1, cfg.n_heads * cfg.hd) @ lp["cross"].wo
                h = _decode_ffn(cfg, lp, h, sh)
                return h, ((kc, vc))
            xs = (params["layers"], cache["k"], cache["v"])
            if has_cross:
                xs = xs + (cache["cross_k"], cache["cross_v"])
            x, (kc, vc) = _maybe_scan(cfg, body, x, xs)
            new_cache["k"], new_cache["v"] = kc, vc
    elif kind == "M":
        stacked = params["layers"]
        ssm_out, conv_out = [], []
        app = 0
        segs = segments(cfg)
        off = 0
        new_ssm = cache["ssm"]
        new_conv = cache["conv"]
        for seg in segs:
            span = _slice_layers(stacked, seg.start, seg.length)
            ssm_span = jax.lax.slice_in_dim(cache["ssm"], seg.start,
                                            seg.start + seg.length, axis=0)
            conv_span = jax.lax.slice_in_dim(cache["conv"], seg.start,
                                             seg.start + seg.length, axis=0)

            def body(carry, xs):
                h = carry
                lp, s_st, c_st = xs
                hh = rms_norm(h, lp["ln1"], cfg.norm_eps)
                y, s_st, c_st = m2.mamba2_decode(
                    lp["mamba"], hh, s_st, c_st, expand=cfg.ssm_expand,
                    head_dim=cfg.ssm_head_dim, state=cfg.ssm_state,
                    conv=cfg.ssm_conv)
                return h + y, (s_st, c_st)

            x, (s_new, c_new) = _maybe_scan(cfg, body, x,
                                            (span, ssm_span, conv_span))
            new_ssm = jax.lax.dynamic_update_slice_in_dim(new_ssm, s_new,
                                                          seg.start, axis=0)
            new_conv = jax.lax.dynamic_update_slice_in_dim(new_conv, c_new,
                                                           seg.start, axis=0)
            if seg.shared_after:
                lp = params["shared_attn"]
                hh = rms_norm(x, lp["ln1"], cfg.norm_eps)
                a, kc, vc = attn.gqa_decode(
                    lp["attn"], hh, cache["shared_k"][app], cache["shared_v"][app],
                    pos, n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads, hd=cfg.hd,
                    rope_theta=cfg.rope_theta, window=cfg.sliding_window)
                x = x + a
                x = _decode_ffn(cfg, lp, x, sh)
                new_cache["shared_k"] = new_cache["shared_k"].at[app].set(kc)
                new_cache["shared_v"] = new_cache["shared_v"].at[app].set(vc)
                app += 1
            off += seg.length
        new_cache["ssm"], new_cache["conv"] = new_ssm, new_conv
    elif kind == "R":
        def body(carry, xs):
            h = carry
            lp, st, sh1, sh2 = xs
            hh = rms_norm(h, lp["ln1"], cfg.norm_eps)
            y, st, last1 = rk.rwkv6_time_mix(lp["rwkv"], hh, n_heads=cfg.n_heads,
                                             state=st, x_prev=sh1)
            h = h + y
            hh = rms_norm(h, lp["ln2"], cfg.norm_eps)
            y, last2 = rk.rwkv6_channel_mix(lp["rwkv"], hh, x_prev=sh2)
            return h + y, (st, last1, last2)
        x, (st, s1, s2) = _maybe_scan(
            cfg, body, x, (params["layers"], cache["wkv"], cache["shift1"],
                           cache["shift2"]))
        new_cache["wkv"], new_cache["shift1"], new_cache["shift2"] = st, s1, s2

    h = rms_norm(x, params["out_norm"], cfg.norm_eps)
    logits = h @ params["lm_head"]
    logits = sh.act_btv(logits)
    new_cache["pos"] = pos + 1
    return logits, new_cache
