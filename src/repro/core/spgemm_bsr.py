"""Block-CSR SpGEMM paths for the MXU (DESIGN.md §2, adaptation #5).

TPU compute is a 128×128 systolic array: element-wise CSR MACs waste it.
The LM-integration paths therefore use BSR with MXU-aligned blocks; the
row-wise Gustavson structure (and the AIA indirection pattern) is preserved
at block granularity:  ``C[i,:] += A[i,k] @ B[k,:]`` where ``k`` ranges over
the block-column ids of block-row i — a ranged indirect access over B's
block rows, served by scalar-prefetch DMA in the Pallas kernel
(``repro.kernels.spgemm_bsr``).  This module holds the XLA reference path.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.sparse.formats import BSR


def bsr_spgemm_dense_rhs(a: BSR, x: jax.Array) -> jax.Array:
    """C = A @ X with BSR A and dense X (n_cols, d) — XLA fallback path."""
    br, bc = a.block_shape
    nbr = a.n_brows
    d = x.shape[1]
    cap = a.indices.shape[0]
    xb = x.reshape(a.shape[1] // bc, bc, d)
    p = jnp.arange(cap, dtype=jnp.int32)
    rid = jnp.searchsorted(a.indptr, p, side="right").astype(jnp.int32) - 1
    valid = p < a.nnzb
    gathered = jnp.take(xb, a.indices, axis=0, mode="clip")  # (cap, bc, d)
    prods = jnp.einsum("kab,kbd->kad", a.blocks, gathered)  # (cap, br, d)
    prods = jnp.where(valid[:, None, None], prods, 0)
    rid = jnp.where(valid, rid, nbr)
    out = jnp.zeros((nbr + 1, br, d), prods.dtype).at[rid].add(prods)
    return out[:nbr].reshape(nbr * br, d)
