"""Algorithm 1 — Intermediate Product Counting.

For C = A·B (Gustavson row-wise), row i of C is built from
``IP[i] = Σ_{j ∈ row_i(A)} nnz(B[col_A[j]])`` intermediate products.
IP drives the paper's load-balancing (Table I) and the hash-table sizing.

The paper notes this O(nnz(A)) pass costs >10% of GPU runtime because of
atomic adds to global memory; in JAX it is a gather + segment-sum, and on
TPU the gather ``row_nnz_B[col_A[j]]`` is itself an AIA-range-1 access.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.sparse.formats import CSR


def intermediate_products(a: CSR, b: CSR) -> jax.Array:
    """IP per row of A (int32, shape (a.n_rows,)). Algorithm 1, vectorized."""
    row_nnz_b = b.row_nnz()  # (= rpt_B[col+1] - rpt_B[col] precomputed)
    valid = a.valid_mask()
    contrib = jnp.where(valid, jnp.take(row_nnz_b, a.indices, mode="clip"), 0)
    rid = a.row_ids()
    ip = jnp.zeros(a.n_rows + 1, jnp.int32).at[rid].add(contrib.astype(jnp.int32))
    return ip[: a.n_rows]


def total_intermediate_products(a: CSR, b: CSR) -> jax.Array:
    """Σ IP — the paper's FLOP basis: GFLOPS = 2·ΣIP / time."""
    return jnp.sum(intermediate_products(a, b))


def ip_histogram(ip: jax.Array, boundaries=(32, 512, 8192)) -> jax.Array:
    """Row counts per Table-I group (log-binned)."""
    b = jnp.asarray(boundaries)
    group = jnp.searchsorted(b, ip, side="right")  # 0..len(boundaries)
    return jnp.zeros(len(boundaries) + 1, jnp.int32).at[group].add(1)
