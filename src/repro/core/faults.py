"""Deterministic fault injection for the resilience layer.

A small registry of **named failure points** placed at the real call sites
the recovery paths protect (``FAULT_POINTS`` below).  Tests and the chaos
bench probe arm a point with the ``fault_injection(...)`` context manager
and a deterministic trigger schedule (fail on the Nth hit, a bounded
number of times), then drive the normal API — the site consults the
registry, the fault fires exactly where a real failure would, and the
recovery path (capacity retry, placement/staging retry, batch isolation)
is exercised end to end instead of being simulated.

Disarmed points cost one dict lookup per consult and can never fire, so
the hooks are safe to leave in production code paths.

Usage::

    from repro.core import faults

    with faults.fault_injection("capacity_undersize") as fault:
        res = spgemm(a, a, engine="fused_hash")   # under-sizes one chunk
    assert fault.triggers == 1                     # ...and recovered

Sites call either ``fire(name)`` (raise ``FaultInjected`` — transient
failures like a staging or dispatch error) or ``trigger(name)`` (returns
True — perturbation faults like shrinking a planned capacity, where the
site corrupts its own state instead of raising).
"""
from __future__ import annotations

import contextlib
import dataclasses
from typing import Dict, Iterator, Optional


class FaultInjected(RuntimeError):
    """The error an armed raise-style fault point throws at its site.

    Recovery code catches exactly this (or the site's natural failure
    type); tests assert the *recovery*, never the raise itself.
    """


#: Every failure point a site consults, with where it lives.  Arming an
#: unknown name is a ``ValueError`` — a typo'd chaos test must fail loudly,
#: not silently test nothing.
FAULT_POINTS: Dict[str, str] = {
    "capacity_undersize": (
        "planned/fused sizing: shrink one chunk's out_cap below its true "
        "uniqueCount (executor._run_planned) so the device-side overflow "
        "flag and the measured-capacity retry are exercised"),
    "gather_fail": (
        "B-operand placement: fail the gather/placement of B's shard "
        "buffers once (executor.execute_plan); recovery re-places"),
    "stage_tile_fail": (
        "streamed lane: fail one tile's host->device staging "
        "(executor.execute_plan_streamed); recovery re-stages the tile"),
    "dispatch_fail": (
        "serving layer: fail a dispatch (SpGEMMService._dispatch_key); "
        "recovery replays the micro-batch members individually and "
        "quarantines a member that fails alone"),
}


@dataclasses.dataclass
class FaultHandle:
    """One armed fault point with its deterministic trigger schedule.

    ``on_hit`` is the 1-based hit index of the first trigger; ``times``
    bounds how many consecutive hits from there trigger (``None`` =
    every hit from ``on_hit`` on).  ``hits``/``triggers`` are the live
    counters tests assert on after the context exits.
    """

    name: str
    on_hit: int = 1
    times: Optional[int] = 1
    hits: int = 0
    triggers: int = 0

    def consult(self) -> bool:
        """Record one site hit; True when this hit should fail."""
        self.hits += 1
        if self.hits < self.on_hit:
            return False
        if self.times is not None and self.triggers >= self.times:
            return False
        self.triggers += 1
        return True


_ARMED: Dict[str, FaultHandle] = {}


def _validate(name: str) -> None:
    if name not in FAULT_POINTS:
        raise ValueError(
            f"unknown fault point {name!r}; registered points: "
            f"{', '.join(sorted(FAULT_POINTS))}")


def armed(name: str) -> bool:
    """True when ``name`` is currently armed (schedule aside)."""
    _validate(name)
    return name in _ARMED


def trigger(name: str) -> bool:
    """Consult a perturbation-style site: True when the armed schedule
    says this hit fails (the site then corrupts its own state)."""
    _validate(name)
    handle = _ARMED.get(name)
    return handle.consult() if handle is not None else False


def fire(name: str) -> None:
    """Consult a raise-style site: throws ``FaultInjected`` on a
    scheduled hit, returns silently otherwise."""
    if trigger(name):
        raise FaultInjected(
            f"injected fault at {name!r} (hit {_ARMED[name].hits})")


@contextlib.contextmanager
def fault_injection(name: str, *, on_hit: int = 1,
                    times: Optional[int] = 1) -> Iterator[FaultHandle]:
    """Arm fault point ``name`` for the duration of the ``with`` block.

    ``on_hit`` (1-based) delays the first trigger to the Nth site hit;
    ``times`` bounds the number of triggers (default 1: fail once, then
    behave — the transient-fault shape; ``None`` = fail every hit).
    Yields the live ``FaultHandle`` so the caller can assert
    ``hits``/``triggers`` afterwards.  Points disarm on exit no matter
    how the block ends; nesting the same point is an error.
    """
    _validate(name)
    if isinstance(on_hit, bool) or not isinstance(on_hit, int) or on_hit < 1:
        raise ValueError(f"on_hit must be an int >= 1; got {on_hit!r}")
    if times is not None and (isinstance(times, bool)
                              or not isinstance(times, int) or times < 1):
        raise ValueError(f"times must be None or an int >= 1; got {times!r}")
    if name in _ARMED:
        raise RuntimeError(f"fault point {name!r} is already armed")
    handle = FaultHandle(name=name, on_hit=on_hit, times=times)
    _ARMED[name] = handle
    try:
        yield handle
    finally:
        del _ARMED[name]
