"""The paper's primary contribution: hash-based multi-phase SpGEMM + AIA.

Phases (paper §III):
  1. Row-grouping  — Algorithm 1 intermediate-product counting + Table I
                     logarithmic binning (``repro.core.grouping``).
  2. Allocation    — symbolic phase: unique output columns per row
                     (``repro.core.allocation``; hash + sort variants).
  3. Accumulation  — numeric phase: value accumulation + gather + sort
                     (``repro.core.accumulation``).

``repro.core.spgemm.spgemm`` is the public API; ``spgemm_bsr`` is the
MXU-native block variant used by the LM integration.
"""
from repro.core.ip_count import intermediate_products, ip_histogram
from repro.core.grouping import group_rows, GroupPlan, TABLE_I
from repro.core.spgemm import spgemm, spgemm_info, SpGEMMResult
from repro.core.spgemm_bsr import bsr_spgemm_dense_rhs

__all__ = [
    "intermediate_products", "ip_histogram",
    "group_rows", "GroupPlan", "TABLE_I",
    "spgemm", "spgemm_info", "SpGEMMResult",
    "bsr_spgemm_dense_rhs",
]
