"""The paper's primary contribution: hash-based multi-phase SpGEMM + AIA.

Phases (paper §III):
  1. Row-grouping  — Algorithm 1 intermediate-product counting + Table I
                     logarithmic binning (``repro.core.grouping``).
  2. Allocation    — symbolic phase: unique output columns per row
                     (hash + sort engines in ``repro.core.phases``).
  3. Accumulation  — numeric phase: value accumulation + gather + sort.

``repro.core.spgemm.spgemm`` is the public API, a thin façade over the
plan-compiled executor in ``repro.core.executor`` (engine registry, gather
backends, program cache, vectorized reassembly); ``spgemm_bsr`` is the
MXU-native block variant used by the LM integration.
"""
from repro.core.ip_count import intermediate_products, ip_histogram
from repro.core.grouping import group_rows, GroupPlan, TABLE_I
from repro.core.executor import (
    DeviceBudgetExceeded, Engine, OperandCache, PlanCache,
    available_engines, cache_stats, chunk_capacity_bounds,
    clear_program_cache, device_budget, estimated_device_bytes,
    execute_plan, execute_plan_streamed, get_engine, register_engine,
    resolve_gather, resolve_operands, resolve_prefetch, resolve_sizing,
    resolve_tile_rows, set_device_budget, tile_ranges,
)
from repro.core.spgemm import (
    spgemm, spgemm_info, spgemm_streamed, SpGEMMResult, SpGEMMStreamResult,
)
from repro.core.spgemm_bsr import bsr_spgemm_dense_rhs

__all__ = [
    "intermediate_products", "ip_histogram",
    "group_rows", "GroupPlan", "TABLE_I",
    "Engine", "register_engine", "get_engine", "available_engines",
    "execute_plan", "resolve_gather", "resolve_operands", "resolve_sizing",
    "chunk_capacity_bounds", "cache_stats", "clear_program_cache",
    "OperandCache", "PlanCache",
    "execute_plan_streamed", "tile_ranges", "resolve_tile_rows",
    "resolve_prefetch", "set_device_budget", "device_budget",
    "estimated_device_bytes", "DeviceBudgetExceeded",
    "spgemm", "spgemm_info", "SpGEMMResult",
    "spgemm_streamed", "SpGEMMStreamResult",
    "bsr_spgemm_dense_rhs",
]
