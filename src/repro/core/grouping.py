"""Row-grouping phase (paper §III-B, Table I).

Rows of A are classified into four groups by intermediate-product count
using logarithmic binning, then *logically* reordered (the ``Map`` array —
no physical data movement, exactly as in the paper).  Each group gets its
own GPU-resource analogue: on TPU that is a (rows-per-program, hash/table
capacity, memory space) tuple instead of a (thread-assignment, block-size,
shared-memory) tuple.

Table I (paper) → TPU analogue used here:

| Group | IP range   | paper: threads  | here: rows/program | table capacity |
|-------|------------|-----------------|--------------------|----------------|
| 0     | 0–31       | PWPR, block 512 | 8 (VPU sublanes)   | 64   (VMEM)    |
| 1     | 32–511     | TBPR, block 256 | 1                  | 1024 (VMEM)    |
| 2     | 512–8191   | TBPR, block 1024| 1                  | 8192 (VMEM)    |
| 3     | ≥8192      | TBPR, global HT | 1                  | next_pow2(max IP) (HBM) |
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.ip_count import intermediate_products
from repro.sparse.formats import CSR

# (ip_lo, ip_hi_exclusive, table_capacity); group 3 capacity resolved at plan
# time from the actual max IP (the paper falls back to global memory).
TABLE_I = (
    (0, 32, 64),
    (32, 512, 1024),
    (512, 8192, 8192),
    (8192, None, None),
)

GROUP_BOUNDARIES = (32, 512, 8192)


@dataclasses.dataclass(frozen=True)
class GroupPlan:
    """Host-side schedule produced by the row-grouping phase.

    ``map_rows`` is the paper's ``Map``: ``map_rows[i]`` = original row id of
    the i-th row in group-sorted order.  ``group_offsets`` delimits groups in
    that order; ``group_sizes_padded`` are the static per-group row counts
    each group's kernel is compiled for (padded up so recompilation is rare).

    ``row_ip`` keeps the Algorithm-1 IP count per original row.  Phase 1
    already pays for these counts; carrying them in the plan gives the
    executor a *free* per-chunk capacity bound (uniqueCount ≤ IP per row),
    which the sync-free ``sizing="planned"`` path uses to pick ``out_cap``
    without the blocking uniqueCount host sync.

    ``group_engines`` is the per-bin engine assignment (nsparse-style
    adaptive dispatch): one registered engine name per Table-I group, or
    ``None`` for uniform dispatch under the caller's ``engine=``.
    ``group_rows`` leaves it ``None``; the executor fills it at run time
    when ``engine="auto"`` resolves an assignment (static bin-size ×
    backend heuristics refined by the ``AutotuneCache``), and callers can
    force a mixed assignment with ``dataclasses.replace(plan,
    group_engines=(...))`` — every work item of group ``g`` then runs
    ``group_engines[g]`` regardless of the call-level ``engine=``.
    """

    map_rows: np.ndarray  # (n_rows,) int32
    group_id: np.ndarray  # (n_rows,) int32 per original row
    group_offsets: np.ndarray  # (5,) int32 cumulative
    group_sizes: Tuple[int, int, int, int]
    group_sizes_padded: Tuple[int, int, int, int]
    table_capacities: Tuple[int, int, int, int]
    max_ip: int
    total_ip: int
    row_ip: np.ndarray = None  # (n_rows,) int64 Alg. 1 IP per original row
    group_engines: Tuple[str, str, str, str] = None  # per-bin engine names

    def rows_of_group(self, g: int) -> np.ndarray:
        return self.map_rows[self.group_offsets[g]: self.group_offsets[g + 1]]


def assign_groups(ip: jax.Array) -> jax.Array:
    """Group id per row (0..3) from IP, log-binned per Table I."""
    b = jnp.asarray(GROUP_BOUNDARIES)
    return jnp.searchsorted(b, ip, side="right").astype(jnp.int32)


def build_map(ip: jax.Array) -> jax.Array:
    """The paper's Map: stable argsort of rows by group id (pure JAX)."""
    return jnp.argsort(assign_groups(ip), stable=True).astype(jnp.int32)


def _pad_size(n: int, quantum: int = 64) -> int:
    if n == 0:
        return 0
    return int(np.ceil(n / quantum) * quantum)


def group_rows(a: CSR, b: CSR, pad_quantum: int = 64) -> GroupPlan:
    """Run the row-grouping phase and return the host-side schedule.

    Like the paper's implementation (which reads group sizes back to the
    host to configure kernel launches/streams), this is the one intentional
    host sync in the pipeline.
    """
    ip = np.asarray(intermediate_products(a, b))
    gid = np.searchsorted(np.asarray(GROUP_BOUNDARIES), ip, side="right").astype(np.int32)
    map_rows = np.argsort(gid, kind="stable").astype(np.int32)
    sizes = tuple(int((gid == g).sum()) for g in range(4))
    offsets = np.concatenate([[0], np.cumsum(sizes)]).astype(np.int32)
    max_ip = int(ip.max(initial=0))
    caps = []
    for g, (_, _, cap) in enumerate(TABLE_I):
        if cap is None:
            # group 3: global-memory table sized to the next pow2 ≥ max IP
            c = 1 << int(np.ceil(np.log2(max(max_ip, 2))))
            caps.append(int(c))
        else:
            caps.append(cap)
    return GroupPlan(
        map_rows=map_rows,
        group_id=gid,
        group_offsets=offsets,
        group_sizes=sizes,
        group_sizes_padded=tuple(_pad_size(s, pad_quantum) for s in sizes),
        table_capacities=tuple(caps),
        max_ip=max_ip,
        total_ip=int(ip.sum()),
        row_ip=ip.astype(np.int64),
    )


def support_footprint(indptr: np.ndarray, indices: np.ndarray,
                      rows: np.ndarray) -> np.ndarray:
    """Sorted unique column ids of A restricted to ``rows`` — i.e. the
    B-row footprint of the work items that own those rows.

    Phase 1 already walked A's structure to count intermediate products, so
    the footprint is free host arithmetic on the same arrays: every product
    of row ``r`` reads B row ``indices[slot]`` for slots in
    ``[indptr[r], indptr[r+1])``, and nothing else.  The executor's
    communication-avoiding operand placement unions these per shard to
    decide which B rows must actually travel to that shard's device.
    """
    indptr = np.asarray(indptr, np.int64)
    indices = np.asarray(indices)
    rows = np.asarray(rows, np.int64)
    if rows.size == 0:
        return np.empty(0, np.int64)
    starts = indptr[rows]
    counts = indptr[rows + 1] - starts
    total = int(counts.sum())
    if total == 0:
        return np.empty(0, np.int64)
    # flat slot ids of every (row, nnz-slot) pair without per-row Python
    offsets = np.zeros(len(counts), np.int64)
    np.cumsum(counts[:-1], out=offsets[1:])
    flat = np.repeat(starts - offsets, counts) + np.arange(total)
    return np.unique(np.asarray(indices[flat], np.int64))
