"""Pure-jnp dense oracles for the SpGEMM pipeline (test ground truth)."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.sparse.formats import CSR, csr_to_dense


def spgemm_dense(a: CSR, b: CSR) -> jnp.ndarray:
    """densify(A) @ densify(B) — the semantic ground truth for C = AB."""
    return csr_to_dense(a) @ csr_to_dense(b)


def intermediate_products_dense(a: CSR, b: CSR) -> np.ndarray:
    """Algorithm 1 ground truth via explicit loops (host numpy)."""
    indptr_a = np.asarray(a.indptr)
    indices_a = np.asarray(a.indices)
    indptr_b = np.asarray(b.indptr)
    out = np.zeros(a.n_rows, np.int64)
    for i in range(a.n_rows):
        for p in range(indptr_a[i], indptr_a[i + 1]):
            col = indices_a[p]
            out[i] += indptr_b[col + 1] - indptr_b[col]
    return out
