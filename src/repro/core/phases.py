"""Allocation + accumulation phase engines (paper Algorithms 2/3/5).

Each engine consumes one *group* of rows (from the row-grouping phase) with
static shapes: ``a_cap`` = max nnz(A-row) in the group, ``kb_cap`` = max
nnz(B-row) globally, ``table_cap`` = the group's Table-I hash capacity.

Two interchangeable engines, validated against each other and the dense
oracle:

* ``*_hash``  — faithful Algorithm 4 semantics (linear-probing table per
  row, sequential insert stream, vmapped across rows = the paper's
  PWPR/TBPR across-row parallelism).
* ``*_sort``  — the TPU-vectorized engine (Nagasaka-style sort+segment-sum);
  same results, MXU/VPU-friendly, used for large scale and inside jitted
  training graphs.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import hashtable as ht

INT_MAX = jnp.int32(2**31 - 1)


# ---------------------------------------------------------------------------
# Intermediate-product enumeration (the two-level indirection itself)
# ---------------------------------------------------------------------------

def combine_products(cols_a, vals_a, bi, bv):
    """Form intermediate products from already-gathered B rows.

    cols_a, vals_a: (R, a_cap) padded with -1 / 0 — the rows' A entries.
    bi, bv:         (R, a_cap, kb) the gathered B rows ``b_idx[cols_a]`` /
                    ``b_val[cols_a]`` (any gather backend; padding rows may
                    hold garbage — they are masked by ``cols_a < 0``).
    Returns keys (R, a_cap*kb) int32 (-1 padded) and vals (same shape).
    """
    r, a_cap = cols_a.shape
    kb = bi.shape[2]
    valid = (cols_a >= 0)[:, :, None] & (bi >= 0)
    keys = jnp.where(valid, bi, -1).reshape(r, a_cap * kb)
    vals = jnp.where(valid, vals_a[:, :, None] * bv, 0).reshape(r, a_cap * kb)
    return keys, vals


def combine_products_batched(cols_a, vals_a_b, bi, bv_b):
    """``combine_products`` over a leading batch of same-pattern values.

    The sparsity pattern (and therefore ``keys``) is shared across the
    batch; only the values carry the batch axis, so the key tensor is
    computed once and the product values broadcast over it.

    cols_a: (R, a_cap) shared structure; vals_a_b: (B, R, a_cap).
    bi: (R, a_cap, kb) shared gathered B structure; bv_b: (B, R, a_cap, kb).
    Returns keys (R, a_cap*kb) and vals (B, R, a_cap*kb).
    """
    r, a_cap = cols_a.shape
    kb = bi.shape[2]
    batch = vals_a_b.shape[0]
    valid = (cols_a >= 0)[:, :, None] & (bi >= 0)
    keys = jnp.where(valid, bi, -1).reshape(r, a_cap * kb)
    vals = jnp.where(valid[None], vals_a_b[:, :, :, None] * bv_b, 0)
    return keys, vals.reshape(batch, r, a_cap * kb)


def enumerate_products(cols_a, vals_a, b_idx, b_val):
    """Per-row intermediate products (XLA-gather variant).

    cols_a, vals_a: (R, a_cap) padded with -1 / 0 — the rows' A entries.
    b_idx, b_val:  (nB, kb_cap) ELL of B.
    Returns keys (R, a_cap*kb_cap) int32 (-1 padded) and vals (same shape).

    ``b_idx[cols_a]`` is exactly the AIA ranged indirect access
    (``rpt_B[col_A[j]]`` → row of B); here expressed as an XLA gather, in
    ``repro.kernels.aia_gather`` as a scalar-prefetch DMA stream (selected
    via the executor's ``gather=`` knob).
    """
    safe = jnp.clip(cols_a, 0, b_idx.shape[0] - 1)
    bi = b_idx[safe]  # (R, a_cap, kb)
    bv = b_val[safe]
    return combine_products(cols_a, vals_a, bi, bv)


def remap_columns(cols, remap):
    """Translate global A-column ids to block-local B-row ids.

    ``remap`` is the footprint block's (n_rows(B),) int32 map — ``-1`` for
    rows absent from the block.  Padding entries (``cols < 0``) stay ``-1``,
    and a valid column that the block does not hold also maps to ``-1``, so
    downstream masking (``combine_products``'s ``cols_a >= 0``) drops it
    instead of gathering garbage — by construction a shard's own work items
    never produce such a column, but the guarantee keeps the remapped
    gather safe under any footprint.
    """
    safe = jnp.clip(cols, 0, remap.shape[0] - 1)
    return jnp.where(cols >= 0, remap[safe], -1)


def gather_group_rows(indptr, indices, data, rows, a_cap):
    """Gather the A entries of ``rows`` (padded with -1) into (R, a_cap)."""
    n_rows = indptr.shape[0] - 1
    safe_rows = jnp.clip(rows, 0, n_rows - 1)
    starts = indptr[safe_rows]  # (R,)
    counts = indptr[safe_rows + 1] - starts
    offs = jnp.arange(a_cap, dtype=jnp.int32)[None, :]
    pos = starts[:, None] + offs
    ok = (offs < counts[:, None]) & (rows >= 0)[:, None]
    pos = jnp.where(ok, pos, 0)
    cols = jnp.where(ok, indices[pos], -1)
    vals = jnp.where(ok, data[pos], 0)
    return cols, vals


def gather_group_rows_batched(indptr, indices, data_b, rows, a_cap):
    """``gather_group_rows`` with a leading batch of value sets.

    The CSR structure (indptr/indices) is shared; ``data_b`` is (B, cap).
    Returns (cols (R, a_cap), vals (B, R, a_cap)) — one structural gather
    serving every batch member.
    """
    n_rows = indptr.shape[0] - 1
    safe_rows = jnp.clip(rows, 0, n_rows - 1)
    starts = indptr[safe_rows]  # (R,)
    counts = indptr[safe_rows + 1] - starts
    offs = jnp.arange(a_cap, dtype=jnp.int32)[None, :]
    pos = starts[:, None] + offs
    ok = (offs < counts[:, None]) & (rows >= 0)[:, None]
    pos = jnp.where(ok, pos, 0)
    cols = jnp.where(ok, indices[pos], -1)
    vals = jnp.where(ok[None], data_b[:, pos], 0)  # (B, R, a_cap)
    return cols, vals


# ---------------------------------------------------------------------------
# Fused single-pass hash accumulation (the ``fused_hash`` engine core)
# ---------------------------------------------------------------------------

def fused_hash_sorted(keys, vals, table_cap: int, out_cap: int,
                      kernel: str = "xla"):
    """Algorithms 2/3/5 in one pass: the intermediate-product stream is
    inserted straight into the per-row linear-probing table and the sorted,
    ``out_cap``-trimmed output comes back — no separate allocate pass, so
    the caller must size ``out_cap`` from an a-priori bound (the plan's
    Alg. 1 IP counts guarantee uniqueCount ≤ min(IP, n_cols) per row).

    ``kernel`` routes Algorithm 4: ``"pallas"``/``"interpret"`` use the
    Pallas TPU kernel (``kernels.hash_accum``, unsorted table + occupancy;
    column sorting stays in XLA per the paper's phase split); ``"xla"`` is
    the vmapped sequential-scan engine.  Both consume the stream in the
    same order, so results are bit-identical to the two-pass hash engine.
    """
    if kernel in ("pallas", "interpret"):
        from repro.kernels.hash_accum import hash_accumulate_sorted

        return hash_accumulate_sorted(keys, vals, table_cap, out_cap,
                                      interpret=(kernel == "interpret"))
    cols, out_vals, counts = accumulate_hash(keys, vals, table_cap)
    return cols[:, :out_cap], out_vals[:, :out_cap], counts


# ---------------------------------------------------------------------------
# Device-side CSR reassembly epilogue (inverse-permutation scatter on device)
# ---------------------------------------------------------------------------

def _scatter_pos(counts, starts, out_cap, sentinel):
    """Flat destinations of one chunk's (row, slot) cells: ``starts + offs``
    where the slot is occupied, the out-of-range ``sentinel`` (dropped by
    ``mode="drop"`` scatters) where it is not — the one masking convention
    shared by the direct and sharded, single and batched epilogues."""
    offs = jnp.arange(out_cap, dtype=jnp.int32)[None, :]
    return jnp.where(offs < counts[:, None], starts[:, None] + offs, sentinel)


def reassemble_device(idx_buf, dat_buf, cols, vals, counts, starts):
    """Scatter one chunk's accumulated rows into the final CSR buffers.

    The device-side half of CSR reassembly: each chunk's column-sorted rows
    are written to their flat CSR destinations with one vectorized scatter,
    so the output ``indices``/``data`` never round-trip through NumPy.

    idx_buf, dat_buf: (cap,) int32 / dtype — the output CSR's index and
                      value buffers (functionally updated and returned).
    cols, vals:       (R_pad, out_cap) the chunk's accumulated rows.
    counts:           (R_pad,) int32 per-row occupancy; padding rows are 0.
    starts:           (R_pad,) int32 CSR start offset of each row.

    Everything stays int32 (the CSR index convention); positions past a
    row's count are redirected to ``cap`` and dropped by the scatter, which
    also silently retires padding rows (count 0).
    """
    pos = _scatter_pos(counts, starts, cols.shape[1], idx_buf.shape[0])
    idx_buf = idx_buf.at[pos].set(cols, mode="drop")
    dat_buf = dat_buf.at[pos].set(vals, mode="drop")
    return idx_buf, dat_buf


def reassemble_device_batched(idx_buf, dat_buf_b, cols, vals_b, counts, starts):
    """``reassemble_device`` with the value scatter broadcast over a batch.

    The output structure is shared by every batch member, so the position
    tensor is computed once; ``dat_buf_b`` is (batch, cap) and ``vals_b``
    (batch, R_pad, out_cap).
    """
    pos = _scatter_pos(counts, starts, cols.shape[1], idx_buf.shape[0])
    idx_buf = idx_buf.at[pos].set(cols, mode="drop")
    dat_buf_b = dat_buf_b.at[:, pos].set(vals_b, mode="drop")
    return idx_buf, dat_buf_b


# ---------------------------------------------------------------------------
# Sharded epilogue: shard-local CSR segments + destination-mapped merge
# ---------------------------------------------------------------------------

def _exclusive_cumsum(x):
    return jnp.concatenate([jnp.zeros((1,), x.dtype), jnp.cumsum(x)[:-1]])


def reassemble_segment(seg_idx, seg_dat, dest, off, cols, vals, counts,
                       fin_starts):
    """Shard-local half of the sharded epilogue: pack one chunk's rows
    *densely* into the shard's segment buffers and record each slot's
    destination in the final CSR buffers.

    The shard device does its own reassembly scatter (in parallel with the
    other shards) and the merge device later receives one compact
    ``(segment, dest)`` pair per shard instead of every padded chunk
    output — the merge traffic that used to flow through the lead device
    per chunk stays shard-local until the final per-shard merge.

    seg_idx, seg_dat: (seg_cap,) the shard's local segment buffers.
    dest:             (seg_cap,) int32 final-buffer position per segment
                      slot; unused slots keep their init sentinel (the
                      final capacity), which the merge scatter drops.
    off:              () int32 running shard-local offset (nnz packed so
                      far); threaded through chunk after chunk.
    cols, vals:       (R_pad, out_cap) the chunk's accumulated rows.
    counts:           (R_pad,) int32 per-row occupancy (padding rows 0).
    fin_starts:       (R_pad,) int32 final CSR start offset of each row.
    """
    out_cap = cols.shape[1]
    loc_starts = off + _exclusive_cumsum(counts)
    pos = _scatter_pos(counts, loc_starts, out_cap, seg_idx.shape[0])
    offs = jnp.arange(out_cap, dtype=jnp.int32)[None, :]
    seg_idx = seg_idx.at[pos].set(cols, mode="drop")
    seg_dat = seg_dat.at[pos].set(vals, mode="drop")
    dest = dest.at[pos].set(fin_starts[:, None] + offs, mode="drop")
    return seg_idx, seg_dat, dest, off + jnp.sum(counts)


def reassemble_segment_batched(seg_idx, seg_dat_b, dest, off, cols, vals_b,
                               counts, fin_starts):
    """``reassemble_segment`` with the value packing broadcast over a
    batch: ``seg_dat_b`` is (batch, seg_cap), ``vals_b`` (batch, R_pad,
    out_cap); structure (cols/counts/positions) is shared by every
    member."""
    out_cap = cols.shape[1]
    loc_starts = off + _exclusive_cumsum(counts)
    pos = _scatter_pos(counts, loc_starts, out_cap, seg_idx.shape[0])
    offs = jnp.arange(out_cap, dtype=jnp.int32)[None, :]
    seg_idx = seg_idx.at[pos].set(cols, mode="drop")
    seg_dat_b = seg_dat_b.at[:, pos].set(vals_b, mode="drop")
    dest = dest.at[pos].set(fin_starts[:, None] + offs, mode="drop")
    return seg_idx, seg_dat_b, dest, off + jnp.sum(counts)


def merge_segments(idx_buf, dat_buf, seg_idx, seg_dat, dest):
    """Merge one shard's packed segment into the final CSR buffers: a
    single destination-mapped scatter per shard (unused segment slots
    carry the out-of-range sentinel and are dropped)."""
    idx_buf = idx_buf.at[dest].set(seg_idx, mode="drop")
    dat_buf = dat_buf.at[dest].set(seg_dat, mode="drop")
    return idx_buf, dat_buf


def merge_segments_batched(idx_buf, dat_buf_b, seg_idx, seg_dat_b, dest):
    idx_buf = idx_buf.at[dest].set(seg_idx, mode="drop")
    dat_buf_b = dat_buf_b.at[:, dest].set(seg_dat_b, mode="drop")
    return idx_buf, dat_buf_b


def merge_segments_host(idx_buf, dat_buf, seg_idx, seg_dat, dest):
    """``merge_segments`` for host-resident final buffers — the streamed
    lane's inter-tile epilogue.

    A completed tile comes back as a compact CSR segment exactly like a
    shard's packed segment, and merges the same way: one destination-mapped
    scatter into the final ``indices``/``data`` buffers (a tile is just
    another segment).  Same sentinel convention as the device merge —
    positions at/past the buffer capacity are dropped — but NumPy in-place
    on the host, where the streamed lane accumulates the out-of-core
    result.  Mutates and returns ``idx_buf``/``dat_buf``.
    """
    keep = dest < idx_buf.shape[0]
    idx_buf[dest[keep]] = seg_idx[keep]
    dat_buf[dest[keep]] = seg_dat[keep]
    return idx_buf, dat_buf


# ---------------------------------------------------------------------------
# Hash engine (Algorithm 2/3 allocation; Algorithm 5 accumulation)
# ---------------------------------------------------------------------------

def _row_alloc_hash(keys, table_cap):
    tab = ht.make_table(table_cap)
    tab = ht.insert_stream(tab, keys, jnp.zeros_like(keys, jnp.float32),
                           accumulate=False)
    return tab.count


def _row_accum_hash(keys, vals, table_cap):
    tab = ht.make_table(table_cap, vals.dtype)
    tab = ht.insert_stream(tab, keys, vals, accumulate=True)
    return ht.extract_sorted(tab)


@functools.partial(jax.jit, static_argnames=("table_cap",))
def allocate_hash(keys, table_cap: int):
    """uniqueCount per row (Algorithms 2/3 output).  keys: (R, ip_cap)."""
    return jax.vmap(lambda k: _row_alloc_hash(k, table_cap))(keys)


@functools.partial(jax.jit, static_argnames=("table_cap",))
def accumulate_hash(keys, vals, table_cap: int):
    """(cols, vals, counts) per row, column-sorted (Algorithm 5 output)."""
    return jax.vmap(lambda k, v: _row_accum_hash(k, v, table_cap))(keys, vals)


# ---------------------------------------------------------------------------
# Sort engine (vectorized; identical outputs)
# ---------------------------------------------------------------------------

def sort_unique(keys, vals, out_cap):
    """Per-batch sort + segment-sum + compaction.  keys: (R, ip_cap).

    Public API of the sort engine (used by the executor registry and the
    fully-jitted ``spgemm_ell_fixed``); returns (cols, vals, counts) with
    column-sorted rows padded to ``out_cap``.
    """
    r, ip_cap = keys.shape
    skey = jnp.where(keys >= 0, keys, INT_MAX)
    order = jnp.argsort(skey, axis=1, stable=True)
    sk = jnp.take_along_axis(skey, order, axis=1)
    sv = jnp.take_along_axis(vals, order, axis=1)
    valid = sk != INT_MAX
    is_start = jnp.concatenate(
        [jnp.ones((r, 1), bool), sk[:, 1:] != sk[:, :-1]], axis=1
    ) & valid
    ur = jnp.cumsum(is_start, axis=1) - 1  # unique rank per slot
    counts = jnp.max(jnp.where(valid, ur + 1, 0), axis=1).astype(jnp.int32)
    rows_ix = jnp.arange(r)[:, None]
    tgt = jnp.where(valid & (ur < out_cap), ur, out_cap)
    out_vals = jnp.zeros((r, out_cap + 1), vals.dtype).at[rows_ix, tgt].add(
        jnp.where(valid, sv, 0)
    )[:, :out_cap]
    start_tgt = jnp.where(is_start & (ur < out_cap), ur, out_cap)
    out_cols = jnp.full((r, out_cap + 1), -1, jnp.int32).at[rows_ix, start_tgt].set(
        jnp.where(is_start, sk, -1).astype(jnp.int32)
    )[:, :out_cap]
    return out_cols, out_vals, counts


@jax.jit
def allocate_sort(keys):
    """uniqueCount per row via sort (no value accumulation)."""
    r, ip_cap = keys.shape
    skey = jnp.where(keys >= 0, keys, INT_MAX)
    sk = jnp.sort(skey, axis=1)
    valid = sk != INT_MAX
    is_start = jnp.concatenate(
        [jnp.ones((r, 1), bool), sk[:, 1:] != sk[:, :-1]], axis=1
    ) & valid
    return jnp.sum(is_start, axis=1).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("out_cap",))
def accumulate_sort(keys, vals, out_cap: int):
    return sort_unique(keys, vals, out_cap)
