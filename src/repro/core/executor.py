"""Plan-compiled SpGEMM executor — the group pipeline behind ``spgemm()``.

The row-grouping phase (``core.grouping``) produces a ``GroupPlan``; this
module *compiles* that plan into a small number of cached, jitted per-group
programs and runs the whole allocate → accumulate → reassemble flow without
any per-row Python.  This is the OpSparse move (fuse setup/allocation into
batched device passes) combined with Nagasaka-style per-bin kernel dispatch:
each Table-I group becomes one statically-shaped program, dispatched at most
``ceil(group_size / row_chunk)`` times.

Three pluggable axes, each resolved per group:

* **engine** — the allocation/accumulation pair.  ``"hash"`` is the paper's
  Algorithm 2/3/5 linear-probing table (vmapped across rows); ``"sort"`` is
  the TPU-vectorized sort + segment-sum engine.  Both are registered in
  ``ENGINES`` behind one interface, so capacity policy and out-cap trimming
  live here instead of being duplicated in ``spgemm()``.
* **gather** — how rows of B are fetched for the two-level indirection
  ``b_ell[cols_A]``.  ``"xla"`` is a plain ``jnp`` take; ``"aia"`` routes
  through the scalar-prefetch Pallas kernels in ``kernels.aia_gather`` (the
  paper's AIA ranged indirect access), auto-selecting compiled vs interpret
  mode from the JAX backend.  ``"auto"`` picks ``"aia"`` on TPU and
  ``"xla"`` elsewhere — the paper's software-only vs AIA ablation (Fig. 7)
  is therefore a one-flag switch.
* **schedule** — ``"grouped"`` (Table-I binning) vs ``"natural"`` (one
  group, worst-case capacity; the "without AIA scheduling" baseline).

Per group-chunk the executor runs three cached programs — *enumerate*
(A-row gather → B-row gather → intermediate products; output stays on
device), *allocate* (Algorithms 2/3: uniqueCount), and *accumulate*
(Algorithm 5 on the same device-resident keys) — plus a fourth, the
*scatter* epilogue that reassembles the CSR on device.  Programs live in a
module-level cache keyed on every static quantity that shapes their trace:
``(padded_rows, a_cap, kb_cap, table_cap, out_cap, engine, gather,
dtype)``.  ``a_cap``/``kb_cap`` stay exact (their product is the sort
engine's dominant cost — rounding it up is superlinearly expensive) while
``out_cap`` and the epilogue's total-nnz capacity are pow2-quantized and
row chunks are padded to a fixed quantum, so iterative workloads (MCL
expansion, GNN layers) hit the cache instead of re-tracing;
``cache_stats()`` exposes hit/miss counters for tests and benchmarks.

**Two-wave pipelining**: the blocking point of the whole flow is the
allocate sizing — the host must learn uniqueCount before it can pick
``out_cap``.  Instead of paying that sync once per group-chunk (which
serializes multi-chunk and multi-shard runs on the host exactly where the
paper's AIA pipeline overlaps memory traffic with compute), wave 1
dispatches *every* chunk's enumerate + allocate programs across all shards
without syncing, then one coalesced ``jax.block_until_ready`` over the
stacked uniqueCounts sizes every ``out_cap`` at once; wave 2 runs
accumulate on the already-device-resident keys.  ``cache_stats()`` reports
``host_sync_count`` — exactly one per ``execute_plan`` call on this path,
and CI gates on it.  ``pipeline="legacy"`` keeps the per-chunk-sync
reference path for A/B benchmarks and equivalence tests.

**Fused single-pass engine + sync-free sizing**: the paper's hash flow
forms intermediate products and inserts them into the table in *one pass*
over A's row — ``engine="fused_hash"`` restores exactly that: one cached
program per group-chunk fusing gather → product formation → linear-probe
insertion (Pallas Algorithm-4 kernel on TPU, the vmapped scan engine
elsewhere), so the enumerate key/value stream never becomes an HBM-resident
buffer handed between programs and the allocate pass disappears entirely.
What allocate used to buy — output sizing — comes for free from phase 1:
uniqueCount ≤ min(IP, n_cols) per row, and ``GroupPlan.row_ip`` carries the
Alg. 1 counts, so ``sizing="planned"`` (the fused default) picks every
``out_cap`` and the epilogue capacity from pow2-quantized host bounds and
assembles the int32 indptr *on device* — ``host_sync_count`` stays at
**zero** for the whole call, with ``nnz`` returned as a device scalar that
blocks only at caller materialization.  (The ``spgemm()`` façade
materializes it when it builds ``info`` — but only *after* every program
in the call has been dispatched, so the host never stalls mid-pipeline
the way the measured sizing sync does; callers that want a fully
non-blocking handle use ``execute_plan`` directly.)  ``sizing="measured"`` is the
escape hatch for pathologically overlapping supports where the IP bound is
loose (it keeps the one coalesced uniqueCount sync and exact capacities);
``"auto"`` resolves to planned for fused engines and measured otherwise.

**Sharded scatter epilogue**: with more than one shard, chunk outputs no
longer stream through the lead device one padded block at a time.  Each
chunk packs densely into its shard's *local* CSR segment on the shard
device together with a destination map (``phases.reassemble_segment``, a
running-offset donated-buffer update), and the merge device applies one
destination-mapped scatter per shard (``phases.merge_segments``) — the
reassembly compute parallelizes across shards and merge traffic is
``n_shards`` compact nnz-sized transfers.  Bit-exact vs the direct
single-device epilogue: shard row sets are disjoint, so every final slot
is written by exactly one segment.

CSR reassembly is a vectorized inverse-permutation scatter.  The two-wave
path runs it as a jitted device epilogue (``phases.reassemble_device``):
flat destination offsets derive from the (host) indptr, and each chunk's
rows are scattered into pow2-quantized int32 ``indices`` / ``data``
buffers *on device* — shard outputs merge device-side and ``np``
conversion happens only when the caller materializes the CSR (nnz beyond
int32 raises instead of silently downcasting).  The legacy path keeps the
host-side NumPy scatter.

**Sharded multi-device execution** (``mesh=``): the paper's AIA scheduling
partitions SpGEMM work so each memory stack serves *local* indirection
traffic; ``execute_plan(..., mesh=...)`` applies the same idea across a
``jax.Mesh``.  The plan is split into group-chunk work items
(``partition_plan``), items are assigned round-robin *within each group* so
every shard gets a balanced mix of Table-I bins, the A/B operands are
replicated onto every shard device once per call (the all-gather analogue —
each "stack" holds the B rows its indirection touches), and each item's
enumerate/allocate/accumulate programs run shard-locally on its assigned
device.  Shard outputs merge through the same inverse-permutation
reassembly, so the result is bit-identical to the single-device path for
every engine × gather combination (per-row results never depend on which
shard computed them).  The program cache is shared across shards — one
Python-level signature entry serves every device, and jax's per-device jit
cache keeps each shard's executable warm across iterations.

**Amortization layer** (this module's third concern, after compiling and
sharding): the planning cost — Algorithm 1 IP counting plus Table-I
binning — depends only on the operands' *sparsity patterns*, and the two
headline workloads repeat patterns constantly: MCL re-multiplies the same
support for dozens of iterations once the clustering stabilizes, and GNN
mini-batch sampling produces many matrices that share one structure with
different values.  Two mechanisms exploit that:

* ``PlanCache`` — a fingerprint-keyed (``pattern_fingerprint``: blake2b of
  shape + indptr + occupied indices) map from operand sparsity patterns to
  ``GroupPlan``s.  ``spgemm(..., plan=cache)`` skips ``group_rows``
  entirely on a hit; ``plan_hits``/``plan_misses`` counters are folded
  into ``cache_stats()``.  Shard assignment is memoized the same way
  (``partition_plan`` results keyed on plan content + chunking + shard
  count), so under ``mesh=`` a reused plan also reuses its work-item
  partition.
* ``execute_plan_batched`` — runs the plan once for a whole batch of
  same-pattern operands (values differ, structure shared).  The key
  tensor, allocation sizing (the coalesced host sync), output structure,
  and reassembly offsets are computed once per chunk for the entire batch;
  only the value streams are vmapped through the cached accumulate
  programs.  Under ``mesh=`` the batch rides the same shard assignment as
  the single-matrix path, and results are bit-identical to a per-matrix
  Python loop for every engine × gather combination.
* ``OperandCache`` — B's replicated ELL buffers (conversion + per-shard
  placement) keyed on the operand's identity and the device set, shared
  across batched/iterative calls instead of re-replicated per call;
  ``operand_hits``/``operand_misses`` in ``cache_stats()``.
* ``AutotuneCache`` — ``engine="auto"``'s measured per-bin engine
  assignments, keyed like ``PlanCache`` plus backend + bin signature.
  Each unconverged call measures one candidate per non-empty Table-I bin
  (a timed bin-restricted sub-execution); converged calls serve the
  frozen assignment with zero re-measurement.
  ``autotune_hits``/``autotune_misses`` in ``cache_stats()``.
"""
from __future__ import annotations

import dataclasses
import hashlib
import os
import time
import weakref
from collections import OrderedDict
from typing import Callable, Dict, List, Literal, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import faults, phases
from repro.core.grouping import GroupPlan, group_rows, support_footprint
from repro.launch.sharding import (
    SHARDING_STATS, merge_device, place_operand_block, replicate_to,
    shard_devices, stage_tile)
from repro.sparse.formats import CSR, ELL, csr_to_ell

Gather = Literal["auto", "xla", "aia"]
Schedule = Literal["grouped", "natural"]
Pipeline = Literal["two_wave", "legacy"]
Sizing = Literal["auto", "planned", "measured"]
Operands = Literal["auto", "footprint", "replicate"]
OnBudget = Literal["error", "stream"]

# A shard whose B-row footprint covers at least this fraction of B's rows
# takes the full-replication fast path under ``operands="auto"``: the
# sub-ELL slice + remap would save little and costs an extra indirection.
FOOTPRINT_THRESHOLD = 0.7


def resolve_operands(operands: Operands) -> str:
    """Validate the ``operands=`` placement policy.

    ``"auto"`` (default) places footprint-gathered B blocks on shards whose
    footprint stays under ``FOOTPRINT_THRESHOLD`` of B's rows (full replicas
    elsewhere, and always on a single shard); ``"footprint"`` forces the
    block path on every shard; ``"replicate"`` forces the pre-footprint
    full replication (the A/B baseline the comm-volume probes diff against).
    """
    if operands not in ("auto", "footprint", "replicate"):
        raise ValueError(
            f"unknown operands policy {operands!r}; valid choices: "
            "'auto', 'footprint', 'replicate'")
    return operands


# Streaming-lane defaults (docs/streaming.md): rows per A row-block tile,
# and how many tiles may be resident on the device at once (1 = no overlap,
# 2 = classic double buffering — tile k+1's H2D transfer overlaps tile k's
# compute).
DEFAULT_TILE_ROWS = 4096
DEFAULT_PREFETCH = 2


def resolve_tile_rows(tile_rows) -> int:
    """Validate the streamed lane's ``tile_rows=`` knob (rows per tile).

    ``None`` resolves to ``DEFAULT_TILE_ROWS``.  Any positive integer is
    valid: ``tile_rows >= n_rows(A)`` simply collapses the schedule to a
    single tile (the monolithic shape), smaller values trade per-tile
    planning/launch overhead for a smaller peak device working set.
    """
    if tile_rows is None:
        return DEFAULT_TILE_ROWS
    if isinstance(tile_rows, bool) or not isinstance(tile_rows, (int, np.integer)):
        raise ValueError(
            f"tile_rows must be a positive int (or None for the default "
            f"{DEFAULT_TILE_ROWS}); got {tile_rows!r}")
    if int(tile_rows) < 1:
        raise ValueError(f"tile_rows must be >= 1; got {int(tile_rows)}")
    return int(tile_rows)


def resolve_prefetch(prefetch) -> int:
    """Validate the streamed lane's ``prefetch=`` knob (tiles in flight).

    ``prefetch`` bounds how many staged tiles may be device-resident at
    once: ``1`` disables overlap (stage, compute, merge, repeat), ``2``
    (default) double-buffers so tile *k+1*'s host→device transfer overlaps
    tile *k*'s compute, larger values deepen the pipeline at the cost of
    ``prefetch`` tiles of operand memory.
    """
    if prefetch is None:
        return DEFAULT_PREFETCH
    if isinstance(prefetch, bool) or not isinstance(prefetch, (int, np.integer)):
        raise ValueError(
            f"prefetch must be a positive int; got {prefetch!r}")
    if int(prefetch) < 1:
        raise ValueError(f"prefetch must be >= 1; got {int(prefetch)}")
    return int(prefetch)


# ---------------------------------------------------------------------------
# Device-memory budget — the streamed lane's raison d'être made testable
# ---------------------------------------------------------------------------

# Optional cap (bytes) on the estimated device working set a single
# execute_plan call may allocate.  ``None`` (default) disables the check.
_DEVICE_BUDGET = {"bytes": None}


class DeviceBudgetExceeded(RuntimeError):
    """A plan's estimated device working set exceeds ``set_device_budget``.

    Raised by ``execute_plan`` before any device allocation happens, so an
    over-memory monolithic call fails fast and cleanly; the streamed lane
    (``execute_plan_streamed``) runs the same check per *tile*, which is
    how a graph that exceeds the budget monolithically still completes —
    pick ``tile_rows`` small enough that every tile's estimate fits.
    """


def set_device_budget(nbytes: Optional[int]) -> None:
    """Set (or clear, with ``None``) the device working-set budget in bytes.

    The budget models the accelerator's memory ceiling: ``execute_plan``
    raises ``DeviceBudgetExceeded`` when ``estimated_device_bytes`` of the
    plan it was handed exceeds it.  Tests and the over-memory MCL path use
    this to make "does not fit" an observable, hardware-independent event.
    """
    _DEVICE_BUDGET["bytes"] = None if nbytes is None else int(nbytes)


def device_budget() -> Optional[int]:
    """The configured device working-set budget in bytes (None = off)."""
    return _DEVICE_BUDGET["bytes"]


def estimated_device_bytes(plan: "GroupPlan", itemsize: int) -> int:
    """Upper-bound estimate of a plan's device working set, in bytes.

    The memory model documented in docs/streaming.md: the two-wave
    pipeline keeps every chunk's enumerated key/value streams device-
    resident until wave 2 consumes them, so the peak is dominated by the
    intermediate products — ``total_ip × (4 + itemsize)`` bytes (an int32
    key plus one value per product).  Operands and the output CSR are
    deliberately excluded: they are shared across tiles (B) or bounded by
    the same IP term.  For the streamed lane the bound applies per tile,
    so it shrinks roughly linearly with ``tile_rows``.
    """
    return int(plan.total_ip) * (4 + int(itemsize))


def resolve_on_budget(on_budget: OnBudget) -> str:
    """Validate the ``on_budget=`` over-budget policy (docs/resilience.md).

    Chooses what a monolithic ``spgemm``/``mcl`` call does when
    ``estimated_device_bytes`` of its plan exceeds ``set_device_budget``:
    ``"error"`` (default, the compatible behaviour) raises
    ``DeviceBudgetExceeded``; ``"stream"`` degrades gracefully — the call
    transparently re-runs through ``spgemm_streamed`` with ``tile_rows``
    derived so every tile fits the budget, bit-identical to the
    monolithic result.  With no budget configured the knob is inert.
    """
    if on_budget not in ("error", "stream"):
        raise ValueError(
            f"unknown on_budget policy {on_budget!r}; valid choices: "
            "'error', 'stream'")
    return on_budget


def derive_degradation_tile_rows(plan: "GroupPlan", n_rows: int,
                                 itemsize: int) -> int:
    """Largest pow2 ``tile_rows`` whose worst row-block tile fits the budget.

    The ``on_budget="stream"`` degradation path needs a ``tile_rows`` such
    that *every* contiguous row-block tile's intermediate-product estimate
    (same memory model as ``estimated_device_bytes``, applied to the
    tile's rows) stays within ``set_device_budget``.  Starting from
    ``n_rows`` and halving, the first size whose worst tile fits wins —
    the fewest tiles, hence the least streaming overhead.  Raises
    ``DeviceBudgetExceeded`` when even a single row exceeds the budget
    (no tiling can help), ``ValueError`` with no budget configured.
    """
    budget = _DEVICE_BUDGET["bytes"]
    if budget is None:
        raise ValueError(
            "derive_degradation_tile_rows needs a device budget; call "
            "set_device_budget first")
    row_bytes = np.asarray(plan.row_ip, dtype=np.int64) * (4 + int(itemsize))
    if row_bytes.size != n_rows:
        raise ValueError(
            f"plan has {row_bytes.size} row_ip entries but n_rows={n_rows}")
    worst_row = int(row_bytes.max()) if row_bytes.size else 0
    if worst_row > budget:
        raise DeviceBudgetExceeded(
            f"a single row's intermediate products need ~{worst_row} device "
            f"bytes but the configured device budget is {budget}; no "
            "tile_rows can degrade this call — raise the budget")
    prefix = np.concatenate(([0], np.cumsum(row_bytes)))

    def worst_tile(t: int) -> int:
        starts = np.arange(0, n_rows, t)
        ends = np.minimum(starts + t, n_rows)
        return int((prefix[ends] - prefix[starts]).max()) if starts.size else 0

    t = max(next_pow2(max(n_rows, 1)), 1)
    while t > 1 and worst_tile(t) > budget:
        t //= 2
    return t


# Rows per program dispatch are padded to a multiple of this so repeated
# calls with slightly different group sizes reuse compiled programs.
ROW_QUANTUM = 8


def next_pow2(x: int) -> int:
    """Smallest power of two >= ``x`` (and >= 1) — the capacity quantum
    that keeps compiled-program signatures coarse enough to reuse."""
    return 1 << int(np.ceil(np.log2(max(int(x), 1))))


# ---------------------------------------------------------------------------
# Engine registry — hash and sort behind one interface
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Engine:
    """One allocation/accumulation engine (paper phases 2 + 3).

    ``allocate(keys, table_cap)`` → per-row uniqueCount (Algorithms 2/3).
    ``accumulate(keys, vals, table_cap, out_cap)`` → (cols, vals, counts)
    with rows column-sorted and trimmed/padded to ``out_cap`` (Algorithm 5).

    ``fused=True`` marks a single-pass engine: under ``sizing="planned"``
    the executor compiles one fused program per group-chunk (gather →
    product formation → table insertion, no allocate pass and no
    materialized key/value stream between programs) and sizes ``out_cap``
    from the plan's Alg. 1 IP bounds instead of a uniqueCount host sync.
    The ``allocate``/``accumulate`` pair is still required — it serves the
    ``sizing="measured"`` escape hatch and the legacy pipeline.
    """

    name: str
    allocate: Callable[[jax.Array, int], jax.Array]
    accumulate: Callable[[jax.Array, jax.Array, int, int],
                         Tuple[jax.Array, jax.Array, jax.Array]]
    fused: bool = False


ENGINES: Dict[str, Engine] = {}


def register_engine(engine: Engine) -> Engine:
    """Add an ``Engine`` to the registry (keyed by name) and return it."""
    ENGINES[engine.name] = engine
    return engine


def get_engine(name: str) -> Engine:
    """Look up a registered engine by name (ValueError when unknown)."""
    try:
        return ENGINES[name]
    except KeyError:
        raise ValueError(
            f"unknown engine {name!r}; registered: {sorted(ENGINES)}"
        ) from None


def available_engines() -> Tuple[str, ...]:
    """Sorted names of every registered engine (the ``engine=`` choices
    besides ``"auto"``)."""
    return tuple(sorted(ENGINES))


AUTO_ENGINE = "auto"


def resolve_engine(engine: Optional[str] = None,
                   method: Optional[str] = None) -> str:
    """Validate an ``engine=`` value everywhere it is threaded.

    Accepts any registered engine name plus ``"auto"`` (per-bin adaptive
    dispatch: the executor resolves one engine per Table-I group from the
    static heuristics + the ``AutotuneCache``).  ``method`` is the legacy
    alias kept by the ``spgemm`` façade; ``None`` falls back to
    ``method or "sort"``.  A typo raises immediately with the full list of
    valid choices instead of surfacing as a deep ``get_engine`` failure.
    """
    if engine is None:
        engine = method or "sort"
    elif method is not None and method != engine:
        raise ValueError(
            f"conflicting method={method!r} (legacy alias) and "
            f"engine={engine!r}")
    if engine != AUTO_ENGINE and engine not in ENGINES:
        raise ValueError(
            f"unknown engine {engine!r}; valid choices: "
            f"{', '.join(sorted(ENGINES))}, or 'auto' (per-bin adaptive "
            "dispatch)")
    return engine


def static_bin_engines(backend: Optional[str] = None) -> Tuple[str, ...]:
    """Static bin-size × backend seed for ``engine="auto"``.

    The CI baseline says the vectorized sort engine dominates on CPU
    (selfprod: sort 67 ms vs hash 500 ms / fused_hash 297 ms) while the
    fused single-pass Pallas lane is the TPU winner, so the seed is
    per-backend: every Table-I bin starts on ``"sort"`` off-TPU and on
    ``"fused_hash"`` on TPU.  This is only the *starting point* — the
    ``AutotuneCache`` measures each bin's candidates on the live pattern
    and converges to the measured per-bin optimum (nsparse-style adaptive
    accumulator selection, arXiv:1804.01698).
    """
    if backend is None:
        backend = jax.default_backend()
    name = "fused_hash" if backend == "tpu" else "sort"
    return (name, name, name, name)


def _hash_accumulate(keys, vals, table_cap: int, out_cap: int):
    cols, out_vals, counts = phases.accumulate_hash(keys, vals, table_cap)
    # The table must hold up to ``table_cap`` probes, but uniqueCount never
    # exceeds ``out_cap`` (≥ n_cols bound); trim to the sorted prefix.
    return cols[:, :out_cap], out_vals[:, :out_cap], counts


def _sort_accumulate(keys, vals, table_cap: int, out_cap: int):
    return phases.accumulate_sort(keys, vals, out_cap)


register_engine(Engine("hash", phases.allocate_hash, _hash_accumulate))
register_engine(Engine("sort", lambda k, cap: phases.allocate_sort(k),
                       _sort_accumulate))
# The paper's Alg. 2/3/5 as ONE pass over A's row (the multi-phase flow the
# hash table exists for): gather → product formation → linear-probe insert
# fused into a single cached program per group-chunk.  The allocate/
# accumulate pair below only serves sizing="measured" and pipeline="legacy";
# the planned path never runs them.
register_engine(Engine("fused_hash", phases.allocate_hash, _hash_accumulate,
                       fused=True))


# ---------------------------------------------------------------------------
# Gather backends — how b_ell[cols_A] is served
# ---------------------------------------------------------------------------

def resolve_gather(gather: Gather) -> str:
    """``"auto"`` → AIA kernels on TPU, XLA take elsewhere (Fig. 7 switch).

    Honors the ``REPRO_KERNEL_BACKEND`` override with the same semantics as
    ``kernels.ops.resolve_backend``: ``xla`` forces the software-only take,
    ``pallas``/``interpret`` force the AIA kernels.
    """
    if gather == "auto":
        env = os.environ.get("REPRO_KERNEL_BACKEND")
        if env == "xla":
            return "xla"
        if env in ("pallas", "interpret"):
            return "aia"
        return "aia" if jax.default_backend() == "tpu" else "xla"
    if gather not in ("xla", "aia"):
        raise ValueError(f"unknown gather backend {gather!r}")
    return gather


def _gather_b_xla(b_idx, b_val, cols_a):
    safe = jnp.clip(cols_a, 0, b_idx.shape[0] - 1)
    return b_idx[safe], b_val[safe]


def _gather_b_aia(b_idx, b_val, cols_a):
    """B-row gather as the paper's AIA stream (scalar-prefetch DMA kernel).

    ``cols_a`` rows are flattened into one bulk index stream, gathered
    near-memory, and reshaped back; the interpret/compiled choice follows
    the JAX backend inside the kernel.
    """
    from repro.kernels.aia_gather import gather_rows_any

    r, a_cap = cols_a.shape
    kb = b_idx.shape[1]
    flat = cols_a.reshape(-1)
    bi = gather_rows_any(b_idx, flat)
    bv = gather_rows_any(b_val, flat)
    return bi.reshape(r, a_cap, kb), bv.reshape(r, a_cap, kb)


GATHERS: Dict[str, Callable] = {"xla": _gather_b_xla, "aia": _gather_b_aia}


def _gather_b_xla_batched(b_idx, b_val_b, cols_a):
    """Batched-value variant: one structural gather, values broadcast."""
    safe = jnp.clip(cols_a, 0, b_idx.shape[0] - 1)
    return b_idx[safe], b_val_b[:, safe]  # (R,a_cap,kb), (B,R,a_cap,kb)


def _gather_b_aia_batched(b_idx, b_val_b, cols_a):
    """Batched AIA gather: the batch axis folds into the row payload, so a
    single widened DMA stream serves every batch member's B rows — the same
    index stream, amortized (the near-memory analogue of reading one wider
    row instead of B narrow ones)."""
    from repro.kernels.aia_gather import gather_rows_any

    r, a_cap = cols_a.shape
    nb, kb = b_idx.shape
    batch = b_val_b.shape[0]
    flat = cols_a.reshape(-1)
    bi = gather_rows_any(b_idx, flat).reshape(r, a_cap, kb)
    folded = jnp.transpose(b_val_b, (1, 0, 2)).reshape(nb, batch * kb)
    bv = gather_rows_any(folded, flat).reshape(r, a_cap, batch, kb)
    return bi, jnp.transpose(bv, (2, 0, 1, 3))


BATCHED_GATHERS: Dict[str, Callable] = {
    "xla": _gather_b_xla_batched, "aia": _gather_b_aia_batched,
}


# ---------------------------------------------------------------------------
# Output sizing — measured (uniqueCount sync) vs planned (Alg. 1 bounds)
# ---------------------------------------------------------------------------

def _engines_in_use(engine: str, plan=None,
                    group_engines: Optional[Sequence[str]] = None
                    ) -> Tuple[str, ...]:
    """The engine names a call will actually dispatch: the per-bin
    assignment restricted to non-empty groups when one is set, else the
    uniform ``engine=``."""
    if group_engines is None:
        return (engine,)
    sizes = getattr(plan, "group_sizes", None)
    used = tuple(e for g, e in enumerate(group_engines)
                 if sizes is None or sizes[g] > 0)
    return used or (group_engines[0],)


def resolve_sizing(sizing: Sizing, engine: str, plan=None,
                   group_engines: Optional[Sequence[str]] = None) -> str:
    """``"auto"`` → ``"planned"`` for fused engines, ``"measured"``
    otherwise.

    Planned sizing derives every chunk's ``out_cap`` and the epilogue
    capacity from the plan's per-row Alg. 1 IP counts (uniqueCount ≤
    min(IP, n_cols) per row — a bound phase 1 already paid for), so the
    two-wave pipeline dispatches end-to-end with **zero** blocking host
    syncs.  ``"measured"`` is the escape hatch for pathological overlap
    (many duplicate columns per row make the IP bound loose, inflating
    ``out_cap`` and the output buffers): it keeps the single coalesced
    uniqueCount sync and exact capacities.

    With a per-bin assignment (``engine="auto"`` or
    ``plan.group_engines``), the rule applies to every engine the call
    will actually dispatch: planned only when **all** non-empty bins
    resolved to fused engines, measured as soon as any bin picked a
    non-fused one (that bin needs the uniqueCount sync anyway, and the
    coalesced sync sizes every chunk at once).
    """
    if sizing not in ("auto", "planned", "measured"):
        raise ValueError(f"unknown sizing {sizing!r}")
    if sizing == "auto":
        engines = _engines_in_use(engine, plan, group_engines)
        all_fused = all(get_engine(e).fused for e in engines)
        return "planned" if (all_fused
                             and getattr(plan, "row_ip", None) is not None) \
            else "measured"
    if sizing == "planned" and plan is not None \
            and getattr(plan, "row_ip", None) is None:
        raise ValueError(
            "sizing='planned' needs a plan carrying Alg. 1 row IP counts "
            "(GroupPlan.row_ip); re-plan with core.grouping.group_rows")
    return sizing


def chunk_capacity_bounds(plan: GroupPlan, rows: np.ndarray,
                          n_cols: int) -> Tuple[int, int]:
    """(max-unique, total-unique) bounds for one chunk of rows.

    uniqueCount of row r is at most ``min(IP[r], n_cols(B))`` — every
    intermediate product lands on one output column, and there are only
    ``n_cols`` distinct columns.  Both bounds are exact host arithmetic on
    the plan's Alg. 1 counts: no device work, no sync.
    """
    ip = np.asarray(plan.row_ip)[rows].astype(np.int64)
    unique = np.minimum(ip, int(n_cols))
    return int(unique.max(initial=0)), int(unique.sum())


def _planned_out_cap(max_unique: int, table_cap: int, ncol_cap: int) -> int:
    """pow2-quantized chunk output capacity from the plan-derived bound —
    the sync-free mirror of ``_out_cap_from_counts``."""
    return max(min(next_pow2(max(max_unique, 1)), max(table_cap, 1),
                   ncol_cap), 1)


def _fused_kernel_mode(dt: str) -> str:
    """Algorithm-4 routing inside the fused program: the Pallas kernel
    (compiled on TPU, interpret under ``REPRO_KERNEL_BACKEND=interpret``)
    for float32 streams, the vmapped scan engine everywhere else (the
    kernel's value plane is float32-only)."""
    if dt != np.dtype(np.float32).str:
        return "xla"
    from repro.kernels.ops import resolve_backend

    be = resolve_backend("auto")
    return be if be in ("pallas", "interpret") else "xla"


# ---------------------------------------------------------------------------
# Program cache — one jitted program per static-shape signature
# ---------------------------------------------------------------------------

_PROGRAM_CACHE: Dict[tuple, Callable] = {}
_CACHE_STATS = {"hits": 0, "misses": 0}
_PLAN_STATS = {"plan_hits": 0, "plan_misses": 0}
# One increment per *blocking* host synchronization.  The two-wave pipeline
# pays exactly one per execute_plan call (the coalesced allocate sync); the
# legacy pipeline pays one per group-chunk.  CI gates on this.
_SYNC_STATS = {"host_sync_count": 0}
# OperandCache lookups: a hit means the B-side placed ELL buffers were
# served without any re-placement (zero device transfers).  The comm-volume
# counters accumulate at *placement* time (cache misses only):
# ``operand_bytes_placed`` — bytes of B-side buffers (indices + values +
# remap) actually shipped to shard devices; ``operand_rows_footprint`` —
# B rows placed, summed over shards; ``operand_rows_total`` — what full
# replication would have placed (n_shards × n_rows(B)).  CI diffs a
# replicated run against a footprint run and gates on the saving.
_OPERAND_STATS = {"operand_hits": 0, "operand_misses": 0,
                  "operand_bytes_placed": 0, "operand_rows_footprint": 0,
                  "operand_rows_total": 0}
# AutotuneCache lookups for engine="auto": a hit serves a fully-measured
# per-bin assignment with zero re-measurement; a miss covers both the first
# sighting of a (pattern, backend, bin-signature) key and every incremental
# measurement round until the per-bin candidates are exhausted.
_AUTOTUNE_STATS = {"autotune_hits": 0, "autotune_misses": 0}
# Streamed (out-of-core) lane: ``tiles_streamed`` counts row-block tiles
# dispatched through the tile scheduler; ``tile_bytes_h2d`` accumulates the
# bytes of tile operand arrays (indptr + indices + data) staged host→device;
# ``prefetch_overlap_hits`` counts tiles whose staging was issued while an
# earlier tile's compute was still in flight — i.e. transfers the double
# buffering actually overlapped with compute (0 whenever ``prefetch=1``).
_STREAM_STATS = {"tiles_streamed": 0, "tile_bytes_h2d": 0,
                 "prefetch_overlap_hits": 0}
# Resilience layer (docs/resilience.md): ``capacity_retries`` counts
# planned/fused chunks whose device-side overflow flag tripped and were
# re-executed once at measured capacity; ``budget_degradations`` counts
# monolithic calls that ``on_budget="stream"`` transparently re-routed
# through the streamed lane.  Both are 0 on every clean path — any nonzero
# value is a recovery event worth surfacing.  ``sharding_fallbacks`` (owned
# by launch.sharding to avoid a circular import) counts constrain() calls
# that degraded to unconstrained placement outside a mesh context.
_RESILIENCE_STATS = {"capacity_retries": 0, "budget_degradations": 0}


def cache_stats() -> Dict[str, int]:
    """Global executor counters, one flat dict.  Every field:

    * ``hits`` / ``misses`` — jitted-program cache lookups: a hit reuses a
      compiled enumerate/allocate/accumulate/fused/scatter program, a miss
      traces and compiles a new one.
    * ``plan_hits`` / ``plan_misses`` — ``PlanCache`` lookups (every
      instance folds into these): a hit skips Alg. 1 + Table-I binning.
    * ``host_sync_count`` — blocking host synchronizations paid inside the
      pipeline: exactly one per measured two-wave call, zero per
      planned/fused call, one per chunk on ``pipeline="legacy"``.
    * ``operand_hits`` / ``operand_misses`` — B-side placement cache
      lookups (every ``OperandCache`` instance folds into these): a hit
      serves the placed ELL buffers with zero conversions or transfers.
    * ``operand_bytes_placed`` — bytes of B-side buffers (indices + values
      + remap) actually shipped to shard devices, accumulated at placement
      (miss) time.
    * ``operand_rows_footprint`` / ``operand_rows_total`` — B rows placed
      (summed over shards) vs what full replication would have placed
      (``n_shards × n_rows(B)``); their ratio is the comm saving.
    * ``autotune_hits`` / ``autotune_misses`` — ``engine="auto"`` lookups:
      a hit serves a converged per-bin assignment with zero
      re-measurement, a miss covers every round that still measured.
    * ``tiles_streamed`` — row-block tiles dispatched by the streamed
      (out-of-core) lane's tile scheduler.
    * ``tile_bytes_h2d`` — bytes of streamed tile operands (indptr +
      indices + data) staged host→device.
    * ``prefetch_overlap_hits`` — streamed tiles whose staging was issued
      while an earlier tile's compute was still in flight (the double
      buffering actually overlapped; 0 under ``prefetch=1``).
    * ``capacity_retries`` — planned/fused chunks whose device-side
      overflow flag tripped and were re-executed once at measured
      capacity (0 on every clean path; see docs/resilience.md).
    * ``budget_degradations`` — monolithic calls ``on_budget="stream"``
      transparently re-routed through the streamed lane because their
      estimate exceeded the device budget.
    * ``sharding_fallbacks`` — ``constrain()`` calls that degraded to
      unconstrained placement because no mesh context was active.
    """
    return {**_CACHE_STATS, **_PLAN_STATS, **_SYNC_STATS, **_OPERAND_STATS,
            **_AUTOTUNE_STATS, **_STREAM_STATS, **_RESILIENCE_STATS,
            **SHARDING_STATS}


def clear_program_cache() -> None:
    """Drop every executor-level cache and zero the ``cache_stats()``
    counters (tests and benchmarks use this to isolate measurements)."""
    _PROGRAM_CACHE.clear()
    _PARTITION_CACHE.clear()
    _FOOTPRINT_CACHE.clear()
    _OPERAND_CACHE.clear()
    _AUTOTUNE_CACHE.clear()
    _CACHE_STATS["hits"] = 0
    _CACHE_STATS["misses"] = 0
    _PLAN_STATS["plan_hits"] = 0
    _PLAN_STATS["plan_misses"] = 0
    _SYNC_STATS["host_sync_count"] = 0
    for k in _OPERAND_STATS:
        _OPERAND_STATS[k] = 0
    _AUTOTUNE_STATS["autotune_hits"] = 0
    _AUTOTUNE_STATS["autotune_misses"] = 0
    for k in _STREAM_STATS:
        _STREAM_STATS[k] = 0
    for k in _RESILIENCE_STATS:
        _RESILIENCE_STATS[k] = 0
    for k in SHARDING_STATS:
        SHARDING_STATS[k] = 0


def _coalesced_sync(arrays: Sequence[jax.Array]) -> List[np.ndarray]:
    """The pipeline's single blocking host sync: every pending device
    computation was already dispatched, so one ``block_until_ready`` over
    the whole list drains them together instead of serializing per chunk."""
    _SYNC_STATS["host_sync_count"] += 1
    arrays = jax.block_until_ready(list(arrays))
    return [np.asarray(x) for x in arrays]


# ---------------------------------------------------------------------------
# Plan cache — amortize Alg. 1 + Table-I binning across same-pattern calls
# ---------------------------------------------------------------------------

def pattern_fingerprint(*mats) -> str:
    """Sparsity-pattern fingerprint of CSR operands: blake2b over shape,
    indptr, and the *occupied* slots of indices.

    Values and capacity padding are deliberately excluded — two matrices
    with the same support but different values (an MCL iteration at
    fixpoint, one mini-batch value set vs another) fingerprint identically,
    while mutating a single column index (same nnz, different support)
    changes the digest.
    """
    h = hashlib.blake2b(digest_size=16)
    for m in mats:
        indptr = np.asarray(m.indptr)
        indices = np.asarray(m.indices)
        nnz = int(indptr[-1])
        h.update(np.asarray(m.shape, np.int64).tobytes())
        h.update(indptr.tobytes())
        h.update(indices[:nnz].tobytes())
    return h.hexdigest()


class PlanCache:
    """Fingerprint-keyed ``GroupPlan`` cache (LRU, bounded).

    ``plan_for(a, b)`` returns the cached plan when the operands' sparsity
    patterns were seen before and runs ``group_rows`` otherwise — the
    OpSparse-style setup-cost amortization for iterative (MCL) and batched
    (GNN sampling) workloads.  Hits/misses are tracked per instance *and*
    folded into the module-level ``cache_stats()`` counters.
    """

    def __init__(self, max_entries: int = 64):
        self.max_entries = max_entries
        self._entries: "OrderedDict[str, GroupPlan]" = OrderedDict()
        self.hits = 0
        self.misses = 0

    def __len__(self) -> int:
        """Number of cached plans currently held (bounded by
        ``max_entries``)."""
        return len(self._entries)

    def plan_for(self, a: "CSR", b: "CSR",
                 supplier: Optional[Callable[[], GroupPlan]] = None
                 ) -> GroupPlan:
        """Serve (hit) or build (miss) the plan for ``(a, b)``'s pattern.

        ``supplier`` overrides how a miss is filled: instead of running
        ``group_rows``, the cache stores whatever the callable returns.
        This is the multi-tenant scoping hook — when one coalesced dispatch
        spans several tenants' caches, the first cache computes the plan
        and the others *account* the same plan against their own quota
        without re-planning (``serve.spgemm_service`` uses exactly this).
        A supplier-filled miss still counts as a miss.
        """
        key = pattern_fingerprint(a, b)
        plan = self._entries.get(key)
        if plan is None:
            self.misses += 1
            _PLAN_STATS["plan_misses"] += 1
            plan = group_rows(a, b) if supplier is None else supplier()
            self._entries[key] = plan
            while len(self._entries) > self.max_entries:
                self._entries.popitem(last=False)
        else:
            self.hits += 1
            _PLAN_STATS["plan_hits"] += 1
            self._entries.move_to_end(key)
        return plan

    def stats(self) -> Dict[str, int]:
        """Per-instance counters: ``hits`` (pattern seen before, planning
        skipped), ``misses`` (``group_rows`` ran — or a ``supplier`` filled
        the slot), and ``entries`` (current cache occupancy)."""
        return {"hits": self.hits, "misses": self.misses,
                "entries": len(self._entries)}


# ---------------------------------------------------------------------------
# Operand cache — B-side replicated ELL buffers shared across calls
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class _OperandEntry:
    """Cached B operands: the ELL conversion plus its per-shard placements.

    ``source`` pins the origin CSR arrays so their ``id()``s (the cache key)
    cannot be recycled while the entry is alive — jax arrays are immutable,
    so identical ids imply identical contents.

    Each shard holds ``(b_idx, b_val, remap)``: the full replicated ELL with
    ``remap=None``, or a footprint-gathered sub-ELL (only the B rows the
    shard's work items touch) with the global→local row ``remap`` the
    executor threads into that shard's gather programs.  ``footprints``
    keeps the per-shard row selections (``None`` = full replica) so the
    batched lane can slice fresh per-member value planes the same way.
    """

    source: tuple
    b_ell: ELL
    shards: List[tuple]  # per-device (b_idx, b_val, remap-or-None)
    footprints: Optional[List[Optional[np.ndarray]]] = None


def _footprint_fingerprint(footprints) -> Optional[str]:
    """Content digest of a per-shard footprint selection (``None`` = full
    replication everywhere) — the OperandCache key component that keeps
    blocks built for one work partition from serving another."""
    if footprints is None:
        return None
    h = hashlib.blake2b(digest_size=8)
    for fp in footprints:
        if fp is None:
            h.update(b"\xff")
        else:
            fp = np.asarray(fp, np.int64)
            h.update(np.int64(fp.size).tobytes())
            h.update(fp.tobytes())
    return h.hexdigest()


class OperandCache:
    """(B identity, kb_cap, devices, footprint)-keyed cache of placed ELL
    buffers.

    Iterative (MCL with a fixed B, the sampling chain's shared adjacency)
    and batched workloads re-multiply against the *same* B object call after
    call; previously every call re-ran ``csr_to_ell`` and re-placed the
    result onto every shard device.  A hit serves both from the cache —
    zero conversions, zero device transfers.  Lookups fold into the
    module-level ``cache_stats()`` as ``operand_hits``/``operand_misses``,
    and every *build* accumulates the comm-volume counters
    (``operand_bytes_placed``/``operand_rows_footprint``/
    ``operand_rows_total``) — placement cost is paid exactly where it is
    counted.

    ``footprints`` (per-shard B-row selections from the plan's A-support,
    ``None`` entries = full replica) switches a shard from replication to a
    footprint-gathered block: only the selected ELL rows travel to the
    device, plus the global→local ``remap``.  The key carries a content
    fingerprint of the selection, so the same B served under two partitions
    (different meshes, row_chunks) gets distinct block sets.

    Identity keying is only sound for immutable arrays, so CSRs backed by
    mutable buffers (plain NumPy arrays) are *never cached* — they take the
    uncached build path every call, exactly the pre-cache behavior (an
    in-place edit of a NumPy-backed B must be honored, not served stale).
    """

    def __init__(self, max_entries: int = 8):
        self.max_entries = max_entries
        self._entries: "OrderedDict[tuple, _OperandEntry]" = OrderedDict()

    def __len__(self) -> int:
        return len(self._entries)

    def clear(self) -> None:
        """Drop every cached placement (does not touch the counters)."""
        self._entries.clear()

    @staticmethod
    def _build(b: CSR, kb_cap: int, devices,
               footprints=None) -> _OperandEntry:
        b_ell = csr_to_ell(b, kb_cap)
        n_rows = int(b_ell.indices.shape[0])
        shards = []
        for s, dev in enumerate(devices):
            fp = None if footprints is None else footprints[s]
            if fp is None:
                shard = (replicate_to(b_ell.indices, dev),
                         replicate_to(b_ell.data, dev), None)
                rows_placed = n_rows
            else:
                shard = place_operand_block(b_ell.indices, b_ell.data,
                                            fp, dev)
                rows_placed = len(fp)
            _OPERAND_STATS["operand_bytes_placed"] += sum(
                int(x.nbytes) for x in shard if x is not None)
            _OPERAND_STATS["operand_rows_footprint"] += rows_placed
            _OPERAND_STATS["operand_rows_total"] += n_rows
            shards.append(shard)
        return _OperandEntry(
            source=(b.indptr, b.indices, b.data),
            b_ell=b_ell,
            shards=shards,
            footprints=None if footprints is None else list(footprints),
        )

    def b_operands(self, b: CSR, kb_cap: int, devices,
                   footprints=None) -> _OperandEntry:
        """Serve (hit) or build+place (miss) B's per-shard operand entry.

        The key is the identity of B's buffers + ``kb_cap`` + the device
        set + the footprint fingerprint; NumPy-backed CSRs are never
        cached (mutable buffers can be edited in place)."""
        if not all(isinstance(x, jax.Array)
                   for x in (b.indptr, b.indices, b.data)):
            _OPERAND_STATS["operand_misses"] += 1
            return self._build(b, kb_cap, devices,
                               footprints)  # mutable: never cache
        key = (
            id(b.indptr), id(b.indices), id(b.data), int(kb_cap),
            tuple(getattr(d, "id", None) for d in devices),
            _footprint_fingerprint(footprints),
        )
        entry = self._entries.get(key)
        if entry is None:
            _OPERAND_STATS["operand_misses"] += 1
            entry = self._build(b, kb_cap, devices, footprints)
            self._entries[key] = entry
            while len(self._entries) > self.max_entries:
                self._entries.popitem(last=False)
        else:
            _OPERAND_STATS["operand_hits"] += 1
            self._entries.move_to_end(key)
        return entry


_OPERAND_CACHE = OperandCache()


# ---------------------------------------------------------------------------
# Autotune cache — measured per-bin engine assignment for engine="auto"
# ---------------------------------------------------------------------------

def autotune_key(a: "CSR", b: "CSR", plan: GroupPlan) -> tuple:
    """AutotuneCache key: the operands' sparsity-pattern fingerprint (the
    ``PlanCache`` key), the JAX backend (the winning engine is
    backend-dependent — sort on CPU, the fused Pallas lane on TPU), and
    the plan's bin signature (group sizes + table capacities: a different
    binning of the same pattern, e.g. ``ungrouped_plan``, re-measures)."""
    return (pattern_fingerprint(a, b), jax.default_backend(),
            tuple(plan.group_sizes), tuple(plan.table_capacities))


@dataclasses.dataclass
class _AutotuneEntry:
    """Measured per-bin state for one (pattern, backend, bins) key.

    ``pending`` holds each non-empty group's not-yet-measured candidate
    engines (seed heuristic first); ``timings`` the measured µs per
    (group, engine); ``assignment`` the current per-group pick — the
    measured argmin where timings exist, the static seed elsewhere."""

    seed: Tuple[str, ...]
    pending: Dict[int, List[str]]
    timings: Dict[int, Dict[str, float]]
    assignment: Tuple[str, ...]

    @property
    def converged(self) -> bool:
        return not any(self.pending.values())

    def _recompute(self) -> None:
        picks = []
        for g in range(4):
            t = self.timings.get(g)
            picks.append(min(t, key=t.get) if t else self.seed[g])
        self.assignment = tuple(picks)


class AutotuneCache:
    """LRU cache of measured per-bin engine assignments (``engine="auto"``).

    Keyed like ``PlanCache`` (``autotune_key``: pattern fingerprint +
    backend + bin signature).  The first sighting of a key seeds every
    non-empty Table-I group with the static bin-size × backend heuristic
    and queues the remaining registered engines as measurement candidates;
    each subsequent ``engine="auto"`` call measures **one** candidate per
    bin (a timed bin-restricted sub-execution) until the queue drains, so
    iterative workloads (MCL expansion, GNN epochs through
    ``reuse_plan=True``) converge to the measured per-bin optimum within a
    run — after which every call is a pure hit serving the frozen
    assignment with zero re-measurement.  Lookups fold into
    ``cache_stats()`` as ``autotune_hits``/``autotune_misses`` (a miss is
    any round that still measured; a hit is a converged serve).
    """

    def __init__(self, max_entries: int = 64,
                 candidates: Optional[Sequence[str]] = None):
        self.max_entries = max_entries
        self.candidates = tuple(candidates) if candidates else None
        self._entries: "OrderedDict[tuple, _AutotuneEntry]" = OrderedDict()
        self.hits = 0
        self.misses = 0

    def __len__(self) -> int:
        return len(self._entries)

    def clear(self) -> None:
        """Drop every cached assignment (does not touch the counters)."""
        self._entries.clear()

    def _candidate_order(self, seed_engine: str) -> List[str]:
        cands = self.candidates or available_engines()
        return [seed_engine] + [e for e in sorted(cands) if e != seed_engine]

    def _entry_for(self, key: tuple, plan: GroupPlan) -> _AutotuneEntry:
        entry = self._entries.get(key)
        if entry is None:
            seed = static_bin_engines()
            entry = _AutotuneEntry(
                seed=seed,
                pending={g: self._candidate_order(seed[g])
                         for g in range(4) if plan.group_sizes[g] > 0},
                timings={},
                assignment=seed,
            )
            self._entries[key] = entry
            while len(self._entries) > self.max_entries:
                self._entries.popitem(last=False)
        else:
            self._entries.move_to_end(key)
        return entry

    def converged(self, key: tuple) -> bool:
        """True when ``key``'s per-bin assignment has no candidates left
        to measure (every further lookup is a pure hit)."""
        entry = self._entries.get(key)
        return entry is not None and entry.converged

    def assignment_for(self, key: tuple, plan: GroupPlan,
                       measure: Callable[[int, str], float]
                       ) -> Tuple[str, ...]:
        """Serve (hit) or refine (miss + one measurement round) the
        per-bin assignment for ``key``.  ``measure(group, engine)``
        returns the measured wall time in µs; it is only called while
        candidates remain."""
        entry = self._entry_for(key, plan)
        if entry.converged:
            self.hits += 1
            _AUTOTUNE_STATS["autotune_hits"] += 1
            return entry.assignment
        self.misses += 1
        _AUTOTUNE_STATS["autotune_misses"] += 1
        for g, cands in entry.pending.items():
            if cands:
                eng = cands.pop(0)
                entry.timings.setdefault(g, {})[eng] = float(measure(g, eng))
        entry._recompute()
        return entry.assignment

    def record(self, key: tuple, plan: GroupPlan, group: int, engine: str,
               us: float) -> None:
        """Fold one externally-measured timing in (the offline measurement
        loop, ``benchmarks.hillclimb.measure_bin_engines``).  Recording
        every candidate of every non-empty bin converges the entry exactly
        as the incremental in-band rounds would."""
        entry = self._entry_for(key, plan)
        pend = entry.pending.get(group)
        if pend is not None and engine in pend:
            pend.remove(engine)
        entry.timings.setdefault(group, {})[engine] = float(us)
        entry._recompute()

    def stats(self) -> Dict[str, int]:
        """Per-instance counters: ``hits`` / ``misses`` / ``entries``."""
        return {"hits": self.hits, "misses": self.misses,
                "entries": len(self._entries)}

    def summary(self) -> List[Dict]:
        """JSON-friendly view of every entry (bench meta / debugging):
        bin signature, measured timings, and the chosen assignment."""
        return [
            {
                "backend": key[1],
                "group_sizes": list(key[2]),
                "assignment": list(e.assignment),
                "converged": e.converged,
                "timings_us": {str(g): dict(t)
                               for g, t in sorted(e.timings.items())},
            }
            for key, e in self._entries.items()
        ]


_AUTOTUNE_CACHE = AutotuneCache()


def default_autotune_cache() -> AutotuneCache:
    """The module-level cache ``engine="auto"`` uses when no explicit
    ``autotune=`` cache is passed (cleared by ``clear_program_cache``)."""
    return _AUTOTUNE_CACHE


def bin_subplan(plan: GroupPlan, group: int) -> GroupPlan:
    """A plan restricted to one Table-I group (every other bin empty).

    The measurement loop times engines on *one bin at a time*; executing a
    bin-restricted plan runs exactly that bin's chunks through the full
    pipeline (rows outside the bin come back empty), so the measured wall
    time isolates the bin's allocate/accumulate cost under each candidate.
    """
    rows = np.asarray(plan.rows_of_group(group), np.int32)
    sizes = [0, 0, 0, 0]
    sizes[group] = len(rows)
    offsets = np.concatenate([[0], np.cumsum(sizes)]).astype(np.int32)
    return GroupPlan(
        map_rows=rows,
        group_id=plan.group_id,
        group_offsets=offsets,
        group_sizes=tuple(sizes),
        group_sizes_padded=tuple(sizes),
        table_capacities=plan.table_capacities,
        max_ip=plan.max_ip,
        total_ip=plan.total_ip,
        row_ip=plan.row_ip,
    )


def measure_group_engine(
    a: "CSR",
    b: "CSR",
    plan: GroupPlan,
    group: int,
    engine: str,
    gather: Gather = "auto",
    row_chunk: int = 4096,
    mesh=None,
    pipeline: Pipeline = "two_wave",
    reps: int = 2,
    warmup: int = 1,
    timer: Callable[[], float] = None,
) -> float:
    """Measured wall time (µs) of one Table-I bin under one engine.

    Runs ``execute_plan`` on the bin-restricted subplan (``bin_subplan``)
    with a *concrete* engine — never ``"auto"``, so measurement cannot
    recurse — ``warmup`` untimed passes first (compilation must not land
    inside the timed region), then the min over ``reps`` timed passes
    (the noise-robust statistic the bench drivers use).  ``timer`` is
    injectable for tests; measurement passes pay their own host syncs, so
    only converged ``engine="auto"`` calls are bound by the two-wave sync
    budget.
    """
    timer = timer or time.perf_counter
    get_engine(engine)  # concrete engines only
    sub = bin_subplan(plan, group)

    def run():
        c, _ = execute_plan(a, b, sub, engine=engine, gather=gather,
                            row_chunk=row_chunk, mesh=mesh,
                            pipeline=pipeline)
        jax.block_until_ready((c.indptr, c.indices, c.data))

    for _ in range(warmup):
        run()
    best = float("inf")
    for _ in range(reps):
        t0 = timer()
        run()
        best = min(best, timer() - t0)
    return best * 1e6


def _autotune_assignment(a, b, plan, gather, row_chunk, mesh, pipeline,
                         cache: Optional[AutotuneCache]) -> Tuple[str, ...]:
    """Resolve ``engine="auto"``'s per-bin assignment through the autotune
    cache (module default unless an explicit cache is threaded)."""
    cache = _AUTOTUNE_CACHE if cache is None else cache

    def measure(g, eng):
        return measure_group_engine(
            a, b, plan, g, eng, gather=gather, row_chunk=row_chunk,
            mesh=mesh, pipeline=pipeline)

    return cache.assignment_for(autotune_key(a, b, plan), plan, measure)


def _build_enumerate(a_cap: int, gather: str,
                     remapped: bool = False) -> Callable:
    """Compile the product-enumeration program: A-row gather → B-row gather
    (xla or AIA stream) → intermediate products.  Output stays on device and
    feeds both the allocation and accumulation programs — the gather runs
    once per chunk, not once per phase.

    ``remapped`` programs take the footprint block's global→local row map as
    a trailing operand and translate A's column ids before the B gather
    (``phases.remap_columns``) — the gather backends then index the compact
    sub-ELL exactly as they would the full replica.  Keys are B *column*
    ids, so the products are bit-identical either way."""
    gat = GATHERS[gather]

    @jax.jit
    def program(a_indptr, a_indices, a_data, rows, b_idx, b_val, remap=None):
        cols_a, vals_a = phases.gather_group_rows(
            a_indptr, a_indices, a_data, rows, a_cap
        )
        if remapped:
            cols_a = phases.remap_columns(cols_a, remap)
        bi, bv = gat(b_idx, b_val, cols_a)
        return phases.combine_products(cols_a, vals_a, bi, bv)

    return program


def _build_allocate(table_cap: int, engine: str) -> Callable:
    eng = get_engine(engine)
    return jax.jit(lambda keys: eng.allocate(keys, table_cap))


def _build_accumulate(table_cap: int, out_cap: int, engine: str) -> Callable:
    eng = get_engine(engine)
    return jax.jit(
        lambda keys, vals: eng.accumulate(keys, vals, table_cap, out_cap))


def _build_enumerate_batched(a_cap: int, gather: str,
                             remapped: bool = False) -> Callable:
    """Batched enumerate: structure (keys) computed once, value streams
    carry the leading batch axis.  Shares the allocation program with the
    unbatched path — uniqueCount depends only on keys, so one host sync
    sizes the whole batch.  ``remapped`` as in ``_build_enumerate``."""
    gat = BATCHED_GATHERS[gather]

    @jax.jit
    def program(a_indptr, a_indices, a_data_b, rows, b_idx, b_val_b,
                remap=None):
        cols_a, vals_a_b = phases.gather_group_rows_batched(
            a_indptr, a_indices, a_data_b, rows, a_cap
        )
        if remapped:
            cols_a = phases.remap_columns(cols_a, remap)
        bi, bv_b = gat(b_idx, b_val_b, cols_a)
        return phases.combine_products_batched(cols_a, vals_a_b, bi, bv_b)

    return program


def _build_accumulate_batched(table_cap: int, out_cap: int,
                              engine: str) -> Callable:
    """vmap the engine's accumulate over the batch's value sets (keys are
    shared, so every member produces the same cols/counts — the caller
    reads them from member 0)."""
    eng = get_engine(engine)
    return jax.jit(lambda keys, vals_b: jax.vmap(
        lambda v: eng.accumulate(keys, v, table_cap, out_cap))(vals_b))


def _build_fused(a_cap: int, gather: str, table_cap: int, out_cap: int,
                 kernel: str, remapped: bool = False) -> Callable:
    """Compile the fused single-pass program: A-row gather → B-row gather
    (xla or the AIA stream, feeding the table directly) → product
    formation → linear-probe insertion → sorted trim, all one jitted
    program — the enumerate key/value stream never becomes a
    device-resident buffer handed between programs, and no allocate pass
    runs (``out_cap`` comes from the plan's Alg. 1 bounds)."""
    gat = GATHERS[gather]

    @jax.jit
    def program(a_indptr, a_indices, a_data, rows, b_idx, b_val, remap=None):
        cols_a, vals_a = phases.gather_group_rows(
            a_indptr, a_indices, a_data, rows, a_cap
        )
        if remapped:
            cols_a = phases.remap_columns(cols_a, remap)
        bi, bv = gat(b_idx, b_val, cols_a)
        keys, vals = phases.combine_products(cols_a, vals_a, bi, bv)
        return phases.fused_hash_sorted(keys, vals, table_cap, out_cap,
                                        kernel=kernel)

    return program


def _build_fused_batched(a_cap: int, gather: str, table_cap: int,
                         out_cap: int, remapped: bool = False) -> Callable:
    """Batched fused program: the structural gather and key stream run
    once, the per-member value streams are vmapped through the single-pass
    insert (scan engine — the batch axis rides XLA's vmap, not the Pallas
    grid)."""
    gat = BATCHED_GATHERS[gather]

    @jax.jit
    def program(a_indptr, a_indices, a_data_b, rows, b_idx, b_val_b,
                remap=None):
        cols_a, vals_a_b = phases.gather_group_rows_batched(
            a_indptr, a_indices, a_data_b, rows, a_cap
        )
        if remapped:
            cols_a = phases.remap_columns(cols_a, remap)
        bi, bv_b = gat(b_idx, b_val_b, cols_a)
        keys, vals_b = phases.combine_products_batched(
            cols_a, vals_a_b, bi, bv_b)
        return jax.vmap(lambda v: phases.fused_hash_sorted(
            keys, v, table_cap, out_cap, kernel="xla"))(vals_b)

    return program


def _build_segment() -> Callable:
    """Shard-local epilogue half (``phases.reassemble_segment``): segment
    buffers, destination map, and the running offset are donated so chunk
    after chunk updates in place on the shard device."""
    return jax.jit(phases.reassemble_segment, donate_argnums=(0, 1, 2, 3))


def _build_segment_batched() -> Callable:
    return jax.jit(phases.reassemble_segment_batched,
                   donate_argnums=(0, 1, 2, 3))


def _build_merge() -> Callable:
    """Per-shard merge scatter into the (donated) final CSR buffers."""
    return jax.jit(phases.merge_segments, donate_argnums=(0, 1))


def _build_merge_batched() -> Callable:
    return jax.jit(phases.merge_segments_batched, donate_argnums=(0, 1))


def _build_scatter() -> Callable:
    """Jitted device-side reassembly epilogue (one chunk → final buffers).
    Keyed on (padded, out_cap, cap, dtype) like every other program, so
    pow2-quantized capacities keep iterative workloads on cached traces.
    The CSR buffers are *donated*: XLA updates them in place instead of
    copying the whole pow2-capacity output once per chunk (the executor
    rebinds the returned buffers, never touching the donated ones again;
    backends without donation fall back to a copy, still correct)."""
    return jax.jit(phases.reassemble_device, donate_argnums=(0, 1))


def _build_scatter_batched() -> Callable:
    return jax.jit(phases.reassemble_device_batched, donate_argnums=(0, 1))


_BUILDERS = {
    "enumerate": _build_enumerate,
    "allocate": _build_allocate,
    "accumulate": _build_accumulate,
    "benumerate": _build_enumerate_batched,
    "baccumulate": _build_accumulate_batched,
    "fused": _build_fused,
    "bfused": _build_fused_batched,
    "scatter": _build_scatter,
    "bscatter": _build_scatter_batched,
    "segment": _build_segment,
    "bsegment": _build_segment_batched,
    "merge": _build_merge,
    "bmerge": _build_merge_batched,
}


def _get_program(kind: str, key: tuple, *build_args) -> Callable:
    cache_key = (kind,) + key
    prog = _PROGRAM_CACHE.get(cache_key)
    if prog is None:
        _CACHE_STATS["misses"] += 1
        prog = _BUILDERS[kind](*build_args)
        _PROGRAM_CACHE[cache_key] = prog
    else:
        _CACHE_STATS["hits"] += 1
    return prog


# ---------------------------------------------------------------------------
# Plan execution
# ---------------------------------------------------------------------------

def ungrouped_plan(plan: GroupPlan) -> GroupPlan:
    """Collapse to one natural-order group at worst-case capacity
    (the Fig. 7 "without AIA scheduling" software baseline)."""
    n = len(plan.map_rows)
    cap = next_pow2(max(plan.max_ip, 2))
    return GroupPlan(
        map_rows=np.arange(n, dtype=np.int32),
        group_id=np.zeros(n, np.int32),
        group_offsets=np.asarray([0, n, n, n, n], np.int32),
        group_sizes=(n, 0, 0, 0),
        group_sizes_padded=(n, 0, 0, 0),
        table_capacities=(cap, cap, cap, cap),
        max_ip=plan.max_ip,
        total_ip=plan.total_ip,
        row_ip=plan.row_ip,
    )


def _pad_rows(k: int) -> int:
    return int(np.ceil(k / ROW_QUANTUM) * ROW_QUANTUM)


@dataclasses.dataclass(frozen=True)
class WorkItem:
    """One (group, row-chunk) dispatch, pinned to one shard."""

    group: int
    shard: int
    rows: np.ndarray      # (R,) original row ids of this chunk
    a_cap: int            # exact max nnz(A row) over the *group*
    table_cap: int        # Table-I hash-table capacity of the group
    engine: Optional[str] = None  # per-bin engine (None = caller's engine=)


def partition_plan(
    plan: GroupPlan,
    a_row_nnz: np.ndarray,
    row_chunk: int,
    n_shards: int = 1,
    group_engines: Optional[Tuple[str, ...]] = None,
) -> List[WorkItem]:
    """Split a ``GroupPlan`` into shard-assigned group-chunk work items.

    Chunks are assigned round-robin with a cursor that carries across
    groups, so each shard receives a balanced mix of Table-I bins (a shard
    never ends up holding only the heavy group-3 rows).  With multiple
    shards the chunk size shrinks to ``ceil(group/n_shards)`` (quantized to
    ``ROW_QUANTUM``) so every shard gets work from every group it can.

    ``a_cap`` stays a *group-level* maximum: per-row results then never
    depend on the chunking or the shard count, which is what makes the
    sharded path bit-identical to the single-device one.

    ``group_engines`` (the resolved ``engine="auto"`` assignment, or a
    plan's forced ``plan.group_engines``) stamps each item with its bin's
    engine; ``None`` leaves items on the caller's uniform ``engine=``.
    """
    items: List[WorkItem] = []
    cursor = 0
    for g in range(4):
        rows = plan.rows_of_group(g)
        if len(rows) == 0:
            continue
        a_cap = max(int(a_row_nnz[rows].max(initial=0)), 1)
        table_cap = plan.table_capacities[g]
        chunk = row_chunk
        if n_shards > 1:
            per_shard = _pad_rows(int(np.ceil(len(rows) / n_shards)))
            chunk = max(min(row_chunk, per_shard), ROW_QUANTUM)
        for lo in range(0, len(rows), chunk):
            items.append(WorkItem(
                group=g,
                shard=cursor % n_shards,
                rows=np.asarray(rows[lo: lo + chunk]),
                a_cap=a_cap,
                table_cap=table_cap,
                engine=None if group_engines is None else group_engines[g],
            ))
            cursor += 1
    return items


_PARTITION_CACHE: Dict[tuple, List[WorkItem]] = {}


def partition_plan_cached(
    plan: GroupPlan,
    a_row_nnz: np.ndarray,
    row_chunk: int,
    n_shards: int = 1,
    group_engines: Optional[Tuple[str, ...]] = None,
) -> List[WorkItem]:
    """Identity-memoized ``partition_plan``: a plan object served twice
    (a ``PlanCache`` hit, an explicit ``plan=`` reuse, or the batched lane)
    reuses its work-item list — iterations and batch members keep the same
    shard assignment under ``mesh=`` instead of re-partitioning.

    Keying on object identity keeps the unamortized path free (no content
    hashing per call), and a ``weakref.finalize`` on the plan evicts the
    entry when the plan dies, so ``id()`` reuse can't alias and the cache
    never outlives the plans it serves.
    """
    key = (id(plan), int(row_chunk), int(n_shards), group_engines)
    items = _PARTITION_CACHE.get(key)
    if items is None:
        items = partition_plan(plan, a_row_nnz, row_chunk, n_shards=n_shards,
                               group_engines=group_engines)
        _PARTITION_CACHE[key] = items
        weakref.finalize(plan, _PARTITION_CACHE.pop, key, None)
    return items


def shard_footprints(items: Sequence[WorkItem], a_indptr: np.ndarray,
                     a_indices: np.ndarray,
                     n_shards: int) -> List[np.ndarray]:
    """Per-shard B-row footprints from the work items' A-support.

    Shard ``s`` will gather exactly the B rows named by the column indices
    of A restricted to the rows of its work items — the union is computed
    on host from the same CSR arrays phase 1 already walked
    (``grouping.support_footprint``).  A shard with no work (or only empty
    rows) gets a single-row footprint ``[0]`` so its block keeps a valid
    ELL shape; nothing ever gathers from it.
    """
    by_shard: List[list] = [[] for _ in range(n_shards)]
    for item in items:
        by_shard[item.shard].append(item.rows)
    out = []
    for rows in by_shard:
        fp = support_footprint(
            a_indptr, a_indices,
            np.concatenate(rows) if rows else np.empty(0, np.int64))
        out.append(fp if fp.size else np.zeros(1, np.int64))
    return out


_FOOTPRINT_CACHE: Dict[tuple, List[np.ndarray]] = {}


def _shard_footprints_cached(plan: GroupPlan, items: Sequence[WorkItem],
                             a: CSR, row_chunk: int, n_shards: int,
                             group_engines) -> List[np.ndarray]:
    """Memoized ``shard_footprints``, keyed like the partition cache: a
    reused plan (same chunking, same shard count) reuses its footprints —
    iterative workloads derive the B placement once, not per call."""
    key = (id(plan), int(row_chunk), int(n_shards), group_engines)
    fps = _FOOTPRINT_CACHE.get(key)
    if fps is None:
        fps = shard_footprints(items, np.asarray(a.indptr),
                               np.asarray(a.indices), n_shards)
        _FOOTPRINT_CACHE[key] = fps
        weakref.finalize(plan, _FOOTPRINT_CACHE.pop, key, None)
    return fps


@dataclasses.dataclass
class _ChunkOut:
    rows: np.ndarray      # (R,) original row ids
    cols: np.ndarray      # (R_pad, out_cap)
    vals: np.ndarray      # (R_pad, out_cap)
    counts: np.ndarray    # (R_pad,)


def _shard_a_operands(a_arrays: Sequence, devices) -> List[tuple]:
    """Replicate A-side arrays onto every shard device.  A is placed per
    call (its values change across iterations); the B-side ELL replicas are
    the expensive, reusable half and ride the ``OperandCache`` (the
    software analogue of the paper's per-stack all-gather: every shard
    serves its two-level indirection from local memory)."""
    return [
        tuple(replicate_to(x, dev) for x in a_arrays) for dev in devices
    ]


def _setup_execution(a: CSR, b: CSR, plan: GroupPlan, engine: str,
                     gather: Gather, row_chunk: int, mesh,
                     group_engines: Optional[Tuple[str, ...]] = None,
                     operands: Operands = "auto"):
    """Shared single-matrix/batched preamble: resolve knobs, derive the
    exact capacities, (memoized) partition the plan over the shards, and
    resolve the per-shard B placement.

    When ``group_engines`` is set (``engine="auto"`` resolved, or a forced
    ``plan.group_engines``), every assigned engine is validated and the
    work items come back stamped per bin; the base ``engine`` may then be
    the string ``"auto"`` and is never dispatched itself.

    The returned ``footprints`` is the resolved ``operands=`` policy:
    ``None`` for full replication on every shard, else one entry per shard
    (row selection, or ``None`` for that shard's full-replica fast path).
    """
    gather = resolve_gather(gather)
    operands = resolve_operands(operands)
    if group_engines is not None:
        for name in group_engines:
            get_engine(name)  # validate the whole assignment early
    else:
        get_engine(engine)  # validate early ("auto" must be resolved first)
    # a_cap/kb_cap stay *exact*: ip_cap = a_cap·kb_cap is the sort engine's
    # dominant dimension and rounding it up is superlinearly expensive.
    # Cache keys still stabilize across iterations because iterative
    # workloads (MCL at fixpoint, GNN layers) keep their sparsity structure.
    kb_cap = int(np.asarray(b.row_nnz()).max(initial=0)) or 1
    # uniqueCount per row is bounded by n_cols(B) regardless of IP.
    ncol_cap = next_pow2(max(b.n_cols, 1))
    a_indptr_np = np.asarray(a.indptr)
    a_row_nnz = a_indptr_np[1:] - a_indptr_np[:-1]
    devices = shard_devices(mesh)
    items = partition_plan_cached(plan, a_row_nnz, row_chunk,
                                  n_shards=len(devices),
                                  group_engines=group_engines)
    footprints = None
    n_shards = len(devices)
    # "auto" only engages under real sharding (one shard's footprint is the
    # whole support — there is no communication to avoid); "footprint"
    # forces blocks everywhere, including single-device, for A/B tests.
    if (operands == "footprint"
            or (operands == "auto" and n_shards > 1)):
        raw = _shard_footprints_cached(plan, items, a, row_chunk, n_shards,
                                       group_engines)
        limit = FOOTPRINT_THRESHOLD * max(b.n_rows, 1)
        footprints = [
            fp if operands == "footprint" or len(fp) < limit else None
            for fp in raw
        ]
        if all(fp is None for fp in footprints):
            footprints = None  # every shard took the replication fast path
    return gather, kb_cap, ncol_cap, devices, items, footprints


def _chunk_rows_padded(chunk: np.ndarray, dev):
    """Pad a chunk's row ids to the quantized length (-1 = padding row)
    and place them on the item's shard device."""
    padded = _pad_rows(len(chunk))
    rows_j = replicate_to(jnp.asarray(np.concatenate(
        [chunk, -np.ones(padded - len(chunk), np.int32)]
    )), dev)
    return padded, rows_j


def _alloc_counts(keys, padded: int, table_cap: int, engine: str) -> jax.Array:
    """Dispatch the allocation program (Algorithms 2/3) — uniqueCount per
    row, returned *on device* so the caller chooses when to sync.  Keys
    depend only on structure, so the batched lane shares this program (same
    cache key) and one sizing serves every batch member."""
    ip_cap = keys.shape[1]
    alloc = _get_program("allocate", (padded, ip_cap, table_cap, engine),
                         table_cap, engine)
    return alloc(keys)


def _out_cap_from_counts(unique_counts: np.ndarray, table_cap: int,
                         ncol_cap: int) -> int:
    """pow2-quantized chunk output capacity from host-resident uniqueCounts
    (keeps the accumulate signature stable across iterative calls)."""
    max_unique = int(unique_counts.max(initial=0))
    return max(min(next_pow2(max_unique), max(table_cap, 1), ncol_cap), 1)


def _size_out_cap(keys, padded: int, table_cap: int, engine: str,
                  ncol_cap: int) -> int:
    """Legacy per-chunk allocation sizing: one *blocking* host sync per
    group-chunk (the serialization the two-wave pipeline removes)."""
    counts = _alloc_counts(keys, padded, table_cap, engine)
    _SYNC_STATS["host_sync_count"] += 1
    return _out_cap_from_counts(np.asarray(counts), table_cap, ncol_cap)


_INT32_MAX = int(np.iinfo(np.int32).max)


def _int32_nnz_capacity(nnz: int) -> int:
    """Total-nnz capacity of the device epilogue's CSR buffers.

    pow2-quantized so iterative workloads reuse compiled scatter programs;
    the epilogue emits int32 ``indptr``/``indices`` throughout, so a result
    whose nnz does not fit int32 must fail loudly instead of silently
    downcasting (the pre-PR reassembly ``astype(np.int32)`` drift).  If the
    pow2 quantum itself would overflow int32 while the nnz still fits, fall
    back to the exact capacity.
    """
    if nnz > _INT32_MAX:
        raise OverflowError(
            f"SpGEMM output has {nnz} nonzeros, which does not fit the "
            "int32 CSR index space used by the device reassembly epilogue")
    cap = next_pow2(max(nnz, 1))
    return cap if cap <= _INT32_MAX else max(int(nnz), 1)


def _coalesce_and_size(pend: List[tuple], n: int):
    """The two-wave pipeline's single blocking point, shared by the
    single-matrix and batched lanes: drain every pending chunk's allocate
    counts with one coalesced sync, assemble the int32 ``indptr``, and size
    the epilogue's pow2-quantized total-nnz capacity (overflow-guarded).

    ``pend`` entries are ``(item, padded, keys, vals, alloc_counts)``;
    returns ``(unique_counts, indptr, nnz, cap)``.
    """
    unique_counts = _coalesced_sync([p[4] for p in pend]) if pend else []
    counts_all = np.zeros(n, np.int64)
    for (item, _, _, _, _), uc in zip(pend, unique_counts):
        counts_all[item.rows] = uc[: len(item.rows)]
    indptr64 = np.zeros(n + 1, np.int64)
    np.cumsum(counts_all, out=indptr64[1:])
    nnz = int(indptr64[-1])
    cap = _int32_nnz_capacity(nnz)
    return unique_counts, indptr64.astype(np.int32), nnz, cap


def _chunk_starts(indptr: np.ndarray, rows: np.ndarray, padded: int,
                  merge_dev) -> jax.Array:
    """int32 CSR start offset of each chunk row, padded rows parked at 0
    (their counts are 0, so the epilogue scatter drops them)."""
    starts = np.zeros(padded, np.int32)
    starts[: len(rows)] = indptr[rows]
    return replicate_to(jnp.asarray(starts), merge_dev)


def _scatter_positions(indptr: np.ndarray, rows: np.ndarray,
                       counts: np.ndarray, out_cap: int):
    """Reassembly offsets for one chunk: flat CSR destinations of the
    occupied (row, slot) cells plus the occupancy mask — shared by the
    single-matrix and batched lanes (the batched value scatter just
    broadcasts over its leading axis)."""
    r = len(rows)
    starts = indptr[rows]  # (R,)
    offs = np.arange(out_cap, dtype=np.int64)[None, :]
    pos = starts[:, None] + offs  # (R, out_cap)
    ok = offs < counts[:r, None]
    return pos[ok], ok, r


@dataclasses.dataclass
class _ChunkRun:
    """One chunk's accumulated output, still on its shard device."""

    item: WorkItem
    padded: int
    out_cap: int
    cols: jax.Array    # (R_pad, out_cap)
    vals: jax.Array    # (R_pad, out_cap) or (batch, R_pad, out_cap)
    counts: jax.Array  # (R_pad,)


class _Epilogue:
    """Device-side CSR scatter epilogue — direct or sharded.

    Direct (one shard): each chunk scatters straight into the final
    pow2-capacity buffers on the merge device (the pre-PR-5 path).

    Sharded (>1 shard): each chunk is packed *densely* into its shard's
    local CSR segment on the shard device, together with a destination map
    into the final buffers (``phases.reassemble_segment``); ``finish()``
    then moves one compact ``(segment, values, dest)`` triple per shard to
    the merge device and applies one merge scatter per shard.  The
    reassembly compute runs shard-parallel and the lead device receives
    ``n_shards`` nnz-sized transfers instead of every padded chunk output
    — the ROADMAP's "shard the epilogue" item.  Results are bit-identical
    to the direct path: row destinations are disjoint across shards, so
    every final slot is written by exactly one segment.

    ``seg_caps`` are the per-shard segment capacities (pow2-quantized,
    from measured uniqueCounts or planned Alg. 1 bounds); ``batch`` turns
    on the batched value planes.
    """

    def __init__(self, devices, cap: int, dtype, dt: str,
                 seg_caps: Optional[List[int]] = None,
                 batch: Optional[int] = None):
        self.devices = devices
        self.merge_dev = merge_device(devices)
        self.cap = cap
        self.dt = dt
        self.batch = batch
        self.sharded = len(devices) > 1
        self.idx_buf = replicate_to(jnp.zeros(cap, jnp.int32), self.merge_dev)
        dat_shape = (cap,) if batch is None else (batch, cap)
        self.dat_buf = replicate_to(jnp.zeros(dat_shape, dtype),
                                    self.merge_dev)
        self.segs: Dict[int, list] = {}
        if self.sharded:
            for s, dev in enumerate(devices):
                seg_cap = seg_caps[s]
                if seg_cap == 0:
                    continue  # shard got no work items
                seg_shape = (seg_cap,) if batch is None else (batch, seg_cap)
                self.segs[s] = [
                    replicate_to(jnp.zeros(seg_cap, jnp.int32), dev),
                    replicate_to(jnp.zeros(seg_shape, dtype), dev),
                    # dest sentinel = final capacity → dropped at merge
                    replicate_to(jnp.full(seg_cap, cap, jnp.int32), dev),
                    replicate_to(jnp.zeros((), jnp.int32), dev),
                    seg_cap,
                ]

    def add_chunk(self, run: _ChunkRun, fin_starts: jax.Array) -> None:
        """Consume one chunk's output.  ``fin_starts`` must live on the
        shard device (sharded) or the merge device (direct)."""
        b = () if self.batch is None else (self.batch,)
        if not self.sharded:
            kind = "scatter" if self.batch is None else "bscatter"
            prog = _get_program(
                kind, b + (run.padded, run.out_cap, self.cap, self.dt))
            self.idx_buf, self.dat_buf = prog(
                self.idx_buf, self.dat_buf,
                replicate_to(run.cols, self.merge_dev),
                replicate_to(run.vals, self.merge_dev),
                replicate_to(run.counts, self.merge_dev),
                fin_starts,
            )
            return
        seg = self.segs.get(run.item.shard)
        if seg is None:
            # seg_cap 0: every row this shard owns is bounded/measured at
            # zero output nnz, so there is nothing to pack or merge.
            return
        kind = "segment" if self.batch is None else "bsegment"
        prog = _get_program(
            kind, b + (run.padded, run.out_cap, seg[4], self.dt))
        seg[0], seg[1], seg[2], seg[3] = prog(
            seg[0], seg[1], seg[2], seg[3],
            run.cols, run.vals, run.counts, fin_starts)

    def finish(self) -> Tuple[jax.Array, jax.Array]:
        if self.sharded:
            b = () if self.batch is None else (self.batch,)
            kind = "merge" if self.batch is None else "bmerge"
            for s in sorted(self.segs):
                seg = self.segs[s]
                prog = _get_program(kind, b + (seg[4], self.cap, self.dt))
                self.idx_buf, self.dat_buf = prog(
                    self.idx_buf, self.dat_buf,
                    replicate_to(seg[0], self.merge_dev),
                    replicate_to(seg[1], self.merge_dev),
                    replicate_to(seg[2], self.merge_dev),
                )
        return self.idx_buf, self.dat_buf


def _shard_seg_caps(items: Sequence[WorkItem], n_shards: int,
                    chunk_nnz: Sequence[int]) -> List[int]:
    """Per-shard segment capacities (pow2-quantized) from per-chunk nnz —
    exact counts on the measured path, Alg. 1 bounds on the planned one."""
    totals = [0] * n_shards
    for item, nnz in zip(items, chunk_nnz):
        totals[item.shard] += int(nnz)
    return [next_pow2(t) if t > 0 else 0 for t in totals]


def _device_indptr(runs: Sequence[_ChunkRun], n: int, merge_dev):
    """Sync-free CSR sizing: assemble the int32 indptr *on device* from the
    chunks' device-resident counts (the chunks' rows partition [0, n), so
    one scatter of the concatenated counts covers every row).  Returns
    (indptr (n+1,) int32 device array, nnz () int32 device scalar)."""
    counts_all = replicate_to(jnp.zeros(n, jnp.int32), merge_dev)
    if runs:
        rows_cat = np.concatenate([r.item.rows for r in runs])
        counts_cat = jnp.concatenate([
            replicate_to(r.counts[: len(r.item.rows)], merge_dev)
            for r in runs
        ])
        counts_all = counts_all.at[
            replicate_to(jnp.asarray(rows_cat), merge_dev)].set(counts_cat)
    indptr = jnp.concatenate(
        [jnp.zeros(1, jnp.int32), jnp.cumsum(counts_all)])
    return indptr, indptr[-1]


def _device_chunk_starts(indptr_dev: jax.Array, rows: np.ndarray,
                         padded: int, dev) -> jax.Array:
    """Per-chunk final CSR start offsets gathered from the device-resident
    indptr (padding rows park at row 0; their counts are 0, so the scatter
    drops them).  ``indptr_dev`` must already live on ``dev``."""
    rows_full = np.zeros(padded, np.int32)
    rows_full[: len(rows)] = rows
    return jnp.take(indptr_dev, replicate_to(jnp.asarray(rows_full), dev))


def execute_plan(
    a: CSR,
    b: CSR,
    plan: GroupPlan,
    engine: str = "sort",
    gather: Gather = "auto",
    row_chunk: int = 4096,
    mesh=None,
    pipeline: Pipeline = "two_wave",
    sizing: Sizing = "auto",
    autotune: Optional[AutotuneCache] = None,
    operands: Operands = "auto",
    operand_cache: Optional[OperandCache] = None,
) -> Tuple[CSR, int]:
    """Run the compiled group pipeline; returns (C, nnz_C).

    ``pipeline="two_wave"`` (default) dispatches *every* chunk's
    enumerate + allocate programs across all shards first, pays **one**
    coalesced blocking host sync to size every chunk's output at once, then
    runs accumulate on the still-device-resident keys and reassembles the
    CSR with the jitted device epilogue (``phases.reassemble_device``) —
    multi-chunk and multi-shard runs no longer serialize on per-chunk
    allocate syncs, and ``indices``/``data`` never round-trip through
    NumPy.  The tradeoff: wave 1 keeps every chunk's intermediate products
    device-resident until wave 2 consumes them (each is freed right after
    its accumulate), so peak memory approaches the *total* intermediate
    products instead of one chunk's worth.  ``pipeline="legacy"`` is the
    pre-pipelined reference path (one blocking sync per chunk, host-side
    reassembly, per-chunk peak memory), kept for A/B benchmarking,
    bit-exactness tests, and memory-bound runs.  ``mesh`` partitions the plan
    across the mesh's devices (round-robin by group); ``mesh=None`` is the
    single-device path — all four combinations produce bit-identical rows.

    ``sizing`` picks how ``out_cap`` and the epilogue capacity are found:
    ``"measured"`` syncs the uniqueCounts (the coalesced sync above);
    ``"planned"`` derives them from the plan's Alg. 1 IP bounds and
    assembles the indptr on device — the call dispatches end-to-end with
    **zero** blocking host syncs (``host_sync_count`` stays flat; ``nnz``
    comes back as a device scalar that only blocks when the caller reads
    it).  ``"auto"`` (default) is planned for fused engines
    (``"fused_hash"``: one single-pass program per chunk, no allocate
    dispatch, no materialized key stream) and measured otherwise.  Under
    more than one shard the epilogue is itself sharded: chunks pack into
    shard-local CSR segments on their own devices and the merge device
    applies one destination-mapped scatter per shard.

    ``engine="auto"`` dispatches *per Table-I bin* (nsparse-style adaptive
    accumulator selection): the assignment comes from
    ``plan.group_engines`` when set (forced mixed dispatch — it also wins
    over a concrete ``engine=``), otherwise from the ``AutotuneCache``
    (``autotune=``, default the module cache): static bin-size × backend
    seeds refined by measured per-bin timings, one candidate measured per
    call until converged.  Sizing then follows the per-bin rule: planned
    iff every non-empty bin's engine is fused, measured the moment any
    bin picks a non-fused engine.

    ``operands`` selects the B-side placement: ``"auto"`` (default) ships
    each shard only the footprint-gathered B block its work items'
    A-support touches (full replica when the footprint covers ≥
    ``FOOTPRINT_THRESHOLD`` of B's rows, and always on a single shard);
    ``"footprint"`` forces the block path, ``"replicate"`` the blind full
    replication.  All three are bit-identical — the remapped gathers read
    the same B rows from shard-local indices — and the comm saving
    surfaces in ``cache_stats()``'s ``operand_bytes_placed`` /
    ``operand_rows_*`` counters.

    ``operand_cache`` scopes the B-side placement cache: ``None`` (default)
    uses the module-level cache; a caller-owned ``OperandCache`` isolates
    placements (and their LRU quota) per scope — the multi-tenant serving
    layer gives each tenant its own instance so one tenant's traffic can
    never evict another's placed buffers.
    """
    if pipeline not in ("two_wave", "legacy"):
        raise ValueError(f"unknown pipeline {pipeline!r}")
    engine = resolve_engine(engine)
    group_engines = plan.group_engines
    if group_engines is None and engine == AUTO_ENGINE:
        group_engines = _autotune_assignment(
            a, b, plan, gather, row_chunk, mesh, pipeline, autotune)
    if pipeline == "legacy":
        if sizing == "planned":
            raise ValueError(
                "sizing='planned' requires pipeline='two_wave' (the legacy "
                "reference path sizes each chunk from a blocking sync)")
        mode = "measured"
    else:
        mode = resolve_sizing(sizing, engine, plan, group_engines)
    budget = _DEVICE_BUDGET["bytes"]
    if budget is not None:
        need = estimated_device_bytes(plan, np.dtype(a.data.dtype).itemsize)
        if need > budget:
            raise DeviceBudgetExceeded(
                f"plan needs ~{need} device bytes for its intermediate "
                f"products (total IP {plan.total_ip}) but the configured "
                f"device budget is {budget}; stream the call instead — "
                "spgemm_streamed with tile_rows small enough that every "
                "tile's estimate fits the budget")
    gather, kb_cap, ncol_cap, devices, items, footprints = _setup_execution(
        a, b, plan, engine, gather, row_chunk, mesh,
        group_engines=group_engines, operands=operands)
    n = a.n_rows
    dtype = np.dtype(a.data.dtype)  # no host round-trip: dtype is metadata
    dt = dtype.str
    ocache = operand_cache if operand_cache is not None else _OPERAND_CACHE
    try:
        faults.fire("gather_fail")
        b_entry = ocache.b_operands(b, kb_cap, devices, footprints=footprints)
    except faults.FaultInjected:
        # Transient placement failure: B-operand gather/placement is
        # idempotent (pure function of B + devices), so one re-issue is the
        # whole recovery (docs/resilience.md).
        b_entry = ocache.b_operands(b, kb_cap, devices, footprints=footprints)
    a_ops = _shard_a_operands((a.indptr, a.indices, a.data), devices)
    shape = (a.n_rows, b.n_cols)
    if pipeline == "legacy":
        return _execute_plan_legacy(
            items, devices, a_ops, b_entry, n, shape, dtype, dt, kb_cap,
            ncol_cap, gather, engine)
    if mode == "planned":
        indptr, idx_buf, dat_buf, nnz, overflow = _run_planned(
            items, devices, a_ops, b_entry.shards, plan, n, dtype, dt,
            kb_cap, ncol_cap, b.n_cols, gather, engine)
        if not _capacity_overflow(overflow):
            return CSR(indptr, idx_buf, dat_buf, shape), nnz
        # Capacity detect-and-retry (docs/resilience.md): an under-sized
        # chunk trimmed its cols/vals buffers below the true uniqueCounts,
        # so the whole planned result is untrustworthy — discard it and
        # fall through to the measured two-wave path below, which re-sizes
        # every chunk from its real counts.  A rare miss costs one retry,
        # never correctness.
        _RESILIENCE_STATS["capacity_retries"] += 1

    # ---- Wave 1: dispatch every chunk's enumerate + allocate, no syncs ----
    pend = []
    for item in items:
        dev = devices[item.shard]
        a_ip, a_ix, a_dt = a_ops[item.shard]
        b_ix, b_vl, b_rm = b_entry.shards[item.shard]
        rmk = b_rm is not None
        padded, rows_j = _chunk_rows_padded(item.rows, dev)
        enum = _get_program(
            "enumerate", (padded, item.a_cap, kb_cap, gather, dt, rmk),
            item.a_cap, gather, rmk)
        keys, vals = enum(a_ip, a_ix, a_dt, rows_j, b_ix, b_vl, b_rm)
        pend.append((item, padded, keys, vals,
                     _alloc_counts(keys, padded, item.table_cap,
                                   item.engine or engine)))

    # ---- The one coalesced host sync: size every out_cap at once ----
    unique_counts, indptr, nnz, cap = _coalesce_and_size(pend, n)

    # ---- Wave 2: accumulate on device-resident keys + device epilogue ----
    epi = _Epilogue(
        devices, cap, dtype, dt,
        seg_caps=_shard_seg_caps(
            [p[0] for p in pend], len(devices),
            [int(uc[: len(p[0].rows)].sum()) for p, uc in
             zip(pend, unique_counts)]))
    for i, uc in enumerate(unique_counts):
        item, padded, keys, vals, _ = pend[i]
        pend[i] = None  # free this chunk's intermediates once consumed
        eng_name = item.engine or engine
        out_cap = _out_cap_from_counts(uc, item.table_cap, ncol_cap)
        ip_cap = keys.shape[1]
        accum = _get_program(
            "accumulate",
            (padded, ip_cap, item.table_cap, out_cap, eng_name, dt),
            item.table_cap, out_cap, eng_name)
        cols_r, vals_r, counts_r = accum(keys, vals)
        # sharded epilogue: starts/outputs stay on the shard device
        starts_dev = devices[item.shard] if epi.sharded else epi.merge_dev
        epi.add_chunk(
            _ChunkRun(item, padded, out_cap, cols_r, vals_r, counts_r),
            _chunk_starts(indptr, item.rows, padded, starts_dev))
    idx_buf, dat_buf = epi.finish()

    c = CSR(jnp.asarray(indptr), idx_buf, dat_buf, shape)
    return c, nnz


def _run_planned(items, devices, a_ops, b_ops, plan, n, dtype, dt, kb_cap,
                 ncol_cap, ncol, gather, engine, batch=None):
    """The sync-free sizing core shared by the single-matrix and batched
    lanes: every capacity comes from the plan's Alg. 1 IP bounds (host
    arithmetic), the indptr is assembled on device, and the whole run —
    fused single-pass programs (or enumerate + accumulate for non-fused
    engines), device indptr, epilogue — is dispatched without a single
    blocking host sync.  ``nnz`` is returned as a device scalar; it blocks
    only when the caller materializes it.  ``batch`` switches the batched
    program kinds and value planes; ``a_ops``/``b_ops`` are per-shard
    operand tuples either way.  Items stamped with a per-bin engine
    (``engine="auto"``) dispatch their own engine's programs; the sizing
    rule guarantees every engine reaching this sync-free core is fused.
    """
    kernel = _fused_kernel_mode(dt)
    bounds = [chunk_capacity_bounds(plan, item.rows, ncol) for item in items]
    cap = _int32_nnz_capacity(sum(s for _, s in bounds))
    bkey = () if batch is None else (batch,)
    runs: List[_ChunkRun] = []
    for item, (max_u, _) in zip(items, bounds):
        eng_name = item.engine or engine
        eng = get_engine(eng_name)
        dev = devices[item.shard]
        a_arrs = a_ops[item.shard]
        b_ix, b_vl, b_rm = b_ops[item.shard]
        rmk = b_rm is not None
        padded, rows_j = _chunk_rows_padded(item.rows, dev)
        out_cap = _planned_out_cap(max_u, item.table_cap, ncol_cap)
        if faults.trigger("capacity_undersize"):
            # Chaos hook (docs/resilience.md): shrink this chunk's planned
            # capacity below any real row's uniqueCount so the device-side
            # overflow flag and the measured-capacity retry are exercised.
            out_cap = 1
        if eng.fused:
            if batch is None:
                prog = _get_program(
                    "fused",
                    (padded, item.a_cap, kb_cap, item.table_cap, out_cap,
                     gather, dt, kernel, rmk),
                    item.a_cap, gather, item.table_cap, out_cap, kernel, rmk)
            else:
                prog = _get_program(
                    "bfused",
                    (batch, padded, item.a_cap, kb_cap, item.table_cap,
                     out_cap, gather, dt, rmk),
                    item.a_cap, gather, item.table_cap, out_cap, rmk)
            cols_r, vals_r, counts_r = prog(*a_arrs, rows_j, b_ix, b_vl, b_rm)
        else:
            enum = _get_program(
                "enumerate" if batch is None else "benumerate",
                bkey + (padded, item.a_cap, kb_cap, gather, dt, rmk),
                item.a_cap, gather, rmk)
            keys, vals = enum(*a_arrs, rows_j, b_ix, b_vl, b_rm)
            accum = _get_program(
                "accumulate" if batch is None else "baccumulate",
                bkey + (padded, keys.shape[1], item.table_cap, out_cap,
                        eng_name, dt),
                item.table_cap, out_cap, eng_name)
            cols_r, vals_r, counts_r = accum(keys, vals)
        if batch is not None:  # shared structure: member 0 carries it
            cols_r, counts_r = cols_r[0], counts_r[0]
        runs.append(_ChunkRun(item, padded, out_cap, cols_r, vals_r,
                              counts_r))

    # ---- Device-side CSR sizing: indptr/nnz never visit the host ----
    merge_dev = merge_device(devices)
    indptr, nnz = _device_indptr(runs, n, merge_dev)

    # Device-side capacity-overflow flag: engine counts are TRUE per-row
    # uniqueCounts (never clipped to out_cap), so ``counts > out_cap``
    # detects an under-sized chunk whose cols/vals buffers were trimmed.
    # Computed async here (a handful of scalar reductions, no sync); the
    # caller decides whether to *read* it — see ``_capacity_overflow``.
    overflow = None
    for run in runs:
        f = replicate_to(
            jnp.any(run.counts[: len(run.item.rows)] > run.out_cap),
            merge_dev)
        overflow = f if overflow is None else jnp.logical_or(overflow, f)

    epi = _Epilogue(devices, cap, dtype, dt, batch=batch,
                    seg_caps=_shard_seg_caps(items, len(devices),
                                             [s for _, s in bounds]))
    indptr_by_dev = {merge_dev: indptr}
    for run in runs:
        dev = devices[run.item.shard] if epi.sharded else merge_dev
        if dev not in indptr_by_dev:
            indptr_by_dev[dev] = replicate_to(indptr, dev)
        epi.add_chunk(run, _device_chunk_starts(
            indptr_by_dev[dev], run.item.rows, run.padded, dev))
    idx_buf, dat_buf = epi.finish()
    return indptr, idx_buf, dat_buf, nnz, overflow


def _capacity_overflow(overflow) -> bool:
    """Read the planned lane's overflow flag — iff it could have tripped.

    On today's sizing lanes a clean planned call can never overflow:
    ``_planned_out_cap`` takes a min over terms that each dominate the
    true uniqueCount (Alg. 1's ``min(IP, ncols)`` bound, the table cap,
    the column count), so the flag is read **only** while the
    ``capacity_undersize`` fault point is armed — the clean planned/fused
    path stays free of blocking host syncs (``host_sync_count == 0``).
    A future ``sizing="estimated"`` lane (OCEAN, arXiv:2604.19004) sizes
    from estimates that *can* undershoot; it will read the flag
    unconditionally and reuse the same measured-capacity retry.
    """
    if overflow is None or not faults.armed("capacity_undersize"):
        return False
    return bool(np.asarray(overflow))


def _execute_plan_legacy(items, devices, a_ops, b_entry, n, shape, dtype, dt,
                         kb_cap, ncol_cap, gather, engine) -> Tuple[CSR, int]:
    """Pre-pipelined reference: one blocking allocate sync per group-chunk
    and NumPy host-side reassembly (``np.asarray`` round-trips)."""
    chunks: List[_ChunkOut] = []
    counts_all = np.zeros(n, np.int64)
    for item in items:
        chunk = item.rows
        dev = devices[item.shard]
        a_ip, a_ix, a_dt = a_ops[item.shard]
        b_ix, b_vl, b_rm = b_entry.shards[item.shard]
        rmk = b_rm is not None
        a_cap, table_cap = item.a_cap, item.table_cap
        padded, rows_j = _chunk_rows_padded(chunk, dev)
        enum = _get_program(
            "enumerate", (padded, a_cap, kb_cap, gather, dt, rmk),
            a_cap, gather, rmk)
        keys, vals = enum(a_ip, a_ix, a_dt, rows_j, b_ix, b_vl, b_rm)
        ip_cap = keys.shape[1]
        eng_name = item.engine or engine
        out_cap = _size_out_cap(keys, padded, table_cap, eng_name, ncol_cap)
        # ---- Accumulation (Algorithm 5) on the same device arrays ----
        accum = _get_program(
            "accumulate", (padded, ip_cap, table_cap, out_cap, eng_name, dt),
            table_cap, out_cap, eng_name)
        cols_r, vals_r, counts_r = accum(keys, vals)
        out = _ChunkOut(
            rows=np.asarray(chunk),
            cols=np.asarray(cols_r),
            vals=np.asarray(vals_r),
            counts=np.asarray(counts_r),
        )
        counts_all[out.rows] = out.counts[: len(chunk)]
        chunks.append(out)

    # ---- Vectorized CSR reassembly (inverse-permutation scatter) ----
    indptr = np.zeros(n + 1, np.int64)
    np.cumsum(counts_all, out=indptr[1:])
    nnz = int(indptr[-1])
    cap = max(nnz, 1)
    indices = np.zeros(cap, np.int32)
    data = np.zeros(cap, dtype)
    for ck in chunks:
        pos_ok, ok, r = _scatter_positions(indptr, ck.rows, ck.counts,
                                           ck.cols.shape[1])
        indices[pos_ok] = ck.cols[:r][ok]
        data[pos_ok] = ck.vals[:r][ok]

    c = CSR(
        jnp.asarray(indptr.astype(np.int32)),
        jnp.asarray(indices),
        jnp.asarray(data),
        shape,
    )
    return c, nnz


# ---------------------------------------------------------------------------
# Batched execution — one plan, many same-pattern value sets
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class _BatchChunkOut:
    rows: np.ndarray      # (R,) original row ids
    cols: np.ndarray      # (R_pad, out_cap) shared output structure
    vals: np.ndarray      # (batch, R_pad, out_cap)
    counts: np.ndarray    # (R_pad,)


def _batched_operands(a: CSR, b: CSR, a_data_batch, b_data_batch, kb_cap: int,
                      devices, footprints=None, operand_cache=None):
    """Per-shard batched operand placement.  The B-side structural buffers
    (ELL indices + the shared value plane) come from the ``OperandCache``
    (``operand_cache`` scopes it; ``None`` = the module cache); only
    per-call value stacks are placed fresh — sliced to each shard's
    footprint rows when the entry carries footprint-gathered blocks."""
    a_data_batch = np.asarray(a_data_batch)
    if a_data_batch.ndim != 2:
        raise ValueError(
            f"a_data_batch must be (batch, capacity), got {a_data_batch.shape}")
    batch = a_data_batch.shape[0]
    ocache = operand_cache if operand_cache is not None else _OPERAND_CACHE
    b_entry = ocache.b_operands(b, kb_cap, devices, footprints=footprints)
    if b_data_batch is None:
        # shared B values: broadcast each shard's cached placement in place
        # (a broadcast of a device-resident array stays on that device)
        b_shards = [
            (b_ix, jnp.broadcast_to(b_vl[None], (batch,) + tuple(b_vl.shape)),
             b_rm)
            for b_ix, b_vl, b_rm in b_entry.shards
        ]
    else:
        b_data_batch = np.asarray(b_data_batch)
        if b_data_batch.shape[0] != batch:
            raise ValueError(
                f"batch mismatch: {batch} A value sets vs "
                f"{b_data_batch.shape[0]} B value sets")
        # structure-only scatter into ELL layout, vmapped over value sets
        to_ell_data = jax.vmap(lambda d: csr_to_ell(
            CSR(b.indptr, b.indices, d, b.shape), kb_cap).data)
        b_val_b = to_ell_data(jnp.asarray(b_data_batch))
        entry_fps = b_entry.footprints or [None] * len(devices)
        b_shards = []
        for (b_ix, _, b_rm), fp, dev in zip(b_entry.shards, entry_fps,
                                            devices):
            vb = b_val_b if fp is None else jnp.take(
                b_val_b, jnp.asarray(np.asarray(fp, np.int32)), axis=1)
            b_shards.append((b_ix, replicate_to(vb, dev), b_rm))
    a_shards = _shard_a_operands(
        (a.indptr, a.indices, jnp.asarray(a_data_batch)), devices)
    return a_data_batch, batch, a_shards, b_shards


def execute_plan_batched(
    a: CSR,
    b: CSR,
    a_data_batch: Sequence,
    b_data_batch: Optional[Sequence] = None,
    plan: Optional[GroupPlan] = None,
    engine: str = "sort",
    gather: Gather = "auto",
    row_chunk: int = 4096,
    mesh=None,
    pipeline: Pipeline = "two_wave",
    sizing: Sizing = "auto",
    autotune: Optional[AutotuneCache] = None,
    operands: Operands = "auto",
    operand_cache: Optional[OperandCache] = None,
) -> Tuple[jax.Array, jax.Array, jax.Array, int]:
    """Run the compiled pipeline once for a whole batch of same-pattern
    operands; returns ``(indptr, indices, data_batch, nnz)``.

    ``a``/``b`` carry the shared sparsity structure; ``a_data_batch`` is a
    ``(batch, capacity)`` stack of A value sets, ``b_data_batch`` the same
    for B (``None`` = ``b.data`` is shared by every member).  Because the
    key tensor depends only on structure, the enumerate gathers, the
    allocation sizing (under ``pipeline="two_wave"`` one coalesced host
    sync for *all* chunks of the *entire* batch), the output structure, and
    the reassembly offsets all run once; only the value streams are vmapped
    through the cached accumulate programs.  The output structure is shared
    by construction, so member i's result is
    ``CSR(indptr, indices, data_batch[i], (a.n_rows, b.n_cols))``.

    ``mesh=`` shards exactly like ``execute_plan`` — the (memoized) work
    item partition of the shared plan is computed once and every batch
    member rides the same shard assignment; B's replicated ELL buffers are
    served by the ``OperandCache`` across calls.  Results are bit-identical
    to a per-matrix Python loop for every engine × gather combination.

    ``sizing`` mirrors ``execute_plan``: ``"planned"`` (the fused-engine
    default) sizes every chunk of the whole batch from the plan's Alg. 1
    bounds and assembles the shared indptr on device — zero blocking
    syncs; ``"measured"`` keeps the one coalesced uniqueCount sync.

    ``engine="auto"`` resolves a per-bin assignment exactly as in
    ``execute_plan`` (forced ``plan.group_engines`` wins; otherwise the
    ``AutotuneCache``), and the whole batch rides the one assignment.

    ``operands`` mirrors ``execute_plan``: footprint-gathered B blocks per
    shard under ``"auto"``/``"footprint"`` (per-member value planes are
    sliced to the same footprint rows), full replication under
    ``"replicate"`` — bit-identical either way.  ``operand_cache`` scopes
    the B placement cache exactly as in ``execute_plan``.
    """
    if pipeline not in ("two_wave", "legacy"):
        raise ValueError(f"unknown pipeline {pipeline!r}")
    if plan is None:
        plan = group_rows(a, b)
    engine = resolve_engine(engine)
    group_engines = plan.group_engines
    if group_engines is None and engine == AUTO_ENGINE:
        group_engines = _autotune_assignment(
            a, b, plan, gather, row_chunk, mesh, pipeline, autotune)
    if pipeline == "legacy":
        if sizing == "planned":
            raise ValueError(
                "sizing='planned' requires pipeline='two_wave' (the legacy "
                "reference path sizes each chunk from a blocking sync)")
        mode = "measured"
    else:
        mode = resolve_sizing(sizing, engine, plan, group_engines)
    gather, kb_cap, ncol_cap, devices, items, footprints = _setup_execution(
        a, b, plan, engine, gather, row_chunk, mesh,
        group_engines=group_engines, operands=operands)
    n = a.n_rows
    a_data_batch, batch, a_shards, b_shards = _batched_operands(
        a, b, a_data_batch, b_data_batch, kb_cap, devices,
        footprints=footprints, operand_cache=operand_cache)
    dtype = a_data_batch.dtype
    dt = np.dtype(dtype).str
    if pipeline == "legacy":
        return _execute_plan_batched_legacy(
            items, devices, a_shards, b_shards, n, batch, dtype, dt, kb_cap,
            ncol_cap, gather, engine)
    if mode == "planned":
        indptr, idx_buf, dat_buf_b, nnz, overflow = _run_planned(
            items, devices, a_shards, b_shards, plan, n, dtype, dt,
            kb_cap, ncol_cap, b.n_cols, gather, engine, batch=batch)
        if not _capacity_overflow(overflow):
            return indptr, idx_buf, dat_buf_b, nnz
        # Same detect-and-retry as execute_plan: discard the under-sized
        # planned result and fall through to the measured batched waves.
        _RESILIENCE_STATS["capacity_retries"] += 1

    # ---- Wave 1: every chunk's benumerate + allocate, no syncs ----
    pend = []
    for item in items:
        dev = devices[item.shard]
        a_ip, a_ix, a_db = a_shards[item.shard]
        b_ix, b_vb, b_rm = b_shards[item.shard]
        rmk = b_rm is not None
        padded, rows_j = _chunk_rows_padded(item.rows, dev)
        benum = _get_program(
            "benumerate",
            (batch, padded, item.a_cap, kb_cap, gather, dt, rmk),
            item.a_cap, gather, rmk)
        keys, vals_b = benum(a_ip, a_ix, a_db, rows_j, b_ix, b_vb, b_rm)
        pend.append((item, padded, keys, vals_b,
                     _alloc_counts(keys, padded, item.table_cap,
                                   item.engine or engine)))

    # ---- One coalesced host sync sizes all chunks for the whole batch ----
    unique_counts, indptr, nnz, cap = _coalesce_and_size(pend, n)

    # ---- Wave 2: batched accumulate + device epilogue (value scatter
    # broadcast over the batch axis) ----
    epi = _Epilogue(
        devices, cap, dtype, dt, batch=batch,
        seg_caps=_shard_seg_caps(
            [p[0] for p in pend], len(devices),
            [int(uc[: len(p[0].rows)].sum()) for p, uc in
             zip(pend, unique_counts)]))
    for i, uc in enumerate(unique_counts):
        item, padded, keys, vals_b, _ = pend[i]
        pend[i] = None  # free this chunk's intermediates once consumed
        eng_name = item.engine or engine
        out_cap = _out_cap_from_counts(uc, item.table_cap, ncol_cap)
        ip_cap = keys.shape[1]
        bacc = _get_program(
            "baccumulate",
            (batch, padded, ip_cap, item.table_cap, out_cap, eng_name, dt),
            item.table_cap, out_cap, eng_name)
        cols_rb, vals_rb, counts_rb = bacc(keys, vals_b)
        starts_dev = devices[item.shard] if epi.sharded else epi.merge_dev
        epi.add_chunk(
            _ChunkRun(item, padded, out_cap, cols_rb[0], vals_rb,
                      counts_rb[0]),
            _chunk_starts(indptr, item.rows, padded, starts_dev))
    idx_buf, dat_buf_b = epi.finish()

    return jnp.asarray(indptr), idx_buf, dat_buf_b, nnz




def _execute_plan_batched_legacy(items, devices, a_shards, b_shards, n,
                                 batch, dtype, dt, kb_cap, ncol_cap, gather,
                                 engine):
    """Pre-pipelined batched reference: per-chunk allocate syncs + NumPy
    shared-structure reassembly."""
    chunks: List[_BatchChunkOut] = []
    counts_all = np.zeros(n, np.int64)
    for item in items:
        chunk = item.rows
        dev = devices[item.shard]
        a_ip, a_ix, a_db = a_shards[item.shard]
        b_ix, b_vb, b_rm = b_shards[item.shard]
        rmk = b_rm is not None
        a_cap, table_cap = item.a_cap, item.table_cap
        padded, rows_j = _chunk_rows_padded(chunk, dev)
        benum = _get_program(
            "benumerate", (batch, padded, a_cap, kb_cap, gather, dt, rmk),
            a_cap, gather, rmk)
        keys, vals_b = benum(a_ip, a_ix, a_db, rows_j, b_ix, b_vb, b_rm)
        ip_cap = keys.shape[1]
        eng_name = item.engine or engine
        out_cap = _size_out_cap(keys, padded, table_cap, eng_name, ncol_cap)
        # ---- Accumulation vmapped over the batch's value sets ----
        bacc = _get_program(
            "baccumulate",
            (batch, padded, ip_cap, table_cap, out_cap, eng_name, dt),
            table_cap, out_cap, eng_name)
        cols_rb, vals_rb, counts_rb = bacc(keys, vals_b)
        out = _BatchChunkOut(
            rows=np.asarray(chunk),
            cols=np.asarray(cols_rb[0]),
            vals=np.asarray(vals_rb),
            counts=np.asarray(counts_rb[0]),
        )
        counts_all[out.rows] = out.counts[: len(chunk)]
        chunks.append(out)

    # ---- Shared-structure reassembly: offsets computed once, the value
    # scatter broadcast over the batch axis ----
    indptr = np.zeros(n + 1, np.int64)
    np.cumsum(counts_all, out=indptr[1:])
    nnz = int(indptr[-1])
    cap = max(nnz, 1)
    indices = np.zeros(cap, np.int32)
    data_batch = np.zeros((batch, cap), dtype)
    for ck in chunks:
        pos_ok, ok, r = _scatter_positions(indptr, ck.rows, ck.counts,
                                           ck.cols.shape[1])
        indices[pos_ok] = ck.cols[:r][ok]
        data_batch[:, pos_ok] = ck.vals[:, :r][:, ok]

    return (jnp.asarray(indptr.astype(np.int32)), jnp.asarray(indices),
            jnp.asarray(data_batch), nnz)


# ---------------------------------------------------------------------------
# Streamed (out-of-core) lane — row-block tiles through the same pipeline
# ---------------------------------------------------------------------------

def tile_ranges(n_rows: int, tile_rows: int) -> List[Tuple[int, int]]:
    """Row-block tile boundaries: half-open ``[r0, r1)`` ranges of
    ``tile_rows`` rows covering ``[0, n_rows)``.  The last tile is ragged
    when ``tile_rows`` does not divide ``n_rows``; ``tile_rows >= n_rows``
    yields a single (monolithic) tile."""
    return [(r0, min(r0 + tile_rows, n_rows))
            for r0 in range(0, n_rows, tile_rows)]


def execute_plan_streamed(
    a: CSR,
    b: CSR,
    *,
    tile_rows: Optional[int] = None,
    prefetch: Optional[int] = None,
    plan: Optional[PlanCache] = None,
    engine: str = "sort",
    gather: Gather = "auto",
    row_chunk: int = 4096,
    schedule: Schedule = "grouped",
    mesh=None,
    pipeline: Pipeline = "two_wave",
    sizing: Sizing = "auto",
    autotune: Optional[AutotuneCache] = None,
    operands: Operands = "auto",
    operand_cache: Optional[OperandCache] = None,
) -> Tuple[CSR, int, Dict[str, int]]:
    """Out-of-core SpGEMM: stream A through the pipeline in row-block tiles.

    A is treated as host-resident: its CSR arrays are sliced into
    ``tile_rows`` row blocks on the host, each tile's operand arrays are
    staged host→device asynchronously (``launch.sharding.stage_tile``),
    planned through the lane's fingerprint-keyed ``PlanCache`` (tile
    patterns repeat across MCL/GNN iterations, so plans amortize), and run
    through ``execute_plan`` — every knob (engine/gather/mesh/pipeline/
    sizing/operands) means exactly what it means monolithically, applied
    per tile.  ``prefetch`` tiles may be device-resident at once: the
    scheduler stages tile *k+1* (…*k+prefetch−1*) right after dispatching
    tile *k*'s programs and before blocking on tile *k*'s result, so the
    H2D transfers overlap wave-1 compute (``prefetch_overlap_hits`` in
    ``cache_stats()`` counts the tiles that actually overlapped).

    Each completed tile is pulled back as a *compact* CSR segment (exact
    nnz, no padding) and merged on the host by the same destination-mapped
    per-segment scatter the sharded device epilogue uses
    (``phases.merge_segments_host`` — a tile is just another segment).
    Device memory therefore holds only B, ``prefetch`` tiles of A, and one
    tile's pipeline intermediates at a time, and the merged C lives in
    host memory — which is what makes the lane out-of-core: with a
    ``set_device_budget`` cap that the monolithic plan exceeds, the same
    product completes here because the per-tile estimate
    (``estimated_device_bytes`` of the tile plan) shrinks with
    ``tile_rows``.

    Tiles partition rows disjointly and every row is planned into the same
    Table-I bin with the same row content it has monolithically, so the
    merged result is bit-identical to the monolithic lane for every
    engine × gather × pipeline combination (the bit-exactness grid in
    tests/test_streaming.py).

    Returns ``(C, nnz_C, stream_info)`` where ``stream_info`` carries the
    per-call tile counters (``n_tiles``, resolved ``tile_rows`` /
    ``prefetch``, ``max_tile_ip``, ``total_ip``).
    """
    t_rows = resolve_tile_rows(tile_rows)
    depth = resolve_prefetch(prefetch)
    if plan is not None and not isinstance(plan, PlanCache):
        raise TypeError(
            "the streamed lane plans per tile, so plan= must be a "
            f"PlanCache (or None for a call-local cache); got {type(plan)!r}")
    cache = plan if plan is not None else PlanCache()
    n = a.n_rows
    # A's home is host memory in this lane; device-backed inputs are
    # materialized once here (tiny for indptr, and the indices/data pull is
    # the one-time cost of switching a resident matrix to streaming).
    a_indptr = np.asarray(a.indptr)
    a_indices = np.asarray(a.indices)
    a_data = np.asarray(a.data)
    dtype = np.dtype(a_data.dtype)
    stage_dev = merge_device(shard_devices(mesh))
    tiles = tile_ranges(n, t_rows)

    staged: List[tuple] = []
    next_tile = [0]

    def _stage(in_flight: bool) -> None:
        r0, r1 = tiles[next_tile[0]]
        lo, hi = int(a_indptr[r0]), int(a_indptr[r1])
        ipt = np.ascontiguousarray(a_indptr[r0:r1 + 1]) - a_indptr[r0]
        idx_h, dat_h = a_indices[lo:hi], a_data[lo:hi]
        try:
            faults.fire("stage_tile_fail")
            idx_d, dat_d = stage_tile((idx_h, dat_h), stage_dev)
        except faults.FaultInjected:
            # Transient host→device staging failure: staging is idempotent
            # (pure device_put of host slices), so the tile is simply
            # re-staged (docs/resilience.md).
            idx_d, dat_d = stage_tile((idx_h, dat_h), stage_dev)
        _STREAM_STATS["tile_bytes_h2d"] += int(
            ipt.nbytes + idx_h.nbytes + dat_h.nbytes)
        if in_flight:
            _STREAM_STATS["prefetch_overlap_hits"] += 1
        staged.append((r0, r1, ipt, idx_h, dat_h, idx_d, dat_d))
        next_tile[0] += 1

    segments = []
    max_tile_ip = 0
    total_ip = 0
    for _ in range(len(tiles)):
        if not staged:
            _stage(in_flight=False)
        r0, r1, ipt, idx_h, dat_h, idx_d, dat_d = staged.pop(0)
        shape_t = (r1 - r0, a.n_cols)
        # plan on the host-side slices (fingerprinting and Alg. 1 are host
        # arithmetic); compute on the staged device arrays
        tplan = cache.plan_for(CSR(ipt, idx_h, dat_h, shape_t), b)
        _STREAM_STATS["tiles_streamed"] += 1
        max_tile_ip = max(max_tile_ip, int(tplan.total_ip))
        total_ip += int(tplan.total_ip)
        run = None
        if tplan.total_ip > 0:
            run_plan = ungrouped_plan(tplan) if schedule == "natural" else tplan
            run = execute_plan(
                CSR(ipt, idx_d, dat_d, shape_t), b, run_plan, engine=engine,
                gather=gather, row_chunk=row_chunk, mesh=mesh,
                pipeline=pipeline, sizing=sizing, autotune=autotune,
                operands=operands, operand_cache=operand_cache)
        # double buffering: stage the next tile(s) while this tile's
        # dispatched programs are still executing, before blocking below
        while next_tile[0] < len(tiles) and len(staged) < depth - 1:
            _stage(in_flight=run is not None)
        if run is None:
            # a tile with zero intermediate products has only empty C rows
            segments.append((r0, r1, np.zeros(r1 - r0 + 1, np.int32),
                             np.empty(0, np.int32), np.empty(0, dtype)))
        else:
            c_t, _ = run
            t_ipt = np.asarray(c_t.indptr)  # blocks on this tile only
            t_nnz = int(t_ipt[-1])
            segments.append((r0, r1, t_ipt,
                             np.asarray(c_t.indices[:t_nnz]),
                             np.asarray(c_t.data[:t_nnz])))

    # ---- Streamed epilogue: tiles are contiguous disjoint row blocks, so
    # the merged indptr is their offset-shifted concatenation and each
    # segment lands with one destination-mapped scatter ----
    indptr = np.zeros(n + 1, np.int64)
    for r0, r1, t_ipt, _, _ in segments:
        indptr[r0 + 1:r1 + 1] = indptr[r0] + np.asarray(t_ipt[1:], np.int64)
    nnz = int(indptr[-1])
    _int32_nnz_capacity(nnz)  # int32 CSR index-space guard (raises loudly)
    idx_buf = np.empty(max(nnz, 1), np.int32)[:nnz]
    dat_buf = np.empty(max(nnz, 1), dtype)[:nnz]
    for r0, r1, t_ipt, seg_idx, seg_dat in segments:
        dest = int(indptr[r0]) + np.arange(len(seg_idx), dtype=np.int64)
        phases.merge_segments_host(idx_buf, dat_buf, seg_idx, seg_dat, dest)
    c = CSR(jnp.asarray(indptr.astype(np.int32)), jnp.asarray(idx_buf),
            jnp.asarray(dat_buf), (n, b.n_cols))
    stream_info = {
        "n_tiles": len(tiles),
        "tile_rows": t_rows,
        "prefetch": depth,
        "max_tile_ip": max_tile_ip,
        "total_ip": total_ip,
    }
    return c, nnz, stream_info
