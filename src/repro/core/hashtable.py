"""Algorithm 4 — InsertIntoTable / AddInTable, adapted to TPU semantics.

The paper's hash table uses linear probing with ``atomicCAS`` because many
GPU threads insert into one row's table concurrently.  Pallas/TPU has no
VMEM atomics, so concurrency is restructured (DESIGN.md §2): *across* rows
we parallelize with ``vmap``/the Pallas grid; *within* a row the insert
stream is sequential, which makes Algorithm 4's CAS a plain read-test-write
and — unlike the GPU version — makes accumulation order deterministic.

Hash function: ``(key * 2654435761) mod tableSize`` (Knuth multiplicative,
the paper's "multiplication and modulo"), linear probe stride 1.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

MULTIPLIER = jnp.uint32(2654435761)
EMPTY = jnp.int32(-1)


class HashTable(NamedTuple):
    keys: jax.Array  # (cap,) int32, EMPTY where unused
    vals: jax.Array  # (cap,) float
    count: jax.Array  # () int32 — uniqueCount of Algorithm 2/3


def make_table(capacity: int, dtype=jnp.float32) -> HashTable:
    return HashTable(
        keys=jnp.full((capacity,), EMPTY, jnp.int32),
        vals=jnp.zeros((capacity,), dtype),
        count=jnp.zeros((), jnp.int32),
    )


def _hash(key: jax.Array, capacity: int) -> jax.Array:
    h = key.astype(jnp.uint32) * MULTIPLIER
    if capacity & (capacity - 1) == 0:  # pow2 fast path
        return (h & jnp.uint32(capacity - 1)).astype(jnp.int32)
    return (h % jnp.uint32(capacity)).astype(jnp.int32)


def insert(table: HashTable, key: jax.Array, val: jax.Array, accumulate: bool = True) -> HashTable:
    """One Algorithm-4 insert (linear probing).  ``key`` < 0 is a no-op pad.

    A probe bound of ``capacity`` guards against a full table (the paper's
    sizing policy — capacity ≥ IP ≥ uniqueCount — guarantees a free slot,
    but an unbounded probe loop would hang on misuse; we drop instead).
    """
    cap = table.keys.shape[0]
    pos0 = _hash(jnp.maximum(key, 0), cap)

    def cond(state):
        _, done, probes, _ = state
        return (~done) & (probes < cap)

    def body(state):
        pos, _, probes, tab = state
        slot = tab.keys[pos]
        hit = slot == key
        empty = slot == EMPTY
        new_keys = jnp.where(empty, tab.keys.at[pos].set(key), tab.keys)
        add = jnp.where(hit | empty, val, 0) if accumulate else 0.0
        new_vals = tab.vals.at[pos].add(add) if accumulate else tab.vals
        new_count = tab.count + jnp.where(empty, 1, 0).astype(jnp.int32)
        done = hit | empty
        new_tab = HashTable(
            keys=jnp.where(done, new_keys, tab.keys),
            vals=jnp.where(done, new_vals, tab.vals) if accumulate else tab.vals,
            count=jnp.where(done, new_count, tab.count),
        )
        next_pos = jnp.where(done, pos, (pos + 1) % cap)
        return next_pos, done, probes + 1, new_tab

    skip = key < 0
    _, _, _, out = jax.lax.while_loop(cond, body, (pos0, skip, jnp.int32(0), table))
    return out


def insert_stream(table: HashTable, keys: jax.Array, vals: jax.Array,
                  accumulate: bool = True) -> HashTable:
    """Insert a padded stream of (key, val); keys < 0 are padding.

    This is the per-row inner loop of Algorithms 2/3/5: on the GPU the
    stream is split across PWPR lanes / TBPR warps; here it is consumed
    sequentially per row and rows are vmapped.
    """

    def body(tab, kv):
        k, v = kv
        return insert(tab, k, v, accumulate=accumulate), None

    out, _ = jax.lax.scan(body, table, (keys, vals))
    return out


def extract_sorted(table: HashTable):
    """Element gathering + column-index sorting (Algorithm 5 steps 2–3).

    Returns (cols, vals, count): entries sorted ascending by column id,
    padded with col = -1 / val = 0 at the tail.  The paper uses a bitonic
    network; ``jnp.sort`` lowers to the same class of sorting network on TPU.
    """
    cap = table.keys.shape[0]
    key = jnp.where(table.keys == EMPTY, jnp.int32(2**31 - 1), table.keys)
    order = jnp.argsort(key, stable=True)
    cols = table.keys[order]
    vals = table.vals[order]
    valid = jnp.arange(cap) < table.count
    return jnp.where(valid, cols, -1), jnp.where(valid, vals, 0), table.count
