"""Public SpGEMM API — the paper's three-phase pipeline end-to-end.

``spgemm(A, B)`` reproduces the paper's flow exactly:

  1. **Row-grouping** (host sync, like the paper's stream setup): Algorithm 1
     IP counts → Table-I groups → ``Map``.
  2. **Allocation** per group: unique-column counts → ``rpt_C``.
  3. **Accumulation** per group: hash/sort accumulate → gather → column sort.

Groups are processed with group-specific static shapes (the TPU analogue of
PWPR/TBPR + per-group hash capacities), then reassembled into one CSR in
original row order.

``spgemm_ell_fixed`` is the fully-jitted single-group variant (no host
syncs) for use inside ``scan``/training graphs (MCL iterations, GNN layers).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Literal

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import phases
from repro.core.grouping import GroupPlan, group_rows
from repro.core.ip_count import intermediate_products
from repro.sparse.formats import CSR, ELL, csr_to_ell


@dataclasses.dataclass
class SpGEMMResult:
    c: CSR
    plan: GroupPlan
    info: Dict[str, float]


def _next_pow2(x: int) -> int:
    return 1 << int(np.ceil(np.log2(max(int(x), 1))))


def spgemm(
    a: CSR,
    b: CSR,
    method: Literal["hash", "sort"] = "sort",
    row_chunk: int = 4096,
    schedule: Literal["grouped", "natural"] = "grouped",
) -> SpGEMMResult:
    """C = A @ B via the paper's multi-phase pipeline (host-orchestrated).

    ``schedule="natural"`` disables the Table-I row grouping (every row
    processed at the global worst-case capacity, natural order) — the
    "without AIA scheduling" software baseline of Fig. 7.
    """
    assert a.n_cols == b.n_rows, (a.shape, b.shape)
    # ---- Phase 1: row grouping (one host sync, as in the paper) ----
    plan = group_rows(a, b)
    if schedule == "natural":
        plan = _ungrouped_plan(plan)
    kb_cap = int(np.asarray(b.row_nnz()).max(initial=0)) or 1
    b_ell = csr_to_ell(b, kb_cap)

    a_indptr = np.asarray(a.indptr)
    a_row_nnz = a_indptr[1:] - a_indptr[:-1]

    n = a.n_rows
    out_cols_np = [None] * n
    out_vals_np = [None] * n
    counts_np = np.zeros(n, np.int64)

    for g in range(4):
        rows = plan.rows_of_group(g)
        if len(rows) == 0:
            continue
        a_cap = max(int(a_row_nnz[rows].max(initial=0)), 1)
        table_cap = plan.table_capacities[g]
        for lo in range(0, len(rows), row_chunk):
            chunk = rows[lo: lo + row_chunk]
            pad = -np.ones(_pad_len(len(chunk)) - len(chunk), np.int32)
            rows_j = jnp.asarray(np.concatenate([chunk, pad]))
            cols_a, vals_a = phases.gather_group_rows(
                a.indptr, a.indices, a.data, rows_j, a_cap
            )
            keys, vals = phases.enumerate_products(
                cols_a, vals_a, b_ell.indices, b_ell.data
            )
            # ---- Phase 2: allocation ----
            if method == "hash":
                counts = phases.allocate_hash(keys, table_cap)
            else:
                counts = phases.allocate_sort(keys)
            max_unique = int(np.asarray(counts).max(initial=0))
            out_cap = min(_next_pow2(max_unique), max(table_cap, 1))
            out_cap = max(out_cap, 1)
            # ---- Phase 3: accumulation ----
            if method == "hash":
                cols_r, vals_r, counts_r = phases.accumulate_hash(keys, vals, table_cap)
                # hash table capacity may exceed out_cap; trim to sorted prefix
                cols_r, vals_r = cols_r[:, :out_cap], vals_r[:, :out_cap]
            else:
                cols_r, vals_r, counts_r = phases.accumulate_sort(keys, vals, out_cap)
            cols_r = np.asarray(cols_r)
            vals_r = np.asarray(vals_r)
            counts_r = np.asarray(counts_r)
            for i, r in enumerate(chunk):
                c = int(counts_r[i])
                out_cols_np[r] = cols_r[i, :c]
                out_vals_np[r] = vals_r[i, :c]
                counts_np[r] = c

    # ---- Reassemble C in original row order ----
    nnz = int(counts_np.sum())
    indptr = np.zeros(n + 1, np.int32)
    indptr[1:] = np.cumsum(counts_np)
    cap = max(nnz, 1)
    indices = np.zeros(cap, np.int32)
    data = np.zeros(cap, np.asarray(a.data).dtype)
    for r in range(n):
        if counts_np[r]:
            s = indptr[r]
            indices[s: s + counts_np[r]] = out_cols_np[r]
            data[s: s + counts_np[r]] = out_vals_np[r]
    c = CSR(jnp.asarray(indptr), jnp.asarray(indices), jnp.asarray(data),
            (a.n_rows, b.n_cols))
    info = spgemm_info(a, b, plan, nnz)
    return SpGEMMResult(c=c, plan=plan, info=info)


def _pad_len(k: int, quantum: int = 8) -> int:
    return int(np.ceil(k / quantum) * quantum)


def _ungrouped_plan(plan: GroupPlan) -> GroupPlan:
    """Collapse to one natural-order group at worst-case capacity."""
    n = len(plan.map_rows)
    cap = _next_pow2(max(plan.max_ip, 2))
    return GroupPlan(
        map_rows=np.arange(n, dtype=np.int32),
        group_id=np.zeros(n, np.int32),
        group_offsets=np.asarray([0, n, n, n, n], np.int32),
        group_sizes=(n, 0, 0, 0),
        group_sizes_padded=(n, 0, 0, 0),
        table_capacities=(cap, cap, cap, cap),
        max_ip=plan.max_ip,
        total_ip=plan.total_ip,
    )


def spgemm_info(a: CSR, b: CSR, plan: GroupPlan, nnz_c: int) -> Dict[str, float]:
    """Hardware-independent counters used throughout EXPERIMENTS.md."""
    total_ip = plan.total_ip
    return {
        "nnz_a": int(np.asarray(a.nnz)),
        "nnz_b": int(np.asarray(b.nnz)),
        "nnz_c": int(nnz_c),
        "intermediate_products": int(total_ip),
        "flops": 2.0 * total_ip,  # paper's FLOP definition (§VI Methodology)
        "compression_ratio": float(total_ip) / max(nnz_c, 1),
        "group_sizes": list(plan.group_sizes),
        "max_ip": plan.max_ip,
    }


# ---------------------------------------------------------------------------
# Fully-jitted fixed-capacity variant (for scan/training graphs)
# ---------------------------------------------------------------------------

def spgemm_ell_fixed(a: ELL, b: ELL, out_cap: int) -> ELL:
    """C = A @ B entirely in-graph: single group, sort engine, static caps.

    Row capacity of C is ``out_cap`` (entries beyond it are dropped — size it
    from Algorithm-1 IP bounds).  Suitable inside ``lax.scan`` (MCL) and
    model forward passes.
    """
    keys, vals = phases.enumerate_products(
        jnp.asarray(a.indices), jnp.asarray(a.data), b.indices, b.data
    )
    cols, out_vals, _ = phases._sort_unique(keys, vals, out_cap)
    return ELL(cols, out_vals, (a.shape[0], b.shape[1]))
