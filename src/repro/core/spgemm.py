"""Public SpGEMM API — the paper's three-phase pipeline end-to-end.

``spgemm(A, B)`` reproduces the paper's flow:

  1. **Row-grouping** (host sync, like the paper's stream setup): Algorithm 1
     IP counts → Table-I groups → ``Map``.
  2. **Allocation + accumulation** per group, compiled and dispatched by the
     plan executor (``repro.core.executor``): cached jitted programs, one per
     (group shape, engine, gather backend) signature.
  3. **Reassembly** into one CSR in original row order via vectorized
     inverse-permutation scatters.

This module is a thin façade: engine registration, capacity policy, gather
backends, the program cache, and reassembly all live in the executor.

Amortized entry points (both delegate to the executor's amortization
layer):

* ``spgemm(..., plan=)`` — pass a ``GroupPlan`` to skip phase 1 outright,
  or a ``PlanCache`` to skip it whenever the operands' sparsity patterns
  were seen before (iterative workloads: MCL expansion at fixpoint,
  epoch-revisited GNN mini-batches).
* ``spgemm_batched`` — one planned pipeline run for a batch of
  same-pattern operands (values differ, structure shared); bit-identical
  to a per-matrix loop.

``spgemm_ell_fixed`` is the fully-jitted single-group variant (no host
syncs) for use inside ``scan``/training graphs (MCL iterations, GNN layers).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Literal, Optional, Sequence, Union

import jax.numpy as jnp
import numpy as np

from repro.core import executor, phases
from repro.core.executor import PlanCache
from repro.core.grouping import GroupPlan, group_rows
from repro.sparse.formats import CSR, ELL

PlanLike = Union[GroupPlan, PlanCache, None]


@dataclasses.dataclass
class SpGEMMResult:
    """One SpGEMM product: the CSR result ``c``, the ``GroupPlan`` that
    executed it (reusable via ``spgemm(plan=...)``), and the ``info``
    counter dict (``nnz_c``, ``intermediate_products``, ``flops``,
    ``compression_ratio``, ``group_sizes``, ``n_shards``...)."""

    c: CSR
    plan: GroupPlan
    info: Dict[str, float]


@dataclasses.dataclass
class SpGEMMBatchResult:
    """Batched product: ``cs[i] = a_batch[i] @ b_batch[i]``; every member
    shares one output structure (indptr/indices are the same arrays)."""

    cs: List[CSR]
    plan: GroupPlan
    info: Dict[str, float]


def _resolve_plan(a: CSR, b: CSR, plan: PlanLike) -> GroupPlan:
    """Phase 1, amortized: reuse a given plan, consult a PlanCache, or run
    ``group_rows`` (the paper's per-matrix setup)."""
    if isinstance(plan, PlanCache):
        return plan.plan_for(a, b)
    if isinstance(plan, GroupPlan):
        return plan
    if plan is not None:
        raise TypeError(
            f"plan must be a GroupPlan, PlanCache, or None; got {type(plan)!r}")
    return group_rows(a, b)


def spgemm(
    a: CSR,
    b: CSR,
    method: Optional[Literal["hash", "sort"]] = None,
    row_chunk: int = 4096,
    schedule: Literal["grouped", "natural"] = "grouped",
    engine: Optional[str] = None,
    gather: executor.Gather = "auto",
    mesh=None,
    plan: PlanLike = None,
    pipeline: executor.Pipeline = "two_wave",
    sizing: executor.Sizing = "auto",
    autotune: Optional[executor.AutotuneCache] = None,
    operands: executor.Operands = "auto",
    operand_cache: Optional[executor.OperandCache] = None,
    on_budget: executor.OnBudget = "error",
) -> SpGEMMResult:
    """C = A @ B via the paper's multi-phase pipeline (plan-compiled).

    ``engine`` selects the allocation/accumulation engine from the executor
    registry (``"hash"``, ``"sort"``, ``"fused_hash"``; ``method`` is the
    legacy alias), or ``"auto"`` for per-bin adaptive dispatch: each
    Table-I group runs the engine the ``AutotuneCache`` resolved for it
    (static backend seed refined by measured per-bin timings; pass
    ``autotune=`` to scope the cache, default the executor module cache).
    ``gather`` selects how B rows are served: ``"xla"`` (software-only
    baseline), ``"aia"`` (scalar-prefetch Pallas kernels), or ``"auto"``
    (AIA on TPU) — the paper's Fig. 7 ablation axis.
    ``schedule="natural"`` disables the Table-I row grouping (every row
    processed at the global worst-case capacity, natural order) — the
    "without AIA scheduling" software baseline.
    ``mesh`` (a ``jax.Mesh``, e.g. ``launch.mesh.make_spgemm_mesh()``)
    partitions the plan's row ranges across the mesh's devices and runs the
    group programs shard-locally; results are bit-identical to ``mesh=None``.
    ``plan`` amortizes phase 1: a ``GroupPlan`` is used as-is (caller
    guarantees it matches the operands' support), a ``PlanCache`` skips
    ``group_rows`` whenever the operands' sparsity patterns were seen
    before (hits/misses surface in ``executor.cache_stats()``).
    ``pipeline`` selects the executor's sync structure: ``"two_wave"``
    (default) pays one coalesced allocate host sync for all chunks and
    reassembles the CSR on device; ``"legacy"`` is the per-chunk-sync
    NumPy-reassembly reference path (A/B benchmarking).
    ``sizing`` selects how output capacities are found: ``"measured"``
    syncs the uniqueCounts, ``"planned"`` derives sync-free bounds from
    the plan's Alg. 1 IP counts — the executor dispatches the whole call
    with zero blocking host syncs and the host stalls only once, at the
    end, when this façade materializes ``info["nnz_c"]`` (use
    ``executor.execute_plan`` directly for a fully non-blocking device
    handle); ``"auto"`` picks planned for fused engines (``"fused_hash"``)
    and measured otherwise.
    ``operands`` selects the B-side placement under ``mesh=``: ``"auto"``
    (default) ships each shard only the footprint-gathered B block its
    work items' A-support touches (full replica when a shard's footprint
    covers ≥ ~70% of B's rows); ``"footprint"``/``"replicate"`` force
    either path — all bit-identical, with the comm volume surfaced in
    ``executor.cache_stats()``.
    ``operand_cache`` scopes the B-side placement cache (``None`` = the
    executor's module cache); the serving layer passes a per-tenant
    instance so placements are quota'd per tenant.
    ``on_budget`` picks what happens when the plan's
    ``estimated_device_bytes`` exceeds ``executor.set_device_budget``:
    ``"error"`` (default) raises ``DeviceBudgetExceeded``, ``"stream"``
    degrades gracefully — the call transparently re-routes through
    ``spgemm_streamed`` with ``tile_rows`` auto-derived so every tile
    fits the budget, bit-identical to the monolithic result
    (``cache_stats()['budget_degradations']`` counts the re-routes; see
    docs/resilience.md).  Inert when no budget is configured.
    """
    assert a.n_cols == b.n_rows, (a.shape, b.shape)
    engine = executor.resolve_engine(engine, method)
    on_budget = executor.resolve_on_budget(on_budget)
    # ---- Phase 1: row grouping (one host sync, amortized via ``plan``) ----
    plan = _resolve_plan(a, b, plan)
    run_plan = plan
    if schedule == "natural":
        run_plan = executor.ungrouped_plan(plan)
    budget = executor.device_budget()
    if on_budget == "stream" and budget is not None:
        itemsize = np.dtype(np.asarray(a.data).dtype).itemsize
        if executor.estimated_device_bytes(plan, itemsize) > budget:
            return _degrade_to_stream(
                a, b, plan, run_plan, itemsize, method=method,
                row_chunk=row_chunk, schedule=schedule, engine=engine,
                gather=gather, mesh=mesh, pipeline=pipeline, sizing=sizing,
                autotune=autotune, operands=operands,
                operand_cache=operand_cache)
    # ---- Phases 2+3: compiled group pipeline + device-side reassembly ----
    c, nnz = executor.execute_plan(
        a, b, run_plan, engine=engine, gather=gather, row_chunk=row_chunk,
        mesh=mesh, pipeline=pipeline, sizing=sizing, autotune=autotune,
        operands=operands, operand_cache=operand_cache,
    )
    info = spgemm_info(a, b, run_plan, nnz, mesh=mesh)
    return SpGEMMResult(c=c, plan=run_plan, info=info)


def spgemm_info(a: CSR, b: CSR, plan: GroupPlan, nnz_c: int,
                mesh=None) -> Dict[str, float]:
    """Hardware-independent counters used throughout EXPERIMENTS.md."""
    total_ip = plan.total_ip
    return {
        "n_shards": 1 if mesh is None else int(np.prod(
            np.asarray(mesh.devices).shape)),
        "nnz_a": int(np.asarray(a.nnz)),
        "nnz_b": int(np.asarray(b.nnz)),
        "nnz_c": int(nnz_c),
        "intermediate_products": int(total_ip),
        "flops": 2.0 * total_ip,  # paper's FLOP definition (§VI Methodology)
        "compression_ratio": float(total_ip) / max(nnz_c, 1),
        "group_sizes": list(plan.group_sizes),
        "max_ip": plan.max_ip,
    }


def _degrade_to_stream(a, b, plan, run_plan, itemsize, *, method, row_chunk,
                       schedule, engine, gather, mesh, pipeline, sizing,
                       autotune, operands, operand_cache) -> SpGEMMResult:
    """``on_budget="stream"``'s graceful-degradation path (docs/resilience.md).

    The monolithic plan's estimate exceeds the device budget, so the call
    re-routes through ``spgemm_streamed`` with the largest ``tile_rows``
    whose worst row-block tile still fits (``executor.
    derive_degradation_tile_rows``) — bit-identical to the monolithic
    result, just with a tiled memory envelope.  The returned
    ``SpGEMMResult`` keeps the monolithic ``run_plan`` (it is still the
    pattern's reusable plan) and marks ``info`` with ``degraded_to_stream``
    plus the streamed lane's tile counters.
    """
    tile_rows = executor.derive_degradation_tile_rows(
        plan, a.n_rows, itemsize)
    executor._RESILIENCE_STATS["budget_degradations"] += 1
    sres = spgemm_streamed(
        a, b, tile_rows=tile_rows, method=method, row_chunk=row_chunk,
        schedule=schedule, engine=engine, gather=gather, mesh=mesh,
        pipeline=pipeline, sizing=sizing, autotune=autotune,
        operands=operands, operand_cache=operand_cache)
    info = dict(sres.info)
    info["degraded_to_stream"] = 1
    return SpGEMMResult(c=sres.c, plan=run_plan, info=info)


# ---------------------------------------------------------------------------
# Streamed (out-of-core) SpGEMM over row-block tiles of A
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class SpGEMMStreamResult:
    """Streamed product: the merged CSR ``c`` plus ``info`` extended with
    the lane's tile counters (``n_tiles``, resolved ``tile_rows`` /
    ``prefetch``, ``max_tile_ip``).  There is no single ``plan`` field:
    each row-block tile executed its own ``GroupPlan``, served and
    retained by the lane's ``PlanCache`` (pass ``plan=`` to keep it across
    calls and iterations)."""

    c: CSR
    info: Dict[str, float]


def spgemm_streamed(
    a: CSR,
    b: CSR,
    *,
    tile_rows: Optional[int] = None,
    prefetch: int = 2,
    method: Optional[Literal["hash", "sort"]] = None,
    row_chunk: int = 4096,
    schedule: Literal["grouped", "natural"] = "grouped",
    engine: Optional[str] = None,
    gather: executor.Gather = "auto",
    mesh=None,
    plan: Optional[PlanCache] = None,
    pipeline: executor.Pipeline = "two_wave",
    sizing: executor.Sizing = "auto",
    autotune: Optional[executor.AutotuneCache] = None,
    operands: executor.Operands = "auto",
    operand_cache: Optional[executor.OperandCache] = None,
) -> SpGEMMStreamResult:
    """C = A @ B out-of-core: stream A through the pipeline in row-block
    tiles instead of allocating the whole product's working set at once.

    A is sliced into ``tile_rows`` row blocks on the host; each tile is
    staged host→device asynchronously, planned through the fingerprint-
    keyed ``PlanCache`` (tile patterns repeat across MCL/GNN iterations,
    so planning amortizes exactly like the monolithic ``plan=`` path), run
    through the same compiled pipeline, and merged back on the host by the
    sharded epilogue's destination-mapped segment scatter — a tile is just
    another segment.  The merged result is **bit-identical** to
    ``spgemm`` for every engine × gather × pipeline combination; what
    changes is the memory envelope: the device holds only B, ``prefetch``
    staged tiles of A, and one tile's intermediates at a time (see
    docs/streaming.md for the peak-bytes model), which is how a graph
    whose monolithic plan exceeds ``executor.set_device_budget`` still
    completes.

    ``tile_rows`` (default ``executor.DEFAULT_TILE_ROWS``) sets the tile
    height; ``tile_rows >= n_rows(A)`` collapses to a single monolithic
    tile.  ``prefetch`` (default 2: double buffering) bounds the tiles in
    flight — tile *k+1*'s H2D transfer overlaps tile *k*'s compute, and
    ``cache_stats()['prefetch_overlap_hits']`` counts the overlaps
    actually achieved (``tiles_streamed`` / ``tile_bytes_h2d`` accumulate
    alongside).  ``plan`` must be a ``PlanCache`` (or None for a
    call-local one): the lane plans per tile, so a single ``GroupPlan``
    cannot apply.  Every other knob means exactly what it means for
    ``spgemm``, applied per tile.
    """
    assert a.n_cols == b.n_rows, (a.shape, b.shape)
    engine = executor.resolve_engine(engine, method)
    # validate the streaming knobs at entry, like every other knob
    executor.resolve_tile_rows(tile_rows)
    executor.resolve_prefetch(prefetch)
    if plan is not None and not isinstance(plan, PlanCache):
        raise TypeError(
            "spgemm_streamed plans per tile, so plan= must be a PlanCache "
            f"(or None for a call-local cache); got {type(plan)!r}")
    c, nnz, stream = executor.execute_plan_streamed(
        a, b, tile_rows=tile_rows, prefetch=prefetch, plan=plan,
        engine=engine, gather=gather, row_chunk=row_chunk,
        schedule=schedule, mesh=mesh, pipeline=pipeline, sizing=sizing,
        autotune=autotune, operands=operands, operand_cache=operand_cache,
    )
    total_ip = stream["total_ip"]
    info = {
        "n_shards": 1 if mesh is None else int(np.prod(
            np.asarray(mesh.devices).shape)),
        "nnz_a": int(np.asarray(a.nnz)),
        "nnz_b": int(np.asarray(b.nnz)),
        "nnz_c": int(nnz),
        "intermediate_products": int(total_ip),
        "flops": 2.0 * total_ip,
        "compression_ratio": float(total_ip) / max(nnz, 1),
        **stream,
    }
    return SpGEMMStreamResult(c=c, info=info)


# ---------------------------------------------------------------------------
# Batched SpGEMM over same-pattern operands
# ---------------------------------------------------------------------------

def _as_members(x, what: str) -> List[CSR]:
    if isinstance(x, CSR):
        return [x]
    members = list(x)
    if not members:
        raise ValueError(f"{what} must contain at least one matrix")
    return members


def _require_same_pattern(mats: List[CSR], what: str) -> None:
    t = mats[0]
    t_indptr = None
    for i, m in enumerate(mats[1:], 1):
        if (m.shape == t.shape and m.indptr is t.indptr
                and m.indices is t.indices):
            continue  # shared structure arrays (e.g. reweighted members)
        if t_indptr is None:
            t_indptr = np.asarray(t.indptr)
            nnz = int(t_indptr[-1])
            t_indices = np.asarray(t.indices)[:nnz]
        if (m.shape != t.shape
                or not np.array_equal(np.asarray(m.indptr), t_indptr)
                or not np.array_equal(np.asarray(m.indices)[:nnz], t_indices)):
            raise ValueError(
                f"{what}[{i}] does not share {what}[0]'s sparsity pattern; "
                "spgemm_batched requires structure-identical operands "
                "(values may differ)")


def _stack_values(mats: List[CSR], template: CSR, batch: int) -> np.ndarray:
    """(batch, capacity) value stack aligned to the template's slots."""
    cap = int(template.indices.shape[0])
    nnz = int(np.asarray(template.indptr)[-1])
    out = np.zeros((batch, cap), np.asarray(template.data).dtype)
    for i in range(batch):
        m = mats[i % len(mats)]  # len 1 broadcasts
        out[i, :nnz] = np.asarray(m.data)[:nnz]
    return out


def spgemm_batched(
    a_batch: Union[CSR, Sequence[CSR]],
    b_batch: Union[CSR, Sequence[CSR]],
    method: Optional[Literal["hash", "sort"]] = None,
    row_chunk: int = 4096,
    schedule: Literal["grouped", "natural"] = "grouped",
    engine: Optional[str] = None,
    gather: executor.Gather = "auto",
    mesh=None,
    plan: PlanLike = None,
    pipeline: executor.Pipeline = "two_wave",
    sizing: executor.Sizing = "auto",
    autotune: Optional[executor.AutotuneCache] = None,
    operands: executor.Operands = "auto",
    operand_cache: Optional[executor.OperandCache] = None,
) -> SpGEMMBatchResult:
    """``cs[i] = a_batch[i] @ b_batch[i]`` for same-pattern operand batches.

    Either side may be a single ``CSR`` (its values are shared by every
    batch member) or a sequence of CSRs that all share one sparsity pattern
    (values free to differ) — the GNN mini-batch / iterative-reweighting
    regime.  The plan runs **once** for the whole batch; enumerate keys,
    allocation host syncs, output structure, and reassembly offsets are all
    amortized, and only the value streams are vmapped.  Results are
    bit-identical to looping ``spgemm`` over the members, for every
    engine × gather combination, single- and multi-device (``mesh=``).
    ``sizing`` mirrors ``spgemm``: planned (the fused-engine default)
    sizes the whole batch from Alg. 1 bounds with zero blocking syncs.
    ``operand_cache`` scopes the B-side placement cache as in ``spgemm``.
    """
    a_members = _as_members(a_batch, "a_batch")
    b_members = _as_members(b_batch, "b_batch")
    batch = max(len(a_members), len(b_members))
    if len(a_members) not in (1, batch) or len(b_members) not in (1, batch):
        raise ValueError(
            f"batch mismatch: {len(a_members)} A members vs "
            f"{len(b_members)} B members")
    a, b = a_members[0], b_members[0]
    assert a.n_cols == b.n_rows, (a.shape, b.shape)
    engine = executor.resolve_engine(engine, method)
    _require_same_pattern(a_members, "a_batch")
    _require_same_pattern(b_members, "b_batch")

    plan = _resolve_plan(a, b, plan)
    run_plan = plan
    if schedule == "natural":
        run_plan = executor.ungrouped_plan(plan)

    a_data = _stack_values(a_members, a, batch)
    b_data = None if len(b_members) == 1 else _stack_values(b_members, b, batch)
    indptr, indices, data_batch, nnz = executor.execute_plan_batched(
        a, b, a_data, b_data, run_plan, engine=engine, gather=gather,
        row_chunk=row_chunk, mesh=mesh, pipeline=pipeline, sizing=sizing,
        autotune=autotune, operands=operands, operand_cache=operand_cache,
    )
    indptr_j = jnp.asarray(indptr)
    indices_j = jnp.asarray(indices)
    shape = (a.n_rows, b.n_cols)
    cs = [CSR(indptr_j, indices_j, jnp.asarray(data_batch[i]), shape)
          for i in range(batch)]
    info = spgemm_info(a, b, run_plan, nnz, mesh=mesh)
    info["batch"] = batch
    return SpGEMMBatchResult(cs=cs, plan=run_plan, info=info)


# ---------------------------------------------------------------------------
# Fully-jitted fixed-capacity variant (for scan/training graphs)
# ---------------------------------------------------------------------------

def spgemm_ell_fixed(a: ELL, b: ELL, out_cap: int, engine: str = "sort") -> ELL:
    """C = A @ B entirely in-graph: single group, static caps.

    Row capacity of C is ``out_cap`` (entries beyond it are dropped — size it
    from Algorithm-1 IP bounds).  Suitable inside ``lax.scan`` (MCL) and
    model forward passes.  The engine is resolved through the executor
    registry; both registered engines are jit/scan-compatible.
    """
    engine = executor.resolve_engine(engine)
    if engine == executor.AUTO_ENGINE:
        raise ValueError(
            "spgemm_ell_fixed runs a single fixed-capacity group, so there "
            "are no Table-I bins for engine='auto' to dispatch over; pick a "
            f"concrete engine: {', '.join(executor.available_engines())}")
    keys, vals = phases.enumerate_products(
        jnp.asarray(a.indices), jnp.asarray(a.data), b.indices, b.data
    )
    eng = executor.get_engine(engine)
    cols, out_vals, _ = eng.accumulate(keys, vals, out_cap, out_cap)
    return ELL(cols, out_vals, (a.shape[0], b.shape[1]))
