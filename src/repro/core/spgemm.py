"""Public SpGEMM API — the paper's three-phase pipeline end-to-end.

``spgemm(A, B)`` reproduces the paper's flow:

  1. **Row-grouping** (host sync, like the paper's stream setup): Algorithm 1
     IP counts → Table-I groups → ``Map``.
  2. **Allocation + accumulation** per group, compiled and dispatched by the
     plan executor (``repro.core.executor``): cached jitted programs, one per
     (group shape, engine, gather backend) signature.
  3. **Reassembly** into one CSR in original row order via vectorized
     inverse-permutation scatters.

This module is a thin façade: engine registration, capacity policy, gather
backends, the program cache, and reassembly all live in the executor.

``spgemm_ell_fixed`` is the fully-jitted single-group variant (no host
syncs) for use inside ``scan``/training graphs (MCL iterations, GNN layers).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Literal, Optional

import jax.numpy as jnp
import numpy as np

from repro.core import executor, phases
from repro.core.grouping import GroupPlan, group_rows
from repro.sparse.formats import CSR, ELL


@dataclasses.dataclass
class SpGEMMResult:
    c: CSR
    plan: GroupPlan
    info: Dict[str, float]


def spgemm(
    a: CSR,
    b: CSR,
    method: Optional[Literal["hash", "sort"]] = None,
    row_chunk: int = 4096,
    schedule: Literal["grouped", "natural"] = "grouped",
    engine: Optional[str] = None,
    gather: executor.Gather = "auto",
    mesh=None,
) -> SpGEMMResult:
    """C = A @ B via the paper's multi-phase pipeline (plan-compiled).

    ``engine`` selects the allocation/accumulation engine from the executor
    registry (``"hash"`` or ``"sort"``; ``method`` is the legacy alias).
    ``gather`` selects how B rows are served: ``"xla"`` (software-only
    baseline), ``"aia"`` (scalar-prefetch Pallas kernels), or ``"auto"``
    (AIA on TPU) — the paper's Fig. 7 ablation axis.
    ``schedule="natural"`` disables the Table-I row grouping (every row
    processed at the global worst-case capacity, natural order) — the
    "without AIA scheduling" software baseline.
    ``mesh`` (a ``jax.Mesh``, e.g. ``launch.mesh.make_spgemm_mesh()``)
    partitions the plan's row ranges across the mesh's devices and runs the
    group programs shard-locally; results are bit-identical to ``mesh=None``.
    """
    assert a.n_cols == b.n_rows, (a.shape, b.shape)
    if engine is None:
        engine = method or "sort"
    elif method is not None and method != engine:
        raise ValueError(
            f"conflicting method={method!r} (legacy alias) and engine={engine!r}")
    # ---- Phase 1: row grouping (one host sync, as in the paper) ----
    plan = group_rows(a, b)
    if schedule == "natural":
        plan = executor.ungrouped_plan(plan)
    # ---- Phases 2+3: compiled group pipeline + vectorized reassembly ----
    c, nnz = executor.execute_plan(
        a, b, plan, engine=engine, gather=gather, row_chunk=row_chunk,
        mesh=mesh,
    )
    info = spgemm_info(a, b, plan, nnz, mesh=mesh)
    return SpGEMMResult(c=c, plan=plan, info=info)


def spgemm_info(a: CSR, b: CSR, plan: GroupPlan, nnz_c: int,
                mesh=None) -> Dict[str, float]:
    """Hardware-independent counters used throughout EXPERIMENTS.md."""
    total_ip = plan.total_ip
    return {
        "n_shards": 1 if mesh is None else int(np.prod(
            np.asarray(mesh.devices).shape)),
        "nnz_a": int(np.asarray(a.nnz)),
        "nnz_b": int(np.asarray(b.nnz)),
        "nnz_c": int(nnz_c),
        "intermediate_products": int(total_ip),
        "flops": 2.0 * total_ip,  # paper's FLOP definition (§VI Methodology)
        "compression_ratio": float(total_ip) / max(nnz_c, 1),
        "group_sizes": list(plan.group_sizes),
        "max_ip": plan.max_ip,
    }


# ---------------------------------------------------------------------------
# Fully-jitted fixed-capacity variant (for scan/training graphs)
# ---------------------------------------------------------------------------

def spgemm_ell_fixed(a: ELL, b: ELL, out_cap: int, engine: str = "sort") -> ELL:
    """C = A @ B entirely in-graph: single group, static caps.

    Row capacity of C is ``out_cap`` (entries beyond it are dropped — size it
    from Algorithm-1 IP bounds).  Suitable inside ``lax.scan`` (MCL) and
    model forward passes.  The engine is resolved through the executor
    registry; both registered engines are jit/scan-compatible.
    """
    keys, vals = phases.enumerate_products(
        jnp.asarray(a.indices), jnp.asarray(a.data), b.indices, b.data
    )
    eng = executor.get_engine(engine)
    cols, out_vals, _ = eng.accumulate(keys, vals, out_cap, out_cap)
    return ELL(cols, out_vals, (a.shape[0], b.shape[1]))
