"""Deterministic synthetic token pipeline.

Fault-tolerance contract (DESIGN.md §6): the batch at step ``t`` is a pure
function of ``(seed, t)`` — after a restart-from-checkpoint the stream
resumes bit-identically, so recovery reproduces the exact gradient sequence.
Host sharding: each data-parallel host materializes only its local slice.

The "dataset" is a mixture of Zipfian unigrams with Markov bigram structure,
enough signal for loss-decrease integration tests on ~100M-param models.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import numpy as np


@dataclasses.dataclass
class TokenPipeline:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    local_slice: slice = slice(None)  # this host's rows of the global batch
    prefetch: int = 2

    def _rng(self, step: int) -> np.random.Generator:
        return np.random.default_rng(
            np.random.SeedSequence([self.seed, step, 0xA1A]))

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        """The full deterministic global batch for ``step`` (then sliced)."""
        rng = self._rng(step)
        b, s, v = self.global_batch, self.seq_len, self.vocab
        # Zipfian unigram base
        ranks = np.arange(1, v + 1)
        probs = 1.0 / ranks
        probs /= probs.sum()
        toks = rng.choice(v, size=(b, s + 1), p=probs)
        # inject learnable bigram structure: after token t comes (t*7+3)%v
        # with prob .5
        follow = (toks[:, :-1] * 7 + 3) % v
        coin = rng.random((b, s)) < 0.5
        toks[:, 1:] = np.where(coin, follow, toks[:, 1:])
        tokens = toks[:, :-1].astype(np.int32)
        labels = toks[:, 1:].astype(np.int32)
        tokens = tokens[self.local_slice]
        labels = labels[self.local_slice]
        return {"tokens": tokens, "labels": labels}

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


def synthetic_batch(cfg, shape_spec, rng: Optional[np.random.Generator] = None,
                    batch_override: Optional[int] = None) -> Dict:
    """One batch (numpy) matching an (arch, shape) cell, incl. stub inputs."""
    rng = rng or np.random.default_rng(0)
    b = batch_override or shape_spec.global_batch
    s = shape_spec.seq_len
    out = {
        "tokens": rng.integers(0, cfg.vocab, (b, s)).astype(np.int32),
        "labels": rng.integers(0, cfg.vocab, (b, s)).astype(np.int32),
    }
    if cfg.frontend == "vision_stub":
        out["vision_embeds"] = rng.standard_normal(
            (b, cfg.vision_patches, cfg.d_model)).astype(np.float32)
    if cfg.encoder_layers:
        out["frames"] = rng.standard_normal(
            (b, cfg.encoder_seq, cfg.d_model)).astype(np.float32)
    return out
