"""Deterministic data pipeline (restart-reproducible, sharding-aware)."""
from repro.data.pipeline import TokenPipeline, synthetic_batch

__all__ = ["TokenPipeline", "synthetic_batch"]
