"""Fault-tolerant trainer loop (DESIGN.md §6).

* periodic async checkpoints; on any step failure (device loss, preemption —
  surfaced as exceptions from the step call) the trainer restores the last
  complete checkpoint and replays — the deterministic pipeline guarantees
  the replayed batches are identical.
* ``StragglerMonitor`` tracks a step-time EWMA and flags outliers (the hook
  a fleet scheduler would use to evict/re-shard slow hosts).
* ``failure_injector`` lets tests kill arbitrary steps to exercise recovery.
"""
from __future__ import annotations

import dataclasses
import logging
import time
from typing import Callable, Dict, List, Optional

import jax
import numpy as np

log = logging.getLogger(__name__)

from repro.checkpoint import AsyncCheckpointer, latest_step, restore_checkpoint
from repro.data.pipeline import TokenPipeline


@dataclasses.dataclass
class StragglerMonitor:
    alpha: float = 0.2
    threshold: float = 2.5  # flag steps slower than threshold × EWMA
    ewma: Optional[float] = None
    flagged: List[int] = dataclasses.field(default_factory=list)

    def observe(self, step: int, dt: float) -> bool:
        is_straggler = self.ewma is not None and dt > self.threshold * self.ewma
        self.ewma = dt if self.ewma is None else \
            (1 - self.alpha) * self.ewma + self.alpha * dt
        if is_straggler:
            self.flagged.append(step)
        return is_straggler


@dataclasses.dataclass
class TrainerConfig:
    total_steps: int = 100
    checkpoint_every: int = 20
    checkpoint_dir: str = "/tmp/repro_ckpt"
    max_restarts: int = 3
    log_every: int = 10


class Trainer:
    def __init__(self, cfg: TrainerConfig, step_fn: Callable, state,
                 pipeline: TokenPipeline,
                 failure_injector: Optional[Callable[[int], None]] = None,
                 to_device: Optional[Callable] = None):
        self.cfg = cfg
        self.step_fn = step_fn
        self.state = state
        self.pipeline = pipeline
        self.failure_injector = failure_injector
        self.to_device = to_device or (lambda b: {k: jax.numpy.asarray(v)
                                                  for k, v in b.items()})
        self.ckpt = AsyncCheckpointer(cfg.checkpoint_dir)
        self.monitor = StragglerMonitor()
        self.history: List[Dict[str, float]] = []
        self.restarts = 0
        # one record per recovered failure: (step, repr(error)) — surfaced
        # instead of silently discarded, so operators can see what killed
        # which steps after the run completes
        self.failures: List[tuple] = []

    def _restore_latest(self):
        step = latest_step(self.cfg.checkpoint_dir)
        if step is None:
            raise RuntimeError("no checkpoint to restore from")
        self.state = restore_checkpoint(self.cfg.checkpoint_dir, step,
                                        self.state)
        return step

    def run(self):
        step = int(np.asarray(self.state.step))
        while step < self.cfg.total_steps:
            try:
                batch = self.to_device(self.pipeline.batch_at(step))
                if self.failure_injector is not None:
                    self.failure_injector(step)
                t0 = time.monotonic()
                self.state, metrics = self.step_fn(self.state, batch)
                metrics = {k: float(np.asarray(v)) for k, v in metrics.items()}
                dt = time.monotonic() - t0
                self.monitor.observe(step, dt)
                metrics["step_time_s"] = dt
                self.history.append(metrics)
                step += 1
                if step % self.cfg.checkpoint_every == 0:
                    self.ckpt.save(step, self.state)
            except RuntimeError as e:
                # failure path: restore + replay (deterministic pipeline).
                # Only RuntimeError is recoverable-by-restart (device loss /
                # preemption surface as XlaRuntimeError, a RuntimeError
                # subclass); programming errors (TypeError, ValueError, ...)
                # propagate immediately instead of burning restarts.
                self.restarts += 1
                self.failures.append((step, repr(e)))
                log.warning(
                    "step %d failed (%s); restart %d/%d from last checkpoint",
                    step, e, self.restarts, self.cfg.max_restarts)
                if self.restarts > self.cfg.max_restarts:
                    raise
                self.ckpt.wait()
                restored = self._restore_latest()
                step = restored
        self.ckpt.wait()
        # final checkpoint so restarts after completion are no-ops
        self.ckpt.save(step, self.state)
        self.ckpt.wait()
        return self.state
