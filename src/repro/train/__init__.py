"""Training: step factory, fault-tolerant trainer loop, straggler monitor."""
from repro.train.step import TrainState, make_train_step, init_train_state
from repro.train.trainer import Trainer, TrainerConfig, StragglerMonitor

__all__ = ["TrainState", "make_train_step", "init_train_state",
           "Trainer", "TrainerConfig", "StragglerMonitor"]
