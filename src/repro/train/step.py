"""train_step factory: loss → grad → clip → (compress) → AdamW, pjit-ready.

Gradient accumulation runs microbatches through ``lax.scan``; per-microbatch
gradients are averaged in fp32.  Under a mesh, XLA's async collectives
overlap each microbatch's gradient all-reduce with the next microbatch's
compute (DESIGN.md §6).  Cross-pod int8 gradient compression is applied via
``shard_map`` when enabled.
"""
from __future__ import annotations

from typing import Any, Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.launch.sharding import Shardings, UNSHARDED
from repro.models.transformer import train_loss
from repro.optim import adamw, apply_updates, clip_by_global_norm
from repro.optim.adamw import AdamWState, Optimizer


class TrainState(NamedTuple):
    step: jax.Array
    params: Dict[str, Any]
    opt: AdamWState


def init_train_state(cfg: ArchConfig, key, opt: Optional[Optimizer] = None
                     ) -> TrainState:
    from repro.models.transformer import init_transformer
    params, _ = init_transformer(cfg, key)
    opt = opt or adamw(3e-4)
    return TrainState(step=jnp.zeros((), jnp.int32), params=params,
                      opt=opt.init(params))


def make_train_step(cfg: ArchConfig, opt: Optional[Optimizer] = None,
                    sh: Shardings = UNSHARDED, microbatches: int = 1,
                    clip_norm: float = 1.0):
    """Returns step(state, batch) -> (state, metrics)."""
    opt = opt or adamw(3e-4)

    def loss_fn(params, batch):
        return train_loss(cfg, params, batch, sh)

    def grads_of(params, batch):
        if microbatches == 1:
            return jax.value_and_grad(loss_fn)(params, batch)

        def micro(carry, mb):
            loss_acc, g_acc = carry
            l, g = jax.value_and_grad(loss_fn)(params, mb)
            g_acc = jax.tree.map(
                lambda a, b: a + b.astype(jnp.float32) / microbatches, g_acc, g)
            return (loss_acc + l / microbatches, g_acc), None

        g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                          params)
        mbs = jax.tree.map(
            lambda x: x.reshape(microbatches, x.shape[0] // microbatches,
                                *x.shape[1:]), batch)
        (loss, grads), _ = jax.lax.scan(micro, (jnp.zeros((), jnp.float32), g0),
                                        mbs)
        return loss, grads

    def step(state: TrainState, batch) -> tuple:
        loss, grads = grads_of(state.params, batch)
        grads, gnorm = clip_by_global_norm(grads, clip_norm)
        updates, opt_state = opt.update(grads, state.opt, state.params)
        params = apply_updates(state.params, updates)
        metrics = {"loss": loss, "grad_norm": gnorm,
                   "step": state.step.astype(jnp.float32)}
        return TrainState(step=state.step + 1, params=params, opt=opt_state), \
            metrics

    return step
