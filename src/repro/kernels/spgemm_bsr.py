"""Block-row Gustavson SpGEMM accumulation on the MXU (paper §III-D on TPU).

C[i·bs:(i+1)·bs, :] = Σ_{p ∈ rowptr[i]..rowptr[i+1]} A_blocks[p] @ B[colidx[p]·bs:+bs, :]

Grid = (block-rows of A, max blocks per row).  Both ``rowptr`` and ``colidx``
are scalar-prefetch operands: the A-block DMA and the *indirect* B-row-block
DMA (`colidx[p]` — the two-level SpGEMM indirection) are resolved by the DMA
engine, AIA-style.  The inner grid dimension accumulates into the same
output block (revisiting is legal on TPU because grid steps run sequentially
per core); `@pl.when` masks the ragged tail of short rows, the TPU analogue
of the paper's load-balanced PWPR/TBPR assignment.

Block sizes default to MXU-native (128, 128); interpret-mode tests sweep
smaller shapes.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _accum_kernel(rowptr_ref, colidx_ref, a_ref, b_ref, o_ref, *, n_brows):
    i = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _():
        o_ref[...] = jnp.zeros_like(o_ref)

    valid = (rowptr_ref[i] + j) < rowptr_ref[i + 1]

    @pl.when(valid)
    def _():
        o_ref[...] += jnp.dot(
            a_ref[0], b_ref[...], preferred_element_type=o_ref.dtype
        )


@functools.partial(
    jax.jit, static_argnames=("max_blocks_per_row", "interpret", "out_dtype")
)
def bsr_spmm(
    rowptr: jax.Array,        # (n_brows+1,) int32
    colidx: jax.Array,        # (bcap,) int32 block-column ids
    a_blocks: jax.Array,      # (bcap, bs, bs)
    b: jax.Array,             # (n_bcols*bs, d) dense RHS
    max_blocks_per_row: int,
    interpret: bool = True,
    out_dtype=jnp.float32,
):
    """C = A_bsr @ B via grid-accumulated MXU matmuls."""
    n_brows = rowptr.shape[0] - 1
    bs = a_blocks.shape[1]
    d = b.shape[1]
    last = colidx.shape[0] - 1

    def a_index(i, j, rowptr_ref, colidx_ref):
        p = jnp.minimum(rowptr_ref[i] + j, last)
        return (p, 0, 0)

    def b_index(i, j, rowptr_ref, colidx_ref):
        p = jnp.minimum(rowptr_ref[i] + j, last)
        return (colidx_ref[p], 0)

    return pl.pallas_call(
        functools.partial(_accum_kernel, n_brows=n_brows),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=(n_brows, max_blocks_per_row),
            in_specs=[
                pl.BlockSpec((1, bs, bs), a_index),
                pl.BlockSpec((bs, d), b_index),
            ],
            out_specs=pl.BlockSpec((bs, d), lambda i, j, *_: (i, 0)),
        ),
        out_shape=jax.ShapeDtypeStruct((n_brows * bs, d), out_dtype),
        interpret=interpret,
    )(rowptr, colidx, a_blocks, b)
