"""Jit'd public wrappers: Pallas on TPU, interpret-mode Pallas or XLA on CPU.

``backend`` resolution:
  * "pallas"    — compiled Pallas (TPU target).
  * "interpret" — Pallas kernel body executed in Python (CPU validation).
  * "xla"       — pure-jnp fallback (also the software-only / "without AIA"
                  baseline used throughout EXPERIMENTS.md).
"""
from __future__ import annotations

import os
from typing import Literal

import jax

from repro.kernels import aia_gather as _aia
from repro.kernels import ref as _ref
from repro.kernels import spgemm_bsr as _bsr
from repro.kernels import topk_spmm as _topk

Backend = Literal["auto", "pallas", "interpret", "xla"]


def resolve_backend(backend: Backend = "auto") -> str:
    if backend != "auto":
        return backend
    if os.environ.get("REPRO_KERNEL_BACKEND"):
        return os.environ["REPRO_KERNEL_BACKEND"]
    return "pallas" if jax.default_backend() == "tpu" else "xla"


def aia_ranged_gather(x, idx, r: int = 1, backend: Backend = "auto"):
    be = resolve_backend(backend)
    if be == "xla":
        return _ref.aia_ranged_gather(x, idx, r)
    return _aia.aia_ranged_gather(x, idx, r, interpret=(be == "interpret"))


def gather_rows(x, idx, rows_per_block: int = 8, backend: Backend = "auto"):
    be = resolve_backend(backend)
    if be == "xla":
        return _ref.gather_rows(x, idx)
    return _aia.gather_rows(x, idx, rows_per_block, interpret=(be == "interpret"))


def bsr_spmm(rowptr, colidx, a_blocks, b, max_blocks_per_row: int,
             backend: Backend = "auto"):
    be = resolve_backend(backend)
    if be == "xla":
        from repro.core.spgemm_bsr import bsr_spgemm_dense_rhs
        from repro.sparse.formats import BSR
        bs = a_blocks.shape[1]
        n_brows = rowptr.shape[0] - 1
        a = BSR(rowptr, colidx, a_blocks,
                (n_brows * bs, b.shape[0]))
        return bsr_spgemm_dense_rhs(a, b)
    return _bsr.bsr_spmm(rowptr, colidx, a_blocks, b, max_blocks_per_row,
                         interpret=(be == "interpret"))


def hash_accumulate(keys, vals, table_cap: int, backend: Backend = "auto"):
    """Algorithm-4 accumulation; XLA fallback = the vmapped hash engine.

    Contract note: the kernel emits the table in *probe order* (unsorted);
    the XLA fallback emits a column-sorted prefix.  Both carry the same
    (col → Σ val) content and uniqueCount; callers needing CSR order sort
    afterward (Algorithm 5 step 3)."""
    be = resolve_backend(backend)
    if be == "xla":
        from repro.core import phases
        return phases.accumulate_hash(keys, vals, table_cap)
    from repro.kernels import hash_accum as _ha
    return _ha.hash_accumulate(keys, vals, table_cap,
                               interpret=(be == "interpret"))


def topk_spmm(vals, idx, w2, backend: Backend = "auto"):
    be = resolve_backend(backend)
    if be == "xla":
        return _ref.topk_spmm(vals, idx, w2)
    return _topk.topk_spmm(vals, idx, w2, interpret=(be == "interpret"))


def block_topk_spmm(h_kept, bidx, w2, block: int = 128, backend: Backend = "auto"):
    be = resolve_backend(backend)
    if be == "xla":
        return _ref.block_topk_spmm(h_kept, bidx, w2, block)
    return _topk.block_topk_spmm(h_kept, bidx, w2, block,
                                 interpret=(be == "interpret"))
