"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def aia_ranged_gather(x: jax.Array, idx: jax.Array, r: int = 1) -> jax.Array:
    """out[i·R:(i+1)·R] = x[idx[i]·R : +R] — reshaped take."""
    n_blocks = x.shape[0] // r
    xb = x.reshape(n_blocks, r, x.shape[1])
    return jnp.take(xb, idx, axis=0).reshape(idx.shape[0] * r, x.shape[1])


def gather_rows(x: jax.Array, idx: jax.Array) -> jax.Array:
    return jnp.take(x, idx, axis=0)


def bsr_spmm(rowptr, colidx, a_blocks, b):
    """Dense oracle: densify BSR then matmul."""
    n_brows = rowptr.shape[0] - 1
    bs = a_blocks.shape[1]
    d = b.shape[1]
    xb = b.reshape(b.shape[0] // bs, bs, d)
    out = jnp.zeros((n_brows, bs, d), jnp.float32)
    rowptr = jax.device_get(rowptr)
    colidx = jax.device_get(colidx)
    for i in range(n_brows):
        acc = jnp.zeros((bs, d), jnp.float32)
        for p in range(int(rowptr[i]), int(rowptr[i + 1])):
            acc = acc + a_blocks[p].astype(jnp.float32) @ xb[colidx[p]].astype(jnp.float32)
        out = out.at[i].set(acc)
    return out.reshape(n_brows * bs, d)


def topk_spmm(vals, idx, w2):
    """y[i] = Σ_t vals[i,t] · w2[idx[i,t]]."""
    gathered = jnp.take(w2, idx, axis=0)  # (n, k, d)
    return jnp.einsum("nk,nkd->nd", vals.astype(jnp.float32),
                      gathered.astype(jnp.float32))


def block_topk_spmm(h_kept, bidx, w2, block: int):
    """Oracle for the tile-block variant."""
    n_tiles, kb, tile, blk = h_kept.shape
    d = w2.shape[1]
    w2b = w2.reshape(w2.shape[0] // block, block, d)
    gathered = jnp.take(w2b, bidx, axis=0)  # (n_tiles, kb, block, d)
    out = jnp.einsum("nktb,nkbd->ntd", h_kept.astype(jnp.float32),
                     gathered.astype(jnp.float32))
    return out.reshape(n_tiles * tile, d)
