"""Pallas TPU kernels — the AIA hardware technique, TPU-native.

The paper's AIA engine lives in the HBM base die and serves *ranged indirect
accesses* as bulk sequential streams.  The TPU-native equivalent is Pallas
**scalar prefetch** (`PrefetchScalarGridSpec`): index arrays are staged into
SMEM before kernel start and drive the `BlockSpec.index_map`, so the DMA
engine — not the compute core — resolves the indirection and streams blocks
HBM→VMEM, double-buffered.  See DESIGN.md §2 for the full mapping table.

Kernels (each with `ops.py` jit'd wrapper + `ref.py` pure-jnp oracle):

* ``aia_gather``  — the AIA primitive itself: out[i] = x[idx[i]·R : +R].
* ``spgemm_bsr``  — block-row Gustavson accumulation on the MXU.
* ``topk_spmm``   — Eq. (1) sparse-activation FFN matmul (per-token and
                    MXU-aligned block-structured variants).
* ``hash_accum``  — Algorithm 4 (linear-probing insert/accumulate) with the
                    table in VMEM scratch, one output row per grid step —
                    the Table-I Group-0/1 kernel policy.
* ``flash_attention`` — fused online-softmax attention (scores stay in
                    VMEM; the §Perf memory-roofline fix).

All kernels are written for TPU (VMEM BlockSpecs, MXU-shaped tiles) and
validated on CPU with ``interpret=True``.
"""
from repro.kernels import ops, ref

__all__ = ["ops", "ref"]
