"""Fused causal attention (flash) — the §Perf fix for the memory roofline.

The measured baseline (EXPERIMENTS.md §Perf) is memory-bound on attention
score traffic: XLA materializes the (S×S) scores between the two matmuls,
costing ~4·B·H·S² bytes of HBM traffic per layer per pass.  This kernel
keeps the running (m, l, acc) online-softmax state in VMEM scratch across
the kv-block grid dimension, so scores never touch HBM — the canonical
FlashAttention schedule mapped to the TPU grid/BlockSpec model.

Grid: (B·H, nq, nk), kv innermost (sequential on a TPU core → scratch
carries state).  Causal masking: whole kv-blocks strictly above the
diagonal are skipped via ``pl.when`` (no FLOPs, no DMA consumed from the
pipeline's perspective beyond the prefetch); the diagonal block applies an
elementwise mask.  GQA: callers pass KV already expanded to H (the
repo-wide layout; see models/attention.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  q_blk, k_blk, nk, causal, scale):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    run = (not causal) or (ki * k_blk <= qi * q_blk + q_blk - 1)

    @pl.when(run)
    def _step():
        q = q_ref[0].astype(jnp.float32)            # (q_blk, D)
        k = k_ref[0].astype(jnp.float32)            # (k_blk, D)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if causal:
            qpos = qi * q_blk + jax.lax.broadcasted_iota(jnp.int32,
                                                         (q_blk, k_blk), 0)
            kpos = ki * k_blk + jax.lax.broadcasted_iota(jnp.int32,
                                                         (q_blk, k_blk), 1)
            s = jnp.where(kpos <= qpos, s, NEG_INF)
        m_prev = m_ref[...]
        l_prev = l_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_prev * corr + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ki == nk - 1)
    def _emit():
        o_ref[0] = (acc_ref[...] /
                    jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("causal", "q_blk", "k_blk", "interpret"))
def flash_attention_fused(q: jax.Array, k: jax.Array, v: jax.Array,
                          causal: bool = True, q_blk: int = 128,
                          k_blk: int = 128, interpret: bool = True):
    """q, k, v: (BH, S, D) with KV pre-expanded to the query head count."""
    bh, s, d = q.shape
    q_blk = min(q_blk, s)
    k_blk = min(k_blk, s)
    assert s % q_blk == 0 and s % k_blk == 0, (s, q_blk, k_blk)
    nq, nk = s // q_blk, s // k_blk
    scale = 1.0 / (d ** 0.5)
    kernel = functools.partial(_flash_kernel, q_blk=q_blk, k_blk=k_blk,
                               nk=nk, causal=causal, scale=scale)
    return pl.pallas_call(
        kernel,
        grid=(bh, nq, nk),
        in_specs=[
            pl.BlockSpec((1, q_blk, d), lambda b, qi, ki: (b, qi, 0)),
            pl.BlockSpec((1, k_blk, d), lambda b, qi, ki: (b, ki, 0)),
            pl.BlockSpec((1, k_blk, d), lambda b, qi, ki: (b, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, q_blk, d), lambda b, qi, ki: (b, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, s, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((q_blk, 1), jnp.float32),   # running max
            pltpu.VMEM((q_blk, 1), jnp.float32),   # running denom
            pltpu.VMEM((q_blk, d), jnp.float32),   # accumulator
        ],
        interpret=interpret,
    )(q, k, v)
