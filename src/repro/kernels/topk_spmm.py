"""TopK-sparse FFN matmul — paper Eq. (1) as a Pallas kernel.

``y = TopK(h) @ W2`` where TopK keeps k of d_ff entries per token: row i of
the output is ``Σ_t vals[i,t] · W2[idx[i,t], :]`` — a *ranged indirect
access* over W2 rows (range = one d_model row), exactly the paper's AIA
pattern with the activation indices as the index array ``b``.

Two variants:

* ``topk_spmm``       — per-token faithful form: grid (tokens, k); each step
  DMAs one W2 row chosen by the prefetched index and FMAs it (VPU).
* ``block_topk_spmm`` — beyond-paper MXU form: TopK selects ``kb`` blocks of
  ``block`` contiguous d_ff lanes per *token tile*; each grid step is then a
  dense (tile × block) @ (block × d_model) MXU matmul on a DMA'd W2 block.
  Same indirection, tile-aligned — see DESIGN.md §4.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _token_kernel(idx_ref, vals_ref, w2_ref, o_ref):
    i = pl.program_id(0)
    t = pl.program_id(1)

    @pl.when(t == 0)
    def _():
        o_ref[...] = jnp.zeros_like(o_ref)

    v = vals_ref[0, t]
    o_ref[...] += v.astype(o_ref.dtype) * w2_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret", "out_dtype"))
def topk_spmm(vals: jax.Array, idx: jax.Array, w2: jax.Array,
              interpret: bool = True, out_dtype=jnp.float32):
    """y[i] = Σ_t vals[i,t] · w2[idx[i,t]].  vals/idx: (n, k); w2: (d_ff, d)."""
    n, k = vals.shape
    d = w2.shape[1]
    return pl.pallas_call(
        _token_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(n, k),
            in_specs=[
                pl.BlockSpec((1, k), lambda i, t, idx_ref: (i, 0)),
                pl.BlockSpec((1, d), lambda i, t, idx_ref: (idx_ref[i, t], 0)),
            ],
            out_specs=pl.BlockSpec((1, d), lambda i, t, idx_ref: (i, 0)),
        ),
        out_shape=jax.ShapeDtypeStruct((n, d), out_dtype),
        interpret=interpret,
    )(idx, vals, w2)


def _tile_kernel(bidx_ref, h_ref, w2_ref, o_ref):
    t = pl.program_id(1)

    @pl.when(t == 0)
    def _():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        h_ref[0, 0], w2_ref[0], preferred_element_type=o_ref.dtype
    )


@functools.partial(jax.jit, static_argnames=("block", "interpret", "out_dtype"))
def block_topk_spmm(h_kept: jax.Array, bidx: jax.Array, w2: jax.Array,
                    block: int = 128, interpret: bool = True,
                    out_dtype=jnp.float32):
    """MXU-aligned variant.

    h_kept: (n_tiles, kb, tile, block) — kept activation lanes per token tile.
    bidx:   (n_tiles, kb) int32 — selected d_ff block ids (shared per tile).
    w2:     (d_ff, d) with d_ff = n_blocks·block.
    Returns (n_tiles·tile, d).
    """
    n_tiles, kb, tile, blk = h_kept.shape
    assert blk == block
    d = w2.shape[1]
    w2b = w2.reshape(w2.shape[0] // block, block, d)
    return pl.pallas_call(
        _tile_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(n_tiles, kb),
            in_specs=[
                pl.BlockSpec((1, 1, tile, block),
                             lambda i, t, bidx_ref: (i, t, 0, 0)),
                pl.BlockSpec((1, block, d),
                             lambda i, t, bidx_ref: (bidx_ref[i, t], 0, 0)),
            ],
            out_specs=pl.BlockSpec((tile, d), lambda i, t, bidx_ref: (i, 0)),
        ),
        out_shape=jax.ShapeDtypeStruct((n_tiles * tile, d), out_dtype),
        interpret=interpret,
    )(bidx, h_kept, w2b)
