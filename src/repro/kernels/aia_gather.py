"""AIA ranged indirect gather — the paper's Fig. 2 primitive on TPU.

Semantics (paper §IV-C): given index array ``b`` and data array ``a``, serve
``a[b[i]·R] … a[b[i]·R + R − 1]`` for i = 0..N−1 as **one bulk stream**
instead of 2N processor⇄memory round trips.

TPU mapping: ``b`` is a scalar-prefetch operand — it is copied to SMEM
*before* the kernel body runs, and ``BlockSpec.index_map`` reads it to
program each grid step's HBM→VMEM DMA.  The compute core never issues the
indirection; the DMA engine does, near memory, and Pallas double-buffers the
stream (block i+1's DMA overlaps block i's consumption).  This is the same
request-consolidation AIA performs in the HBM base die.

Alignment note: BlockSpec indices are in units of the block shape, so ranges
start at multiples of R (library callers pad rows accordingly).  ``R = 1``
(``gather_rows``) covers CSR row gathers with arbitrary row ids — the
dominant SpGEMM pattern (`rpt_B[col_A[j]]` → row of B).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _copy_kernel(idx_ref, x_ref, o_ref):
    # The gather already happened at DMA time (index_map); just stream out.
    o_ref[...] = x_ref[...]


def _resolve_interpret(interpret: bool | None) -> bool:
    """Backend auto-detection via ``kernels.ops.resolve_backend`` (one
    policy, including the ``REPRO_KERNEL_BACKEND`` override): compiled
    Pallas only when it resolves to "pallas"; any other resolution runs
    interpret mode (this module has no jnp fallback of its own)."""
    if interpret is not None:
        return interpret
    from repro.kernels.ops import resolve_backend  # lazy: ops imports us
    return resolve_backend("auto") != "pallas"


@functools.partial(jax.jit, static_argnames=("r", "interpret"))
def aia_ranged_gather(x: jax.Array, idx: jax.Array, r: int = 1,
                      interpret: bool | None = None) -> jax.Array:
    """out[i·R:(i+1)·R, :] = x[idx[i]·R : idx[i]·R+R, :].

    x:   (n_blocks·R, d) data array (HBM).
    idx: (N,) int32 block indices (the paper's ``b``; prefetched to SMEM).
    """
    interpret = _resolve_interpret(interpret)
    n = idx.shape[0]
    d = x.shape[1]
    return pl.pallas_call(
        _copy_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(n,),
            in_specs=[pl.BlockSpec((r, d), lambda i, idx_ref: (idx_ref[i], 0))],
            out_specs=pl.BlockSpec((r, d), lambda i, idx_ref: (i, 0)),
        ),
        out_shape=jax.ShapeDtypeStruct((n * r, d), x.dtype),
        interpret=interpret,
    )(idx, x)


def _copy_kernel_2d(idx_ref, x_ref, o_ref):
    o_ref[...] = x_ref[...]


@functools.partial(jax.jit, static_argnames=("rows_per_block", "interpret"))
def gather_rows(x: jax.Array, idx: jax.Array, rows_per_block: int = 8,
                interpret: bool | None = None) -> jax.Array:
    """out[i] = x[idx[i]] with idx grouped ``rows_per_block`` at a time.

    Each grid step DMAs ``rows_per_block`` independent rows (one descriptor
    per row — the AIA "switching network" role) and emits them contiguously.
    idx length must be a multiple of rows_per_block (callers pad with any
    valid row id).
    """
    interpret = _resolve_interpret(interpret)
    n = idx.shape[0]
    d = x.shape[1]
    assert n % rows_per_block == 0, (n, rows_per_block)
    n_steps = n // rows_per_block

    def kernel(idx_ref, x_hbm, o_ref, *, rpb):
        step = pl.program_id(0)

        def body(sem):
            for r in range(rpb):
                row = idx_ref[step * rpb + r]
                cp = pltpu.make_async_copy(
                    x_hbm.at[pl.ds(row, 1), :], o_ref.at[pl.ds(r, 1), :], sem
                )
                cp.start()
                cp.wait()

        pl.run_scoped(body, pltpu.SemaphoreType.DMA)

    return pl.pallas_call(
        functools.partial(kernel, rpb=rows_per_block),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(n_steps,),
            in_specs=[pl.BlockSpec(memory_space=pl.ANY)],
            out_specs=pl.BlockSpec((rows_per_block, d), lambda i, idx_ref: (i, 0)),
        ),
        out_shape=jax.ShapeDtypeStruct((n, d), x.dtype),
        interpret=interpret,
    )(idx, x)


def gather_rows_any(x: jax.Array, idx: jax.Array, rows_per_block: int = 8,
                    interpret: bool | None = None) -> jax.Array:
    """``x[idx]`` for arbitrary-length ``idx``: clips out-of-range ids, pads
    the stream to the kernel's block multiple, gathers, and trims back.

    The convenience wrapper shared by the SpGEMM executor's ``gather="aia"``
    backend and ``sparse.ops.csr_spmm`` — keeps the pad/clip/trim arithmetic
    in one place next to the kernel it feeds.
    """
    n = idx.shape[0]
    n_pad = int(np.ceil(n / rows_per_block) * rows_per_block)
    idx = jnp.clip(idx, 0, x.shape[0] - 1).astype(jnp.int32)
    if n_pad > n:
        idx = jnp.concatenate([idx, jnp.zeros(n_pad - n, jnp.int32)])
    return gather_rows(x, idx, rows_per_block, interpret=interpret)[:n]
