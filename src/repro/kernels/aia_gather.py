"""AIA ranged indirect gather — the paper's Fig. 2 primitive on TPU.

Semantics (paper §IV-C): given index array ``b`` and data array ``a``, serve
``a[b[i]·R] … a[b[i]·R + R − 1]`` for i = 0..N−1 as **one bulk stream**
instead of 2N processor⇄memory round trips.

TPU mapping: ``b`` is a scalar-prefetch operand — it is copied to SMEM
*before* the kernel body runs, and ``BlockSpec.index_map`` reads it to
program each grid step's HBM→VMEM DMA.  The compute core never issues the
indirection; the DMA engine does, near memory, and Pallas double-buffers the
stream (block i+1's DMA overlaps block i's consumption).  This is the same
request-consolidation AIA performs in the HBM base die.

Alignment note: BlockSpec indices are in units of the block shape, so ranges
start at multiples of R (library callers pad rows accordingly).  ``R = 1``
(``gather_rows``) covers CSR row gathers with arbitrary row ids — the
dominant SpGEMM pattern (`rpt_B[col_A[j]]` → row of B).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _copy_kernel(idx_ref, x_ref, o_ref):
    # The gather already happened at DMA time (index_map); just stream out.
    o_ref[...] = x_ref[...]


@functools.partial(jax.jit, static_argnames=("r", "interpret"))
def aia_ranged_gather(x: jax.Array, idx: jax.Array, r: int = 1,
                      interpret: bool = True) -> jax.Array:
    """out[i·R:(i+1)·R, :] = x[idx[i]·R : idx[i]·R+R, :].

    x:   (n_blocks·R, d) data array (HBM).
    idx: (N,) int32 block indices (the paper's ``b``; prefetched to SMEM).
    """
    n = idx.shape[0]
    d = x.shape[1]
    return pl.pallas_call(
        _copy_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(n,),
            in_specs=[pl.BlockSpec((r, d), lambda i, idx_ref: (idx_ref[i], 0))],
            out_specs=pl.BlockSpec((r, d), lambda i, idx_ref: (i, 0)),
        ),
        out_shape=jax.ShapeDtypeStruct((n * r, d), x.dtype),
        interpret=interpret,
    )(idx, x)


def _copy_kernel_2d(idx_ref, x_ref, o_ref):
    o_ref[...] = x_ref[...]


@functools.partial(jax.jit, static_argnames=("rows_per_block", "interpret"))
def gather_rows(x: jax.Array, idx: jax.Array, rows_per_block: int = 8,
                interpret: bool = True) -> jax.Array:
    """out[i] = x[idx[i]] with idx grouped ``rows_per_block`` at a time.

    Each grid step DMAs ``rows_per_block`` independent rows (one descriptor
    per row — the AIA "switching network" role) and emits them contiguously.
    idx length must be a multiple of rows_per_block (callers pad with any
    valid row id).
    """
    n = idx.shape[0]
    d = x.shape[1]
    assert n % rows_per_block == 0, (n, rows_per_block)
    n_steps = n // rows_per_block

    def kernel(idx_ref, x_hbm, o_ref, *, rpb):
        step = pl.program_id(0)

        def body(sem):
            for r in range(rpb):
                row = idx_ref[step * rpb + r]
                cp = pltpu.make_async_copy(
                    x_hbm.at[pl.ds(row, 1), :], o_ref.at[pl.ds(r, 1), :], sem
                )
                cp.start()
                cp.wait()

        pl.run_scoped(body, pltpu.SemaphoreType.DMA)

    return pl.pallas_call(
        functools.partial(kernel, rpb=rows_per_block),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(n_steps,),
            in_specs=[pl.BlockSpec(memory_space=pl.ANY)],
            out_specs=pl.BlockSpec((rows_per_block, d), lambda i, idx_ref: (i, 0)),
        ),
        out_shape=jax.ShapeDtypeStruct((n, d), x.dtype),
        interpret=interpret,
    )(idx, x)
