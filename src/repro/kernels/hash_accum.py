"""Algorithm 4 (InsertIntoTable/AddInTable) as a Pallas TPU kernel.

One grid step = one output row of C: the row's padded intermediate-product
stream (keys, vals) is consumed sequentially against a VMEM-resident
linear-probing hash table — the TPU realization of the paper's Table-I
per-group kernels (Group 0/1: small tables in fast memory, one row per
program; across-row parallelism comes from the grid, replacing PWPR/TBPR
thread blocks; no atomics needed because the per-row stream is sequential,
DESIGN.md §2 adaptation #1/#2).

Emits the *unsorted* table + uniqueCount per row; column-index sorting
(Algorithm 5 step 3) stays in XLA (`jnp.sort` lowers to a sorting network),
matching the phase split of the paper.

Scalar-sequential probing maps to the TPU's scalar core; it is the right
tool for the small-IP groups the paper assigns to PWPR.  Large-IP rows use
the sort engine (repro.core.phases) instead — same policy split as Table I.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

MULTIPLIER = 2654435761
EMPTY = -1
_INT_MAX = jnp.int32(2**31 - 1)


def _resolve_interpret(interpret: bool | None) -> bool:
    """Backend auto-detection via ``kernels.ops.resolve_backend`` (one
    policy, including the ``REPRO_KERNEL_BACKEND`` override): compiled
    Pallas only when it resolves to "pallas"; any other resolution runs
    interpret mode (the fused executor path routes "xla" to the scan
    engine before it ever reaches this module)."""
    if interpret is not None:
        return interpret
    from repro.kernels.ops import resolve_backend

    return resolve_backend("auto") != "pallas"


def _hash_kernel(keys_ref, vals_ref, cols_ref, out_ref, cnt_ref,
                 tkey_ref, tval_ref, *, ip_cap, table_cap):
    # reset the VMEM table for this row
    tkey_ref[...] = jnp.full_like(tkey_ref, EMPTY)
    tval_ref[...] = jnp.zeros_like(tval_ref)

    def insert(i, count):
        key = keys_ref[0, i]
        val = vals_ref[0, i]
        h = (key.astype(jnp.uint32) * jnp.uint32(MULTIPLIER))
        pos0 = (h % jnp.uint32(table_cap)).astype(jnp.int32)

        def cond(state):
            _, done, probes = state
            return jnp.logical_and(jnp.logical_not(done), probes < table_cap)

        def body(state):
            pos, _, probes = state
            slot = tkey_ref[pos]
            hit = slot == key
            empty = slot == EMPTY

            @pl.when(empty)
            def _():
                tkey_ref[pos] = key

            @pl.when(hit | empty)
            def _():
                tval_ref[pos] = tval_ref[pos] + val

            done = hit | empty
            nxt = jnp.where(done, pos, (pos + 1) % table_cap)
            return nxt, done, probes + 1

        jax.lax.while_loop(cond, body, (pos0, key < 0, jnp.int32(0)))
        return count  # uniqueCount is recovered from table occupancy below

    jax.lax.fori_loop(0, ip_cap, insert, jnp.int32(0))
    # gather the table out; uniqueCount = occupied slots
    occupied = tkey_ref[...] != EMPTY
    cols_ref[0, :] = tkey_ref[...]
    out_ref[0, :] = tval_ref[...]
    cnt_ref[0, 0] = jnp.sum(occupied.astype(jnp.int32))


def hash_accumulate_sorted(keys: jax.Array, vals: jax.Array, table_cap: int,
                           out_cap: int, interpret: bool | None = None):
    """Kernel accumulation + Algorithm 5 step 3 (column-index sort) + trim.

    The fused-engine entry point: the per-row table comes back from the
    Pallas kernel in *probe order*; the XLA sort (a sorting network on TPU,
    matching the paper's bitonic phase split) moves the occupied slots to a
    column-sorted prefix, which is trimmed to the caller's ``out_cap``
    capacity bound (``out_cap`` ≥ uniqueCount must hold — the executor's
    plan-derived sizing guarantees it).

    Returns (cols (R, out_cap) int32 -1-padded, vals (R, out_cap), counts
    (R,) int32) — the same layout as ``phases.accumulate_hash`` trimmed to
    ``out_cap``, and bit-identical to it (same insertion order, same sort).
    """
    # Resolve the backend OUTSIDE the jitted body: ``interpret=None`` is a
    # static argument, so resolving it at trace time would bake the first
    # call's env-var state into the jit cache and silently ignore later
    # ``REPRO_KERNEL_BACKEND`` changes for same-shape calls.
    return _hash_accumulate_sorted(keys, vals, table_cap, out_cap,
                                   _resolve_interpret(interpret))


@functools.partial(jax.jit, static_argnames=("table_cap", "out_cap",
                                             "interpret"))
def _hash_accumulate_sorted(keys: jax.Array, vals: jax.Array, table_cap: int,
                            out_cap: int, interpret: bool):
    tc, tv, cnt = hash_accumulate(keys, vals, table_cap, interpret=interpret)
    skey = jnp.where(tc == EMPTY, _INT_MAX, tc)
    order = jnp.argsort(skey, axis=1, stable=True)
    sc = jnp.take_along_axis(tc, order, axis=1)
    sv = jnp.take_along_axis(tv, order, axis=1)
    valid = jnp.arange(table_cap, dtype=jnp.int32)[None, :] < cnt[:, None]
    cols = jnp.where(valid, sc, EMPTY)[:, :out_cap]
    out = jnp.where(valid, sv, 0)[:, :out_cap]
    return cols, out, cnt


@functools.partial(jax.jit, static_argnames=("table_cap", "interpret"))
def hash_accumulate(keys: jax.Array, vals: jax.Array, table_cap: int,
                    interpret: bool = True):
    """Per-row Algorithm-4 accumulation.

    keys: (R, ip_cap) int32, -1 padded; vals: (R, ip_cap) float32.
    Returns (cols (R, table_cap) int32 EMPTY-padded — *unsorted*,
             vals (R, table_cap) float32, counts (R,) int32).
    """
    r, ip_cap = keys.shape
    kernel = functools.partial(_hash_kernel, ip_cap=ip_cap,
                               table_cap=table_cap)
    cols, out, cnt = pl.pallas_call(
        kernel,
        grid=(r,),
        in_specs=[
            pl.BlockSpec((1, ip_cap), lambda i: (i, 0)),
            pl.BlockSpec((1, ip_cap), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, table_cap), lambda i: (i, 0)),
            pl.BlockSpec((1, table_cap), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((r, table_cap), jnp.int32),
            jax.ShapeDtypeStruct((r, table_cap), jnp.float32),
            jax.ShapeDtypeStruct((r, 1), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((table_cap,), jnp.int32),
            pltpu.VMEM((table_cap,), jnp.float32),
        ],
        interpret=interpret,
    )(keys, vals)
    return cols, out, cnt[:, 0]
