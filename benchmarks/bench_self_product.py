"""Table II + Fig. 6: matrix self-product A·A.

Compares, per scaled Table-II workload:
  * dense-XLA   — densify + jnp matmul (the "library default"/cuSPARSE role)
  * hash        — paper-faithful multi-phase hash SpGEMM
  * sort        — TPU-vectorized multi-phase SpGEMM (same pipeline)
GFLOPS uses the paper's definition: 2 × intermediate products / time.
"""
from __future__ import annotations

import time
from typing import Dict, List

import jax
import numpy as np

from repro.apps.graphs import TABLE_II_SCALED, table_ii_matrix
from repro.core.spgemm import spgemm
from repro.core.ip_count import intermediate_products
from repro.sparse.formats import csr_to_dense


def _time(f, reps=3):
    f()  # warmup / compile
    t0 = time.perf_counter()
    for _ in range(reps):
        out = f()
    jax.block_until_ready(out) if hasattr(out, "block_until_ready") else None
    return (time.perf_counter() - t0) / reps


def run(names=None, n_override: int | None = None,
        methods=("sort", "hash"), gathers=("xla",), mesh=None) -> List[Dict]:
    """Per workload: dense baseline + engine×gather grid through the
    plan-compiled executor.  The first gather in ``gathers`` fills the
    legacy ``{m}_ms`` keys; additional gathers add ``{m}_{g}_ms`` columns
    (the Fig. 7 software-only vs AIA ablation axis).  ``mesh`` routes every
    SpGEMM through the sharded multi-device executor."""
    rows = []
    names = names or list(TABLE_II_SCALED)
    for name in names:
        a = table_ii_matrix(name, n_override=n_override)
        ip = int(np.asarray(intermediate_products(a, a)).sum())
        flops = 2.0 * ip

        dense_a = csr_to_dense(a)
        t_dense = _time(lambda: (dense_a @ dense_a).block_until_ready())

        rec = {
            "workload": name,
            "rows": a.n_rows,
            "nnz": int(np.asarray(a.nnz)),
            "intermediate_products": ip,
            "dense_ms": t_dense * 1e3,
            "dense_gflops": flops / t_dense / 1e9,
        }
        for m in methods:
            for gi, g in enumerate(gathers):
                t = _time(lambda m=m, g=g: spgemm(a, a, engine=m, gather=g,
                                                  mesh=mesh),
                          reps=1)
                prefix = m if gi == 0 else f"{m}_{g}"
                rec[f"{prefix}_ms"] = t * 1e3
                rec[f"{prefix}_gflops"] = flops / t / 1e9
                rec[f"{prefix}_vs_dense_reduction_pct"] = 100 * (1 - t / t_dense)
            res = spgemm(a, a, engine=m, gather=gathers[0], mesh=mesh)
            rec["nnz_c"] = res.info["nnz_c"]
            rec["compression"] = res.info["compression_ratio"]
        # Fig. 7-style "AIA scheduling vs software-only": Table-I grouped
        # schedule vs ungrouped natural order (worst-case capacities), same
        # engine both sides so the ablation isolates scheduling alone
        t_nat = _time(lambda: spgemm(a, a, engine=methods[0],
                                     gather=gathers[0], schedule="natural",
                                     mesh=mesh),
                      reps=1)
        rec["natural_ms"] = t_nat * 1e3
        rec["group_sched_reduction_pct"] = 100 * (
            1 - rec[f"{methods[0]}_ms"] / 1e3 / t_nat)
        rows.append(rec)
    return rows


def main():
    import argparse

    from repro.core.executor import available_engines

    ap = argparse.ArgumentParser()
    ap.add_argument("--engine", default="sort", choices=available_engines())
    ap.add_argument("--gather", default="xla", choices=("auto", "xla", "aia"))
    args = ap.parse_args()
    m = args.engine
    for r in run(names=["scircuit", "p2p-Gnutella04", "Economics"],
                 methods=(m,), gathers=(args.gather,)):
        print(f"selfprod_{r['workload']},{r[f'{m}_ms']*1e3:.0f},"
              f"gflops={r[f'{m}_gflops']:.3f};ip={r['intermediate_products']};"
              f"nnz_c={r['nnz_c']};vs_dense={r[f'{m}_vs_dense_reduction_pct']:.1f}%")


if __name__ == "__main__":
    main()
