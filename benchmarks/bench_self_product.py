"""Table II + Fig. 6: matrix self-product A·A.

Compares, per scaled Table-II workload:
  * dense-XLA   — densify + jnp matmul (the "library default"/cuSPARSE role)
  * hash        — paper-faithful multi-phase hash SpGEMM
  * sort        — TPU-vectorized multi-phase SpGEMM (same pipeline)
GFLOPS uses the paper's definition: 2 × intermediate products / time.
"""
from __future__ import annotations

import time
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.apps.graphs import TABLE_II_SCALED, table_ii_matrix
from repro.core.spgemm import spgemm
from repro.core.ip_count import intermediate_products
from repro.sparse.formats import csr_to_dense


def _time(f, reps=3):
    f()  # warmup / compile
    t0 = time.perf_counter()
    for _ in range(reps):
        out = f()
    jax.block_until_ready(out) if hasattr(out, "block_until_ready") else None
    return (time.perf_counter() - t0) / reps


def run(names=None, n_override: int | None = None,
        methods=("sort", "hash")) -> List[Dict]:
    rows = []
    names = names or list(TABLE_II_SCALED)
    for name in names:
        a = table_ii_matrix(name, n_override=n_override)
        ip = int(np.asarray(intermediate_products(a, a)).sum())
        flops = 2.0 * ip

        dense_a = csr_to_dense(a)
        t_dense = _time(lambda: (dense_a @ dense_a).block_until_ready())

        rec = {
            "workload": name,
            "rows": a.n_rows,
            "nnz": int(np.asarray(a.nnz)),
            "intermediate_products": ip,
            "dense_ms": t_dense * 1e3,
            "dense_gflops": flops / t_dense / 1e9,
        }
        for m in methods:
            t = _time(lambda m=m: spgemm(a, a, method=m), reps=1)
            res = spgemm(a, a, method=m)
            rec[f"{m}_ms"] = t * 1e3
            rec[f"{m}_gflops"] = flops / t / 1e9
            rec["nnz_c"] = res.info["nnz_c"]
            rec["compression"] = res.info["compression_ratio"]
            rec[f"{m}_vs_dense_reduction_pct"] = 100 * (1 - t / t_dense)
        # Fig. 7-style "AIA scheduling vs software-only": Table-I grouped
        # schedule vs ungrouped natural order (worst-case capacities)
        t_nat = _time(lambda: spgemm(a, a, method="sort", schedule="natural"),
                      reps=1)
        rec["natural_ms"] = t_nat * 1e3
        rec["group_sched_reduction_pct"] = 100 * (1 - rec["sort_ms"] / 1e3 / t_nat)
        rows.append(rec)
    return rows


def main():
    for r in run(names=["scircuit", "p2p-Gnutella04", "Economics"],
                 methods=("sort",)):
        print(f"selfprod_{r['workload']},{r['sort_ms']*1e3:.0f},"
              f"gflops={r['sort_gflops']:.3f};ip={r['intermediate_products']};"
              f"nnz_c={r['nnz_c']};vs_dense={r['sort_vs_dense_reduction_pct']:.1f}%")


if __name__ == "__main__":
    main()
