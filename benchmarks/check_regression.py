"""Benchmark regression gate for the bench-smoke CI job.

Compares a fresh ``benchmarks/run.py --ci --json`` output against the
committed baseline and fails (exit 1) if any wall-time record regressed by
more than ``--max-ratio`` (default 2x — generous enough for runner noise,
tight enough to catch re-tracing / cache-key regressions, which are
order-of-magnitude events).

Usage:
    python benchmarks/check_regression.py BENCH_ci.json \
        benchmarks/BENCH_baseline.json --max-ratio 2.0 [--require-all]

Failures name every offending record with its baseline-vs-current µs and
ratio (plus the worst offender up front), so a red CI log says *what*
regressed without downloading the artifact.

Records with ``us == 0`` (pure-counter rows) are never gated, and records
where both sides sit under the ``--min-us`` noise floor are skipped too:
the tiny CI-tier records bottom out at tens of µs where ``perf_counter``
jitter alone exceeds 2x, so they only flake the gate (a record that
*crosses* the floor — tiny baseline, blown-up current — still gates, which
is exactly the re-tracing signature).  ``--merge PATH`` folds additional
fresh-run JSONs (e.g. the medium tier's ``BENCH_medium.json``) into the
current record set so one gate invocation compares every tier against the
single committed baseline.  Record-set
*drift* is reported as a WARN by default: records present in the fresh
JSON but absent from the baseline (a PR adding a benchmark) and records
present in the baseline but absent from the fresh run (a renamed/removed
benchmark whose gate would otherwise silently vanish) both print warnings
without failing, so landing a new bench record doesn't require a lockstep
baseline commit.  ``--require-all`` turns both warnings into failures —
used on main, where the baseline is expected to be regenerated in the
same commit that changes the record set.

When ``$GITHUB_STEP_SUMMARY`` is set (every GitHub Actions step), the
per-record comparison is also appended there as a markdown table, so the
bench-smoke trend is readable from the run's Summary page without
downloading artifacts.
"""
from __future__ import annotations

import argparse
import json
import os
import sys


def load_records(path: str) -> dict:
    with open(path) as f:
        doc = json.load(f)
    return {r["name"]: r for r in doc.get("records", [])}


def compare(current: dict, baseline: dict, max_ratio: float,
            min_us: float = 0.0) -> list:
    """Returns the list of (name, cur_us, base_us, ratio) regressions.

    Records where *both* sides are under ``min_us`` are timer-noise
    dominated and skipped; a record whose current time blows past the
    floor still gates against its tiny baseline (re-tracing regressions
    are order-of-magnitude events, never noise)."""
    regressions = []
    for name, base in sorted(baseline.items()):
        cur = current.get(name)
        if cur is None or base["us"] <= 0 or cur["us"] <= 0:
            continue
        if base["us"] < min_us and cur["us"] < min_us:
            continue
        ratio = cur["us"] / base["us"]
        if ratio > max_ratio:
            regressions.append((name, cur["us"], base["us"], ratio))
    return regressions


def record_drift(current: dict, baseline: dict) -> tuple:
    """(new_names, missing_names): fresh-only records and baseline-only
    records — warnings by default, failures under ``--require-all``."""
    new = sorted(n for n in current if n not in baseline)
    missing = sorted(n for n in baseline if n not in current)
    return new, missing


def write_step_summary(current: dict, baseline: dict, shared: list,
                       regressions: list, new: list, missing: list,
                       max_ratio: float, min_us: float,
                       path: str) -> None:
    """Append the per-record comparison as a markdown table to ``path``
    (the ``$GITHUB_STEP_SUMMARY`` file), so the bench trend is readable
    from the Actions Summary page without downloading artifacts."""
    regressed = {name for name, _, _, _ in regressions}
    lines = ["### Benchmark regression gate", "",
             "| record | baseline µs | current µs | ratio | |",
             "|---|---:|---:|---:|---|"]
    for name in sorted(shared):
        cur, base = current[name]["us"], baseline[name]["us"]
        ratio = cur / base
        if name in regressed:
            note = f"❌ > {max_ratio:.1f}x"
        elif cur < min_us and base < min_us:
            note = "under noise floor, ungated"
        else:
            note = "✅"
        lines.append(f"| {name} | {base:.0f} | {cur:.0f} | {ratio:.2f}x "
                     f"| {note} |")
    for name in new:
        lines.append(f"| {name} | — | {current[name]['us']:.0f} | — "
                     "| ⚠️ no baseline |")
    for name in missing:
        lines.append(f"| {name} | {baseline[name]['us']:.0f} | — | — "
                     "| ⚠️ missing from run |")
    verdict = (f"**FAIL** — {len(regressions)} record(s) beyond "
               f"{max_ratio:.1f}x" if regressions
               else f"**OK** — {len(shared)} record(s) within "
                    f"{max_ratio:.1f}x of baseline")
    lines += ["", verdict, ""]
    with open(path, "a") as f:
        f.write("\n".join(lines))


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("current", help="fresh run.py --json output")
    ap.add_argument("baseline", help="committed baseline JSON")
    ap.add_argument("--max-ratio", type=float, default=2.0,
                    help="fail if current/baseline wall-time exceeds this")
    ap.add_argument("--min-us", type=float, default=0.0,
                    help="noise floor: skip the ratio gate for records whose "
                         "baseline AND current times are both under this "
                         "(tiny records are perf_counter-jitter dominated)")
    ap.add_argument("--merge", action="append", default=[], metavar="PATH",
                    help="additional fresh-run JSON(s) merged into the "
                         "current record set (e.g. the medium tier's "
                         "artifact), so one invocation gates every tier")
    ap.add_argument("--require-all", action="store_true",
                    help="fail (not warn) when the record sets differ — "
                         "strict mode for main, where the baseline must be "
                         "regenerated alongside record-set changes")
    args = ap.parse_args()

    current = load_records(args.current)
    for extra in args.merge:
        for name, rec in load_records(extra).items():
            if name in current:
                print(f"FAIL: --merge {extra} record {name!r} collides with "
                      "an existing current record — tiers must emit "
                      "disjoint record names", file=sys.stderr)
                return 1
            current[name] = rec
    baseline = load_records(args.baseline)
    shared = [n for n in baseline if n in current and baseline[n]["us"] > 0]
    if not shared:
        print(f"FAIL: no comparable records between {args.current} "
              f"({len(current)} records: {sorted(current) or 'none'}) and "
              f"{args.baseline} ({len(baseline)} records: "
              f"{sorted(baseline) or 'none'}) — was the benchmark run "
              "renamed wholesale, or did run.py emit nothing?",
              file=sys.stderr)
        return 1

    new, missing = record_drift(current, baseline)
    for name in new:
        print(f"WARN: record {name!r} has no baseline entry (new benchmark?"
              " regenerate benchmarks/BENCH_baseline.json to gate it)",
              file=sys.stderr)
    for name in missing:
        print(f"WARN: baseline record {name!r} missing from the fresh run"
              " (renamed/removed benchmark? its gate no longer applies)",
              file=sys.stderr)

    regressions = compare(current, baseline, args.max_ratio,
                          min_us=args.min_us)
    summary_path = os.environ.get("GITHUB_STEP_SUMMARY")
    if summary_path:
        write_step_summary(current, baseline, shared, regressions, new,
                           missing, args.max_ratio, args.min_us,
                           summary_path)
    for name in shared:
        ratio = current[name]["us"] / baseline[name]["us"]
        floor = (" [under --min-us floor, ungated]"
                 if current[name]["us"] < args.min_us
                 and baseline[name]["us"] < args.min_us else "")
        print(f"{name}: {current[name]['us']:.0f}us vs "
              f"baseline {baseline[name]['us']:.0f}us ({ratio:.2f}x){floor}")
    if regressions:
        worst = max(regressions, key=lambda r: r[3])
        print(f"\nFAIL: {len(regressions)} record(s) regressed more than "
              f"{args.max_ratio}x vs {args.baseline} (worst: {worst[0]} at "
              f"{worst[3]:.2f}x):", file=sys.stderr)
        for name, cur, base, ratio in regressions:
            print(f"  {name}: baseline {base:.0f}us -> current {cur:.0f}us "
                  f"({ratio:.2f}x > {args.max_ratio:.1f}x limit)",
                  file=sys.stderr)
        print("deliberate perf change? regenerate the baseline with the "
              "same `run.py --ci --json` invocation and commit it",
              file=sys.stderr)
        return 1
    if args.require_all and (new or missing):
        print(f"\nFAIL (--require-all): record sets differ "
              f"({len(new)} new, {len(missing)} missing) — regenerate "
              "benchmarks/BENCH_baseline.json", file=sys.stderr)
        return 1
    print(f"\nOK: {len(shared)} record(s) within {args.max_ratio}x of baseline")
    return 0


if __name__ == "__main__":
    sys.exit(main())
