"""Benchmark regression gate for the bench-smoke CI job.

Compares a fresh ``benchmarks/run.py --ci --json`` output against the
committed baseline and fails (exit 1) if any wall-time record regressed by
more than ``--max-ratio`` (default 2x — generous enough for runner noise,
tight enough to catch re-tracing / cache-key regressions, which are
order-of-magnitude events).

Usage:
    python benchmarks/check_regression.py BENCH_ci.json \
        benchmarks/BENCH_baseline.json --max-ratio 2.0

Records with ``us == 0`` (pure-counter rows) and records missing from
either side are skipped — new benchmarks don't need a baseline update to
land, but renaming one silently drops its gate, so keep names stable.
"""
from __future__ import annotations

import argparse
import json
import sys


def load_records(path: str) -> dict:
    with open(path) as f:
        doc = json.load(f)
    return {r["name"]: r for r in doc.get("records", [])}


def compare(current: dict, baseline: dict, max_ratio: float) -> list:
    """Returns the list of (name, cur_us, base_us, ratio) regressions."""
    regressions = []
    for name, base in sorted(baseline.items()):
        cur = current.get(name)
        if cur is None or base["us"] <= 0 or cur["us"] <= 0:
            continue
        ratio = cur["us"] / base["us"]
        if ratio > max_ratio:
            regressions.append((name, cur["us"], base["us"], ratio))
    return regressions


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("current", help="fresh run.py --json output")
    ap.add_argument("baseline", help="committed baseline JSON")
    ap.add_argument("--max-ratio", type=float, default=2.0,
                    help="fail if current/baseline wall-time exceeds this")
    args = ap.parse_args()

    current = load_records(args.current)
    baseline = load_records(args.baseline)
    shared = [n for n in baseline if n in current and baseline[n]["us"] > 0]
    if not shared:
        print("no comparable records between current and baseline",
              file=sys.stderr)
        return 1

    regressions = compare(current, baseline, args.max_ratio)
    for name in shared:
        ratio = current[name]["us"] / baseline[name]["us"]
        print(f"{name}: {current[name]['us']:.0f}us vs "
              f"baseline {baseline[name]['us']:.0f}us ({ratio:.2f}x)")
    if regressions:
        print(f"\nFAIL: {len(regressions)} record(s) regressed "
              f">{args.max_ratio}x:", file=sys.stderr)
        for name, cur, base, ratio in regressions:
            print(f"  {name}: {cur:.0f}us vs {base:.0f}us ({ratio:.2f}x)",
                  file=sys.stderr)
        return 1
    print(f"\nOK: {len(shared)} record(s) within {args.max_ratio}x of baseline")
    return 0


if __name__ == "__main__":
    sys.exit(main())
