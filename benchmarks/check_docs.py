"""Docs-vs-code drift gate: knob tables in docs/knobs.md must list exactly
what the executor's resolvers accept.

For each table-checked knob section in ``docs/knobs.md`` (a ``## `knob` ``
heading followed by a markdown table whose first column holds backticked
choice names), the documented choice set is compared against the
code-derived one:

* ``engine`` — the live ``available_engines()`` registry plus ``"auto"``
  (so registering a new engine without documenting it fails CI).
* ``gather`` / ``schedule`` / ``pipeline`` / ``sizing`` / ``operands`` —
  the executor's ``Literal`` type aliases (the same sets the
  ``resolve_*`` validators enforce).

Each documented choice is additionally pushed through its resolver
(``resolve_engine`` / ``resolve_gather`` / ``resolve_sizing`` /
``resolve_operands``) so a doc entry the code would reject is caught even
if the alias and validator ever disagree.

Streaming knobs (``tile_rows`` / ``prefetch``) take integers, not an
enumerable choice set, so their tables are checked differently: every
documented row value must survive ``resolve_tile_rows`` /
``resolve_prefetch``, and the code defaults (``DEFAULT_TILE_ROWS`` /
``DEFAULT_PREFETCH``) must appear among the rows — changing a default
without re-documenting it fails CI.  The serving request knobs
(``deadline`` / ``retries`` / ``backoff``) are checked the same way
through the serving layer's resolvers, with ``none`` standing for the
Python ``None`` default and dotted values parsed as floats.

When an architecture doc is passed as the second argument, its
``## Observability`` counter table is compared against the live
``cache_stats()`` key set in both directions: an undocumented counter
fails, and so does a documented counter the code no longer exports.

Usage (the CI docs-check step)::

    PYTHONPATH=src python benchmarks/check_docs.py docs/knobs.md \
        docs/architecture.md
"""
from __future__ import annotations

import argparse
import re
import sys
import typing
from typing import Dict, List, Set


HEADING_RE = re.compile(r"^##\s+`(?P<knob>[a-z_]+)`\s*$")
ROW_RE = re.compile(r"^\|\s*`(?P<choice>[A-Za-z0-9_.]+)`\s*\|")


def parse_knob_tables(text: str) -> Dict[str, Set[str]]:
    """Extract {knob: documented choice set} from knobs.md.

    A knob section is a ``## `name` `` heading; its choices are the
    backticked first-column entries of every table row until the next
    heading.
    """
    tables: Dict[str, Set[str]] = {}
    knob = None
    for line in text.splitlines():
        m = HEADING_RE.match(line)
        if m:
            knob = m.group("knob")
            tables.setdefault(knob, set())
            continue
        if line.startswith("## "):  # non-knob heading ends the section
            knob = None
            continue
        if knob is not None:
            r = ROW_RE.match(line)
            if r:
                tables[knob].add(r.group("choice"))
    return {k: v for k, v in tables.items() if v}


def expected_choices() -> Dict[str, Set[str]]:
    """The code-derived choice set per knob."""
    from repro.core import executor

    return {
        "engine": set(executor.available_engines()) | {executor.AUTO_ENGINE},
        "gather": set(typing.get_args(executor.Gather)),
        "pipeline": set(typing.get_args(executor.Pipeline)),
        "sizing": set(typing.get_args(executor.Sizing)),
        "operands": set(typing.get_args(executor.Operands)),
        "on_budget": set(typing.get_args(executor.OnBudget)),
        "schedule": {"grouped", "natural"},
    }


def check(text: str) -> List[str]:
    """Compare documented vs code-derived choices; returns failures."""
    from repro.core import executor

    documented = parse_knob_tables(text)
    expected = expected_choices()
    errs = []
    for knob, exp in sorted(expected.items()):
        doc = documented.get(knob)
        if doc is None:
            errs.append(f"knobs.md has no table for `{knob}` "
                        f"(expected choices: {sorted(exp)})")
            continue
        if doc != exp:
            missing, extra = sorted(exp - doc), sorted(doc - exp)
            errs.append(f"`{knob}` table drift: missing {missing}, "
                        f"undocumented-in-code {extra}")
    # every documented choice must survive its resolver
    resolvers = {
        "engine": executor.resolve_engine,
        "gather": executor.resolve_gather,
        "operands": executor.resolve_operands,
        "sizing": lambda s: executor.resolve_sizing(s, "sort"),
        "on_budget": executor.resolve_on_budget,
    }
    for knob, resolve in resolvers.items():
        for choice in sorted(documented.get(knob, ())):
            try:
                resolve(choice)
            except ValueError as e:
                errs.append(f"`{knob}` documents {choice!r} but the "
                            f"resolver rejects it: {e}")
    errs.extend(check_stream_knobs(documented))
    errs.extend(check_serve_knobs(documented))
    return errs


def check_stream_knobs(documented: Dict[str, Set[str]]) -> List[str]:
    """Integer-valued streaming knob tables: every documented row value
    must survive its resolver, and the code default must be documented."""
    from repro.core import executor

    specs = {
        "tile_rows": (executor.resolve_tile_rows,
                      executor.DEFAULT_TILE_ROWS),
        "prefetch": (executor.resolve_prefetch, executor.DEFAULT_PREFETCH),
    }
    errs = []
    for knob, (resolve, default) in sorted(specs.items()):
        doc = documented.get(knob)
        if doc is None:
            errs.append(f"knobs.md has no table for `{knob}` (an integer "
                        f"knob; rows must include the default {default})")
            continue
        values = set()
        for choice in sorted(doc):
            try:
                values.add(resolve(int(choice)))
            except ValueError as e:
                errs.append(f"`{knob}` documents {choice!r} but the "
                            f"resolver rejects it: {e}")
        if default not in values:
            errs.append(f"`{knob}` table does not document the code "
                        f"default {default}")
    return errs


def _parse_serve_value(choice: str):
    """A serving-knob doc row value: ``none`` → None, dotted → float,
    else int."""
    if choice == "none":
        return None
    if "." in choice:
        return float(choice)
    return int(choice)


def check_serve_knobs(documented: Dict[str, Set[str]]) -> List[str]:
    """Serving request knob tables (``deadline``/``retries``/``backoff``):
    every documented row value must survive its resolver, and the code
    default must be documented (``none`` stands for ``None``)."""
    from repro.serve import spgemm_service as svc

    specs = {
        "deadline": (svc.resolve_deadline, None),
        "retries": (svc.resolve_retries, 0),
        "backoff": (svc.resolve_backoff, svc.DEFAULT_BACKOFF),
    }
    errs = []
    for knob, (resolve, default) in sorted(specs.items()):
        doc = documented.get(knob)
        if doc is None:
            errs.append(f"knobs.md has no table for `{knob}` (a serving "
                        f"request knob; rows must include the default "
                        f"{'none' if default is None else default})")
            continue
        values = set()
        for choice in sorted(doc):
            try:
                values.add(resolve(_parse_serve_value(choice)))
            except ValueError as e:
                errs.append(f"`{knob}` documents {choice!r} but the "
                            f"resolver rejects it: {e}")
        if default not in values:
            errs.append(f"`{knob}` table does not document the code "
                        f"default {'none' if default is None else default}")
    return errs


COUNTER_HEADING_RE = re.compile(r"^##\s+Observability\s*$")
COUNTER_ROW_RE = re.compile(r"^\|\s*`(?P<counter>[A-Za-z0-9_]+)`\s*\|")


def parse_counter_table(text: str) -> Set[str]:
    """Extract the counter names from architecture.md's ``## Observability``
    table (backticked first-column entries until the next heading)."""
    counters: Set[str] = set()
    in_section = False
    for line in text.splitlines():
        if COUNTER_HEADING_RE.match(line):
            in_section = True
            continue
        if line.startswith("## "):
            in_section = False
            continue
        if in_section:
            m = COUNTER_ROW_RE.match(line)
            if m:
                counters.add(m.group("counter"))
    return counters


def check_observability(text: str) -> List[str]:
    """architecture.md's Observability table vs the live ``cache_stats()``
    key set, both directions."""
    from repro.core import executor

    documented = parse_counter_table(text)
    if not documented:
        return ["architecture.md has no `## Observability` counter table"]
    live = set(executor.cache_stats())
    errs = []
    missing, extra = sorted(live - documented), sorted(documented - live)
    if missing:
        errs.append(f"cache_stats() counters undocumented in "
                    f"architecture.md: {missing}")
    if extra:
        errs.append(f"architecture.md documents counters cache_stats() "
                    f"does not export: {extra}")
    return errs


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("knobs_md", nargs="?", default="docs/knobs.md")
    ap.add_argument("architecture_md", nargs="?", default=None,
                    help="also check this doc's ## Observability counter "
                         "table against cache_stats()")
    args = ap.parse_args(argv)
    with open(args.knobs_md) as f:
        text = f.read()
    errs = check(text)
    if args.architecture_md:
        with open(args.architecture_md) as f:
            errs.extend(check_observability(f.read()))
    if errs:
        for e in errs:
            print(f"FAIL {e}", file=sys.stderr)
        return 1
    n = len(parse_knob_tables(text))
    msg = f"{args.knobs_md}: {n} knob tables match the code"
    if args.architecture_md:
        from repro.core import executor

        msg += (f"; {args.architecture_md}: "
                f"{len(executor.cache_stats())} counters documented")
    print(msg)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
