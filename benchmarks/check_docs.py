"""Docs-vs-code drift gate: knob tables in docs/knobs.md must list exactly
what the executor's resolvers accept.

For each table-checked knob section in ``docs/knobs.md`` (a ``## `knob` ``
heading followed by a markdown table whose first column holds backticked
choice names), the documented choice set is compared against the
code-derived one:

* ``engine`` — the live ``available_engines()`` registry plus ``"auto"``
  (so registering a new engine without documenting it fails CI).
* ``gather`` / ``schedule`` / ``pipeline`` / ``sizing`` / ``operands`` —
  the executor's ``Literal`` type aliases (the same sets the
  ``resolve_*`` validators enforce).

Each documented choice is additionally pushed through its resolver
(``resolve_engine`` / ``resolve_gather`` / ``resolve_sizing`` /
``resolve_operands``) so a doc entry the code would reject is caught even
if the alias and validator ever disagree.

Usage (the CI docs-check step)::

    PYTHONPATH=src python benchmarks/check_docs.py docs/knobs.md
"""
from __future__ import annotations

import argparse
import re
import sys
import typing
from typing import Dict, List, Set


HEADING_RE = re.compile(r"^##\s+`(?P<knob>[a-z_]+)`\s*$")
ROW_RE = re.compile(r"^\|\s*`(?P<choice>[A-Za-z0-9_]+)`\s*\|")


def parse_knob_tables(text: str) -> Dict[str, Set[str]]:
    """Extract {knob: documented choice set} from knobs.md.

    A knob section is a ``## `name` `` heading; its choices are the
    backticked first-column entries of every table row until the next
    heading.
    """
    tables: Dict[str, Set[str]] = {}
    knob = None
    for line in text.splitlines():
        m = HEADING_RE.match(line)
        if m:
            knob = m.group("knob")
            tables.setdefault(knob, set())
            continue
        if line.startswith("## "):  # non-knob heading ends the section
            knob = None
            continue
        if knob is not None:
            r = ROW_RE.match(line)
            if r:
                tables[knob].add(r.group("choice"))
    return {k: v for k, v in tables.items() if v}


def expected_choices() -> Dict[str, Set[str]]:
    """The code-derived choice set per knob."""
    from repro.core import executor

    return {
        "engine": set(executor.available_engines()) | {executor.AUTO_ENGINE},
        "gather": set(typing.get_args(executor.Gather)),
        "pipeline": set(typing.get_args(executor.Pipeline)),
        "sizing": set(typing.get_args(executor.Sizing)),
        "operands": set(typing.get_args(executor.Operands)),
        "schedule": {"grouped", "natural"},
    }


def check(text: str) -> List[str]:
    """Compare documented vs code-derived choices; returns failures."""
    from repro.core import executor

    documented = parse_knob_tables(text)
    expected = expected_choices()
    errs = []
    for knob, exp in sorted(expected.items()):
        doc = documented.get(knob)
        if doc is None:
            errs.append(f"knobs.md has no table for `{knob}` "
                        f"(expected choices: {sorted(exp)})")
            continue
        if doc != exp:
            missing, extra = sorted(exp - doc), sorted(doc - exp)
            errs.append(f"`{knob}` table drift: missing {missing}, "
                        f"undocumented-in-code {extra}")
    # every documented choice must survive its resolver
    resolvers = {
        "engine": executor.resolve_engine,
        "gather": executor.resolve_gather,
        "operands": executor.resolve_operands,
        "sizing": lambda s: executor.resolve_sizing(s, "sort"),
    }
    for knob, resolve in resolvers.items():
        for choice in sorted(documented.get(knob, ())):
            try:
                resolve(choice)
            except ValueError as e:
                errs.append(f"`{knob}` documents {choice!r} but the "
                            f"resolver rejects it: {e}")
    return errs


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("knobs_md", nargs="?", default="docs/knobs.md")
    args = ap.parse_args(argv)
    with open(args.knobs_md) as f:
        text = f.read()
    errs = check(text)
    if errs:
        for e in errs:
            print(f"FAIL {e}", file=sys.stderr)
        return 1
    n = len(parse_knob_tables(text))
    print(f"{args.knobs_md}: {n} knob tables match the code")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
