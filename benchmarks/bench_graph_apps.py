"""Fig. 7/8: Graph Contraction + Markov Clustering performance.

Per workload: dense-XLA baseline (cuSPARSE role) vs the multi-phase SpGEMM
pipeline ("software"), plus the locality metrics that quantify the AIA term.
Reported as % time reduction, matching the paper's presentation.
"""
from __future__ import annotations

import time
from typing import Dict, List

import jax
import numpy as np

from repro.apps.graph_contraction import graph_contraction, label_matrix
from repro.apps.graphs import table_ii_matrix
from repro.apps.markov_clustering import mcl
from repro.sparse.formats import csr_to_dense


def _wall(f, reps=1):
    t0 = time.perf_counter()
    for _ in range(reps):
        # async dispatch: drain device work before stopping the clock
        # (pytree-aware; non-array leaves like result dataclasses pass
        # through untouched)
        out = jax.block_until_ready(f())
    return (time.perf_counter() - t0) / reps, out


def bench_contraction(names=("RoadTX", "web-Google", "Economics", "amazon0601",
                             "WindTunnel", "Protein"),
                      n_override=None, engine="sort",
                      gather="auto", mesh=None,
                      pipeline="two_wave", sizing="auto") -> List[Dict]:
    rows = []
    rng = np.random.default_rng(0)
    for name in names:
        g = table_ii_matrix(name, n_override=n_override)
        labels = rng.integers(0, max(g.n_rows // 64, 2), g.n_rows)
        t_sp, (c, infos) = _wall(
            lambda: graph_contraction(g, labels, engine, gather=gather,
                                      mesh=mesh, pipeline=pipeline,
                                      sizing=sizing))
        # dense baseline: S G S^T with dense matmuls
        s = csr_to_dense(label_matrix(labels, n=g.n_rows))
        gd = csr_to_dense(g)
        t_dense, _ = _wall(lambda: ((s @ gd) @ s.T).block_until_ready())
        rows.append({
            "workload": name, "n": g.n_rows,
            "spgemm_ms": t_sp * 1e3, "dense_ms": t_dense * 1e3,
            "reduction_vs_dense_pct": 100 * (1 - t_sp / t_dense),
            "total_ip": sum(i["intermediate_products"] for i in infos),
        })
    return rows


def bench_mcl(names=("web-Google", "Economics", "Protein"),
              max_iters=3, n_override=None, engine="sort",
              gather="auto", mesh=None, reuse_plan=True,
              pipeline="two_wave", sizing="auto") -> List[Dict]:
    rows = []
    for name in names:
        g = table_ii_matrix(name, n_override=n_override)
        t_sp, res = _wall(lambda: mcl(g, e=2, max_iters=max_iters, tol=0.0,
                                      method=engine, gather=gather,
                                      mesh=mesh, reuse_plan=reuse_plan,
                                      pipeline=pipeline, sizing=sizing))
        # dense baseline: same loop with dense matmul expansion
        import jax.numpy as jnp
        from repro.apps.markov_clustering import add_self_loops
        from repro.sparse.ops import csr_column_normalize

        def dense_mcl():
            a = csr_to_dense(csr_column_normalize(add_self_loops(g)))
            for _ in range(max_iters):
                b = a @ a
                b = jnp.where(b >= 1e-4, b, 0)
                b = b * b
                s = b.sum(axis=0, keepdims=True)
                a = jnp.where(s > 0, b / jnp.maximum(s, 1e-12), 0)
            return a.block_until_ready()

        t_dense, _ = _wall(dense_mcl)
        rows.append({
            "workload": name, "n": g.n_rows, "iters": res.n_iterations,
            "spgemm_ms": t_sp * 1e3, "dense_ms": t_dense * 1e3,
            "reduction_vs_dense_pct": 100 * (1 - t_sp / t_dense),
            "n_clusters": int(len(np.unique(res.clusters))),
            "plan_hits": res.plan_cache_hits,
        })
    return rows


def bench_batched_selfprod(names=("Economics", "Protein"), batch=4,
                           n_override=None, engine="sort", gather="auto",
                           mesh=None, pipeline="two_wave",
                           sizing="auto") -> List[Dict]:
    """Amortized batched SpGEMM vs a per-matrix loop (same-pattern batch).

    Each workload's matrix spawns ``batch`` value variants sharing its
    support (random positive rescaling of the edge weights — the GNN
    mini-batch / iterative-reweighting regime); the batched executor runs
    the plan once for all of them, the loop pays setup per member.
    """
    from repro.apps.sampling import _weighted_members
    from repro.core.spgemm import spgemm, spgemm_batched

    rows = []
    rng = np.random.default_rng(0)
    for name in names:
        g = table_ii_matrix(name, n_override=n_override)
        nnz = int(np.asarray(g.indptr)[-1])
        weights = np.asarray(g.data)[None, :nnz] * rng.uniform(
            0.5, 1.5, (batch, nnz)).astype(np.float32)
        members = _weighted_members(g, weights)
        spgemm_batched(members, g, engine=engine, gather=gather, mesh=mesh,
                       pipeline=pipeline, sizing=sizing)
        for m in members:
            spgemm(m, g, engine=engine, gather=gather, mesh=mesh,
                   pipeline=pipeline, sizing=sizing)
        t_batched, res = _wall(lambda: spgemm_batched(
            members, g, engine=engine, gather=gather, mesh=mesh,
            pipeline=pipeline, sizing=sizing))
        t_loop, _ = _wall(lambda: [spgemm(
            m, g, engine=engine, gather=gather, mesh=mesh,
            pipeline=pipeline, sizing=sizing) for m in members])
        rows.append({
            "workload": name, "n": g.n_rows, "batch": batch,
            "batched_ms": t_batched * 1e3, "loop_ms": t_loop * 1e3,
            "speedup_x": t_loop / max(t_batched, 1e-12),
            "nnz_c": res.info["nnz_c"],
        })
    return rows


def main():
    for r in bench_contraction(names=("Economics", "Protein")):
        print(f"contraction_{r['workload']},{r['spgemm_ms']*1e3:.0f},"
              f"vs_dense={r['reduction_vs_dense_pct']:.1f}%;ip={r['total_ip']}")
    for r in bench_mcl(names=("Economics",), max_iters=2):
        print(f"mcl_{r['workload']},{r['spgemm_ms']*1e3:.0f},"
              f"vs_dense={r['reduction_vs_dense_pct']:.1f}%;"
              f"clusters={r['n_clusters']}")


if __name__ == "__main__":
    main()
