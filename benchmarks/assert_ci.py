"""CI gates over benchmark-smoke artifacts — the tested replacement for
the workflow's former inline assert heredocs.

Each contract is one flag backed by one pure check function that takes the
parsed artifact and returns a list of failure strings (empty = pass), so
the gating logic itself is unit-testable (``tests/test_benchmarks.py``)
instead of living untested inside ``ci.yml``:

* ``--plan-hits`` — the amortized path recorded PlanCache hits.
* ``--batched-beats-looped`` — the batched executor beat the per-matrix
  loop (``ci_batched_sort`` < ``ci_batched_loop_sort``).
* ``--sync-budget`` — two-wave contract: the pipelined probe paid at most
  one blocking allocate sync, the legacy probe more than one, and both
  wall-time records are present.
* ``--fused-zero-sync`` — the fused plan-sized probe paid ZERO blocking
  syncs, with both fused records present.
* ``--operand-gate`` — communication-avoiding B placement: the
  ``operand_probe`` meta shows footprint bytes strictly below the
  replicated bytes (and footprint rows strictly below the replicated row
  count) on a multi-shard plan.
* ``--serve-gate`` — the serving layer's contract: the pattern-coalescing
  service actually coalesced (multi-request ``spgemm_batched`` dispatches
  with a coalescing ratio > 1), beat the per-request replay of the same
  Zipf trace within ``--serve-tolerance``, and every tenant's plan cache
  respected its LRU quota (including the deliberately-tight audit replay).
* ``--stream-gate`` — out-of-core contract: the streamed row-block lane
  reproduced the monolithic product bit-exactly, actually tiled the work
  (``tiles_streamed`` >= 2) with at least one prefetch/compute overlap,
  and its wall time stayed within ``--stream-tolerance`` of the
  monolithic record.
* ``--resilience-gate`` — the failure-recovery contract
  (docs/resilience.md): the chaos probe's forced ``capacity_undersize``
  fault actually triggered a detect-and-retry that reproduced the
  measured-sizing result bit-exactly, the clean planned path stayed
  retry-free AND sync-free, and the over-budget ``on_budget="stream"``
  run degraded to the streamed lane bit-exactly.
* ``--autotune`` — engine="auto" within ``--auto-tolerance`` of the best
  single engine, converged runs pure cache hits (zero re-measurement).
* ``--pipelined-beats-legacy`` — the fused two-wave lane within
  ``--pipeline-tolerance`` of legacy at medium scale.

Usage (exactly what ``.github/workflows/ci.yml`` runs)::

    python benchmarks/assert_ci.py BENCH_ci.json \
        --plan-hits --batched-beats-looped --sync-budget \
        --fused-zero-sync --operand-gate --serve-gate --stream-gate \
        --resilience-gate
    python benchmarks/assert_ci.py BENCH_medium.json \
        --autotune --pipelined-beats-legacy --operand-gate --stream-gate
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import Dict, List


def _records(doc: dict) -> Dict[str, float]:
    return {r["name"]: r["us"] for r in doc.get("records", [])}


def check_plan_hits(doc: dict) -> List[str]:
    stats = doc.get("meta", {}).get("cache_stats", {})
    if stats.get("plan_hits", 0) <= 0:
        return [f"no plan-cache hits: {stats}"]
    return []


def check_batched_beats_looped(doc: dict,
                               batched: str = "ci_batched_sort",
                               looped: str = "ci_batched_loop_sort"
                               ) -> List[str]:
    rec = _records(doc)
    missing = [n for n in (batched, looped) if n not in rec]
    if missing:
        return [f"batched-vs-looped records missing {missing}: {sorted(rec)}"]
    if rec[batched] >= rec[looped]:
        return [f"batched SpGEMM ({rec[batched]}us) did not beat the "
                f"per-matrix loop ({rec[looped]}us)"]
    return []


def check_sync_budget(doc: dict) -> List[str]:
    """Two-wave contract: at most one blocking allocate sync per wave on
    the pipelined path, one per chunk (so >1) on the legacy path."""
    probe = doc.get("meta", {}).get("pipeline_probe")
    if probe is None:
        return ["pipeline_probe meta missing"]
    errs = []
    if probe.get("host_syncs_pipelined", 99) > 1:
        errs.append(f"pipelined path syncs per chunk, not per wave: {probe}")
    if probe.get("host_syncs_legacy", 0) <= 1:
        errs.append(f"legacy probe did not split into multiple chunks: "
                    f"{probe}")
    rec = _records(doc)
    for name in ("ci_selfprod_pipelined", "ci_selfprod_legacy"):
        if name not in rec:
            errs.append(f"pipelined-vs-legacy record {name!r} missing: "
                        f"{sorted(rec)}")
    return errs


def check_fused_zero_sync(doc: dict) -> List[str]:
    """Fused contract: plan-derived sizing dispatches the whole call (all
    chunks, device indptr, sharded epilogue) with ZERO blocking syncs."""
    fused = doc.get("meta", {}).get("fused_probe")
    if fused is None:
        return ["fused_probe meta missing"]
    errs = []
    if fused.get("host_syncs_fused", 99) != 0:
        errs.append(f"fused plan-sized path paid blocking syncs: {fused}")
    rec = _records(doc)
    for name in ("ci_selfprod_fused", "ci_selfprod_fused_hash"):
        if name not in rec:
            errs.append(f"fused record {name!r} missing: {sorted(rec)}")
    return errs


def check_operand_gate(doc: dict) -> List[str]:
    """Communication-avoiding placement contract: on a multi-chunk
    multi-shard plan, footprint-gathered B blocks place strictly fewer
    bytes (and rows) than full replication."""
    probe = doc.get("meta", {}).get("operand_probe")
    if probe is None:
        return ["operand_probe meta missing"]
    errs = []
    if probe.get("n_shards", 0) < 2:
        errs.append(f"operand probe must run on >= 2 shards: {probe}")
    rep = probe.get("bytes_replicated", 0)
    fp = probe.get("bytes_footprint", 0)
    if not (0 < fp < rep):
        errs.append(f"footprint bytes ({fp}) not strictly below replicated "
                    f"bytes ({rep}): {probe}")
    rows_fp = probe.get("rows_footprint", 0)
    rows_total = probe.get("rows_total", 0)
    if not (0 < rows_fp < rows_total):
        errs.append(f"footprint rows ({rows_fp}) not strictly below the "
                    f"replicated row count ({rows_total}): {probe}")
    return errs


def check_serve_gate(doc: dict, tolerance: float = 1.0) -> List[str]:
    """Serving contract: the coalescing service batched same-pattern
    traffic, beat the per-request replay within ``tolerance`` (1.0 =
    strictly faster), and per-tenant plan quotas held."""
    probe = doc.get("meta", {}).get("serve_probe")
    if probe is None:
        return ["serve_probe meta missing"]
    errs = []
    rec = _records(doc)
    for name in ("ci_serve_coalesced", "ci_serve_per_request"):
        if name not in rec:
            errs.append(f"serve record {name!r} missing: {sorted(rec)}")
    if probe.get("batched_dispatches", 0) <= 0:
        errs.append(f"no multi-request spgemm_batched dispatches: {probe}")
    if probe.get("coalescing_ratio", 0) <= 1.0:
        errs.append(f"coalescing ratio not above 1 request/dispatch: {probe}")
    coal = probe.get("coalesced_s", float("inf"))
    per = probe.get("per_request_s", 0.0)
    if coal > per * tolerance:
        errs.append(f"coalesced replay ({coal:.4f}s) did not beat "
                    f"per-request ({per:.4f}s) within {tolerance}x: {probe}")
    if not probe.get("quota_respected", False):
        errs.append(f"a tenant plan cache exceeded its LRU quota: {probe}")
    if probe.get("requests_shed", 0) != 0:
        errs.append(f"open-loop replay shed requests (queue bound must "
                    f"cover the trace): {probe}")
    return errs


def check_stream_gate(doc: dict, tolerance: float = 2.5) -> List[str]:
    """Out-of-core streaming contract: bit-exact vs the monolithic lane,
    genuinely tiled (>= 2 tiles) with prefetch overlapping compute, and
    the streamed wall time within ``tolerance``x of the monolithic
    record's (tiling trades peak device bytes for bounded overhead)."""
    probe = doc.get("meta", {}).get("stream_probe")
    if probe is None:
        return ["stream_probe meta missing"]
    errs = []
    if not probe.get("bit_exact", False):
        errs.append(f"streamed product diverged from monolithic: {probe}")
    rec = _records(doc)
    streamed_name = probe.get("streamed_record", "")
    mono_name = probe.get("monolithic_record", "")
    missing = [n for n in (streamed_name, mono_name) if n not in rec]
    if missing:
        errs.append(f"stream records missing {missing}: {sorted(rec)}")
        return errs
    streamed, mono = rec[streamed_name], rec[mono_name]
    if streamed > mono * tolerance:
        errs.append(f"streamed lane ({streamed}us) exceeded {tolerance}x "
                    f"the monolithic record ({mono}us)")
    if probe.get("tiles_streamed", 0) < 2:
        errs.append(f"streamed probe did not tile the product "
                    f"(tiles_streamed < 2): {probe}")
    if probe.get("prefetch_overlap_hits", 0) <= 0:
        errs.append(f"no tile was staged while a prior tile computed "
                    f"(prefetch_overlap_hits == 0): {probe}")
    if probe.get("tile_bytes_h2d", 0) <= 0:
        errs.append(f"streamed probe recorded no host-to-device tile "
                    f"traffic: {probe}")
    return errs


def check_resilience_gate(doc: dict) -> List[str]:
    """Failure-recovery contract: every chaos-probe recovery path fired
    and reproduced its fault-free reference bit-exactly, and the clean
    planned fast path paid zero retries and zero blocking syncs."""
    probe = doc.get("meta", {}).get("resilience_probe")
    if probe is None:
        return ["resilience_probe meta missing"]
    errs = []
    rec = _records(doc)
    for name in ("ci_chaos_capacity_retry", "ci_chaos_degraded"):
        if name not in rec:
            errs.append(f"chaos record {name!r} missing: {sorted(rec)}")
    if probe.get("capacity_retries_forced", 0) < 1:
        errs.append(f"forced capacity_undersize fault did not trigger a "
                    f"retry: {probe}")
    if not probe.get("capacity_retry_bit_exact", False):
        errs.append(f"capacity retry diverged from measured sizing: {probe}")
    if probe.get("capacity_retries_clean", 99) != 0:
        errs.append(f"clean planned run paid capacity retries: {probe}")
    if probe.get("host_syncs_clean", 99) != 0:
        errs.append(f"clean planned run paid blocking host syncs (the "
                    f"overflow flag must stay unread): {probe}")
    if probe.get("budget_degradations", 0) < 1:
        errs.append(f"over-budget on_budget='stream' run did not degrade "
                    f"to the streamed lane: {probe}")
    if not probe.get("degraded_bit_exact", False):
        errs.append(f"degraded-to-stream MCL diverged from the monolithic "
                    f"clustering: {probe}")
    return errs


def check_autotune(doc: dict, tolerance: float = 1.5) -> List[str]:
    rec = _records(doc)
    engines = ("sort", "hash", "fused_hash")
    needed = [f"medium_selfprod_{e}" for e in engines] + [
        "medium_selfprod_auto"]
    missing = [n for n in needed if n not in rec]
    if missing:
        return [f"autotune records missing {missing}: {sorted(rec)}"]
    singles = {e: rec[f"medium_selfprod_{e}"] for e in engines}
    best_engine = min(singles, key=singles.get)
    best = singles[best_engine]
    auto = rec["medium_selfprod_auto"]
    errs = []
    if auto > best * tolerance:
        errs.append(f"engine='auto' ({auto}us) not within {tolerance}x of "
                    f"best single engine {best_engine} ({best}us): {singles}")
    probe = doc.get("meta", {}).get("autotune_probe")
    if probe is None:
        errs.append("autotune_probe meta missing")
        return errs
    if probe.get("autotune_hits_converged", 0) <= 0:
        errs.append(f"converged auto runs recorded no autotune hits: {probe}")
    if probe.get("autotune_misses_converged", 99) != 0:
        errs.append(f"converged auto runs still measuring (misses > 0): "
                    f"{probe}")
    return errs


def check_pipelined_beats_legacy(doc: dict,
                                 tolerance: float = 1.1) -> List[str]:
    rec = _records(doc)
    names = ("medium_selfprod_pipelined", "medium_selfprod_legacy")
    missing = [n for n in names if n not in rec]
    if missing:
        return [f"pipelined-vs-legacy records missing {missing}: "
                f"{sorted(rec)}"]
    pipelined, legacy = rec[names[0]], rec[names[1]]
    if pipelined > legacy * tolerance:
        return [f"fused two-wave ({pipelined}us) lost to legacy "
                f"({legacy}us) beyond {tolerance}x at medium scale"]
    return []


CHECKS = {
    "plan_hits": check_plan_hits,
    "batched_beats_looped": check_batched_beats_looped,
    "sync_budget": check_sync_budget,
    "fused_zero_sync": check_fused_zero_sync,
    "operand_gate": check_operand_gate,
    "serve_gate": check_serve_gate,
    "stream_gate": check_stream_gate,
    "resilience_gate": check_resilience_gate,
    "autotune": check_autotune,
    "pipelined_beats_legacy": check_pipelined_beats_legacy,
}


def run_checks(doc: dict, names: List[str], auto_tolerance: float = 1.5,
               pipeline_tolerance: float = 1.1,
               serve_tolerance: float = 1.0,
               stream_tolerance: float = 2.5) -> List[str]:
    """Run the named checks over one parsed artifact; returns every failure
    (prefixed with its check name) instead of stopping at the first."""
    failures = []
    for name in names:
        if name == "autotune":
            errs = check_autotune(doc, tolerance=auto_tolerance)
        elif name == "pipelined_beats_legacy":
            errs = check_pipelined_beats_legacy(
                doc, tolerance=pipeline_tolerance)
        elif name == "serve_gate":
            errs = check_serve_gate(doc, tolerance=serve_tolerance)
        elif name == "stream_gate":
            errs = check_stream_gate(doc, tolerance=stream_tolerance)
        else:
            errs = CHECKS[name](doc)
        failures.extend(f"[{name}] {e}" for e in errs)
    return failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("artifact", help="benchmark JSON artifact to gate")
    ap.add_argument("--plan-hits", action="store_true")
    ap.add_argument("--batched-beats-looped", action="store_true")
    ap.add_argument("--sync-budget", action="store_true")
    ap.add_argument("--fused-zero-sync", action="store_true")
    ap.add_argument("--operand-gate", action="store_true")
    ap.add_argument("--serve-gate", action="store_true")
    ap.add_argument("--stream-gate", action="store_true")
    ap.add_argument("--resilience-gate", action="store_true")
    ap.add_argument("--autotune", action="store_true")
    ap.add_argument("--pipelined-beats-legacy", action="store_true")
    ap.add_argument("--auto-tolerance", type=float, default=1.5,
                    help="engine='auto' vs best-single-engine ratio bound")
    ap.add_argument("--pipeline-tolerance", type=float, default=1.1,
                    help="fused two-wave vs legacy ratio bound")
    ap.add_argument("--serve-tolerance", type=float, default=1.0,
                    help="coalesced vs per-request replay ratio bound "
                         "(1.0 = coalesced must be strictly no slower)")
    ap.add_argument("--stream-tolerance", type=float, default=2.5,
                    help="streamed vs monolithic wall-time ratio bound "
                         "(tiling buys memory headroom, not speed)")
    args = ap.parse_args(argv)

    names = [n for n in CHECKS if getattr(args, n)]
    if not names:
        ap.error("no checks selected; pass at least one contract flag")
    with open(args.artifact) as f:
        doc = json.load(f)
    failures = run_checks(doc, names, auto_tolerance=args.auto_tolerance,
                          pipeline_tolerance=args.pipeline_tolerance,
                          serve_tolerance=args.serve_tolerance,
                          stream_tolerance=args.stream_tolerance)
    if failures:
        for f in failures:
            print(f"FAIL {f}", file=sys.stderr)
        return 1
    print(f"{args.artifact}: {len(names)} contracts OK ({', '.join(names)})")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
