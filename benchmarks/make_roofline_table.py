"""Generate the EXPERIMENTS.md §Roofline table from dry-run JSON records.

    PYTHONPATH=src python -m benchmarks.make_roofline_table \
        results/measure_single.json results/dryrun_single.json

First file: measurement-mode records (trip-count-corrected flops/bytes/
collectives — see DESIGN.md §7).  Second (optional): production scan-graph
records supplying memory_analysis and compile times.
"""
from __future__ import annotations

import json
import sys

from benchmarks.roofline import roofline_from_record, format_table
from repro.configs import get_config, SHAPE_SETS


def load(path):
    with open(path) as f:
        return json.load(f)


def main():
    meas_path = sys.argv[1] if len(sys.argv) > 1 else "results/measure_single.json"
    prod_path = sys.argv[2] if len(sys.argv) > 2 else "results/dryrun_single.json"
    meas = load(meas_path)
    prod = {(r["arch"], r["shape"]): r for r in load(prod_path)
            if "skipped" not in r}
    shapes = {s.name: s for s in SHAPE_SETS}
    rows, skips, errors = [], [], []
    for rec in meas:
        if "skipped" in rec:
            skips.append(rec)
            continue
        if "error" in rec:
            errors.append(rec)
            continue
        cfg = get_config(rec["arch"])
        rl = roofline_from_record(rec, cfg, shapes[rec["shape"]])
        rows.append((rec, rl))

    print("### Roofline terms (single-pod 16×16, measurement-mode corrected)\n")
    print(format_table([r for _, r in rows]))
    print()
    if skips:
        print("Skipped cells (DESIGN.md §5):\n")
        for s in skips:
            print(f"* {s['arch']} × {s['shape']} — {s['skipped']}")
        print()
    if errors:
        print("MEASUREMENT ERRORS (fix before finalizing):\n")
        for e in errors:
            print(f"* {e['arch']} × {e['shape']} — {e['error']}")
        print()
    print("### Production-graph memory & compile (scan graphs, per device)\n")
    print("| arch | shape | args GiB | temps GiB | out GiB | compile s |")
    print("|---|---|---|---|---|---|")
    for (rec, _) in rows:
        p = prod.get((rec["arch"], rec["shape"]))
        if not p:
            continue
        m = p["memory"]
        print(f"| {p['arch']} | {p['shape']} "
              f"| {m['argument_bytes']/2**30:.2f} "
              f"| {m['temp_bytes']/2**30:.2f} "
              f"| {m['output_bytes']/2**30:.2f} | {p['compile_s']:.0f} |")


if __name__ == "__main__":
    main()
