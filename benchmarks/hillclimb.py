"""§Perf hillclimbing harness: measure a cell under config variants, and
the offline measurement loop behind ``engine="auto"``'s per-bin autotuner.

Arch-config roofline mode (the original harness):

    PYTHONPATH=src python -m benchmarks.hillclimb --arch granite-3-2b \
        --shape train_4k --variant baseline
    ... --variant seq_parallel --set sequence_parallel=True

Each run appends a record to results/perf_log.json with the three roofline
terms, so EXPERIMENTS.md §Perf can show hypothesis → change → before/after.
Variants are applied as ArchConfig field overrides and/or Shardings flags.

SpGEMM per-bin engine sweep (``--spgemm-bins``):

    PYTHONPATH=src python -m benchmarks.hillclimb --spgemm-bins \
        --n 1024 --density 0.02 --row-chunk 128

Runs ``measure_bin_engines``: every registered engine timed on every
non-empty Table-I bin of a synthetic self-product (bin-restricted
sub-executions through ``executor.measure_group_engine``), each timing
recorded into an ``AutotuneCache`` entry — the full-sweep complement of
the executor's incremental in-band measurement (one candidate per
``engine="auto"`` call).  Recording every candidate converges the entry
exactly as the in-band rounds would, so a cache swept here serves
``engine="auto"`` as pure hits from the first call.  Appends the sweep to
results/autotune_log.json and prints it, so EXPERIMENTS.md can show the
measured per-bin engine landscape per backend.

Both the per-(bin, engine) ``measure`` callable and the wall-clock
``timer`` are injectable, so the loop's mechanics (candidate coverage,
cache recording, argmin assignment) are unit-testable without timing real
kernels.
"""
import argparse
import dataclasses
import json
import os

LOG = "results/perf_log.json"
AUTOTUNE_LOG = "results/autotune_log.json"


def append_log(path: str, record: dict) -> list:
    """Append ``record`` to the JSON list at ``path`` and return the full
    log.  Creates the parent directory on first write — a fresh checkout
    has no ``results/``, and a bare filename (empty dirname) must not trip
    ``makedirs``."""
    log = []
    if os.path.exists(path):
        log = json.load(open(path))
    log.append(record)
    d = os.path.dirname(path)
    if d:
        os.makedirs(d, exist_ok=True)
    with open(path, "w") as f:
        json.dump(log, f, indent=1)
    return log


def parse_override(kv: str):
    k, v = kv.split("=", 1)
    for cast in (int, float):
        try:
            return k, cast(v)
        except ValueError:
            pass
    if v in ("True", "False"):
        return k, v == "True"
    return k, v


# ---------------------------------------------------------------------------
# SpGEMM per-bin engine measurement loop (engine="auto" offline sweep)
# ---------------------------------------------------------------------------

def measure_bin_engines(a, b, plan=None, engines=None, cache=None,
                        gather="auto", row_chunk=4096, mesh=None,
                        pipeline="two_wave", reps=2, warmup=1,
                        measure=None):
    """Full per-bin engine sweep for one operand pair; returns the record.

    Measures every candidate engine on every *non-empty* Table-I group of
    ``plan`` (default: ``group_rows(a, b)``) and folds each timing into
    ``cache`` (an ``executor.AutotuneCache``; default the executor's
    module cache) via ``cache.record`` — after the sweep the entry is
    converged and ``engine="auto"`` serves it as pure hits.

    ``measure(group, engine) -> µs`` is injectable for tests; the default
    wraps ``executor.measure_group_engine`` (warmup + min-over-reps timed
    bin-restricted ``execute_plan`` runs).  Returns::

        {"backend": ..., "group_sizes": [...], "timings_us":
         {group: {engine: us}}, "assignment": [per-bin engine names]}
    """
    from repro.core import executor
    from repro.core.grouping import group_rows

    if plan is None:
        plan = group_rows(a, b)
    if engines is None:
        engines = executor.available_engines()
    if cache is None:
        cache = executor.default_autotune_cache()
    if measure is None:
        def measure(group, engine):
            return executor.measure_group_engine(
                a, b, plan, group, engine, gather=gather,
                row_chunk=row_chunk, mesh=mesh, pipeline=pipeline,
                reps=reps, warmup=warmup)

    key = executor.autotune_key(a, b, plan)
    timings = {}
    for g in range(4):
        if plan.group_sizes[g] == 0:
            continue
        timings[g] = {}
        for eng in engines:
            us = float(measure(g, eng))
            timings[g][eng] = us
            cache.record(key, plan, g, eng, us)
    import jax

    entry = cache._entries[key]
    return {
        "backend": jax.default_backend(),
        "group_sizes": list(plan.group_sizes),
        "timings_us": {str(g): dict(t) for g, t in sorted(timings.items())},
        "assignment": list(entry.assignment),
        "converged": entry.converged,
    }


def _spgemm_bins_main(args) -> None:
    """CLI wrapper: sweep a synthetic self-product and log the landscape."""
    import numpy as np

    from repro.sparse.formats import csr_from_dense

    rng = np.random.default_rng(args.seed)
    n = args.n
    x = np.where(rng.random((n, n)) < args.density,
                 rng.integers(1, 5, (n, n)), 0).astype(np.float32)
    a = csr_from_dense(x)
    record = measure_bin_engines(a, a, row_chunk=args.row_chunk,
                                 reps=args.reps)
    record.update(n=n, density=args.density, row_chunk=args.row_chunk,
                  note=args.note)
    append_log(AUTOTUNE_LOG, record)
    print(json.dumps(record, indent=1))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--spgemm-bins", action="store_true",
                    help="run the per-bin engine sweep behind engine='auto' "
                         "instead of the arch-config roofline harness")
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--variant")
    ap.add_argument("--set", action="append", default=[],
                    help="ArchConfig field override, e.g. topk_k=1024")
    ap.add_argument("--seq-parallel", action="store_true")
    ap.add_argument("--note", default="")
    # --spgemm-bins knobs (synthetic self-product workload)
    ap.add_argument("--n", type=int, default=1024,
                    help="spgemm-bins: synthetic graph size")
    ap.add_argument("--density", type=float, default=0.02,
                    help="spgemm-bins: synthetic graph density")
    ap.add_argument("--row-chunk", type=int, default=128,
                    help="spgemm-bins: executor row chunk")
    ap.add_argument("--reps", type=int, default=2,
                    help="spgemm-bins: timed reps per (bin, engine)")
    ap.add_argument("--seed", type=int, default=0,
                    help="spgemm-bins: synthetic graph seed")
    args = ap.parse_args()

    if args.spgemm_bins:
        return _spgemm_bins_main(args)

    if not (args.arch and args.shape and args.variant):
        ap.error("--arch, --shape and --variant are required "
                 "(or pass --spgemm-bins for the engine sweep)")

    # The roofline harness wants a big forced-host-device mesh; set it
    # before jax is imported (this CLI must be the process entry point).
    os.environ.setdefault(
        "XLA_FLAGS", "--xla_force_host_platform_device_count=512")

    from repro.configs import get_config, SHAPE_SETS
    from repro.launch.dryrun import measure_cell
    from repro.launch.mesh import make_production_mesh
    from benchmarks.roofline import roofline_from_record

    cfg = get_config(args.arch)
    overrides = dict(parse_override(s) for s in args.set)
    nested = {k: v for k, v in overrides.items() if "." in k}
    flat = {k: v for k, v in overrides.items() if "." not in k}
    by_outer = {}
    for k, v in nested.items():
        outer, inner = k.split(".", 1)
        by_outer.setdefault(outer, {})[inner] = v
    for outer, kw in by_outer.items():
        flat[outer] = dataclasses.replace(getattr(cfg, outer), **kw)
    if flat:
        cfg = dataclasses.replace(cfg, **flat)
    shape = {s.name: s for s in SHAPE_SETS}[args.shape]
    mesh = make_production_mesh()

    if args.seq_parallel:
        import repro.launch.sharding as shmod
        orig = shmod.make_shardings

        def patched(mesh, sequence_parallel=False):
            return orig(mesh, sequence_parallel=True)
        shmod.make_shardings = patched
        import repro.launch.dryrun as dr
        dr.make_shardings = patched

    rec = measure_cell(cfg, shape, mesh)
    rl = roofline_from_record(rec, cfg, shape)
    entry = {
        "variant": args.variant,
        "arch": args.arch,
        "shape": args.shape,
        "overrides": overrides,
        "seq_parallel": args.seq_parallel,
        "note": args.note,
        "t_compute": rl.t_compute,
        "t_memory": rl.t_memory,
        "t_collective": rl.t_collective,
        "dominant": rl.dominant,
        "roofline_fraction": rl.roofline_fraction,
        "useful_ratio": rl.useful_ratio,
        "flops_per_device": rec["flops_per_device"],
        "bytes_per_device": rec["bytes_accessed_per_device"],
        "collective_bytes": rec["collective_bytes"],
    }
    append_log(LOG, entry)
    print(json.dumps(entry, indent=1))


if __name__ == "__main__":
    main()
