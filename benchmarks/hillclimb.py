"""§Perf hillclimbing harness: measure a cell under config variants.

    PYTHONPATH=src python -m benchmarks.hillclimb --arch granite-3-2b \
        --shape train_4k --variant baseline
    ... --variant seq_parallel --set sequence_parallel=True

Each run appends a record to results/perf_log.json with the three roofline
terms, so EXPERIMENTS.md §Perf can show hypothesis → change → before/after.
Variants are applied as ArchConfig field overrides and/or Shardings flags.
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

import argparse
import dataclasses
import json

from repro.configs import get_config, SHAPE_SETS
from repro.launch.dryrun import measure_cell
from repro.launch.mesh import make_production_mesh
from benchmarks.roofline import roofline_from_record

LOG = "results/perf_log.json"


def parse_override(kv: str):
    k, v = kv.split("=", 1)
    for cast in (int, float):
        try:
            return k, cast(v)
        except ValueError:
            pass
    if v in ("True", "False"):
        return k, v == "True"
    return k, v


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--variant", required=True)
    ap.add_argument("--set", action="append", default=[],
                    help="ArchConfig field override, e.g. topk_k=1024")
    ap.add_argument("--seq-parallel", action="store_true")
    ap.add_argument("--note", default="")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    overrides = dict(parse_override(s) for s in args.set)
    nested = {k: v for k, v in overrides.items() if "." in k}
    flat = {k: v for k, v in overrides.items() if "." not in k}
    by_outer = {}
    for k, v in nested.items():
        outer, inner = k.split(".", 1)
        by_outer.setdefault(outer, {})[inner] = v
    for outer, kw in by_outer.items():
        flat[outer] = dataclasses.replace(getattr(cfg, outer), **kw)
    if flat:
        cfg = dataclasses.replace(cfg, **flat)
    shape = {s.name: s for s in SHAPE_SETS}[args.shape]
    mesh = make_production_mesh()

    if args.seq_parallel:
        import repro.launch.sharding as shmod
        orig = shmod.make_shardings

        def patched(mesh, sequence_parallel=False):
            return orig(mesh, sequence_parallel=True)
        shmod.make_shardings = patched
        import repro.launch.dryrun as dr
        dr.make_shardings = patched

    rec = measure_cell(cfg, shape, mesh)
    rl = roofline_from_record(rec, cfg, shape)
    entry = {
        "variant": args.variant,
        "arch": args.arch,
        "shape": args.shape,
        "overrides": overrides,
        "seq_parallel": args.seq_parallel,
        "note": args.note,
        "t_compute": rl.t_compute,
        "t_memory": rl.t_memory,
        "t_collective": rl.t_collective,
        "dominant": rl.dominant,
        "roofline_fraction": rl.roofline_fraction,
        "useful_ratio": rl.useful_ratio,
        "flops_per_device": rec["flops_per_device"],
        "bytes_per_device": rec["bytes_accessed_per_device"],
        "collective_bytes": rec["collective_bytes"],
    }
    log = []
    if os.path.exists(LOG):
        log = json.load(open(LOG))
    log.append(entry)
    os.makedirs("results", exist_ok=True)
    with open(LOG, "w") as f:
        json.dump(log, f, indent=1)
    print(json.dumps(entry, indent=1))


if __name__ == "__main__":
    main()
