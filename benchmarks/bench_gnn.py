"""Fig. 9/10/11: GNN training with TopK structured pruning.

Per Table-III dataset × {GCN, GIN, GraphSAGE}: full-batch training time for
  * sparse (Eq. 1: aggregation over TopK features — the paper's path), vs
  * dense  (the unpruned baseline),
plus the Fig. 9 scaling study: time-reduction ratio vs graph size with the
Pearson correlation the paper reports (r = 0.94 at H200 scale).
"""
from __future__ import annotations

import time
from typing import Dict, List

import numpy as np

from repro.apps.gnn import GNNConfig, train_gnn, normalize_adjacency
from repro.apps.graphs import TABLE_III_SCALED, rmat_graph, uniform_graph


def _make_dataset(name, seed=0):
    n, deg, n_classes, kind = TABLE_III_SCALED[name]
    gen = rmat_graph if kind == "rmat" else uniform_graph
    g = gen(n, deg, seed=seed)
    rng = np.random.default_rng(seed)
    d_in = 64
    x = rng.standard_normal((n, d_in)).astype(np.float32)
    labels = rng.integers(0, n_classes, n)
    return g, x, labels, n_classes


def bench_one(name: str, arch: str, n_steps=5, topk=16) -> Dict:
    g, x, labels, n_classes = _make_dataset(name)
    a = normalize_adjacency(g)
    out = {"dataset": name, "arch": arch, "nodes": g.n_rows,
           "edges": int(np.asarray(g.nnz))}
    for mode in ("topk", "dense"):
        cfg = GNNConfig(arch=arch, d_in=64, d_hidden=64,
                        n_classes=n_classes, topk=topk, sparse_mode=mode)
        t0 = time.perf_counter()
        _, hist = train_gnn(cfg, a, x, labels, n_steps=n_steps)
        out[f"{mode}_s"] = time.perf_counter() - t0
        out[f"{mode}_final_loss"] = hist[-1]
    out["reduction_pct"] = 100 * (1 - out["topk_s"] / out["dense_s"])
    return out


def scaling_study(arch="gcn", sizes=(512, 1024, 2048, 4096), n_steps=4
                  ) -> Dict:
    """Fig. 9: improvement ratio vs graph size (+ Pearson r)."""
    rows = []
    for n in sizes:
        g = rmat_graph(n, 16.0, seed=1)
        a = normalize_adjacency(g)
        rng = np.random.default_rng(1)
        x = rng.standard_normal((n, 64)).astype(np.float32)
        labels = rng.integers(0, 8, n)
        rec = {"nodes": n}
        for mode in ("topk", "dense"):
            cfg = GNNConfig(arch=arch, d_in=64, d_hidden=64, n_classes=8,
                            topk=16, sparse_mode=mode)
            t0 = time.perf_counter()
            train_gnn(cfg, a, x, labels, n_steps=n_steps)
            rec[f"{mode}_s"] = time.perf_counter() - t0
        rec["reduction_pct"] = 100 * (1 - rec["topk_s"] / rec["dense_s"])
        rows.append(rec)
    xs = np.asarray([r["nodes"] for r in rows], np.float64)
    ys = np.asarray([r["reduction_pct"] for r in rows], np.float64)
    r = float(np.corrcoef(xs, ys)[0, 1]) if len(xs) > 1 else 0.0
    return {"rows": rows, "pearson_r": r}


def run(datasets=("Flickr", "ogbn-arxiv"), archs=("gcn", "gin", "sage"),
        n_steps=5) -> List[Dict]:
    return [bench_one(d, a, n_steps) for d in datasets for a in archs]


def main():
    for r in run(datasets=("Flickr",), archs=("gcn",), n_steps=3):
        print(f"gnn_{r['dataset']}_{r['arch']},{r['topk_s']*1e6:.0f},"
              f"reduction={r['reduction_pct']:.1f}%;"
              f"loss={r['topk_final_loss']:.3f}")


if __name__ == "__main__":
    main()
