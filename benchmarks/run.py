"""Benchmark driver — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV.  Fast-mode defaults keep the whole
suite under a few minutes on CPU; pass --full for the larger workloads used
in EXPERIMENTS.md.
"""
from __future__ import annotations

import argparse
import sys
import time


def _emit(name, us, derived):
    print(f"{name},{us:.0f},{derived}")
    sys.stdout.flush()


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--engine", default="sort", choices=("sort", "hash"),
                    help="accumulation engine for the SpGEMM benchmarks")
    ap.add_argument("--gather", default="xla", choices=("auto", "xla", "aia"),
                    help="B-row gather backend (Fig. 7 ablation axis)")
    args = ap.parse_args()
    eng = args.engine

    from benchmarks import bench_self_product, bench_locality, \
        bench_graph_apps, bench_gnn

    # --- Table II / Fig 6: matrix self-product ---
    names = list(bench_self_product.run(
        names=None if args.full else ["scircuit", "p2p-Gnutella04",
                                      "Economics", "Protein"],
        n_override=None if args.full else 1024,
        methods=(eng,) if not args.full else ("sort", "hash"),
        gathers=(args.gather,)))
    for r in names:
        _emit(f"selfprod_{r['workload']}", r[f"{eng}_ms"] * 1e3,
              f"gflops={r[f'{eng}_gflops']:.3f};ip={r['intermediate_products']};"
              f"nnz_c={r['nnz_c']};vs_dense_pct={r[f'{eng}_vs_dense_reduction_pct']:.1f};"
              f"group_sched_pct={r['group_sched_reduction_pct']:.1f}")

    # --- Fig 5: locality / cache-hit proxy ---
    loc_names = ("scircuit", "cage15") if not args.full else \
        ("scircuit", "cage15", "web-Google")
    for r in bench_locality.run(names=loc_names,
                                n_override=None if args.full else 2048):
        _emit(f"locality_{r['workload']}", 0,
              f"hit_without_pct={r['without_aia_hit_pct']:.1f};"
              f"hit_with_pct={r['with_aia_hit_pct']:.1f};"
              f"round_trip_x={r['round_trip_reduction']:.1f}")

    # --- Fig 7/8: graph applications ---
    for r in bench_graph_apps.bench_contraction(
            names=("Economics", "Protein") if not args.full else
            ("RoadTX", "web-Google", "Economics", "amazon0601",
             "WindTunnel", "Protein"),
            n_override=None if args.full else 1024,
            engine=eng, gather=args.gather):
        _emit(f"contraction_{r['workload']}", r["spgemm_ms"] * 1e3,
              f"vs_dense_pct={r['reduction_vs_dense_pct']:.1f};ip={r['total_ip']}")
    for r in bench_graph_apps.bench_mcl(
            names=("Economics",) if not args.full else
            ("web-Google", "Economics", "Protein"),
            max_iters=2 if not args.full else 3,
            n_override=None if args.full else 1024,
            engine=eng, gather=args.gather):
        _emit(f"mcl_{r['workload']}", r["spgemm_ms"] * 1e3,
              f"vs_dense_pct={r['reduction_vs_dense_pct']:.1f};"
              f"clusters={r['n_clusters']}")

    # --- Fig 10/11: GNN training ---
    for r in bench_gnn.run(
            datasets=("Flickr",) if not args.full else
            ("Flickr", "ogbn-arxiv", "Yelp"),
            archs=("gcn",) if not args.full else ("gcn", "gin", "sage"),
            n_steps=3 if not args.full else 8):
        _emit(f"gnn_{r['dataset']}_{r['arch']}", r["topk_s"] * 1e6,
              f"reduction_pct={r['reduction_pct']:.1f};"
              f"topk_loss={r['topk_final_loss']:.3f};"
              f"dense_loss={r['dense_final_loss']:.3f}")

    # --- Fig 9: scaling study ---
    s = bench_gnn.scaling_study(
        sizes=(512, 1024, 2048) if not args.full else (512, 1024, 2048, 4096))
    _emit("gnn_scaling", 0,
          "pearson_r={:.2f};reductions={}".format(
              s["pearson_r"],
              "/".join(f"{r['reduction_pct']:.0f}%" for r in s["rows"])))


if __name__ == "__main__":
    main()
