"""Benchmark driver — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV.  Fast-mode defaults keep the whole
suite under a few minutes on CPU; pass --full for the larger workloads used
in EXPERIMENTS.md.

Multi-device: ``--devices N`` forces N host devices (XLA_FLAGS is set
*before* jax is imported, so this must be the process entry point) and runs
every SpGEMM through the sharded executor on a ``("shard",)`` mesh.

CI: ``--ci`` runs a tiny synthetic-graph smoke suite and ``--json PATH``
writes the records for the bench-smoke regression gate
(``benchmarks/check_regression.py`` compares against the committed
``benchmarks/BENCH_baseline.json``).
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

RECORDS: list = []


def _emit(name, us, derived):
    RECORDS.append({"name": name, "us": float(f"{us:.0f}"), "derived": derived})
    print(f"{name},{us:.0f},{derived}")
    sys.stdout.flush()


def _make_mesh(n_devices: int):
    if n_devices <= 1:
        return None
    from repro.launch.mesh import make_spgemm_mesh

    return make_spgemm_mesh(n_devices)


def ci_smoke(mesh) -> None:
    """Tiny synthetic-graph smoke run for the bench-smoke CI job.

    One spgemm self-product and a 2-iteration MCL on a 256-node random
    graph; small enough for an ubuntu-latest runner, large enough that a
    pathological slowdown (re-tracing per iteration, broken cache keys)
    blows past the 2x regression gate.
    """
    import numpy as np
    from repro.apps.markov_clustering import mcl
    from repro.core.spgemm import spgemm
    from repro.sparse.formats import csr_from_dense

    rng = np.random.default_rng(0)
    n = 256
    x = np.where(rng.random((n, n)) < 0.04,
                 rng.integers(1, 5, (n, n)), 0).astype(np.float32)
    a = csr_from_dense(x)

    for engine in ("sort", "hash"):
        spgemm(a, a, engine=engine, mesh=mesh)  # warm the program cache
        # min over reps: the noise-robust statistic for a shared CI runner
        best = float("inf")
        for _ in range(3):
            t0 = time.perf_counter()
            res = spgemm(a, a, engine=engine, mesh=mesh)
            best = min(best, time.perf_counter() - t0)
        _emit(f"ci_selfprod_{engine}", best * 1e6,
              f"nnz_c={res.info['nnz_c']};shards={res.info['n_shards']}")

    t0 = time.perf_counter()
    r = mcl(a, e=2, max_iters=2, tol=0.0, mesh=mesh)
    us = (time.perf_counter() - t0) * 1e6
    _emit("ci_mcl", us, f"iters={r.n_iterations};"
          f"clusters={len(np.unique(r.clusters))}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--engine", default="sort", choices=("sort", "hash"),
                    help="accumulation engine for the SpGEMM benchmarks")
    ap.add_argument("--gather", default="xla", choices=("auto", "xla", "aia"),
                    help="B-row gather backend (Fig. 7 ablation axis)")
    ap.add_argument("--devices", type=int, default=1,
                    help="shard the SpGEMM executor over N forced host "
                         "devices (sets XLA_FLAGS before importing jax)")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write records as JSON (bench-smoke artifact)")
    ap.add_argument("--ci", action="store_true",
                    help="tiny synthetic smoke suite for the CI gate")
    args = ap.parse_args()
    eng = args.engine

    if args.devices > 1:
        if "jax" in sys.modules:
            raise RuntimeError(
                "--devices must be set before jax is imported; run "
                "benchmarks/run.py as the process entry point")
        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "")
            + f" --xla_force_host_platform_device_count={args.devices}"
        ).strip()
    mesh = _make_mesh(args.devices)

    if args.ci:
        ci_smoke(mesh)
        if args.json:
            _write_json(args.json, args)
        return

    from benchmarks import bench_self_product, bench_locality, \
        bench_graph_apps, bench_gnn

    # --- Table II / Fig 6: matrix self-product ---
    names = list(bench_self_product.run(
        names=None if args.full else ["scircuit", "p2p-Gnutella04",
                                      "Economics", "Protein"],
        n_override=None if args.full else 1024,
        methods=(eng,) if not args.full else ("sort", "hash"),
        gathers=(args.gather,), mesh=mesh))
    for r in names:
        _emit(f"selfprod_{r['workload']}", r[f"{eng}_ms"] * 1e3,
              f"gflops={r[f'{eng}_gflops']:.3f};ip={r['intermediate_products']};"
              f"nnz_c={r['nnz_c']};vs_dense_pct={r[f'{eng}_vs_dense_reduction_pct']:.1f};"
              f"group_sched_pct={r['group_sched_reduction_pct']:.1f}")

    # --- Fig 5: locality / cache-hit proxy ---
    loc_names = ("scircuit", "cage15") if not args.full else \
        ("scircuit", "cage15", "web-Google")
    for r in bench_locality.run(names=loc_names,
                                n_override=None if args.full else 2048):
        _emit(f"locality_{r['workload']}", 0,
              f"hit_without_pct={r['without_aia_hit_pct']:.1f};"
              f"hit_with_pct={r['with_aia_hit_pct']:.1f};"
              f"round_trip_x={r['round_trip_reduction']:.1f}")

    # --- Fig 7/8: graph applications ---
    for r in bench_graph_apps.bench_contraction(
            names=("Economics", "Protein") if not args.full else
            ("RoadTX", "web-Google", "Economics", "amazon0601",
             "WindTunnel", "Protein"),
            n_override=None if args.full else 1024,
            engine=eng, gather=args.gather, mesh=mesh):
        _emit(f"contraction_{r['workload']}", r["spgemm_ms"] * 1e3,
              f"vs_dense_pct={r['reduction_vs_dense_pct']:.1f};ip={r['total_ip']}")
    for r in bench_graph_apps.bench_mcl(
            names=("Economics",) if not args.full else
            ("web-Google", "Economics", "Protein"),
            max_iters=2 if not args.full else 3,
            n_override=None if args.full else 1024,
            engine=eng, gather=args.gather, mesh=mesh):
        _emit(f"mcl_{r['workload']}", r["spgemm_ms"] * 1e3,
              f"vs_dense_pct={r['reduction_vs_dense_pct']:.1f};"
              f"clusters={r['n_clusters']}")

    # --- Fig 10/11: GNN training ---
    for r in bench_gnn.run(
            datasets=("Flickr",) if not args.full else
            ("Flickr", "ogbn-arxiv", "Yelp"),
            archs=("gcn",) if not args.full else ("gcn", "gin", "sage"),
            n_steps=3 if not args.full else 8):
        _emit(f"gnn_{r['dataset']}_{r['arch']}", r["topk_s"] * 1e6,
              f"reduction_pct={r['reduction_pct']:.1f};"
              f"topk_loss={r['topk_final_loss']:.3f};"
              f"dense_loss={r['dense_final_loss']:.3f}")

    # --- Fig 9: scaling study ---
    s = bench_gnn.scaling_study(
        sizes=(512, 1024, 2048) if not args.full else (512, 1024, 2048, 4096))
    _emit("gnn_scaling", 0,
          "pearson_r={:.2f};reductions={}".format(
              s["pearson_r"],
              "/".join(f"{r['reduction_pct']:.0f}%" for r in s["rows"])))

    if args.json:
        _write_json(args.json, args)


def _write_json(path: str, args) -> None:
    with open(path, "w") as f:
        json.dump({
            "meta": {"devices": args.devices, "engine": args.engine,
                     "gather": args.gather, "ci": bool(args.ci),
                     "full": bool(args.full)},
            "records": RECORDS,
        }, f, indent=2)
    print(f"wrote {len(RECORDS)} records to {path}", file=sys.stderr)


if __name__ == "__main__":
    main()
