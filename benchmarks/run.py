"""Benchmark driver — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV.  Fast-mode defaults keep the whole
suite under a few minutes on CPU; pass --full for the larger workloads used
in EXPERIMENTS.md.

Multi-device: ``--devices N`` forces N host devices (XLA_FLAGS is set
*before* jax is imported, so this must be the process entry point) and runs
every SpGEMM through the sharded executor on a ``("shard",)`` mesh.

CI: ``--ci`` runs a tiny synthetic-graph smoke suite and ``--json PATH``
writes the records for the bench-smoke regression gate
(``benchmarks/check_regression.py`` compares against the committed
``benchmarks/BENCH_baseline.json``).

Amortized paths: ``--batch N`` adds batched-vs-looped SpGEMM records
(one plan serving N same-pattern value sets vs N independent ``spgemm``
calls) and ``--reuse-plan`` adds a plan-cache-served self-product record;
both also fold the executor's ``cache_stats()`` into the JSON meta so CI
can assert nonzero plan-cache hits from the artifact alone.

Pipelining: the CI smoke always emits a ``ci_selfprod_pipelined`` vs
``ci_selfprod_legacy`` pair on a forced multi-chunk plan and writes a
``pipeline_probe`` into the JSON meta (blocking allocate syncs per call on
each path) so the workflow can gate ``host_sync_count`` ≤ waves, not
per-chunk; ``--pipeline`` switches the sync structure for the full suite.

Fused engine: the CI smoke loops its self-product records over every
engine in ``core.executor.available_engines()`` (so ``fused_hash`` and any
future registration are benched automatically), adds a ``ci_selfprod_fused``
multi-chunk probe, and writes a ``fused_probe`` into the JSON meta whose
``host_syncs_fused`` the workflow gates at **zero** — the plan-derived
sizing contract.  ``--sizing`` switches the sizing policy for the full
suite.

Serving: the CI smoke replays one Zipf-popular multi-tenant trace through
the pattern-coalescing ``SpGEMMService`` and a per-request service
(``benchmarks/bench_serve.py``), emitting a ``ci_serve_coalesced`` /
``ci_serve_per_request`` record pair plus a ``serve_probe`` meta dict
(coalescing ratio, p50/p99 latency, per-tenant quota audit) gated by
``assert_ci.py --serve-gate``.

Streaming: both smoke tiers run the out-of-core row-block lane
(``spgemm_streamed``) against the monolithic lane on the tier's
self-product graph, emitting a ``{tier}_selfprod_streamed`` /
``{tier}_selfprod_stream_mono`` record pair plus a ``stream_probe`` meta
dict (bit-exactness verdict, tile/H2D/overlap counter deltas) gated by
``assert_ci.py --stream-gate``.

Operand placement: under ``--devices >= 2`` both smoke tiers append an
``operand_probe`` to the JSON meta — a banded-graph self-product run under
``operands="replicate"`` then ``operands="footprint"``, recording the
B-side bytes/rows actually placed on shard devices in each mode so CI can
gate footprint bytes strictly below replicated bytes from the artifact
alone (``benchmarks/assert_ci.py --operand-gate``).

Resilience: the CI smoke runs a chaos probe (docs/resilience.md) — a
forced ``capacity_undersize`` fault through the fused planned lane, a
clean planned run, and an over-budget MCL expansion through the
``on_budget="stream"`` degradation — emitting ``ci_chaos_capacity_retry``
/ ``ci_chaos_degraded`` records plus a ``resilience_probe`` meta dict
(retry counter deltas, bit-exactness verdicts, clean-path sync/retry
counts) gated by ``assert_ci.py --resilience-gate``.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

RECORDS: list = []
# Filled by the CI smoke's pipeline probe; written into the JSON meta so the
# workflow can gate host_sync_count ≤ waves (not per-chunk) from the artifact.
PIPELINE_PROBE: dict = {}
# Filled by the CI smoke's fused probe: blocking syncs of one fused-engine
# two-wave call (the plan-derived sizing contract is exactly zero).
FUSED_PROBE: dict = {}
# Filled by the medium tier's engine="auto" probe: converged-run autotune
# hit/miss deltas (the no-re-measurement contract) + the chosen per-bin
# assignment, so CI can gate the autotuner from the artifact alone.
AUTOTUNE_PROBE: dict = {}
# Filled by the communication-volume probe (multi-device tiers only):
# bytes/rows of B-side ELL buffers placed on shard devices under full
# replication vs footprint-gathered blocks, so CI can gate the
# communication-avoiding placement saving from the artifact alone.
OPERAND_PROBE: dict = {}
# Filled by the CI smoke's serving probe (benchmarks/bench_serve.py): the
# same Zipf trace replayed through a coalescing SpGEMMService and a
# per-request one, plus the per-tenant plan-quota audit, so CI can gate
# coalesced-beats-per-request and quota isolation from the artifact alone.
SERVE_PROBE: dict = {}
# Filled by the streaming probe (both smoke tiers): the streamed row-block
# lane's bit-exactness verdict vs the monolithic lane plus its tile /
# H2D-bytes / prefetch-overlap counter deltas, so CI can gate the
# out-of-core contract from the artifact alone (assert_ci --stream-gate).
STREAM_PROBE: dict = {}
# Filled by the CI smoke's chaos probe (docs/resilience.md): a forced
# capacity_undersize fault and an over-budget on_budget="stream" MCL,
# recording retry/degradation counter deltas, bit-exactness verdicts, and
# the clean planned path's sync/retry counts, so CI can gate every
# recovery path from the artifact alone (assert_ci --resilience-gate).
RESILIENCE_PROBE: dict = {}


def _emit(name, us, derived):
    RECORDS.append({"name": name, "us": float(f"{us:.0f}"), "derived": derived})
    print(f"{name},{us:.0f},{derived}")
    sys.stdout.flush()


def _make_mesh(n_devices: int):
    if n_devices <= 1:
        return None
    from repro.launch.mesh import make_spgemm_mesh

    return make_spgemm_mesh(n_devices)


def _operand_probe(mesh, row_chunk: int = 64) -> None:
    """Comm-volume probe (multi-device tiers only): one banded-graph
    self-product under ``operands="replicate"`` then ``"footprint"``,
    recording the B-placement byte/row deltas from ``cache_stats()``.

    A banded matrix keeps each shard's A-support inside a partial row band
    of B, so footprint blocks are genuinely smaller than replicas — the
    uniform smoke graphs have near-full footprints and would show no
    saving.  Deltas (not absolute counters) so the probe composes with the
    smoke records that already ran in this process; the banded pattern is
    fresh, so both runs are guaranteed operand-cache misses and the
    placement counters actually move."""
    if mesh is None or mesh.devices.size < 2:
        return
    import numpy as np
    from repro.core import executor
    from repro.core.spgemm import spgemm
    from repro.sparse.formats import csr_from_dense

    n, w = 256, 8
    rng = np.random.default_rng(7)
    dense = np.zeros((n, n), np.float32)
    for i in range(n):
        lo, hi = max(0, i - w), min(n, i + w + 1)
        dense[i, lo:hi] = rng.integers(1, 5, hi - lo)
    band = csr_from_dense(dense)

    keys = ("operand_bytes_placed", "operand_rows_footprint",
            "operand_rows_total")
    deltas = {}
    n_shards = 0
    for mode in ("replicate", "footprint"):
        s0 = executor.cache_stats()
        res = spgemm(band, band, mesh=mesh, row_chunk=row_chunk,
                     operands=mode)
        s1 = executor.cache_stats()
        deltas[mode] = {k: s1[k] - s0[k] for k in keys}
        n_shards = res.info["n_shards"]
    OPERAND_PROBE.update(
        n_shards=n_shards,
        bytes_replicated=deltas["replicate"]["operand_bytes_placed"],
        bytes_footprint=deltas["footprint"]["operand_bytes_placed"],
        rows_footprint=deltas["footprint"]["operand_rows_footprint"],
        rows_total=deltas["footprint"]["operand_rows_total"],
    )


def _stream_probe(mesh, a, prefix: str, tile_rows: int,
                  reps: int = 3) -> None:
    """Out-of-core probe: the streamed row-block lane vs the monolithic
    lane on the tier's self-product graph.

    Emits a ``{prefix}_selfprod_streamed`` / ``{prefix}_selfprod_stream_mono``
    record pair and fills ``STREAM_PROBE`` with the bit-exactness verdict
    plus the streamed lane's counter deltas over the timed reps — CI gates
    bit-exactness, real tiling (>= 2 tiles), prefetch/compute overlap, and
    the streamed-vs-monolithic overhead ratio from the artifact alone
    (``assert_ci.py --stream-gate``).  A per-run ``PlanCache`` is warmed
    first so the timed calls measure the steady-state streaming loop, not
    tile planning."""
    import jax
    import numpy as np
    from repro.core import executor
    from repro.core.spgemm import PlanCache, spgemm, spgemm_streamed

    cache = PlanCache()
    keys = ("tiles_streamed", "tile_bytes_h2d", "prefetch_overlap_hits")
    # warm both lanes: tile plans + compiled programs
    res_s = spgemm_streamed(a, a, tile_rows=tile_rows, mesh=mesh, plan=cache)
    res_m = spgemm(a, a, mesh=mesh)

    ipt_m = np.asarray(res_m.c.indptr)
    nnz = int(ipt_m[-1])
    bit_exact = (
        np.array_equal(np.asarray(res_s.c.indptr), ipt_m)
        and np.array_equal(np.asarray(res_s.c.indices)[:nnz],
                           np.asarray(res_m.c.indices)[:nnz])
        and np.array_equal(np.asarray(res_s.c.data)[:nnz],
                           np.asarray(res_m.c.data)[:nnz]))

    s0 = {k: executor.cache_stats()[k] for k in keys}
    best_s = best_m = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        rs = spgemm_streamed(a, a, tile_rows=tile_rows, mesh=mesh,
                             plan=cache)
        jax.block_until_ready(rs.c)
        best_s = min(best_s, time.perf_counter() - t0)
        t0 = time.perf_counter()
        rm = spgemm(a, a, mesh=mesh)
        jax.block_until_ready(rm.c)
        best_m = min(best_m, time.perf_counter() - t0)
    s1 = {k: executor.cache_stats()[k] for k in keys}
    # per-call deltas so the probe composes with earlier streamed runs
    deltas = {k: (s1[k] - s0[k]) // reps for k in keys}

    streamed_name = f"{prefix}_selfprod_streamed"
    mono_name = f"{prefix}_selfprod_stream_mono"
    STREAM_PROBE.update(
        bit_exact=bool(bit_exact),
        streamed_record=streamed_name, monolithic_record=mono_name,
        n_tiles=int(res_s.info["n_tiles"]),
        tile_rows=int(res_s.info["tile_rows"]),
        prefetch=int(res_s.info["prefetch"]),
        max_tile_ip=int(res_s.info["max_tile_ip"]),
        plan_hits=cache.hits,
        **deltas,
    )
    _emit(streamed_name, best_s * 1e6,
          f"tiles={res_s.info['n_tiles']};tile_rows={tile_rows};"
          f"bit_exact={int(bit_exact)};overlap={deltas['prefetch_overlap_hits']}")
    _emit(mono_name, best_m * 1e6,
          f"nnz_c={res_m.info['nnz_c']};shards={res_m.info['n_shards']}")


def _resilience_probe(mesh, a) -> None:
    """Chaos probe: force every executor recovery path and record that it
    recovered (docs/resilience.md).

    Three measurements on the CI smoke graph: (1) a ``capacity_undersize``
    fault through the fused planned lane — the device-side overflow flag
    must trip, the call must re-execute at measured capacity, and the
    result must be bit-exact vs ``sizing="measured"``; (2) a clean planned
    run — zero ``capacity_retries`` and zero blocking host syncs, the
    fast-path contract the retry machinery must not erode; (3) a
    self-product whose monolithic estimate exceeds a deliberately halved
    device budget, run through ``on_budget="stream"`` — the degradation
    must re-route to the streamed lane and match the un-budgeted product
    bit-exactly (integer-valued graph, so engines agree to the bit).
    Emits ``ci_chaos_capacity_retry`` / ``ci_chaos_degraded`` records and
    fills ``RESILIENCE_PROBE`` for ``assert_ci.py --resilience-gate``.
    """
    import jax
    import numpy as np
    from repro.core import executor, faults
    from repro.core.spgemm import spgemm
    from repro.sparse.formats import csr_to_dense

    # --- forced capacity undersize through the fused planned lane ---
    ref = spgemm(a, a, engine="fused_hash", mesh=mesh, sizing="measured")
    dref = csr_to_dense(ref.c)
    r0 = executor.cache_stats()["capacity_retries"]
    t0 = time.perf_counter()
    with faults.fault_injection("capacity_undersize"):
        res = spgemm(a, a, engine="fused_hash", mesh=mesh)
        jax.block_until_ready(res.c)
    retry_s = time.perf_counter() - t0
    retries_forced = executor.cache_stats()["capacity_retries"] - r0
    retry_bit_exact = bool(np.array_equal(csr_to_dense(res.c), dref))

    # --- clean planned run: the fast path must stay sync- and retry-free
    spgemm(a, a, engine="fused_hash", mesh=mesh)  # warm
    r0 = executor.cache_stats()["capacity_retries"]
    s0 = executor.cache_stats()["host_sync_count"]
    clean = spgemm(a, a, engine="fused_hash", mesh=mesh)
    jax.block_until_ready(clean.c)
    retries_clean = executor.cache_stats()["capacity_retries"] - r0
    syncs_clean = executor.cache_stats()["host_sync_count"] - s0

    _emit("ci_chaos_capacity_retry", retry_s * 1e6,
          f"retries={retries_forced};bit_exact={int(retry_bit_exact)};"
          f"clean_retries={retries_clean};clean_syncs={syncs_clean}")

    # --- over-budget call through the on_budget="stream" degradation ---
    # half the monolithic estimate: the call must degrade to the streamed
    # lane, while the graph's worst single row still fits a tile easily
    need = executor.estimated_device_bytes(
        ref.plan, np.dtype(np.float32).itemsize)
    budget = need // 2
    d0 = executor.cache_stats()["budget_degradations"]
    try:
        executor.set_device_budget(budget)
        t0 = time.perf_counter()
        deg = spgemm(a, a, mesh=mesh, on_budget="stream")
        jax.block_until_ready(deg.c)
        degraded_s = time.perf_counter() - t0
    finally:
        executor.set_device_budget(None)
    degradations = executor.cache_stats()["budget_degradations"] - d0
    degraded_bit_exact = bool(
        deg.info.get("degraded_to_stream") == 1
        and np.array_equal(csr_to_dense(deg.c), dref))

    _emit("ci_chaos_degraded", degraded_s * 1e6,
          f"degradations={degradations};"
          f"bit_exact={int(degraded_bit_exact)};"
          f"budget_bytes={budget}")

    RESILIENCE_PROBE.update(
        capacity_retries_forced=int(retries_forced),
        capacity_retry_bit_exact=bool(retry_bit_exact),
        capacity_retries_clean=int(retries_clean),
        host_syncs_clean=int(syncs_clean),
        budget_degradations=int(degradations),
        degraded_bit_exact=bool(degraded_bit_exact),
    )


def ci_smoke(mesh, batch: int = 0, reuse_plan: bool = False,
             pipeline: str = "two_wave", sizing: str = "auto") -> None:
    """Tiny synthetic-graph smoke run for the bench-smoke CI job.

    One spgemm self-product per *registered engine* (the loop reads
    ``core.executor.available_engines()``, so new engines are benched
    without editing this driver) and a 2-iteration MCL on a 256-node
    random graph; small enough for an ubuntu-latest runner, large enough
    that a pathological slowdown (re-tracing per iteration, broken cache
    keys) blows past the 2x regression gate.  ``batch``/``reuse_plan`` add
    the amortized-path records (batched vs per-matrix loop;
    plan-cache-served self-product) the workflow asserts on.  ``pipeline``
    switches the executor sync structure for every record except the
    explicit pipelined-vs-legacy and fused probes, which always run their
    own paths.
    """
    import jax
    import numpy as np
    from repro.apps.markov_clustering import mcl
    from repro.core.executor import available_engines
    from repro.core.spgemm import PlanCache, spgemm, spgemm_batched
    from repro.sparse.formats import csr_from_dense, csr_to_dense

    rng = np.random.default_rng(0)
    n = 256
    x = np.where(rng.random((n, n)) < 0.04,
                 rng.integers(1, 5, (n, n)), 0).astype(np.float32)
    a = csr_from_dense(x)

    for engine in available_engines():
        spgemm(a, a, engine=engine, mesh=mesh, pipeline=pipeline,
               sizing=sizing)  # warm the program cache
        # min over reps: the noise-robust statistic for a shared CI runner
        best = float("inf")
        for _ in range(3):
            t0 = time.perf_counter()
            res = spgemm(a, a, engine=engine, mesh=mesh, pipeline=pipeline,
                         sizing=sizing)
            jax.block_until_ready(res.c)  # async dispatch: time ALL the work
            best = min(best, time.perf_counter() - t0)
        _emit(f"ci_selfprod_{engine}", best * 1e6,
              f"nnz_c={res.info['nnz_c']};shards={res.info['n_shards']}")

    # Two-wave vs legacy pipeline on a deliberately multi-chunk plan
    # (row_chunk=64 on a 256-row graph): the probe counts the blocking
    # allocate syncs of one call on each path — the pipelined one must pay
    # per *wave* (≤ 1), the legacy one per chunk.
    from repro.core.executor import cache_stats

    for pipe in ("two_wave", "legacy"):
        spgemm(a, a, engine="sort", mesh=mesh, row_chunk=64,
               pipeline=pipe)  # warm
        s0 = cache_stats()["host_sync_count"]
        best = float("inf")
        for _ in range(3):
            t0 = time.perf_counter()
            res = spgemm(a, a, engine="sort", mesh=mesh, row_chunk=64,
                         pipeline=pipe)
            jax.block_until_ready(res.c)  # the benchmark's sync, not the
            # executor's: host_sync_count only counts pipeline-internal syncs
            best = min(best, time.perf_counter() - t0)
        syncs = (cache_stats()["host_sync_count"] - s0) // 3
        name = "ci_selfprod_pipelined" if pipe == "two_wave" \
            else "ci_selfprod_legacy"
        _emit(name, best * 1e6,
              f"host_syncs={syncs};nnz_c={res.info['nnz_c']};"
              f"shards={res.info['n_shards']}")
        key = "host_syncs_pipelined" if pipe == "two_wave" \
            else "host_syncs_legacy"
        PIPELINE_PROBE[key] = syncs

    # Fused zero-sync probe on the same forced multi-chunk plan: the fused
    # engine's plan-derived sizing must dispatch the whole call — all
    # chunks, device indptr, epilogue — without a single blocking host
    # sync.  The workflow gates host_syncs_fused == 0 from the artifact.
    spgemm(a, a, engine="fused_hash", mesh=mesh, row_chunk=64)  # warm
    s0 = cache_stats()["host_sync_count"]
    best = float("inf")
    for _ in range(3):
        t0 = time.perf_counter()
        res = spgemm(a, a, engine="fused_hash", mesh=mesh, row_chunk=64)
        jax.block_until_ready(res.c)
        best = min(best, time.perf_counter() - t0)
    # raw delta over ALL reps (no per-call averaging): the contract is
    # zero syncs, and a single stray sync must not floor-divide away
    syncs = cache_stats()["host_sync_count"] - s0
    _emit("ci_selfprod_fused", best * 1e6,
          f"host_syncs={syncs};nnz_c={res.info['nnz_c']};"
          f"shards={res.info['n_shards']}")
    FUSED_PROBE["host_syncs_fused"] = syncs

    if reuse_plan:
        # Plan-cache-served self-product: first call plans + populates,
        # timed calls skip Alg. 1 + Table-I binning entirely.
        cache = PlanCache()
        spgemm(a, a, engine="sort", mesh=mesh, plan=cache, pipeline=pipeline,
               sizing=sizing)
        best = float("inf")
        for _ in range(3):
            t0 = time.perf_counter()
            jax.block_until_ready(spgemm(a, a, engine="sort", mesh=mesh,
                                         plan=cache, pipeline=pipeline,
                                         sizing=sizing).c)
            best = min(best, time.perf_counter() - t0)
        _emit("ci_selfprod_sort_reuse", best * 1e6,
              f"plan_hits={cache.hits};plan_misses={cache.misses}")

    if batch > 1:
        # Same-pattern value variants: one planned batched run vs a
        # per-matrix Python loop (the amortization headline).
        pattern = rng.random((n, n)) < 0.04
        mats = [csr_from_dense(np.where(
            pattern, rng.integers(1, 5, (n, n)), 0.0).astype(np.float32))
            for _ in range(batch)]
        b = mats[0]
        spgemm_batched(mats, b, engine="sort", mesh=mesh,
                       pipeline=pipeline, sizing=sizing)        # warm
        for m in mats:
            spgemm(m, b, engine="sort", mesh=mesh, pipeline=pipeline,
                   sizing=sizing)  # warm
        best_b = best_l = float("inf")
        for _ in range(3):
            t0 = time.perf_counter()
            res_b = spgemm_batched(mats, b, engine="sort", mesh=mesh,
                                   pipeline=pipeline, sizing=sizing)
            jax.block_until_ready(res_b.cs)
            best_b = min(best_b, time.perf_counter() - t0)
            t0 = time.perf_counter()
            res_l = [spgemm(m, b, engine="sort", mesh=mesh,
                            pipeline=pipeline, sizing=sizing) for m in mats]
            jax.block_until_ready([r.c for r in res_l])
            best_l = min(best_l, time.perf_counter() - t0)
        for mi, (cb, rl) in enumerate(zip(res_b.cs, res_l)):
            assert np.array_equal(np.asarray(csr_to_dense(cb)),
                                  np.asarray(csr_to_dense(rl.c))), (
                f"batched member {mi} diverged from its per-matrix "
                f"spgemm result (engine=sort, pipeline={pipeline})")
        _emit("ci_batched_sort", best_b * 1e6,
              f"batch={batch};nnz_c={res_b.info['nnz_c']};"
              f"shards={res_b.info['n_shards']}")
        _emit("ci_batched_loop_sort", best_l * 1e6,
              f"batch={batch};nnz_c={res_l[0].info['nnz_c']}")

    t0 = time.perf_counter()
    r = mcl(a, e=2, max_iters=2, tol=0.0, mesh=mesh, pipeline=pipeline,
            sizing=sizing)
    us = (time.perf_counter() - t0) * 1e6
    _emit("ci_mcl", us, f"iters={r.n_iterations};"
          f"clusters={len(np.unique(r.clusters))};"
          f"plan_hits={r.plan_cache_hits}")

    # Serving probe: one Zipf trace through the pattern-coalescing
    # SpGEMMService (max_batch=8) and a per-request service (max_batch=1);
    # both pay the full service path, so the record pair isolates what
    # coalescing into spgemm_batched buys.  assert_ci --serve-gate reads
    # the serve_probe meta.
    from benchmarks import bench_serve

    sv = bench_serve.run(mesh=mesh, requests=24, tenants=3, patterns=3,
                         n=128, max_batch=8)
    SERVE_PROBE.update(sv["serve_probe"])
    _emit("ci_serve_coalesced", sv["coalesced_s"] * 1e6,
          f"ratio={sv['serve_probe']['coalescing_ratio']:.2f};"
          f"batched={sv['serve_probe']['batched_dispatches']};"
          f"p99_ms={sv['serve_probe']['latency_p99_ms']:.1f}")
    _emit("ci_serve_per_request", sv["per_request_s"] * 1e6,
          f"dispatches={sv['serve_probe']['per_request_dispatches']};"
          f"speedup_x={sv['serve_probe']['speedup_x']:.2f}")

    _stream_probe(mesh, a, "ci", tile_rows=64)
    _operand_probe(mesh)
    _resilience_probe(mesh, a)


def medium_smoke(mesh, pipeline: str = "two_wave",
                 sizing: str = "auto") -> None:
    """Medium-scale smoke tier (``--tier medium``) — ``medium_*`` records.

    The CI tier's 256-node graph is so small that per-chunk sync overhead
    *beats* the two-wave pipeline (fixed dispatch cost dominates) and
    engine wall times sit inside timer noise.  This tier runs a graph big
    enough that sync elision wins and per-engine differences are stable:

    * ``medium_selfprod_{engine}`` — every registered engine on the same
      forced multi-chunk self-product (the single-engine bar the
      autotuner must match).
    * ``medium_selfprod_pipelined`` / ``medium_selfprod_legacy`` — the
      two-wave-vs-legacy pair on the *fused* engine, where the win the
      tiny tier can't show actually appears: the fused single-pass
      programs + planned zero-sync sizing beat the legacy per-chunk
      allocate-sync path by ~1.4x at this scale.  (On CPU runners host
      syncs are nearly free — host == device, no async dispatch queue —
      so the sort-engine two-wave pair stays within noise of legacy at
      any CI-affordable size; the fused lane is where sync structure
      changes the program count, not just the sync count.)
    * ``medium_selfprod_auto`` — ``engine="auto"`` through a dedicated
      ``AutotuneCache``: warm-up calls converge the per-bin measurement,
      then the timed runs must be pure hits.  The hit/miss deltas of the
      timed (converged) phase and the chosen assignment go into the JSON
      meta as ``autotune_probe`` — CI asserts hits > 0, misses == 0 (no
      re-measurement), and auto ≤ the best single engine within noise
      tolerance, all from the artifact.
    """
    import jax
    import numpy as np
    from repro.core import executor
    from repro.core.spgemm import spgemm
    from repro.sparse.formats import csr_from_dense

    rng = np.random.default_rng(1)
    n = 1024
    x = np.where(rng.random((n, n)) < 0.02,
                 rng.integers(1, 5, (n, n)), 0).astype(np.float32)
    a = csr_from_dense(x)
    row_chunk = 128  # 8 chunks: pipelining has real sync traffic to elide

    def timed(fn, reps=3):
        best = float("inf")
        res = None
        for _ in range(reps):
            t0 = time.perf_counter()
            res = fn()
            jax.block_until_ready(res.c)
            best = min(best, time.perf_counter() - t0)
        return best, res

    for engine in executor.available_engines():
        def run(engine=engine):
            return spgemm(a, a, engine=engine, mesh=mesh,
                          row_chunk=row_chunk, pipeline=pipeline,
                          sizing=sizing)
        run()  # warm the program cache
        best, res = timed(run)
        _emit(f"medium_selfprod_{engine}", best * 1e6,
              f"nnz_c={res.info['nnz_c']};shards={res.info['n_shards']}")

    for pipe in ("two_wave", "legacy"):
        def run(pipe=pipe):
            return spgemm(a, a, engine="fused_hash", mesh=mesh,
                          row_chunk=row_chunk, pipeline=pipe)
        run()  # warm
        best, res = timed(run)
        name = ("medium_selfprod_pipelined" if pipe == "two_wave"
                else "medium_selfprod_legacy")
        _emit(name, best * 1e6,
              f"engine=fused_hash;nnz_c={res.info['nnz_c']};"
              f"shards={res.info['n_shards']}")

    # engine="auto" through a dedicated cache: converge, then time.
    tuner = executor.AutotuneCache()

    def run_auto():
        return spgemm(a, a, engine="auto", mesh=mesh, row_chunk=row_chunk,
                      pipeline=pipeline, sizing=sizing, autotune=tuner)

    # one warm-up round per candidate engine converges every bin
    for _ in range(len(executor.available_engines()) + 1):
        run_auto()
    hits0, misses0 = tuner.hits, tuner.misses
    best, res = timed(run_auto)
    AUTOTUNE_PROBE.update(
        autotune_hits_converged=tuner.hits - hits0,
        autotune_misses_converged=tuner.misses - misses0,
        assignments=tuner.summary(),
    )
    _emit("medium_selfprod_auto", best * 1e6,
          f"nnz_c={res.info['nnz_c']};shards={res.info['n_shards']};"
          f"hits={tuner.hits - hits0};misses={tuner.misses - misses0}")

    _stream_probe(mesh, a, "medium", tile_rows=256)
    _operand_probe(mesh)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--engine", default="sort",
                    help="accumulation engine for the SpGEMM benchmarks; "
                         "validated against core.executor.available_engines()"
                         " after startup, so registered engines (including "
                         "fused_hash and future ones) are benchable without "
                         "editing this driver")
    ap.add_argument("--gather", default="xla", choices=("auto", "xla", "aia"),
                    help="B-row gather backend (Fig. 7 ablation axis)")
    ap.add_argument("--pipeline", default="two_wave",
                    choices=("two_wave", "legacy"),
                    help="executor sync structure: two_wave = one coalesced "
                         "allocate sync + device-side reassembly; legacy = "
                         "per-chunk syncs + NumPy reassembly (A/B baseline)")
    ap.add_argument("--sizing", default="auto",
                    choices=("auto", "planned", "measured"),
                    help="output sizing: planned = sync-free Alg. 1 bounds "
                         "(zero blocking host syncs; the fused_hash "
                         "default), measured = the uniqueCount-sync escape "
                         "hatch, auto = planned for fused engines")
    ap.add_argument("--devices", type=int, default=1,
                    help="shard the SpGEMM executor over N forced host "
                         "devices (sets XLA_FLAGS before importing jax)")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write records as JSON (bench-smoke artifact)")
    ap.add_argument("--ci", action="store_true",
                    help="tiny synthetic smoke suite for the CI gate "
                         "(alias of --tier ci)")
    ap.add_argument("--tier", default=None, choices=("ci", "medium"),
                    help="smoke tier: 'ci' = the tiny 256-node graph; "
                         "'medium' = a 1024-node graph big enough that "
                         "two-wave pipelining wins and per-engine "
                         "differences are stable, emitting medium_* "
                         "records plus the engine='auto' autotune probe")
    ap.add_argument("--batch", type=int, default=0, metavar="N",
                    help="add batched-SpGEMM records: one plan serving N "
                         "same-pattern value sets vs a per-matrix loop")
    ap.add_argument("--reuse-plan", action="store_true",
                    help="add plan-cache records (grouping skipped on "
                         "repeated sparsity patterns)")
    args = ap.parse_args()
    if args.batch == 1:
        ap.error("--batch needs N >= 2 (a batch of one has no loop to "
                 "amortize against); omit the flag to skip batched records")
    eng = args.engine

    if args.devices > 1:
        if "jax" in sys.modules:
            raise RuntimeError(
                "--devices must be set before jax is imported; run "
                "benchmarks/run.py as the process entry point")
        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "")
            + f" --xla_force_host_platform_device_count={args.devices}"
        ).strip()
    mesh = _make_mesh(args.devices)

    # --engine choices come from the live registry (not a frozen argparse
    # list) plus "auto"; imported only now because XLA_FLAGS must precede
    # jax import.  resolve_engine raises naming every valid choice.
    from repro.core.executor import available_engines, resolve_engine

    try:
        resolve_engine(args.engine)
    except ValueError as e:
        ap.error(str(e))

    tier = args.tier or ("ci" if args.ci else None)
    if tier is not None:
        if tier == "ci":
            ci_smoke(mesh, batch=args.batch, reuse_plan=args.reuse_plan,
                     pipeline=args.pipeline, sizing=args.sizing)
        else:
            medium_smoke(mesh, pipeline=args.pipeline, sizing=args.sizing)
        if args.json:
            _write_json(args.json, args)
        return

    from benchmarks import bench_self_product, bench_locality, \
        bench_graph_apps, bench_gnn

    # --- Table II / Fig 6: matrix self-product ---
    names = list(bench_self_product.run(
        names=None if args.full else ["scircuit", "p2p-Gnutella04",
                                      "Economics", "Protein"],
        n_override=None if args.full else 1024,
        methods=(eng,) if not args.full else available_engines(),
        gathers=(args.gather,), mesh=mesh))
    for r in names:
        _emit(f"selfprod_{r['workload']}", r[f"{eng}_ms"] * 1e3,
              f"gflops={r[f'{eng}_gflops']:.3f};ip={r['intermediate_products']};"
              f"nnz_c={r['nnz_c']};"
              f"vs_dense_pct={r[f'{eng}_vs_dense_reduction_pct']:.1f};"
              f"group_sched_pct={r['group_sched_reduction_pct']:.1f}")

    # --- Fig 5: locality / cache-hit proxy ---
    loc_names = ("scircuit", "cage15") if not args.full else \
        ("scircuit", "cage15", "web-Google")
    for r in bench_locality.run(names=loc_names,
                                n_override=None if args.full else 2048):
        _emit(f"locality_{r['workload']}", 0,
              f"hit_without_pct={r['without_aia_hit_pct']:.1f};"
              f"hit_with_pct={r['with_aia_hit_pct']:.1f};"
              f"round_trip_x={r['round_trip_reduction']:.1f}")

    # --- Fig 7/8: graph applications ---
    for r in bench_graph_apps.bench_contraction(
            names=("Economics", "Protein") if not args.full else
            ("RoadTX", "web-Google", "Economics", "amazon0601",
             "WindTunnel", "Protein"),
            n_override=None if args.full else 1024,
            engine=eng, gather=args.gather, mesh=mesh,
            pipeline=args.pipeline, sizing=args.sizing):
        _emit(f"contraction_{r['workload']}", r["spgemm_ms"] * 1e3,
              f"vs_dense_pct={r['reduction_vs_dense_pct']:.1f};ip={r['total_ip']}")
    for r in bench_graph_apps.bench_mcl(
            names=("Economics",) if not args.full else
            ("web-Google", "Economics", "Protein"),
            max_iters=2 if not args.full else 3,
            n_override=None if args.full else 1024,
            engine=eng, gather=args.gather, mesh=mesh,
            pipeline=args.pipeline, sizing=args.sizing):
        _emit(f"mcl_{r['workload']}", r["spgemm_ms"] * 1e3,
              f"vs_dense_pct={r['reduction_vs_dense_pct']:.1f};"
              f"clusters={r['n_clusters']};plan_hits={r['plan_hits']}")

    # --- Amortized batched path: one plan, N same-pattern value sets ---
    if args.batch > 1:
        for r in bench_graph_apps.bench_batched_selfprod(
                names=("Economics", "Protein") if not args.full else
                ("RoadTX", "web-Google", "Economics", "Protein"),
                batch=args.batch, n_override=None if args.full else 1024,
                engine=eng, gather=args.gather, mesh=mesh,
                pipeline=args.pipeline, sizing=args.sizing):
            _emit(f"batched_{r['workload']}", r["batched_ms"] * 1e3,
                  f"batch={r['batch']};loop_ms={r['loop_ms']:.1f};"
                  f"speedup_x={r['speedup_x']:.2f}")

    # --- Fig 10/11: GNN training ---
    for r in bench_gnn.run(
            datasets=("Flickr",) if not args.full else
            ("Flickr", "ogbn-arxiv", "Yelp"),
            archs=("gcn",) if not args.full else ("gcn", "gin", "sage"),
            n_steps=3 if not args.full else 8):
        _emit(f"gnn_{r['dataset']}_{r['arch']}", r["topk_s"] * 1e6,
              f"reduction_pct={r['reduction_pct']:.1f};"
              f"topk_loss={r['topk_final_loss']:.3f};"
              f"dense_loss={r['dense_final_loss']:.3f}")

    # --- Fig 9: scaling study ---
    s = bench_gnn.scaling_study(
        sizes=(512, 1024, 2048) if not args.full else (512, 1024, 2048, 4096))
    _emit("gnn_scaling", 0,
          "pearson_r={:.2f};reductions={}".format(
              s["pearson_r"],
              "/".join(f"{r['reduction_pct']:.0f}%" for r in s["rows"])))

    if args.json:
        _write_json(args.json, args)


def _write_json(path: str, args) -> None:
    from repro.core.executor import cache_stats

    meta = {"devices": args.devices, "engine": args.engine,
            "gather": args.gather, "ci": bool(args.ci),
            "tier": args.tier or ("ci" if args.ci else None),
            "full": bool(args.full), "batch": args.batch,
            "reuse_plan": bool(args.reuse_plan),
            "sizing": args.sizing,
            "cache_stats": cache_stats()}
    if PIPELINE_PROBE:
        meta["pipeline_probe"] = dict(PIPELINE_PROBE)
    if FUSED_PROBE:
        meta["fused_probe"] = dict(FUSED_PROBE)
    if AUTOTUNE_PROBE:
        meta["autotune_probe"] = dict(AUTOTUNE_PROBE)
    if OPERAND_PROBE:
        meta["operand_probe"] = dict(OPERAND_PROBE)
    if SERVE_PROBE:
        meta["serve_probe"] = dict(SERVE_PROBE)
    if STREAM_PROBE:
        meta["stream_probe"] = dict(STREAM_PROBE)
    if RESILIENCE_PROBE:
        meta["resilience_probe"] = dict(RESILIENCE_PROBE)
    with open(path, "w") as f:
        json.dump({"meta": meta, "records": RECORDS}, f, indent=2)
    print(f"wrote {len(RECORDS)} records to {path}", file=sys.stderr)


if __name__ == "__main__":
    main()
