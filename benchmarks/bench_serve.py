"""Open-loop serving bench: coalesced vs per-request dispatch under Zipf.

Synthesizes multi-tenant SpGEMM traffic the way the paper's GNN serving
story assumes it arrives — many tenants issuing small queries whose
sparsity patterns follow a Zipf popularity law (a few hot structures
dominate, a long tail of cold ones) — and replays the *same* trace through
two ``SpGEMMService`` configurations:

* **coalesced** — ``max_batch=B``: same-pattern requests ride one
  ``spgemm_batched`` dispatch;
* **per-request** — ``max_batch=1``: every request dispatches alone, but
  still pays the full service path (validation, fingerprinting, queueing),
  so the timing delta isolates coalescing rather than service overhead.

Both paths keep per-tenant plan caches, so plan amortization is equal;
what coalescing buys is fewer executor dispatches.  ``run()`` returns the
timing pair plus a ``serve_probe`` dict (coalescing ratio, p50/p99
latency, shed counts, and a per-tenant quota audit) that
``benchmarks/run.py`` folds into the bench-smoke JSON for the CI serve
gate (``assert_ci.py --serve-gate``).
"""
from __future__ import annotations

import time
from typing import Dict, List, Tuple

import numpy as np


def _make_trace(requests: int, tenants: int, patterns: int, n: int,
                density: float, zipf: float, seed: int
                ) -> List[Tuple[str, object, object]]:
    """Build the (tenant, A, B) request trace with Zipf pattern popularity."""
    from repro.sparse.formats import csr_from_dense

    rng = np.random.default_rng(seed)
    masks = [rng.random((n, n)) < density for _ in range(patterns)]
    b_side = [csr_from_dense(
        (m * rng.standard_normal((n, n))).astype(np.float32)) for m in masks]
    ranks = np.arange(1, patterns + 1, dtype=np.float64)
    pop = ranks ** -zipf
    pop /= pop.sum()
    trace = []
    for i in range(requests):
        pid = int(rng.choice(patterns, p=pop))
        vals = rng.standard_normal((n, n)).astype(np.float32)
        a = csr_from_dense((masks[pid] * vals).astype(np.float32))
        trace.append((f"tenant{i % tenants}", a, b_side[pid]))
    return trace


def _replay(trace, *, max_batch: int, plan_quota: int, mesh=None) -> tuple:
    """Replay the trace through a fresh service; returns (seconds, stats)."""
    import jax

    from repro.serve import SpGEMMService

    svc = SpGEMMService(max_batch=max_batch, max_wait=1e9,
                        max_queue=len(trace) + 1,
                        tenant_plan_quota=plan_quota,
                        clock=time.perf_counter)
    tickets = []
    t0 = time.perf_counter()
    for tenant, a, b in trace:
        tickets.append(svc.submit(tenant, a, b))
    svc.flush()
    jax.block_until_ready([t.result().c.data for t in tickets])
    return time.perf_counter() - t0, svc.stats()


def run(mesh=None, requests: int = 32, tenants: int = 4, patterns: int = 4,
        n: int = 128, density: float = 0.04, zipf: float = 1.2,
        max_batch: int = 8, plan_quota: int = 8, reps: int = 3,
        seed: int = 0) -> Dict[str, object]:
    """Bench coalesced vs per-request dispatch on one Zipf trace.

    Returns ``{"coalesced_s", "per_request_s", "speedup_x",
    "serve_probe"}`` where ``serve_probe`` carries the stats CI gates on.
    Timings are min-over-``reps`` of the full open-loop replay (submit
    all → flush → block on every result); a warm-up replay of each path
    absorbs program compilation first.
    """
    trace = _make_trace(requests, tenants, patterns, n, density, zipf, seed)

    _replay(trace, max_batch=max_batch, plan_quota=plan_quota, mesh=mesh)
    _replay(trace, max_batch=1, plan_quota=plan_quota, mesh=mesh)  # warm

    best_c = best_p = float("inf")
    stats_c = stats_p = None
    for _ in range(reps):
        s, st = _replay(trace, max_batch=max_batch, plan_quota=plan_quota,
                        mesh=mesh)
        if s < best_c:
            best_c, stats_c = s, st
        s, st = _replay(trace, max_batch=1, plan_quota=plan_quota, mesh=mesh)
        if s < best_p:
            best_p, stats_p = s, st

    tenant_entries = [t["plan_entries"]
                      for t in stats_c["tenants"].values()]
    # Quota audit: replay once more under a plan quota *smaller* than the
    # pattern count, so LRU eviction actually fires, and check every
    # tenant's cache respects its bound (the per-tenant isolation contract).
    tight_quota = max(1, patterns // 2)
    _, stats_q = _replay(trace, max_batch=max_batch,
                         plan_quota=tight_quota, mesh=mesh)
    tight_entries = [t["plan_entries"] for t in stats_q["tenants"].values()]
    probe = {
        "requests": requests,
        "tenants": tenants,
        "patterns": patterns,
        "max_batch": max_batch,
        "coalesced_s": best_c,
        "per_request_s": best_p,
        "speedup_x": best_p / best_c if best_c > 0 else 0.0,
        "coalescing_ratio": stats_c["coalescing_ratio"],
        "batched_dispatches": stats_c["batched_dispatches"],
        "singleton_dispatches": stats_c["singleton_dispatches"],
        "per_request_dispatches": stats_p["dispatches"],
        "latency_p50_ms": stats_c["latency_p50_ms"],
        "latency_p99_ms": stats_c["latency_p99_ms"],
        "requests_shed": stats_c["requests_shed"],
        "tenant_plan_quota": plan_quota,
        "max_tenant_plan_entries": max(tenant_entries),
        "tight_quota": tight_quota,
        "max_tenant_plan_entries_tight": max(tight_entries),
        "quota_respected": (max(tenant_entries) <= plan_quota
                            and max(tight_entries) <= tight_quota),
    }
    return {"coalesced_s": best_c, "per_request_s": best_p,
            "speedup_x": probe["speedup_x"], "serve_probe": probe}
