"""Roofline analysis from the compiled dry-run (deliverable g).

Three terms per (arch × shape × mesh), TPU v5e constants:

    T_compute    = HLO_FLOPs_per_chip / 197e12            (bf16 MXU peak)
    T_memory     = HLO_bytes_per_chip / 819e9             (HBM bandwidth)
    T_collective = wire_bytes_per_chip / (n_links · 50e9) (ICI)

Sources: ``compiled.cost_analysis()`` (flops / bytes accessed are already
per-partition post-SPMD), and the post-SPMD HLO text for collective operand
bytes.  Wire-cost weights (ring algorithms over the ICI torus):
all-reduce 2(n−1)/n, all-gather & reduce-scatter (n−1)/n, all-to-all
(n−1)/n, collective-permute 1.  n_links: v5e has 4 ICI links per chip
(2D torus); collectives on one mesh axis use 2 of them concurrently.

MODEL_FLOPS: 6·N·D for train (N = params incl. embeddings, D = tokens);
6·N_active·D for MoE; 2·N·B for a decode step (forward only, 1 token);
the ratio MODEL_FLOPS/HLO_FLOPs measures useful compute (remat/redundancy
shows up as ratio < its theoretical ceiling: 1.0 for fwd-only, ~0.75 with
full remat since HLO executes 4 passes of the 3-pass fwd+bwd budget).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

# --- hardware constants (TPU v5e, per chip) ---
PEAK_FLOPS_BF16 = 197e12      # FLOP/s
HBM_BW = 819e9                # B/s
ICI_LINK_BW = 50e9            # B/s per link
ICI_LINKS_USED = 2            # links engaged per mesh-axis collective

WIRE_WEIGHT = {
    "all-reduce": lambda n: 2 * (n - 1) / n,
    "all-gather": lambda n: (n - 1) / n,
    "reduce-scatter": lambda n: (n - 1) / n,
    "all-to-all": lambda n: (n - 1) / n,
    "collective-permute": lambda n: 1.0,
}


@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    mesh: Dict[str, int]
    t_compute: float
    t_memory: float
    t_collective: float
    model_flops_per_chip: float
    hlo_flops_per_chip: float

    @property
    def dominant(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def bound_seconds(self) -> float:
        """Lower bound on step time = max of the three terms (perfect overlap)."""
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def useful_ratio(self) -> float:
        return (self.model_flops_per_chip / self.hlo_flops_per_chip
                if self.hlo_flops_per_chip else 0.0)

    @property
    def roofline_fraction(self) -> float:
        """Fraction of the ideal: useful-FLOPs time / bound time.

        = (MODEL_FLOPS/chip / peak) / max-term.  This is the MFU the step
        would achieve if it ran exactly at the dominant-term bound.
        """
        ideal = self.model_flops_per_chip / PEAK_FLOPS_BF16
        return ideal / self.bound_seconds if self.bound_seconds else 0.0


def model_flops(cfg, shape) -> float:
    """Whole-step analytic FLOPs (global, all chips)."""
    tokens = shape.global_batch * shape.seq_len
    n_active = cfg.n_active_params()
    if shape.kind == "train":
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        return 2.0 * n_active * tokens
    # decode: one token per sequence + attention over the cache
    flops = 2.0 * n_active * shape.global_batch
    # attention reads: 2 (QK^T) + 2 (PV) flops per cached element per head-dim
    if cfg.attention != "none":
        kv_dim = (cfg.mla.kv_lora + cfg.mla.qk_rope_dim) if cfg.attention == "mla" \
            else cfg.n_heads * cfg.hd * 2
        n_attn_layers = cfg.n_layers if cfg.family != "hybrid" else \
            cfg.n_layers // max(cfg.shared_attn_every, 1)
        flops += 2.0 * shape.global_batch * shape.seq_len * kv_dim * n_attn_layers
    return flops


def wire_bytes_per_chip(collective_bytes: Dict[str, float],
                        mesh_shape: Dict[str, int]) -> float:
    """Apply ring wire weights.  cost figures are per-partition already;
    weight by the largest mesh axis (conservative: collectives span one
    axis; cross-pod ARs span pod×data which the max also covers)."""
    n = max(mesh_shape.values()) if mesh_shape else 1
    total = 0.0
    for kind, b in collective_bytes.items():
        w = WIRE_WEIGHT.get(kind, lambda n: 1.0)(max(n, 2))
        total += w * b
    return total


def roofline_from_record(rec: Dict, cfg, shape) -> Optional[Roofline]:
    if "skipped" in rec:
        return None
    n_chips = 1
    for v in rec["mesh"].values():
        n_chips *= v
    mf = model_flops(cfg, shape) / n_chips
    wire = wire_bytes_per_chip(rec["collective_bytes"], rec["mesh"])
    return Roofline(
        arch=rec["arch"], shape=rec["shape"], mesh=rec["mesh"],
        t_compute=rec["flops_per_device"] / PEAK_FLOPS_BF16,
        t_memory=rec["bytes_accessed_per_device"] / HBM_BW,
        t_collective=wire / (ICI_LINKS_USED * ICI_LINK_BW),
        model_flops_per_chip=mf,
        hlo_flops_per_chip=rec["flops_per_device"],
    )


def format_table(rows) -> str:
    hdr = ("| arch | shape | T_comp (s) | T_mem (s) | T_coll (s) | dominant "
           "| MODEL/HLO | roofline frac |")
    sep = "|" + "---|" * 8
    lines = [hdr, sep]
    for r in rows:
        if r is None:
            continue
        lines.append(
            f"| {r.arch} | {r.shape} | {r.t_compute:.3e} | {r.t_memory:.3e} "
            f"| {r.t_collective:.3e} | **{r.dominant}** "
            f"| {r.useful_ratio:.2f} | {r.roofline_fraction:.1%} |")
    return "\n".join(lines)
