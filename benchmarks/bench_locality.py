"""Fig. 5 analogue: cache-hit-ratio / DMA-locality proxy, with vs without AIA.

The paper measures L1 hit ratio on the GPU (allocation 64.66→88.15 %,
accumulation 64.41→75.14 %).  TPUs have no comparable L1, so we measure the
same *phenomenon* — how AIA turns scattered accesses into locality-friendly
streams — with two hardware-independent metrics over the actual SpGEMM
access trace (the sequence of B-rows touched while producing C):

1. **Simulated cache hit ratio**: an LRU over B-row cache lines replays the
   trace.  "Without AIA": rows of A processed in natural order, each B-row
   element access is an independent transaction.  "With AIA": rows processed
   in the row-grouping Map order (the paper's load-balanced mapping, §IV-D)
   and each B-row arrives as ONE ranged transaction (R = row length).
2. **Memory round trips**: the paper's Fig. 2 count — 2N request/response
   pairs without AIA vs 1 bulk request per row stream with AIA.
"""
from __future__ import annotations

from typing import Dict, List

import numpy as np

from repro.apps.graphs import table_ii_matrix
from repro.core.grouping import group_rows
from repro.sparse.formats import CSR

LINE_BYTES = 128  # cache line / DMA granule
ROW_BYTES = 8     # one CSR (col, val) element


class LRU:
    def __init__(self, n_lines: int):
        self.n = n_lines
        self.stamp = 0
        self.lines: Dict[int, int] = {}

    def access(self, line: int) -> bool:
        self.stamp += 1
        hit = line in self.lines
        self.lines[line] = self.stamp
        if len(self.lines) > self.n:
            victim = min(self.lines, key=self.lines.get)
            del self.lines[victim]
        return hit


def access_trace(a: CSR, order: np.ndarray):
    """Yield (b_row, b_row_len) accesses in the given A-row order."""
    indptr = np.asarray(a.indptr)
    indices = np.asarray(a.indices)
    row_len = indptr[1:] - indptr[:-1]
    for i in order:
        for p in range(indptr[i], indptr[i + 1]):
            yield int(indices[p]), int(row_len[indices[p]])


def simulate(a: CSR, cache_kib: int = 128) -> Dict[str, float]:
    n_lines = cache_kib * 1024 // LINE_BYTES
    natural = np.arange(a.n_rows)
    plan = group_rows(a, a)
    grouped = plan.map_rows

    results = {}
    for label, order, ranged in (("without_aia", natural, False),
                                 ("with_aia", grouped, True)):
        lru = LRU(n_lines)
        hits = total = 0
        round_trips = 0
        for brow, blen in access_trace(a, order):
            nbytes = max(blen, 1) * ROW_BYTES
            first_line = brow * 64  # line id space per row (synthetic layout)
            lines = range(first_line, first_line + (nbytes + LINE_BYTES - 1)
                          // LINE_BYTES)
            if ranged:
                # one bulk ranged transaction: a single "access" covering the
                # whole row; hit iff the row's lead line is resident
                total += 1
                hits += lru.access(first_line)
                for ln in lines:
                    lru.lines[ln] = lru.stamp  # prefetched by the bulk stream
                round_trips += 1  # one request/response pair
            else:
                # element-by-element: indptr lookup + per-line accesses
                for ln in lines:
                    total += 1
                    hits += lru.access(ln)
                round_trips += 2 * max(blen, 1)  # Fig. 2: 2 trips per element
        results[f"{label}_hit_pct"] = 100.0 * hits / max(total, 1)
        results[f"{label}_round_trips"] = round_trips
    results["round_trip_reduction"] = (
        results["without_aia_round_trips"] / max(results["with_aia_round_trips"], 1))
    return results


def run(names=("scircuit", "cage15"), n_override=None) -> List[Dict]:
    out = []
    for name in names:
        a = table_ii_matrix(name, n_override=n_override)
        r = {"workload": name}
        r.update(simulate(a))
        out.append(r)
    return out


def main():
    for r in run():
        print(f"locality_{r['workload']},0,"
              f"hit_without={r['without_aia_hit_pct']:.1f}%;"
              f"hit_with={r['with_aia_hit_pct']:.1f}%;"
              f"round_trip_x={r['round_trip_reduction']:.1f}")


if __name__ == "__main__":
    main()
