"""Fused flash-attention Pallas kernel vs the online-softmax reference."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import flash_attention_fused


def ref_attention(q, k, v, causal):
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / np.sqrt(q.shape[-1])
    if causal:
        mask = np.tril(np.ones((q.shape[1], k.shape[1]), bool))
        s = jnp.where(mask[None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p, v.astype(jnp.float32))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("bh,s,d,qb,kb", [
    (2, 64, 32, 16, 16), (1, 128, 64, 32, 64), (3, 32, 16, 32, 16),
])
def test_flash_fused_matches_ref(dtype, causal, bh, s, d, qb, kb):
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.standard_normal((bh, s, d)), dtype)
    k = jnp.asarray(rng.standard_normal((bh, s, d)), dtype)
    v = jnp.asarray(rng.standard_normal((bh, s, d)), dtype)
    got = flash_attention_fused(q, k, v, causal=causal, q_blk=qb, k_blk=kb,
                                interpret=True)
    expect = ref_attention(q, k, v, causal)
    rtol = 5e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(expect, np.float32),
                               rtol=rtol, atol=2e-2 if dtype == jnp.bfloat16
                               else 1e-5)


def test_flash_fused_matches_model_flash():
    """Consistency with the model-side chunked flash (attention.py)."""
    from repro.models.attention import flash_attention
    rng = np.random.default_rng(1)
    b, s, h, d = 2, 64, 4, 32
    q = jnp.asarray(rng.standard_normal((b, s, h, d)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, s, h, d)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, s, h, d)), jnp.float32)
    model_out = flash_attention(q, k, v, causal=True, q_chunk=32, k_chunk=32)
    qk = jnp.moveaxis(q, 2, 1).reshape(b * h, s, d)
    kk = jnp.moveaxis(k, 2, 1).reshape(b * h, s, d)
    vk = jnp.moveaxis(v, 2, 1).reshape(b * h, s, d)
    kern_out = flash_attention_fused(qk, kk, vk, causal=True, q_blk=16,
                                     k_blk=16, interpret=True)
    kern_out = jnp.moveaxis(kern_out.reshape(b, h, s, d), 1, 2)
    np.testing.assert_allclose(np.asarray(kern_out), np.asarray(model_out),
                               rtol=2e-4, atol=2e-5)
