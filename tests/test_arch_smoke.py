"""Per-architecture smoke tests: reduced same-family configs on CPU.

One forward/train step per arch asserting output shapes + no NaNs (per the
brief); plus decode-cache consistency and the paper's TopK-SpGEMM FFN
integration checks.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, smoke_config
from repro.data.pipeline import synthetic_batch
from repro.configs.base import ShapeSpec
from repro.models.transformer import (
    init_transformer, train_loss, forward_hidden, init_decode_cache,
    decode_step,
)

SMOKE_SHAPE = ShapeSpec("smoke", 32, 2, "train")


def make_batch(cfg, b=2, s=32, seed=0):
    rng = np.random.default_rng(seed)
    return {k: jnp.asarray(v) for k, v in
            synthetic_batch(cfg, SMOKE_SHAPE, rng, batch_override=b).items()}


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_smoke_train_step(arch):
    cfg = smoke_config(arch)
    params, specs = init_transformer(cfg, jax.random.PRNGKey(0))
    batch = make_batch(cfg)
    loss, grads = jax.jit(
        jax.value_and_grad(lambda p: train_loss(cfg, p, batch))
    )(params)
    assert np.isfinite(float(loss))
    for leaf in jax.tree.leaves(grads):
        assert np.isfinite(np.asarray(leaf, np.float32)).all()
    # spec tree mirrors the param tree
    assert jax.tree.structure(specs) == jax.tree.structure(
        jax.tree.map(lambda _: object(), params))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_smoke_decode_step(arch):
    cfg = smoke_config(arch)
    params, _ = init_transformer(cfg, jax.random.PRNGKey(0))
    b = 2
    cache = init_decode_cache(cfg, b, 16)
    tok = jnp.asarray(np.random.default_rng(0).integers(0, cfg.vocab, (b, 1)))
    step = jax.jit(lambda p, c, t: decode_step(cfg, p, c, t))
    logits, cache = step(params, cache, tok)
    assert logits.shape == (b, 1, cfg.vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    assert int(cache["pos"]) == 1
    logits2, cache = step(params, cache, tok)
    assert int(cache["pos"]) == 2
    assert np.isfinite(np.asarray(logits2, np.float32)).all()


@pytest.mark.parametrize("arch", ["granite-3-2b", "rwkv6-1.6b", "zamba2-1.2b"])
def test_decode_matches_forward(arch):
    """Greedy decode logits == train-mode forward logits at same positions."""
    cfg = smoke_config(arch)
    params, _ = init_transformer(cfg, jax.random.PRNGKey(1))
    b, s = 1, 8
    rng = np.random.default_rng(1)
    toks = rng.integers(0, cfg.vocab, (b, s)).astype(np.int32)
    h, _ = forward_hidden(cfg, params, jnp.asarray(toks))
    from repro.models.common import rms_norm
    ref_logits = np.asarray(
        rms_norm(h, params["out_norm"], cfg.norm_eps) @ params["lm_head"])
    cache = init_decode_cache(cfg, b, s)
    step = jax.jit(lambda p, c, t: decode_step(cfg, p, c, t))
    for t in range(s):
        logits, cache = step(params, cache, jnp.asarray(toks[:, t:t + 1]))
    np.testing.assert_allclose(np.asarray(logits)[:, 0], ref_logits[:, -1],
                               rtol=2e-2, atol=2e-3)


@pytest.mark.parametrize("mode", ["topk", "block_topk"])
def test_topk_spgemm_ffn_integration(mode):
    """Paper Eq. (1-3) as an LM feature: train step runs, loss finite, and
    k=d_ff reduces to the dense model (topk mode)."""
    base = smoke_config("granite-3-2b")
    cfg = dataclasses.replace(base, ffn_mode=mode, topk_k=64, topk_block=32)
    params, _ = init_transformer(cfg, jax.random.PRNGKey(0))
    batch = make_batch(cfg)
    loss, grads = jax.jit(
        jax.value_and_grad(lambda p: train_loss(cfg, p, batch))
    )(params)
    assert np.isfinite(float(loss))
    gn = sum(float(jnp.sum(jnp.abs(g))) for g in jax.tree.leaves(grads))
    assert gn > 0


def test_topk_full_k_equals_dense():
    base = smoke_config("granite-3-2b")
    cfg_t = dataclasses.replace(base, ffn_mode="topk", topk_k=base.d_ff)
    cfg_d = dataclasses.replace(base, ffn_mode="dense")
    params, _ = init_transformer(cfg_d, jax.random.PRNGKey(2))
    batch = make_batch(cfg_d)
    lt = float(train_loss(cfg_t, params, batch))
    ld = float(train_loss(cfg_d, params, batch))
    np.testing.assert_allclose(lt, ld, rtol=1e-5)


def test_param_count_sanity():
    """Analytic n_params within 2% of actual leaf count for a dense arch."""
    cfg = smoke_config("granite-3-2b")
    params, _ = init_transformer(cfg, jax.random.PRNGKey(0))
    actual = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(params))
    analytic = cfg.n_params()
    assert abs(actual - analytic) / actual < 0.02, (actual, analytic)


def test_full_configs_match_table():
    """The 10 full configs carry the exact published dimensions."""
    expect = {
        "deepseek-67b": (95, 8192, 64, 8, 22016, 102400),
        "internlm2-20b": (48, 6144, 48, 8, 16384, 92544),
        "granite-3-2b": (40, 2048, 32, 8, 8192, 49155),
        "phi3-mini-3.8b": (32, 3072, 32, 32, 8192, 32064),
        "internvl2-76b": (80, 8192, 64, 8, 28672, 128256),
        "zamba2-1.2b": (38, 2048, 32, 32, 8192, 32000),
        "whisper-large-v3": (32, 1280, 20, 20, 5120, 51866),
        "llama4-scout-17b-a16e": (48, 5120, 40, 8, 8192, 202048),
        "rwkv6-1.6b": (24, 2048, 32, 32, 7168, 65536),
    }
    for arch, (L, d, h, kv, ff, v) in expect.items():
        c = get_config(arch)
        assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
                c.vocab) == (L, d, h, kv, ff, v), arch
    dsl = get_config("deepseek-v2-lite-16b")
    assert (dsl.n_layers, dsl.d_model, dsl.n_heads, dsl.vocab) == \
        (27, 2048, 16, 102400)
    assert dsl.moe.n_experts == 64 and dsl.moe.top_k == 6 and dsl.moe.n_shared == 2
    assert dsl.moe.d_ff_expert == 1408 and dsl.mla.kv_lora == 512
