"""Multi-phase SpGEMM vs dense oracle: both engines, Table-I grouping, API."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import intermediate_products, ip_histogram, spgemm
from repro.core.grouping import assign_groups, build_map
from repro.core.ref import spgemm_dense, intermediate_products_dense
from repro.core.spgemm import spgemm_ell_fixed
from repro.core import hashtable as ht
from repro.sparse import csr_from_dense, csr_to_dense, ell_from_dense, ell_to_dense


def random_sparse(rng, n, m, density=0.2):
    x = rng.standard_normal((n, m)).astype(np.float32)
    mask = rng.random((n, m)) < density
    return np.where(mask, x, 0.0).astype(np.float32)


# ---------------------------------------------------------------------------
# Phase 1: Algorithm 1
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,k,m,da,db", [(8, 6, 7, 0.3, 0.3), (20, 20, 20, 0.1, 0.5),
                                          (5, 30, 4, 0.8, 0.05)])
def test_ip_count_matches_loop_oracle(n, k, m, da, db):
    rng = np.random.default_rng(0)
    a = csr_from_dense(random_sparse(rng, n, k, da))
    b = csr_from_dense(random_sparse(rng, k, m, db))
    ip = np.asarray(intermediate_products(a, b))
    expect = intermediate_products_dense(a, b)
    np.testing.assert_array_equal(ip, expect)


def test_group_assignment_table_i():
    ip = jnp.asarray([0, 31, 32, 511, 512, 8191, 8192, 100000])
    g = np.asarray(assign_groups(ip))
    np.testing.assert_array_equal(g, [0, 0, 1, 1, 2, 2, 3, 3])


def test_map_is_stable_group_sort():
    ip = jnp.asarray([600, 3, 40, 5, 9000, 35])
    m = np.asarray(build_map(ip))
    # group ids: [2,0,1,0,3,1] -> stable sort: rows 1,3 (g0), 2,5 (g1), 0 (g2), 4 (g3)
    np.testing.assert_array_equal(m, [1, 3, 2, 5, 0, 4])


def test_ip_histogram():
    ip = jnp.asarray([0, 10, 100, 1000, 10000])
    h = np.asarray(ip_histogram(ip))
    np.testing.assert_array_equal(h, [2, 1, 1, 1])


# ---------------------------------------------------------------------------
# Algorithm 4 hash table
# ---------------------------------------------------------------------------

def test_hash_insert_semantics():
    tab = ht.make_table(8)
    tab = ht.insert(tab, jnp.int32(5), jnp.float32(1.0))
    tab = ht.insert(tab, jnp.int32(5), jnp.float32(2.0))   # accumulate on hit
    tab = ht.insert(tab, jnp.int32(13), jnp.float32(7.0))  # 13*MULT%8 may collide
    tab = ht.insert(tab, jnp.int32(-1), jnp.float32(99.0))  # padding no-op
    cols, vals, count = ht.extract_sorted(tab)
    assert int(count) == 2
    np.testing.assert_array_equal(np.asarray(cols[:2]), [5, 13])
    np.testing.assert_allclose(np.asarray(vals[:2]), [3.0, 7.0])


def test_hash_collision_storm():
    """All keys map to the same slot class: linear probing must resolve."""
    cap = 16
    # 8 colliding keys? varies
    keys = jnp.asarray(np.arange(0, 8 * cap, cap, dtype=np.int32))
    tab = ht.make_table(cap)
    for k in np.asarray(keys):
        tab = ht.insert(tab, jnp.int32(k), jnp.float32(1.0))
    cols, vals, count = ht.extract_sorted(tab)
    assert int(count) == len(np.unique(np.asarray(keys)))


# ---------------------------------------------------------------------------
# Full pipeline vs dense oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("method", ["sort", "hash"])
@pytest.mark.parametrize("n,k,m,da,db", [
    (8, 6, 7, 0.3, 0.3),
    (16, 16, 16, 0.15, 0.15),
    (12, 5, 20, 0.5, 0.4),
    (6, 6, 6, 0.0, 0.3),   # empty A
    (6, 6, 6, 1.0, 1.0),   # dense
])
def test_spgemm_matches_dense(method, n, k, m, da, db):
    rng = np.random.default_rng(42)
    a = csr_from_dense(random_sparse(rng, n, k, da))
    b = csr_from_dense(random_sparse(rng, k, m, db))
    res = spgemm(a, b, method=method)
    got = np.asarray(csr_to_dense(res.c))
    expect = np.asarray(spgemm_dense(a, b))
    np.testing.assert_allclose(got, expect, rtol=1e-4, atol=1e-5)


def test_spgemm_self_product():
    """Matrix self-product A@A — the paper's Table II workload shape."""
    rng = np.random.default_rng(1)
    x = random_sparse(rng, 30, 30, 0.1)
    a = csr_from_dense(x)
    res = spgemm(a, a, method="sort")
    np.testing.assert_allclose(
        np.asarray(csr_to_dense(res.c)), x @ x, rtol=1e-4, atol=1e-5
    )
    # info counters are consistent
    assert res.info["intermediate_products"] >= res.info["nnz_c"]
    assert res.info["flops"] == 2 * res.info["intermediate_products"]


def test_spgemm_engines_agree():
    rng = np.random.default_rng(2)
    a = csr_from_dense(random_sparse(rng, 25, 18, 0.2))
    b = csr_from_dense(random_sparse(rng, 18, 22, 0.25))
    r1 = spgemm(a, b, method="sort")
    r2 = spgemm(a, b, method="hash")
    np.testing.assert_allclose(
        np.asarray(csr_to_dense(r1.c)), np.asarray(csr_to_dense(r2.c)),
        rtol=1e-4, atol=1e-5,
    )


def test_spgemm_deterministic():
    rng = np.random.default_rng(3)
    a = csr_from_dense(random_sparse(rng, 20, 20, 0.3))
    r1 = spgemm(a, a, method="hash")
    r2 = spgemm(a, a, method="hash")
    np.testing.assert_array_equal(np.asarray(r1.c.data), np.asarray(r2.c.data))
    np.testing.assert_array_equal(np.asarray(r1.c.indices), np.asarray(r2.c.indices))


def test_spgemm_ell_fixed_jit_and_scan():
    """The in-graph variant: correct under jit and inside lax.scan (MCL shape)."""
    rng = np.random.default_rng(4)
    x = random_sparse(rng, 12, 12, 0.25)
    e = ell_from_dense(x, k_cap=8)

    @jax.jit
    def sq(e):
        return spgemm_ell_fixed(e, e, out_cap=12)

    c = sq(e)
    np.testing.assert_allclose(np.asarray(ell_to_dense(c)), x @ x, rtol=1e-4, atol=1e-4)


@settings(max_examples=15, deadline=None)
@given(
    n=st.integers(1, 10), k=st.integers(1, 10), m=st.integers(1, 10),
    seed=st.integers(0, 2**16),
)
def test_property_spgemm_equals_dense(n, k, m, seed):
    rng = np.random.default_rng(seed)
    a = csr_from_dense(random_sparse(rng, n, k, 0.3))
    b = csr_from_dense(random_sparse(rng, k, m, 0.3))
    res = spgemm(a, b, method="sort")
    np.testing.assert_allclose(
        np.asarray(csr_to_dense(res.c)),
        np.asarray(csr_to_dense(a)) @ np.asarray(csr_to_dense(b)),
        rtol=1e-4, atol=1e-4,
    )
