"""Sharded multi-device SpGEMM executor.

Two layers of coverage:

* **In-process** (1 device, cheap, always runs): ``partition_plan`` shard
  assignment invariants, and the ``mesh=`` code path on a 1-device
  ``("shard",)`` mesh — same loop the multi-device path takes.
* **Subprocess** (forced host device counts, the acceptance bar):
  1/2/4/8 devices must produce CSR output *bit-identical* to both the
  single-device executor and the dense oracle for every engine × gather
  combination, and repeated MCL-style iterations under a mesh must reuse
  cached per-shard programs instead of re-tracing.
"""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.core import executor
from repro.core.grouping import group_rows
from repro.core.ref import spgemm_dense
from repro.core.spgemm import spgemm
from repro.sparse.formats import csr_from_dense, csr_to_dense

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_py(body: str, n_devices: int = 8, timeout: int = 900):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    script = "import os\n" + textwrap.dedent(body)
    proc = subprocess.run([sys.executable, "-c", script], env=env,
                          capture_output=True, text=True, timeout=timeout)
    assert proc.returncode == 0, f"STDOUT:\n{proc.stdout}\nSTDERR:\n{proc.stderr}"
    return proc.stdout


def int_sparse(rng, n, m, density=0.3):
    x = rng.integers(-4, 5, (n, m)).astype(np.float32)
    mask = rng.random((n, m)) < density
    return np.where(mask, x, 0.0).astype(np.float32)


# ---------------------------------------------------------------------------
# partition_plan: host-side shard assignment invariants (no devices needed)
# ---------------------------------------------------------------------------

def _plan_fixture():
    rng = np.random.default_rng(2)
    a = csr_from_dense(int_sparse(rng, 64, 48, 0.25))
    b = csr_from_dense(int_sparse(rng, 48, 52, 0.25))
    plan = group_rows(a, b)
    nnz = np.diff(np.asarray(a.indptr))
    return plan, nnz


def test_partition_plan_covers_every_row_exactly_once():
    plan, nnz = _plan_fixture()
    for n_shards in (1, 2, 4, 8):
        items = executor.partition_plan(plan, nnz, 4096, n_shards=n_shards)
        rows = np.concatenate([i.rows for i in items])
        assert sorted(rows.tolist()) == sorted(plan.map_rows.tolist())
        assert all(0 <= i.shard < n_shards for i in items)


def test_partition_plan_round_robin_balances_groups():
    """The shard cursor carries across groups: chunks of one group spread
    over consecutive shards instead of piling onto shard 0."""
    plan, nnz = _plan_fixture()
    items = executor.partition_plan(plan, nnz, 4096, n_shards=4)
    # every populated group's chunks land on distinct consecutive shards
    by_group = {}
    for it in items:
        by_group.setdefault(it.group, []).append(it.shard)
    multi = [shards for shards in by_group.values() if len(shards) > 1]
    for shards in multi:
        assert len(set(shards)) == len(shards)
    # and the whole item list uses more than one shard
    assert len({i.shard for i in items}) > 1


def test_partition_plan_single_shard_matches_row_chunking():
    plan, nnz = _plan_fixture()
    items = executor.partition_plan(plan, nnz, 16, n_shards=1)
    assert all(i.shard == 0 for i in items)
    assert all(len(i.rows) <= 16 for i in items)


def test_partition_plan_shrinks_chunks_to_feed_all_shards():
    plan, nnz = _plan_fixture()
    items = executor.partition_plan(plan, nnz, 4096, n_shards=8)
    biggest_group = max(plan.group_sizes)
    per_shard = max(len(i.rows) for i in items)
    assert per_shard <= max(
        int(np.ceil(biggest_group / 8 / executor.ROW_QUANTUM))
        * executor.ROW_QUANTUM, executor.ROW_QUANTUM)


# ---------------------------------------------------------------------------
# Footprint-gathered operand placement: host-side derivation (no devices)
# ---------------------------------------------------------------------------

def test_support_footprint_unique_sorted_union():
    from repro.core.grouping import support_footprint

    indptr = np.array([0, 2, 2, 5])
    indices = np.array([4, 1, 3, 1, 0])
    np.testing.assert_array_equal(
        support_footprint(indptr, indices, np.array([0, 2])), [0, 1, 3, 4])
    np.testing.assert_array_equal(
        support_footprint(indptr, indices, np.array([2])), [0, 1, 3])
    assert support_footprint(indptr, indices, np.array([1])).size == 0
    assert support_footprint(indptr, indices,
                             np.empty(0, np.int64)).size == 0


def test_resolve_operands_validates():
    for mode in ("auto", "footprint", "replicate"):
        assert executor.resolve_operands(mode) == mode
    with pytest.raises(ValueError, match="operands"):
        executor.resolve_operands("footprnt")


def test_shard_footprints_cover_item_support_and_pad_empty_shards():
    plan, nnz = _plan_fixture()
    rng = np.random.default_rng(2)
    a = csr_from_dense(int_sparse(rng, 64, 48, 0.25))
    items = executor.partition_plan(plan, nnz, 4096, n_shards=8)
    fps = executor.shard_footprints(items, np.asarray(a.indptr),
                                    np.asarray(a.indices), n_shards=8)
    assert len(fps) == 8
    a_ip, a_ix = np.asarray(a.indptr), np.asarray(a.indices)
    for s, fp in enumerate(fps):
        assert fp.size >= 1  # empty shards padded to a valid 1-row block
        want = set()
        for it in items:
            if it.shard != s:
                continue
            for r in it.rows:
                want.update(a_ix[a_ip[r]:a_ip[r + 1]].tolist())
        assert want <= set(fp.tolist())
        np.testing.assert_array_equal(fp, np.unique(fp))  # sorted, unique


# ---------------------------------------------------------------------------
# mesh= code path on a single device (runs in the main session)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("engine", ("sort", "hash", "fused_hash"))
def test_mesh_single_device_matches_unsharded(engine):
    from repro.launch.mesh import make_spgemm_mesh

    rng = np.random.default_rng(5)
    a = csr_from_dense(int_sparse(rng, 30, 24, 0.3))
    b = csr_from_dense(int_sparse(rng, 24, 28, 0.3))
    mesh = make_spgemm_mesh(1)
    r0 = spgemm(a, b, engine=engine)
    r1 = spgemm(a, b, engine=engine, mesh=mesh)
    assert r1.info["n_shards"] == 1
    np.testing.assert_array_equal(
        np.asarray(csr_to_dense(r0.c)), np.asarray(csr_to_dense(r1.c)))
    np.testing.assert_array_equal(
        np.asarray(csr_to_dense(r1.c)), np.asarray(spgemm_dense(a, b)))


def test_make_spgemm_mesh_rejects_oversubscription():
    from repro.launch.mesh import make_spgemm_mesh
    import jax

    with pytest.raises(ValueError, match="shard devices"):
        make_spgemm_mesh(len(jax.devices()) + 1)


def _half_support_fixture():
    """A 64x64 self-product whose A-support only names columns < 32: the
    B footprint is a genuine half-size block even on a single shard."""
    rng = np.random.default_rng(21)
    x = np.zeros((64, 64), np.float32)
    x[:, :32] = int_sparse(rng, 64, 32, 0.3)
    return csr_from_dense(x)


def _operand_stat_delta(fn):
    before = executor.cache_stats()
    res = fn()
    after = executor.cache_stats()
    keys = ("operand_bytes_placed", "operand_rows_footprint",
            "operand_rows_total")
    return res, {k: after[k] - before[k] for k in keys}


def test_footprint_forced_single_shard_bit_exact_and_counted():
    """operands="footprint" forces blocks even on one shard: bit-exact vs
    the replicated path, with the comm-volume counters showing the
    half-size placement."""
    from repro.launch.mesh import make_spgemm_mesh

    a = _half_support_fixture()
    mesh = make_spgemm_mesh(1)
    rep, d_rep = _operand_stat_delta(
        lambda: spgemm(a, a, engine="hash", mesh=mesh, operands="replicate"))
    fp, d_fp = _operand_stat_delta(
        lambda: spgemm(a, a, engine="hash", mesh=mesh, operands="footprint"))
    np.testing.assert_array_equal(
        np.asarray(csr_to_dense(fp.c)), np.asarray(csr_to_dense(rep.c)))
    np.testing.assert_array_equal(
        np.asarray(csr_to_dense(fp.c)), np.asarray(spgemm_dense(a, a)))
    assert d_rep["operand_rows_footprint"] == d_rep["operand_rows_total"] == 64
    assert d_fp["operand_rows_footprint"] <= 32 < d_fp["operand_rows_total"]
    assert 0 < d_fp["operand_bytes_placed"]
    # the block ships remap (64 x int32) but halves idx+val: still smaller
    assert d_fp["operand_bytes_placed"] < d_rep["operand_bytes_placed"]


def test_auto_operands_keep_full_replica_on_single_shard():
    """operands="auto" only engages under n_shards > 1 — one shard always
    takes the replicated fast path regardless of footprint size."""
    from repro.launch.mesh import make_spgemm_mesh

    a = _half_support_fixture()
    res, delta = _operand_stat_delta(
        lambda: spgemm(a, a, engine="sort", mesh=make_spgemm_mesh(1),
                       operands="auto"))
    assert res.info["n_shards"] == 1
    assert delta["operand_rows_footprint"] == delta["operand_rows_total"]


def test_footprints_memoized_per_plan():
    """A PlanCache-served second call reuses the memoized footprints (one
    _FOOTPRINT_CACHE entry, not one per call)."""
    from repro.core.spgemm import PlanCache
    from repro.launch.mesh import make_spgemm_mesh

    rng = np.random.default_rng(23)
    pattern = rng.random((48, 48)) < 0.2
    def member():
        return csr_from_dense(np.where(
            pattern, rng.integers(1, 5, (48, 48)), 0.0).astype(np.float32))
    mesh = make_spgemm_mesh(1)
    cache = PlanCache()
    executor.clear_program_cache()
    spgemm(member(), member(), engine="sort", mesh=mesh, plan=cache,
           operands="footprint")
    n_entries = len(executor._FOOTPRINT_CACHE)
    assert n_entries > 0
    spgemm(member(), member(), engine="sort", mesh=mesh, plan=cache,
           operands="footprint")
    assert len(executor._FOOTPRINT_CACHE) == n_entries, (
        "same-plan call re-derived its shard footprints")


# ---------------------------------------------------------------------------
# Subprocess: forced device counts (the acceptance bar)
# ---------------------------------------------------------------------------

INVARIANCE_BODY = """
import jax, numpy as np
from repro.core.spgemm import spgemm
from repro.core.ref import spgemm_dense
from repro.launch.mesh import make_spgemm_mesh
from repro.sparse.formats import csr_from_dense, csr_to_dense

n_dev = {n_devices}
assert len(jax.devices()) == n_dev, jax.devices()
rng = np.random.default_rng(7)
def sp(n, m, d):
    x = rng.integers(-4, 5, (n, m)).astype(np.float32)
    return np.where(rng.random((n, m)) < d, x, 0.0).astype(np.float32)
a = csr_from_dense(sp(96, 72, 0.22))
b = csr_from_dense(sp(72, 80, 0.28))
oracle = np.asarray(spgemm_dense(a, b))
mesh = make_spgemm_mesh(n_dev)
for engine in ("sort", "hash", "fused_hash"):
    for gather in ("xla", "aia"):
        single = spgemm(a, b, engine=engine, gather=gather)
        sharded = spgemm(a, b, engine=engine, gather=gather, mesh=mesh)
        assert sharded.info["n_shards"] == n_dev
        d_single = np.asarray(csr_to_dense(single.c))
        d_sharded = np.asarray(csr_to_dense(sharded.c))
        np.testing.assert_array_equal(d_sharded, d_single)
        np.testing.assert_array_equal(d_sharded, oracle)
        # CSR layout itself is identical, not just the densified view
        np.testing.assert_array_equal(np.asarray(sharded.c.indptr),
                                      np.asarray(single.c.indptr))
        print("OK", engine, gather, n_dev)
"""


@pytest.mark.parametrize("n_devices", (1, 2, 4, 8))
def test_shard_count_invariance_bit_exact(n_devices):
    """1/2/4/8 forced host devices: sharded CSR == single-device CSR ==
    dense oracle, bit-exact, for every engine × gather combination."""
    out = run_py(INVARIANCE_BODY.format(n_devices=n_devices),
                 n_devices=n_devices)
    assert out.count("OK") == 6


def test_sharded_program_cache_reused_across_mcl_iterations():
    """Two same-support MCL-style iterations under a 4-device mesh: the
    second must be all program-cache hits (no re-tracing per shard)."""
    run_py("""
    import numpy as np
    from repro.core import executor
    from repro.core.spgemm import spgemm
    from repro.launch.mesh import make_spgemm_mesh
    from repro.sparse.formats import csr_from_dense

    rng = np.random.default_rng(9)
    pattern = rng.random((48, 48)) < 0.2
    x1 = np.where(pattern, rng.integers(1, 5, (48, 48)), 0).astype(np.float32)
    x2 = np.where(pattern, rng.integers(1, 5, (48, 48)), 0).astype(np.float32)
    mesh = make_spgemm_mesh(4)
    executor.clear_program_cache()
    spgemm(csr_from_dense(x1), csr_from_dense(x1), engine="sort", mesh=mesh)
    first = executor.cache_stats()
    assert first["misses"] > 0
    spgemm(csr_from_dense(x2), csr_from_dense(x2), engine="sort", mesh=mesh)
    second = executor.cache_stats()
    assert second["misses"] == first["misses"], (
        "second sharded MCL iteration re-traced", first, second)
    assert second["hits"] > first["hits"]
    print("CACHE OK", first, second)
    """, n_devices=4)


BATCHED_BODY = """
import jax, numpy as np
from repro.core.spgemm import spgemm, spgemm_batched
from repro.core.ref import spgemm_dense
from repro.launch.mesh import make_spgemm_mesh
from repro.sparse.formats import csr_from_dense, csr_to_dense

n_dev = {n_devices}
assert len(jax.devices()) == n_dev, jax.devices()
rng = np.random.default_rng(11)
pat_a = rng.random((72, 56)) < 0.22
pat_b = rng.random((56, 64)) < 0.28
def members(pat, k):
    return [csr_from_dense(np.where(
        pat, rng.integers(1, 5, pat.shape), 0.0).astype(np.float32))
        for _ in range(k)]
a_mats = members(pat_a, 3)
b_mats = members(pat_b, 3)
mesh = make_spgemm_mesh(n_dev)
for engine in ("sort", "hash", "fused_hash"):
    for gather in ("xla", "aia"):
        batched = spgemm_batched(a_mats, b_mats, engine=engine,
                                 gather=gather, mesh=mesh)
        assert batched.info["n_shards"] == n_dev
        for i in range(3):
            single = spgemm(a_mats[i], b_mats[i], engine=engine,
                            gather=gather)  # unsharded per-matrix loop
            np.testing.assert_array_equal(
                np.asarray(batched.cs[i].indptr), np.asarray(single.c.indptr))
            np.testing.assert_array_equal(
                np.asarray(csr_to_dense(batched.cs[i])),
                np.asarray(csr_to_dense(single.c)))
            np.testing.assert_array_equal(
                np.asarray(csr_to_dense(batched.cs[i])),
                np.asarray(spgemm_dense(a_mats[i], b_mats[i])))
        print("BOK", engine, gather, n_dev)
"""


@pytest.mark.parametrize("n_devices", (1, 2, 4))
def test_batched_bit_exact_vs_loop_sharded(n_devices):
    """spgemm_batched under a 1/2/4-device mesh == unsharded per-matrix
    loop == dense oracle, bit-exact, for every engine × gather combo."""
    out = run_py(BATCHED_BODY.format(n_devices=n_devices),
                 n_devices=n_devices)
    assert out.count("BOK") == 6


FOOTPRINT_BODY = """
import jax, numpy as np
from repro.core import executor
from repro.core.spgemm import spgemm
from repro.core.ref import spgemm_dense
from repro.launch.mesh import make_spgemm_mesh
from repro.sparse.formats import csr_from_dense, csr_to_dense

n_dev = {n_devices}
assert len(jax.devices()) == n_dev, jax.devices()
rng = np.random.default_rng(17)
# banded matrix: each shard's A-support names a partial row band of B, so
# footprint blocks are genuinely smaller than replicas under n_dev >= 2
n, w = 96, 6
x = np.zeros((n, n), np.float32)
for i in range(n):
    lo, hi = max(0, i - w), min(n, i + w + 1)
    x[i, lo:hi] = np.where(rng.random(hi - lo) < 0.7,
                           rng.integers(-4, 5, hi - lo), 0.0)
a = csr_from_dense(x)
oracle = np.asarray(spgemm_dense(a, a))
mesh = make_spgemm_mesh(n_dev)
row_chunk = 24  # multi-chunk plan at every shard count
for engine in ("sort", "hash", "fused_hash"):
    for gather in ("xla", "aia"):
        for schedule in ("grouped", "natural"):
            for pipeline in ("two_wave", "legacy"):
                kw = dict(engine=engine, gather=gather, schedule=schedule,
                          pipeline=pipeline, mesh=mesh, row_chunk=row_chunk)
                rep = spgemm(a, a, operands="replicate", **kw)
                fp = spgemm(a, a, operands="footprint", **kw)
                assert fp.info["n_shards"] == n_dev
                np.testing.assert_array_equal(
                    np.asarray(fp.c.indptr), np.asarray(rep.c.indptr))
                np.testing.assert_array_equal(
                    np.asarray(fp.c.indices), np.asarray(rep.c.indices))
                np.testing.assert_array_equal(
                    np.asarray(fp.c.data), np.asarray(rep.c.data))
                np.testing.assert_array_equal(
                    np.asarray(csr_to_dense(fp.c)), oracle)
                print("FOK", engine, gather, schedule, pipeline, n_dev)
stats = executor.cache_stats()
assert stats["operand_bytes_placed"] > 0, stats
if n_dev >= 2:
    # partial bands: the footprint runs placed strictly fewer rows than
    # the replicated runs mixed into the same counters would alone
    assert stats["operand_rows_footprint"] < stats["operand_rows_total"], stats
print("FSTATS", stats["operand_rows_footprint"], stats["operand_rows_total"])
"""


@pytest.mark.parametrize("n_devices", (1, 2, 4, 8))
def test_footprint_operands_bit_exact_full_grid(n_devices):
    """The tentpole acceptance bar: operands="footprint" produces CSR
    output bit-identical to operands="replicate" (and the dense oracle)
    for every engine x gather x schedule x pipeline combination at
    1/2/4/8 forced host devices."""
    out = run_py(FOOTPRINT_BODY.format(n_devices=n_devices),
                 n_devices=n_devices)
    assert out.count("FOK") == 24
    assert "FSTATS" in out


BATCHED_FOOTPRINT_BODY = """
import jax, numpy as np
from repro.core.spgemm import spgemm, spgemm_batched
from repro.core.ref import spgemm_dense
from repro.launch.mesh import make_spgemm_mesh
from repro.sparse.formats import csr_from_dense, csr_to_dense

n_dev = {n_devices}
assert len(jax.devices()) == n_dev, jax.devices()
rng = np.random.default_rng(19)
n, w = 72, 5
pat = np.zeros((n, n), bool)
for i in range(n):
    lo, hi = max(0, i - w), min(n, i + w + 1)
    pat[i, lo:hi] = rng.random(hi - lo) < 0.6
def members(k):
    return [csr_from_dense(np.where(
        pat, rng.integers(1, 5, pat.shape), 0.0).astype(np.float32))
        for _ in range(k)]
a_mats, b_mats = members(3), members(3)
mesh = make_spgemm_mesh(n_dev)
for operands in ("replicate", "footprint"):
    batched = spgemm_batched(a_mats, b_mats, engine="sort", mesh=mesh,
                             operands=operands)
    assert batched.info["n_shards"] == n_dev
    for i in range(3):
        single = spgemm(a_mats[i], b_mats[i], engine="sort")
        np.testing.assert_array_equal(
            np.asarray(csr_to_dense(batched.cs[i])),
            np.asarray(csr_to_dense(single.c)))
        np.testing.assert_array_equal(
            np.asarray(csr_to_dense(batched.cs[i])),
            np.asarray(spgemm_dense(a_mats[i], b_mats[i])))
    print("BFOK", operands, n_dev)
"""


@pytest.mark.parametrize("n_devices", (2, 4))
def test_batched_footprint_operands_bit_exact(n_devices):
    """The batched lane under footprint blocks (vmapped B value planes
    sliced per footprint): bit-exact vs the unsharded per-matrix loop."""
    out = run_py(BATCHED_FOOTPRINT_BODY.format(n_devices=n_devices),
                 n_devices=n_devices)
    assert out.count("BFOK") == 2


AUTO_BODY = """
import dataclasses
import jax, numpy as np
from repro.core import executor
from repro.core.grouping import group_rows
from repro.core.spgemm import spgemm
from repro.core.ref import spgemm_dense
from repro.launch.mesh import make_spgemm_mesh
from repro.sparse.formats import csr_from_dense, csr_to_dense

n_dev = {n_devices}
assert len(jax.devices()) == n_dev, jax.devices()
rng = np.random.default_rng(2)
# A spans three Table-I groups: single-nnz rows (group 0), 0.25-density
# rows (group 1), full rows (group 2) — so the forced-mixed assignment
# really dispatches different engines side by side.
xa = np.zeros((64, 48), np.float32)
for i in range(24):
    xa[i, rng.integers(0, 48)] = float(rng.integers(1, 5))
mask = rng.random((24, 48)) < 0.25
xa[24:48] = np.where(mask, rng.integers(-4, 5, (24, 48)), 0.0)
xa[48:] = rng.integers(1, 5, (16, 48))
a = csr_from_dense(xa)
xb = np.where(rng.random((48, 52)) < 0.25,
              rng.integers(-4, 5, (48, 52)), 0.0).astype(np.float32)
b = csr_from_dense(xb)
oracle = np.asarray(spgemm_dense(a, b))
mesh = make_spgemm_mesh(n_dev)
tuner = executor.AutotuneCache()
for gather in ("xla", "aia"):
    for schedule in ("grouped", "natural"):
        for pipeline in ("two_wave", "legacy"):
            res = spgemm(a, b, engine="auto", gather=gather,
                         schedule=schedule, pipeline=pipeline,
                         mesh=mesh, autotune=tuner)
            assert res.info["n_shards"] == n_dev
            np.testing.assert_array_equal(
                np.asarray(csr_to_dense(res.c)), oracle)
            print("AOK", gather, schedule, pipeline, n_dev)
# forced-mixed per-bin assignment under the mesh: different engines on
# different populated bins, still bit-exact, winning over engine=
plan = group_rows(a, b)
populated = [g for g in range(4) if plan.group_sizes[g] > 0]
assert len(populated) >= 3, plan.group_sizes
names = executor.available_engines()
ge = ["sort"] * 4
for i, g in enumerate(populated):
    ge[g] = names[i % len(names)]
forced = dataclasses.replace(plan, group_engines=tuple(ge))
for pipeline in ("two_wave", "legacy"):
    res = spgemm(a, b, engine="auto", plan=forced, pipeline=pipeline,
                 mesh=mesh)
    np.testing.assert_array_equal(np.asarray(csr_to_dense(res.c)), oracle)
    alt = spgemm(a, b, engine="hash", plan=forced, pipeline=pipeline,
                 mesh=mesh)
    np.testing.assert_array_equal(np.asarray(csr_to_dense(alt.c)), oracle)
    print("MOK", pipeline, n_dev)
"""


@pytest.mark.parametrize("n_devices", (1, 2, 4))
def test_auto_engine_bit_exact_sharded(n_devices):
    """engine="auto" (in-band measured assignment AND a forced-mixed
    plan.group_engines) under 1/2/4 forced host devices: bit-identical to
    the dense oracle for every gather × schedule × pipeline combination."""
    out = run_py(AUTO_BODY.format(n_devices=n_devices),
                 n_devices=n_devices)
    assert out.count("AOK") == 8 and out.count("MOK") == 2


def test_plan_cache_reuses_shard_partition_under_mesh():
    """PlanCache + mesh: the second same-support call must hit the plan
    cache AND reuse the memoized work-item partition (shard assignment)."""
    run_py("""
    import numpy as np
    from repro.core import executor
    from repro.core.spgemm import PlanCache, spgemm
    from repro.launch.mesh import make_spgemm_mesh
    from repro.sparse.formats import csr_from_dense

    rng = np.random.default_rng(13)
    pattern = rng.random((48, 48)) < 0.2
    def member():
        return csr_from_dense(np.where(
            pattern, rng.integers(1, 5, (48, 48)), 0.0).astype(np.float32))
    m1, m2 = member(), member()
    mesh = make_spgemm_mesh(4)
    executor.clear_program_cache()
    cache = PlanCache()
    spgemm(m1, m1, engine="sort", mesh=mesh, plan=cache)
    n_partitions = len(executor._PARTITION_CACHE)
    assert n_partitions > 0
    spgemm(m2, m2, engine="sort", mesh=mesh, plan=cache)
    stats = executor.cache_stats()
    assert stats["plan_hits"] == 1, stats
    assert len(executor._PARTITION_CACHE) == n_partitions, (
        "same-support call re-partitioned the plan")
    print("PARTITION OK", stats)
    """, n_devices=4)


def test_sharded_mcl_end_to_end_matches_unsharded():
    """Full MCL app on a 4-device mesh: same clusters as mesh=None."""
    run_py("""
    import numpy as np
    from repro.apps.markov_clustering import mcl
    from repro.launch.mesh import make_spgemm_mesh
    from repro.sparse.formats import csr_from_dense, csr_to_dense

    rng = np.random.default_rng(3)
    n = 40
    blocks = np.kron(np.eye(4), np.ones((n // 4, n // 4)))
    noise = rng.random((n, n)) < 0.02
    adj = ((blocks + noise + noise.T) > 0).astype(np.float32)
    g = csr_from_dense(adj)
    r0 = mcl(g, max_iters=3, tol=0.0)
    r1 = mcl(g, max_iters=3, tol=0.0, mesh=make_spgemm_mesh(4))
    np.testing.assert_array_equal(
        np.asarray(csr_to_dense(r0.matrix)), np.asarray(csr_to_dense(r1.matrix)))
    np.testing.assert_array_equal(r0.clusters, r1.clusters)
    print("MCL OK", r0.n_iterations)
    """, n_devices=4)
