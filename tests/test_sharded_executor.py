"""Sharded multi-device SpGEMM executor.

Two layers of coverage:

* **In-process** (1 device, cheap, always runs): ``partition_plan`` shard
  assignment invariants, and the ``mesh=`` code path on a 1-device
  ``("shard",)`` mesh — same loop the multi-device path takes.
* **Subprocess** (forced host device counts, the acceptance bar):
  1/2/4/8 devices must produce CSR output *bit-identical* to both the
  single-device executor and the dense oracle for every engine × gather
  combination, and repeated MCL-style iterations under a mesh must reuse
  cached per-shard programs instead of re-tracing.
"""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.core import executor
from repro.core.grouping import group_rows
from repro.core.ref import spgemm_dense
from repro.core.spgemm import spgemm
from repro.sparse.formats import csr_from_dense, csr_to_dense

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_py(body: str, n_devices: int = 8, timeout: int = 900):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    script = "import os\n" + textwrap.dedent(body)
    proc = subprocess.run([sys.executable, "-c", script], env=env,
                          capture_output=True, text=True, timeout=timeout)
    assert proc.returncode == 0, f"STDOUT:\n{proc.stdout}\nSTDERR:\n{proc.stderr}"
    return proc.stdout


def int_sparse(rng, n, m, density=0.3):
    x = rng.integers(-4, 5, (n, m)).astype(np.float32)
    mask = rng.random((n, m)) < density
    return np.where(mask, x, 0.0).astype(np.float32)


# ---------------------------------------------------------------------------
# partition_plan: host-side shard assignment invariants (no devices needed)
# ---------------------------------------------------------------------------

def _plan_fixture():
    rng = np.random.default_rng(2)
    a = csr_from_dense(int_sparse(rng, 64, 48, 0.25))
    b = csr_from_dense(int_sparse(rng, 48, 52, 0.25))
    plan = group_rows(a, b)
    nnz = np.diff(np.asarray(a.indptr))
    return plan, nnz


def test_partition_plan_covers_every_row_exactly_once():
    plan, nnz = _plan_fixture()
    for n_shards in (1, 2, 4, 8):
        items = executor.partition_plan(plan, nnz, 4096, n_shards=n_shards)
        rows = np.concatenate([i.rows for i in items])
        assert sorted(rows.tolist()) == sorted(plan.map_rows.tolist())
        assert all(0 <= i.shard < n_shards for i in items)


def test_partition_plan_round_robin_balances_groups():
    """The shard cursor carries across groups: chunks of one group spread
    over consecutive shards instead of piling onto shard 0."""
    plan, nnz = _plan_fixture()
    items = executor.partition_plan(plan, nnz, 4096, n_shards=4)
    # every populated group's chunks land on distinct consecutive shards
    by_group = {}
    for it in items:
        by_group.setdefault(it.group, []).append(it.shard)
    multi = [shards for shards in by_group.values() if len(shards) > 1]
    for shards in multi:
        assert len(set(shards)) == len(shards)
    # and the whole item list uses more than one shard
    assert len({i.shard for i in items}) > 1


def test_partition_plan_single_shard_matches_row_chunking():
    plan, nnz = _plan_fixture()
    items = executor.partition_plan(plan, nnz, 16, n_shards=1)
    assert all(i.shard == 0 for i in items)
    assert all(len(i.rows) <= 16 for i in items)


def test_partition_plan_shrinks_chunks_to_feed_all_shards():
    plan, nnz = _plan_fixture()
    items = executor.partition_plan(plan, nnz, 4096, n_shards=8)
    biggest_group = max(plan.group_sizes)
    per_shard = max(len(i.rows) for i in items)
    assert per_shard <= max(
        int(np.ceil(biggest_group / 8 / executor.ROW_QUANTUM))
        * executor.ROW_QUANTUM, executor.ROW_QUANTUM)


# ---------------------------------------------------------------------------
# mesh= code path on a single device (runs in the main session)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("engine", ("sort", "hash", "fused_hash"))
def test_mesh_single_device_matches_unsharded(engine):
    from repro.launch.mesh import make_spgemm_mesh

    rng = np.random.default_rng(5)
    a = csr_from_dense(int_sparse(rng, 30, 24, 0.3))
    b = csr_from_dense(int_sparse(rng, 24, 28, 0.3))
    mesh = make_spgemm_mesh(1)
    r0 = spgemm(a, b, engine=engine)
    r1 = spgemm(a, b, engine=engine, mesh=mesh)
    assert r1.info["n_shards"] == 1
    np.testing.assert_array_equal(
        np.asarray(csr_to_dense(r0.c)), np.asarray(csr_to_dense(r1.c)))
    np.testing.assert_array_equal(
        np.asarray(csr_to_dense(r1.c)), np.asarray(spgemm_dense(a, b)))


def test_make_spgemm_mesh_rejects_oversubscription():
    from repro.launch.mesh import make_spgemm_mesh
    import jax

    with pytest.raises(ValueError, match="shard devices"):
        make_spgemm_mesh(len(jax.devices()) + 1)


# ---------------------------------------------------------------------------
# Subprocess: forced device counts (the acceptance bar)
# ---------------------------------------------------------------------------

INVARIANCE_BODY = """
import jax, numpy as np
from repro.core.spgemm import spgemm
from repro.core.ref import spgemm_dense
from repro.launch.mesh import make_spgemm_mesh
from repro.sparse.formats import csr_from_dense, csr_to_dense

n_dev = {n_devices}
assert len(jax.devices()) == n_dev, jax.devices()
rng = np.random.default_rng(7)
def sp(n, m, d):
    x = rng.integers(-4, 5, (n, m)).astype(np.float32)
    return np.where(rng.random((n, m)) < d, x, 0.0).astype(np.float32)
a = csr_from_dense(sp(96, 72, 0.22))
b = csr_from_dense(sp(72, 80, 0.28))
oracle = np.asarray(spgemm_dense(a, b))
mesh = make_spgemm_mesh(n_dev)
for engine in ("sort", "hash", "fused_hash"):
    for gather in ("xla", "aia"):
        single = spgemm(a, b, engine=engine, gather=gather)
        sharded = spgemm(a, b, engine=engine, gather=gather, mesh=mesh)
        assert sharded.info["n_shards"] == n_dev
        d_single = np.asarray(csr_to_dense(single.c))
        d_sharded = np.asarray(csr_to_dense(sharded.c))
        np.testing.assert_array_equal(d_sharded, d_single)
        np.testing.assert_array_equal(d_sharded, oracle)
        # CSR layout itself is identical, not just the densified view
        np.testing.assert_array_equal(np.asarray(sharded.c.indptr),
                                      np.asarray(single.c.indptr))
        print("OK", engine, gather, n_dev)
"""


@pytest.mark.parametrize("n_devices", (1, 2, 4, 8))
def test_shard_count_invariance_bit_exact(n_devices):
    """1/2/4/8 forced host devices: sharded CSR == single-device CSR ==
    dense oracle, bit-exact, for every engine × gather combination."""
    out = run_py(INVARIANCE_BODY.format(n_devices=n_devices),
                 n_devices=n_devices)
    assert out.count("OK") == 6


def test_sharded_program_cache_reused_across_mcl_iterations():
    """Two same-support MCL-style iterations under a 4-device mesh: the
    second must be all program-cache hits (no re-tracing per shard)."""
    run_py("""
    import numpy as np
    from repro.core import executor
    from repro.core.spgemm import spgemm
    from repro.launch.mesh import make_spgemm_mesh
    from repro.sparse.formats import csr_from_dense

    rng = np.random.default_rng(9)
    pattern = rng.random((48, 48)) < 0.2
    x1 = np.where(pattern, rng.integers(1, 5, (48, 48)), 0).astype(np.float32)
    x2 = np.where(pattern, rng.integers(1, 5, (48, 48)), 0).astype(np.float32)
    mesh = make_spgemm_mesh(4)
    executor.clear_program_cache()
    spgemm(csr_from_dense(x1), csr_from_dense(x1), engine="sort", mesh=mesh)
    first = executor.cache_stats()
    assert first["misses"] > 0
    spgemm(csr_from_dense(x2), csr_from_dense(x2), engine="sort", mesh=mesh)
    second = executor.cache_stats()
    assert second["misses"] == first["misses"], (
        "second sharded MCL iteration re-traced", first, second)
    assert second["hits"] > first["hits"]
    print("CACHE OK", first, second)
    """, n_devices=4)


BATCHED_BODY = """
import jax, numpy as np
from repro.core.spgemm import spgemm, spgemm_batched
from repro.core.ref import spgemm_dense
from repro.launch.mesh import make_spgemm_mesh
from repro.sparse.formats import csr_from_dense, csr_to_dense

n_dev = {n_devices}
assert len(jax.devices()) == n_dev, jax.devices()
rng = np.random.default_rng(11)
pat_a = rng.random((72, 56)) < 0.22
pat_b = rng.random((56, 64)) < 0.28
def members(pat, k):
    return [csr_from_dense(np.where(
        pat, rng.integers(1, 5, pat.shape), 0.0).astype(np.float32))
        for _ in range(k)]
a_mats = members(pat_a, 3)
b_mats = members(pat_b, 3)
mesh = make_spgemm_mesh(n_dev)
for engine in ("sort", "hash", "fused_hash"):
    for gather in ("xla", "aia"):
        batched = spgemm_batched(a_mats, b_mats, engine=engine,
                                 gather=gather, mesh=mesh)
        assert batched.info["n_shards"] == n_dev
        for i in range(3):
            single = spgemm(a_mats[i], b_mats[i], engine=engine,
                            gather=gather)  # unsharded per-matrix loop
            np.testing.assert_array_equal(
                np.asarray(batched.cs[i].indptr), np.asarray(single.c.indptr))
            np.testing.assert_array_equal(
                np.asarray(csr_to_dense(batched.cs[i])),
                np.asarray(csr_to_dense(single.c)))
            np.testing.assert_array_equal(
                np.asarray(csr_to_dense(batched.cs[i])),
                np.asarray(spgemm_dense(a_mats[i], b_mats[i])))
        print("BOK", engine, gather, n_dev)
"""


@pytest.mark.parametrize("n_devices", (1, 2, 4))
def test_batched_bit_exact_vs_loop_sharded(n_devices):
    """spgemm_batched under a 1/2/4-device mesh == unsharded per-matrix
    loop == dense oracle, bit-exact, for every engine × gather combo."""
    out = run_py(BATCHED_BODY.format(n_devices=n_devices),
                 n_devices=n_devices)
    assert out.count("BOK") == 6


AUTO_BODY = """
import dataclasses
import jax, numpy as np
from repro.core import executor
from repro.core.grouping import group_rows
from repro.core.spgemm import spgemm
from repro.core.ref import spgemm_dense
from repro.launch.mesh import make_spgemm_mesh
from repro.sparse.formats import csr_from_dense, csr_to_dense

n_dev = {n_devices}
assert len(jax.devices()) == n_dev, jax.devices()
rng = np.random.default_rng(2)
# A spans three Table-I groups: single-nnz rows (group 0), 0.25-density
# rows (group 1), full rows (group 2) — so the forced-mixed assignment
# really dispatches different engines side by side.
xa = np.zeros((64, 48), np.float32)
for i in range(24):
    xa[i, rng.integers(0, 48)] = float(rng.integers(1, 5))
mask = rng.random((24, 48)) < 0.25
xa[24:48] = np.where(mask, rng.integers(-4, 5, (24, 48)), 0.0)
xa[48:] = rng.integers(1, 5, (16, 48))
a = csr_from_dense(xa)
xb = np.where(rng.random((48, 52)) < 0.25,
              rng.integers(-4, 5, (48, 52)), 0.0).astype(np.float32)
b = csr_from_dense(xb)
oracle = np.asarray(spgemm_dense(a, b))
mesh = make_spgemm_mesh(n_dev)
tuner = executor.AutotuneCache()
for gather in ("xla", "aia"):
    for schedule in ("grouped", "natural"):
        for pipeline in ("two_wave", "legacy"):
            res = spgemm(a, b, engine="auto", gather=gather,
                         schedule=schedule, pipeline=pipeline,
                         mesh=mesh, autotune=tuner)
            assert res.info["n_shards"] == n_dev
            np.testing.assert_array_equal(
                np.asarray(csr_to_dense(res.c)), oracle)
            print("AOK", gather, schedule, pipeline, n_dev)
# forced-mixed per-bin assignment under the mesh: different engines on
# different populated bins, still bit-exact, winning over engine=
plan = group_rows(a, b)
populated = [g for g in range(4) if plan.group_sizes[g] > 0]
assert len(populated) >= 3, plan.group_sizes
names = executor.available_engines()
ge = ["sort"] * 4
for i, g in enumerate(populated):
    ge[g] = names[i % len(names)]
forced = dataclasses.replace(plan, group_engines=tuple(ge))
for pipeline in ("two_wave", "legacy"):
    res = spgemm(a, b, engine="auto", plan=forced, pipeline=pipeline,
                 mesh=mesh)
    np.testing.assert_array_equal(np.asarray(csr_to_dense(res.c)), oracle)
    alt = spgemm(a, b, engine="hash", plan=forced, pipeline=pipeline,
                 mesh=mesh)
    np.testing.assert_array_equal(np.asarray(csr_to_dense(alt.c)), oracle)
    print("MOK", pipeline, n_dev)
"""


@pytest.mark.parametrize("n_devices", (1, 2, 4))
def test_auto_engine_bit_exact_sharded(n_devices):
    """engine="auto" (in-band measured assignment AND a forced-mixed
    plan.group_engines) under 1/2/4 forced host devices: bit-identical to
    the dense oracle for every gather × schedule × pipeline combination."""
    out = run_py(AUTO_BODY.format(n_devices=n_devices),
                 n_devices=n_devices)
    assert out.count("AOK") == 8 and out.count("MOK") == 2


def test_plan_cache_reuses_shard_partition_under_mesh():
    """PlanCache + mesh: the second same-support call must hit the plan
    cache AND reuse the memoized work-item partition (shard assignment)."""
    run_py("""
    import numpy as np
    from repro.core import executor
    from repro.core.spgemm import PlanCache, spgemm
    from repro.launch.mesh import make_spgemm_mesh
    from repro.sparse.formats import csr_from_dense

    rng = np.random.default_rng(13)
    pattern = rng.random((48, 48)) < 0.2
    def member():
        return csr_from_dense(np.where(
            pattern, rng.integers(1, 5, (48, 48)), 0.0).astype(np.float32))
    m1, m2 = member(), member()
    mesh = make_spgemm_mesh(4)
    executor.clear_program_cache()
    cache = PlanCache()
    spgemm(m1, m1, engine="sort", mesh=mesh, plan=cache)
    n_partitions = len(executor._PARTITION_CACHE)
    assert n_partitions > 0
    spgemm(m2, m2, engine="sort", mesh=mesh, plan=cache)
    stats = executor.cache_stats()
    assert stats["plan_hits"] == 1, stats
    assert len(executor._PARTITION_CACHE) == n_partitions, (
        "same-support call re-partitioned the plan")
    print("PARTITION OK", stats)
    """, n_devices=4)


def test_sharded_mcl_end_to_end_matches_unsharded():
    """Full MCL app on a 4-device mesh: same clusters as mesh=None."""
    run_py("""
    import numpy as np
    from repro.apps.markov_clustering import mcl
    from repro.launch.mesh import make_spgemm_mesh
    from repro.sparse.formats import csr_from_dense, csr_to_dense

    rng = np.random.default_rng(3)
    n = 40
    blocks = np.kron(np.eye(4), np.ones((n // 4, n // 4)))
    noise = rng.random((n, n)) < 0.02
    adj = ((blocks + noise + noise.T) > 0).astype(np.float32)
    g = csr_from_dense(adj)
    r0 = mcl(g, max_iters=3, tol=0.0)
    r1 = mcl(g, max_iters=3, tol=0.0, mesh=make_spgemm_mesh(4))
    np.testing.assert_array_equal(
        np.asarray(csr_to_dense(r0.matrix)), np.asarray(csr_to_dense(r1.matrix)))
    np.testing.assert_array_equal(r0.clusters, r1.clusters)
    print("MCL OK", r0.n_iterations)
    """, n_devices=4)
