"""Distribution tests — each runs in a subprocess with its own device count
(XLA_FLAGS must be set before jax import, and must NOT leak into the main
test session which expects 1 device).

Mesh contexts go through ``launch.mesh.use_mesh`` (``jax.set_mesh`` on new
jax, the legacy ``with mesh:`` resource env otherwise) and all array
placement uses explicit ``NamedSharding``s, so these run on every
supported jax version — no version skips."""
import os
import subprocess
import sys
import textwrap

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_py(body: str, n_devices: int = 8, timeout: int = 900):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    script = "import os\n" + textwrap.dedent(body)
    proc = subprocess.run([sys.executable, "-c", script], env=env,
                          capture_output=True, text=True, timeout=timeout)
    assert proc.returncode == 0, f"STDOUT:\n{proc.stdout}\nSTDERR:\n{proc.stderr}"
    return proc.stdout


def test_sharded_train_step_matches_single_device():
    """Same params+batch: loss on a (2,2) data×model mesh == 1-device loss."""
    run_py("""
    import dataclasses as dc
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.configs import smoke_config
    from repro.models.transformer import init_transformer, train_loss
    from repro.launch.mesh import make_test_mesh, use_mesh
    from repro.launch.sharding import make_shardings

    cfg = dc.replace(smoke_config("granite-3-2b"), n_layers=2)
    params, specs = init_transformer(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (4, 32))),
             "labels": jnp.asarray(rng.integers(0, cfg.vocab, (4, 32)))}
    l_single = float(jax.jit(lambda p: train_loss(cfg, p, batch))(params))

    mesh = make_test_mesh((2, 2), ("data", "model"))
    sh = make_shardings(mesh)
    from repro.models.transformer import param_specs
    specs = param_specs(cfg, params, model_size=2)
    with use_mesh(mesh):
        p_sharded = jax.tree.map(
            lambda x, s: jax.device_put(x, NamedSharding(mesh, s)), params, specs)
        b_sharded = jax.tree.map(
            lambda x: jax.device_put(x, NamedSharding(mesh, P("data", None))), batch)
        l_sharded = float(
            jax.jit(lambda p: train_loss(cfg, p, b_sharded, sh))(p_sharded))
    np.testing.assert_allclose(l_sharded, l_single, rtol=2e-4)
    print("SHARDED OK", l_single, l_sharded)
    """)


def test_elastic_checkpoint_reshard():
    """Save on an 8-device mesh, restore onto a 4-device sub-mesh."""
    run_py("""
    import tempfile
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.checkpoint import save_checkpoint, restore_checkpoint
    from repro.launch.mesh import compat_make_mesh

    tree = {"w": jnp.arange(64, dtype=jnp.float32).reshape(8, 8),
            "b": jnp.ones(8)}
    mesh8 = compat_make_mesh((8,), ("data",))
    sharded = jax.device_put(tree["w"], NamedSharding(mesh8, P("data", None)))
    tree8 = {"w": sharded, "b": tree["b"]}
    with tempfile.TemporaryDirectory() as d:
        save_checkpoint(d, 1, tree8)
        mesh4 = compat_make_mesh((4,), ("data",), devices=jax.devices()[:4])
        sh4 = {"w": NamedSharding(mesh4, P(None, "data")), "b": None}
        restored = restore_checkpoint(d, 1, tree, shardings=sh4)
        np.testing.assert_array_equal(np.asarray(restored["w"]), np.asarray(tree["w"]))
        assert restored["w"].sharding.mesh.shape["data"] == 4
    print("ELASTIC OK")
    """)


def test_compressed_psum_shard_map():
    """int8 gradient compression under shard_map: psum result within bound."""
    run_py("""
    import functools
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P
    from jax.experimental.shard_map import shard_map
    from repro.optim import compressed_psum
    from repro.launch.mesh import compat_make_mesh

    mesh = compat_make_mesh((8,), ("data",))
    x = jnp.arange(64, dtype=jnp.float32).reshape(8, 8) / 13.0

    @functools.partial(shard_map, mesh=mesh, in_specs=P("data", None),
                       out_specs=P("data", None))
    def f(xs):
        return compressed_psum(xs, "data")[None] if xs.ndim == 1 else \
            compressed_psum(xs[0], "data")[None]

    out = f(x)
    expect = np.sum(np.asarray(x), axis=0)
    got = np.asarray(out)[0]
    amax = np.abs(np.asarray(x)).max()
    assert np.abs(got - expect).max() <= 8 * amax / 127.0 + 1e-6, (got, expect)
    print("COMPRESSED PSUM OK")
    """)


def test_pipeline_parallel_shard_map():
    """GPipe-style PP over a 'pipe' axis with ppermute microbatch handoff."""
    run_py("""
    import functools
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import PartitionSpec as P
    from repro.launch.pipeline import pipeline_apply
    from repro.launch.mesh import compat_make_mesh

    mesh = compat_make_mesh((4,), ("pipe",))
    # 4 stages, each a simple affine layer; verify against sequential apply
    rng = np.random.default_rng(0)
    ws = jnp.asarray(rng.standard_normal((4, 8, 8)).astype(np.float32) * 0.3)
    x = jnp.asarray(rng.standard_normal((8, 8)).astype(np.float32))  # 8 microbatches

    def stage_fn(w, h):
        return jnp.tanh(h @ w)

    out = pipeline_apply(mesh, ws, x, stage_fn, n_microbatches=8)
    ref = x
    for i in range(4):
        ref = jnp.tanh(ref @ ws[i])
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-4, atol=2e-5)
    print("PIPELINE OK")
    """)


def test_dryrun_single_cell_multipod():
    """The real contract: one cell lowered+compiled on BOTH production meshes
    (512 host devices).  Uses the smallest arch × decode shape for speed."""
    out = run_py("""
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
    from repro.configs import get_config
    from repro.configs.base import SHAPE_SETS
    from repro.launch.dryrun import lower_cell
    from repro.launch.mesh import make_production_mesh

    cfg = get_config("granite-3-2b")
    shape = [s for s in SHAPE_SETS if s.name == "decode_32k"][0]
    for multi in (False, True):
        mesh = make_production_mesh(multi_pod=multi)
        rec = lower_cell(cfg, shape, mesh, verbose=False)
        assert rec["flops_per_device"] > 0
        print("CELL OK", rec["mesh"], rec["flops_per_device"])
    """, n_devices=512, timeout=1800)
    assert out.count("CELL OK") == 2


def test_moe_shard_map_matches_unsharded():
    """The §Perf EP rewrite must be numerically identical to the unsharded
    single-device forward.  On jax >= 0.6 the mesh-sharded gspmd baseline
    is additionally held to the same truth; on jax 0.4.x that comparison is
    skipped — the sharded gspmd path itself miscompiles the expert
    scatter-add under a mesh (every model shard contributes every expert
    and the combine all-reduce double-counts), so the mesh-free forward is
    the only trustworthy reference there."""
    run_py("""
    import dataclasses as dc
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.configs import smoke_config
    from repro.models.transformer import init_transformer, param_specs
    from repro.models.transformer import forward_hidden
    from repro.launch.mesh import make_test_mesh, use_mesh
    from repro.launch.sharding import make_shardings, UNSHARDED

    base = smoke_config("llama4-scout-17b-a16e")
    # capacity large enough that no tokens drop: global- vs per-shard
    # capacity semantics then coincide and results must match exactly
    moe_full = dc.replace(base.moe, capacity_factor=1000.0)
    cfg_g = dc.replace(base, n_layers=2, moe=moe_full)
    cfg_s = dc.replace(base, n_layers=2,
                       moe=dc.replace(moe_full, impl="shard_map"))
    params, _ = init_transformer(cfg_g, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    tokens = jnp.asarray(rng.integers(0, cfg_g.vocab, (4, 32)))

    # unsharded single-device ground truth (gspmd token-choice impl)
    hg, _ = jax.jit(lambda p: forward_hidden(cfg_g, p, tokens, UNSHARDED))(params)

    mesh = make_test_mesh((2, 2), ("data", "model"))
    sh = make_shardings(mesh)
    specs = param_specs(cfg_g, params, model_size=2)
    with use_mesh(mesh):
        ps = jax.tree.map(lambda x, s: jax.device_put(x, NamedSharding(mesh, s)),
                          params, specs)
        ts = jax.device_put(tokens, NamedSharding(mesh, P("data", None)))
        hs, _ = jax.jit(lambda p: forward_hidden(cfg_s, p, ts, sh))(ps)
        if hasattr(jax, "set_mesh"):
            # new jax: the mesh-sharded gspmd baseline is also correct —
            # hold it to the same unsharded truth
            hgm, _ = jax.jit(lambda p: forward_hidden(cfg_g, p, ts, sh))(ps)
            np.testing.assert_allclose(np.asarray(hgm, np.float32),
                                       np.asarray(hg, np.float32),
                                       rtol=2e-3, atol=2e-4)
    # identical expert math; only the aux-loss *estimator* differs
    np.testing.assert_allclose(np.asarray(hs, np.float32),
                               np.asarray(hg, np.float32), rtol=2e-3, atol=2e-4)
    print("MOE SHARD_MAP OK")
    """, n_devices=4)
