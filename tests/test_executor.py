"""Plan-compiled executor: engine×gather×schedule equivalence + caching.

The acceptance bar: every engine/gather combination produces *bit-identical*
CSR output to the dense oracle (test data is integer-valued so accumulation
order cannot introduce float noise), edge cases included, and repeated
MCL-style iterations reuse compiled programs instead of re-tracing.

The amortization layer carries its own bars: ``PlanCache`` must hit on
same-support/different-values operands and miss when a single column index
mutates (same nnz), converged MCL iterations must skip ``group_rows``, and
``spgemm_batched`` must be bit-identical to a per-matrix loop for every
engine × gather combination.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import executor
from repro.core.grouping import group_rows
from repro.core.ref import spgemm_dense
from repro.core.spgemm import (
    PlanCache, spgemm, spgemm_batched, spgemm_ell_fixed,
)
from repro.sparse.formats import (
    CSR, csr_from_dense, csr_to_dense, ell_from_dense, ell_to_dense,
)

ENGINES = ("sort", "hash", "fused_hash")
GATHERS = ("xla", "aia")
SCHEDULES = ("grouped", "natural")


def int_sparse(rng, n, m, density=0.3):
    """Integer-valued float32 matrix: exact under any accumulation order."""
    x = rng.integers(-4, 5, (n, m)).astype(np.float32)
    mask = rng.random((n, m)) < density
    return np.where(mask, x, 0.0).astype(np.float32)


def _dense(c):
    return np.asarray(csr_to_dense(c))


def same_pattern_batch(rng, pattern, k, lo=1, hi=5):
    """k CSRs sharing ``pattern``'s support with independent integer values
    (never zero, so the structure is identical by construction)."""
    return [csr_from_dense(np.where(
        pattern, rng.integers(lo, hi, pattern.shape), 0.0
    ).astype(np.float32)) for _ in range(k)]


# ---------------------------------------------------------------------------
# Engine registry
# ---------------------------------------------------------------------------

def test_registry_contents_and_unknown_engine():
    assert set(executor.available_engines()) >= {"hash", "sort", "fused_hash"}
    assert executor.get_engine("sort").name == "sort"
    assert executor.get_engine("fused_hash").fused
    assert not executor.get_engine("hash").fused
    with pytest.raises(ValueError, match="unknown engine"):
        executor.get_engine("nope")
    with pytest.raises(ValueError, match="unknown gather"):
        executor.resolve_gather("nope")


def test_resolve_gather_auto_is_backend_dependent(monkeypatch):
    import jax
    monkeypatch.delenv("REPRO_KERNEL_BACKEND", raising=False)
    expect = "aia" if jax.default_backend() == "tpu" else "xla"
    assert executor.resolve_gather("auto") == expect
    assert executor.resolve_gather("xla") == "xla"
    assert executor.resolve_gather("aia") == "aia"
    monkeypatch.setenv("REPRO_KERNEL_BACKEND", "xla")
    assert executor.resolve_gather("auto") == "xla"
    monkeypatch.setenv("REPRO_KERNEL_BACKEND", "interpret")
    assert executor.resolve_gather("auto") == "aia"


# ---------------------------------------------------------------------------
# Equivalence grid vs dense oracle (bit-identical on integer-valued data)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("engine", ENGINES)
@pytest.mark.parametrize("gather", GATHERS)
@pytest.mark.parametrize("schedule", SCHEDULES)
def test_engine_gather_schedule_grid_matches_oracle(engine, gather, schedule):
    rng = np.random.default_rng(7)
    a = csr_from_dense(int_sparse(rng, 18, 14, 0.25))
    b = csr_from_dense(int_sparse(rng, 14, 16, 0.35))
    res = spgemm(a, b, engine=engine, gather=gather, schedule=schedule)
    np.testing.assert_array_equal(_dense(res.c), np.asarray(spgemm_dense(a, b)))


@pytest.mark.parametrize("engine", ENGINES)
@pytest.mark.parametrize("gather", GATHERS)
def test_empty_matrix(engine, gather):
    rng = np.random.default_rng(0)
    a = csr_from_dense(np.zeros((6, 5), np.float32))
    b = csr_from_dense(int_sparse(rng, 5, 4, 0.5))
    res = spgemm(a, b, engine=engine, gather=gather)
    assert res.info["nnz_c"] == 0
    np.testing.assert_array_equal(_dense(res.c), np.zeros((6, 4), np.float32))


@pytest.mark.parametrize("engine", ENGINES)
def test_all_zero_rows_interleaved(engine):
    """Rows with nnz=0 interleave with dense rows; reassembly must not
    misplace offsets around the empty rows."""
    rng = np.random.default_rng(3)
    x = int_sparse(rng, 12, 10, 0.6)
    x[::2] = 0.0  # every other row empty
    a = csr_from_dense(x)
    b = csr_from_dense(int_sparse(rng, 10, 9, 0.4))
    res = spgemm(a, b, engine=engine)
    np.testing.assert_array_equal(_dense(res.c), np.asarray(spgemm_dense(a, b)))


def test_group3_row_sort_engine():
    """A row with IP >= 8192 lands in Table-I group 3 (global-table bin)."""
    rng = np.random.default_rng(11)
    # row 0 of A: 128 nnz; every B row: 64 nnz -> IP(row 0) = 128*64 = 8192
    xa = np.zeros((4, 128), np.float32)
    xa[0] = rng.integers(1, 4, 128).astype(np.float32)
    xa[1, :3] = 1.0
    xb = np.zeros((128, 256), np.float32)
    for i in range(128):
        cols = rng.choice(256, 64, replace=False)
        xb[i, cols] = rng.integers(1, 4, 64).astype(np.float32)
    a, b = csr_from_dense(xa), csr_from_dense(xb)
    plan = group_rows(a, b)
    assert plan.group_sizes[3] >= 1  # the heavy row really is in group 3
    res = spgemm(a, b, engine="sort")
    np.testing.assert_array_equal(_dense(res.c), np.asarray(spgemm_dense(a, b)))


def test_row_chunking_matches_unchunked():
    rng = np.random.default_rng(5)
    a = csr_from_dense(int_sparse(rng, 40, 30, 0.2))
    b = csr_from_dense(int_sparse(rng, 30, 25, 0.2))
    big = spgemm(a, b, engine="sort")
    small = spgemm(a, b, engine="sort", row_chunk=8)
    np.testing.assert_array_equal(_dense(big.c), _dense(small.c))
    np.testing.assert_array_equal(
        np.asarray(big.c.indptr), np.asarray(small.c.indptr))


# ---------------------------------------------------------------------------
# Program cache: MCL-style iterations must not re-trace
# ---------------------------------------------------------------------------

def test_compile_cache_hit_across_mcl_iterations():
    rng = np.random.default_rng(9)
    pattern = rng.random((20, 20)) < 0.25
    x1 = np.where(pattern, rng.integers(1, 5, (20, 20)), 0).astype(np.float32)
    # iteration 2: same sparsity structure, different values (a converged
    # MCL expansion keeps the support, the executor must reuse programs)
    x2 = np.where(pattern, rng.integers(1, 5, (20, 20)), 0).astype(np.float32)
    executor.clear_program_cache()
    spgemm(csr_from_dense(x1), csr_from_dense(x1), engine="sort")
    after_first = executor.cache_stats()
    assert after_first["misses"] > 0
    spgemm(csr_from_dense(x2), csr_from_dense(x2), engine="sort")
    after_second = executor.cache_stats()
    assert after_second["misses"] == after_first["misses"], (
        "second MCL iteration re-traced group programs")
    assert after_second["hits"] > after_first["hits"]


def test_cache_keys_engine_and_gather_disjoint():
    rng = np.random.default_rng(13)
    a = csr_from_dense(int_sparse(rng, 10, 10, 0.3))
    executor.clear_program_cache()
    spgemm(a, a, engine="sort", gather="xla")
    m1 = executor.cache_stats()["misses"]
    spgemm(a, a, engine="hash", gather="xla")
    m2 = executor.cache_stats()["misses"]
    spgemm(a, a, engine="sort", gather="aia")
    m3 = executor.cache_stats()["misses"]
    assert m1 < m2 < m3  # each axis value compiles its own programs


# ---------------------------------------------------------------------------
# Plan cache: same-support reuse + invalidation
# ---------------------------------------------------------------------------

def test_plan_cache_hits_on_same_support_different_values():
    """A converged MCL expansion keeps the support: the second lookup must
    skip group_rows, and the counters must surface in cache_stats()."""
    rng = np.random.default_rng(21)
    pattern = rng.random((24, 24)) < 0.25
    m1, m2 = same_pattern_batch(rng, pattern, 2)
    executor.clear_program_cache()
    cache = PlanCache()
    r1 = spgemm(m1, m1, engine="sort", plan=cache)
    assert cache.stats() == {"hits": 0, "misses": 1, "entries": 1}
    r2 = spgemm(m2, m2, engine="sort", plan=cache)
    assert cache.stats() == {"hits": 1, "misses": 1, "entries": 1}
    stats = executor.cache_stats()
    assert stats["plan_hits"] == 1 and stats["plan_misses"] == 1
    # the reused plan is the *same object* — group_rows really was skipped
    assert r2.plan is r1.plan
    np.testing.assert_array_equal(_dense(r2.c), np.asarray(spgemm_dense(m2, m2)))


def test_plan_cache_invalidated_by_index_mutation():
    """Same nnz, one column index changed → different support → miss."""
    rng = np.random.default_rng(22)
    a = csr_from_dense(int_sparse(rng, 16, 16, 0.3))
    b = csr_from_dense(int_sparse(rng, 16, 12, 0.3))
    cache = PlanCache()
    spgemm(a, b, engine="sort", plan=cache)
    ind = np.asarray(a.indices).copy()
    row0 = np.asarray(a.indptr)[:2]
    assert row0[1] > row0[0], "fixture needs a nonempty row 0"
    ind[row0[0]] = (ind[row0[0]] + 1) % a.n_cols
    mutated = CSR(a.indptr, jnp.asarray(ind), a.data, a.shape)
    res = spgemm(mutated, b, engine="sort", plan=cache)
    assert cache.stats() == {"hits": 0, "misses": 2, "entries": 2}
    np.testing.assert_array_equal(
        _dense(res.c), np.asarray(spgemm_dense(mutated, b)))


def test_plan_cache_keys_on_both_operands():
    """B's support is part of the fingerprint (kb caps derive from it)."""
    rng = np.random.default_rng(23)
    a = csr_from_dense(int_sparse(rng, 14, 14, 0.3))
    b1 = csr_from_dense(int_sparse(rng, 14, 10, 0.3))
    b2 = csr_from_dense(int_sparse(rng, 14, 10, 0.3))
    cache = PlanCache()
    spgemm(a, b1, plan=cache)
    spgemm(a, b2, plan=cache)
    assert cache.misses == 2 and cache.hits == 0


def test_plan_cache_lru_bound():
    rng = np.random.default_rng(24)
    cache = PlanCache(max_entries=2)
    mats = [csr_from_dense(int_sparse(rng, 10, 10, 0.4)) for _ in range(3)]
    for m in mats:
        spgemm(m, m, plan=cache)
    assert len(cache) == 2
    spgemm(mats[0], mats[0], plan=cache)  # evicted → miss again
    assert cache.misses == 4 and cache.hits == 0


def test_spgemm_accepts_explicit_plan():
    rng = np.random.default_rng(25)
    a = csr_from_dense(int_sparse(rng, 20, 15, 0.3))
    b = csr_from_dense(int_sparse(rng, 15, 18, 0.3))
    plan = group_rows(a, b)
    res = spgemm(a, b, engine="sort", plan=plan)
    assert res.plan is plan
    np.testing.assert_array_equal(_dense(res.c), np.asarray(spgemm_dense(a, b)))
    with pytest.raises(TypeError, match="plan must be"):
        spgemm(a, b, plan="yes")


def test_converged_mcl_iterations_hit_plan_cache():
    """The headline iterative workload: once MCL's support stabilizes,
    further expansions must be plan-cache hits (reuse_plan=True default)."""
    from repro.apps.markov_clustering import mcl

    n = 16
    x = np.zeros((n, n), np.float32)
    x[:8, :8] = 1.0
    x[8:, 8:] = 1.0
    np.fill_diagonal(x, 0)
    x[7, 8] = x[8, 7] = 0.1
    g = csr_from_dense(x)
    res = mcl(g, e=2, r=2.0, k=16, max_iters=6, tol=0.0)
    assert res.plan_cache_hits > 0
    off = mcl(g, e=2, r=2.0, k=16, max_iters=6, tol=0.0, reuse_plan=False)
    assert off.plan_cache_hits == 0
    np.testing.assert_array_equal(
        _dense(res.matrix), _dense(off.matrix))
    np.testing.assert_array_equal(res.clusters, off.clusters)


# ---------------------------------------------------------------------------
# Batched SpGEMM: bit-exact vs the per-matrix loop
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("engine", ENGINES)
@pytest.mark.parametrize("gather", GATHERS)
def test_spgemm_batched_matches_per_matrix_loop(engine, gather):
    """The acceptance bar: batched output CSRs (values *and* layout) are
    bit-identical to looping spgemm over the members."""
    rng = np.random.default_rng(31)
    pat_a = rng.random((18, 14)) < 0.3
    pat_b = rng.random((14, 16)) < 0.35
    a_mats = same_pattern_batch(rng, pat_a, 3)
    b_mats = same_pattern_batch(rng, pat_b, 3)
    res = spgemm_batched(a_mats, b_mats, engine=engine, gather=gather)
    assert res.info["batch"] == 3
    for i in range(3):
        single = spgemm(a_mats[i], b_mats[i], engine=engine, gather=gather)
        np.testing.assert_array_equal(
            np.asarray(res.cs[i].indptr), np.asarray(single.c.indptr))
        np.testing.assert_array_equal(
            np.asarray(res.cs[i].indices), np.asarray(single.c.indices))
        np.testing.assert_array_equal(
            np.asarray(res.cs[i].data), np.asarray(single.c.data))
        np.testing.assert_array_equal(
            _dense(res.cs[i]), np.asarray(spgemm_dense(a_mats[i], b_mats[i])))


@pytest.mark.parametrize("engine", ENGINES)
def test_spgemm_batched_shared_b_broadcast(engine):
    """A single CSR on either side broadcasts its values to every member."""
    rng = np.random.default_rng(32)
    pat_a = rng.random((16, 12)) < 0.3
    a_mats = same_pattern_batch(rng, pat_a, 4)
    b = csr_from_dense(int_sparse(rng, 12, 10, 0.35))
    res = spgemm_batched(a_mats, b, engine=engine)
    for i in range(4):
        single = spgemm(a_mats[i], b, engine=engine)
        np.testing.assert_array_equal(_dense(res.cs[i]), _dense(single.c))
    # and the symmetric case: one A, many B
    b_mats = same_pattern_batch(rng, rng.random((12, 10)) < 0.35, 2)
    a = a_mats[0]
    res2 = spgemm_batched(a, b_mats, engine=engine)
    for i in range(2):
        np.testing.assert_array_equal(
            _dense(res2.cs[i]), _dense(spgemm(a, b_mats[i], engine=engine).c))


def test_spgemm_batched_output_structure_is_shared():
    rng = np.random.default_rng(33)
    a_mats = same_pattern_batch(rng, rng.random((15, 15)) < 0.3, 3)
    res = spgemm_batched(a_mats, a_mats[0], engine="sort")
    assert all(c.indptr is res.cs[0].indptr for c in res.cs)
    assert all(c.indices is res.cs[0].indices for c in res.cs)


def test_spgemm_batched_natural_schedule_and_empty():
    rng = np.random.default_rng(34)
    a_mats = same_pattern_batch(rng, rng.random((12, 10)) < 0.3, 2)
    b = csr_from_dense(int_sparse(rng, 10, 8, 0.3))
    res = spgemm_batched(a_mats, b, engine="sort", schedule="natural")
    for i in range(2):
        np.testing.assert_array_equal(
            _dense(res.cs[i]), np.asarray(spgemm_dense(a_mats[i], b)))
    # all-zero members: nnz_c == 0, shapes intact
    z = csr_from_dense(np.zeros((6, 5), np.float32))
    rz = spgemm_batched([z, z], csr_from_dense(int_sparse(rng, 5, 4, 0.5)))
    assert rz.info["nnz_c"] == 0
    np.testing.assert_array_equal(_dense(rz.cs[1]), np.zeros((6, 4)))


def test_spgemm_batched_rejects_mismatched_patterns():
    rng = np.random.default_rng(35)
    a1 = csr_from_dense(int_sparse(rng, 10, 10, 0.3))
    a2 = csr_from_dense(int_sparse(rng, 10, 10, 0.3))
    b = csr_from_dense(int_sparse(rng, 10, 8, 0.3))
    with pytest.raises(ValueError, match="sparsity pattern"):
        spgemm_batched([a1, a2], b)
    with pytest.raises(ValueError, match="batch mismatch"):
        spgemm_batched([a1, a1], [b, b, b])


def test_spgemm_batched_amortizes_allocation_and_plan():
    """One batched call shares the allocate programs with the unbatched
    path (same signature) and a PlanCache feeds both entry points."""
    rng = np.random.default_rng(36)
    pat = rng.random((20, 20)) < 0.25
    mats = same_pattern_batch(rng, pat, 3)
    cache = PlanCache()
    spgemm(mats[0], mats[0], engine="sort", plan=cache)
    res = spgemm_batched(mats, mats[0], engine="sort", plan=cache)
    assert cache.hits == 1  # batched call reused the single-matrix plan
    np.testing.assert_array_equal(
        _dense(res.cs[1]), np.asarray(spgemm_dense(mats[1], mats[0])))


# ---------------------------------------------------------------------------
# spgemm_ell_fixed rides the public engine API
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("engine", ENGINES)
def test_ell_fixed_through_engine_registry(engine):
    rng = np.random.default_rng(4)
    x = int_sparse(rng, 12, 12, 0.25)
    e = ell_from_dense(x, k_cap=8)
    c = spgemm_ell_fixed(e, e, out_cap=12, engine=engine)
    np.testing.assert_array_equal(np.asarray(ell_to_dense(c)), x @ x)
