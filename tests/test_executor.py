"""Plan-compiled executor: engine×gather×schedule equivalence + caching.

The acceptance bar: every engine/gather combination produces *bit-identical*
CSR output to the dense oracle (test data is integer-valued so accumulation
order cannot introduce float noise), edge cases included, and repeated
MCL-style iterations reuse compiled programs instead of re-tracing.
"""
import numpy as np
import pytest

from repro.core import executor
from repro.core.grouping import group_rows
from repro.core.ref import spgemm_dense
from repro.core.spgemm import spgemm, spgemm_ell_fixed
from repro.sparse.formats import (
    csr_from_dense, csr_to_dense, ell_from_dense, ell_to_dense,
)

ENGINES = ("sort", "hash")
GATHERS = ("xla", "aia")
SCHEDULES = ("grouped", "natural")


def int_sparse(rng, n, m, density=0.3):
    """Integer-valued float32 matrix: exact under any accumulation order."""
    x = rng.integers(-4, 5, (n, m)).astype(np.float32)
    mask = rng.random((n, m)) < density
    return np.where(mask, x, 0.0).astype(np.float32)


def _dense(c):
    return np.asarray(csr_to_dense(c))


# ---------------------------------------------------------------------------
# Engine registry
# ---------------------------------------------------------------------------

def test_registry_contents_and_unknown_engine():
    assert set(executor.available_engines()) >= {"hash", "sort"}
    assert executor.get_engine("sort").name == "sort"
    with pytest.raises(ValueError, match="unknown engine"):
        executor.get_engine("nope")
    with pytest.raises(ValueError, match="unknown gather"):
        executor.resolve_gather("nope")


def test_resolve_gather_auto_is_backend_dependent(monkeypatch):
    import jax
    monkeypatch.delenv("REPRO_KERNEL_BACKEND", raising=False)
    expect = "aia" if jax.default_backend() == "tpu" else "xla"
    assert executor.resolve_gather("auto") == expect
    assert executor.resolve_gather("xla") == "xla"
    assert executor.resolve_gather("aia") == "aia"
    monkeypatch.setenv("REPRO_KERNEL_BACKEND", "xla")
    assert executor.resolve_gather("auto") == "xla"
    monkeypatch.setenv("REPRO_KERNEL_BACKEND", "interpret")
    assert executor.resolve_gather("auto") == "aia"


# ---------------------------------------------------------------------------
# Equivalence grid vs dense oracle (bit-identical on integer-valued data)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("engine", ENGINES)
@pytest.mark.parametrize("gather", GATHERS)
@pytest.mark.parametrize("schedule", SCHEDULES)
def test_engine_gather_schedule_grid_matches_oracle(engine, gather, schedule):
    rng = np.random.default_rng(7)
    a = csr_from_dense(int_sparse(rng, 18, 14, 0.25))
    b = csr_from_dense(int_sparse(rng, 14, 16, 0.35))
    res = spgemm(a, b, engine=engine, gather=gather, schedule=schedule)
    np.testing.assert_array_equal(_dense(res.c), np.asarray(spgemm_dense(a, b)))


@pytest.mark.parametrize("engine", ENGINES)
@pytest.mark.parametrize("gather", GATHERS)
def test_empty_matrix(engine, gather):
    rng = np.random.default_rng(0)
    a = csr_from_dense(np.zeros((6, 5), np.float32))
    b = csr_from_dense(int_sparse(rng, 5, 4, 0.5))
    res = spgemm(a, b, engine=engine, gather=gather)
    assert res.info["nnz_c"] == 0
    np.testing.assert_array_equal(_dense(res.c), np.zeros((6, 4), np.float32))


@pytest.mark.parametrize("engine", ENGINES)
def test_all_zero_rows_interleaved(engine):
    """Rows with nnz=0 interleave with dense rows; reassembly must not
    misplace offsets around the empty rows."""
    rng = np.random.default_rng(3)
    x = int_sparse(rng, 12, 10, 0.6)
    x[::2] = 0.0  # every other row empty
    a = csr_from_dense(x)
    b = csr_from_dense(int_sparse(rng, 10, 9, 0.4))
    res = spgemm(a, b, engine=engine)
    np.testing.assert_array_equal(_dense(res.c), np.asarray(spgemm_dense(a, b)))


def test_group3_row_sort_engine():
    """A row with IP >= 8192 lands in Table-I group 3 (global-table bin)."""
    rng = np.random.default_rng(11)
    # row 0 of A: 128 nnz; every B row: 64 nnz -> IP(row 0) = 128*64 = 8192
    xa = np.zeros((4, 128), np.float32)
    xa[0] = rng.integers(1, 4, 128).astype(np.float32)
    xa[1, :3] = 1.0
    xb = np.zeros((128, 256), np.float32)
    for i in range(128):
        cols = rng.choice(256, 64, replace=False)
        xb[i, cols] = rng.integers(1, 4, 64).astype(np.float32)
    a, b = csr_from_dense(xa), csr_from_dense(xb)
    plan = group_rows(a, b)
    assert plan.group_sizes[3] >= 1  # the heavy row really is in group 3
    res = spgemm(a, b, engine="sort")
    np.testing.assert_array_equal(_dense(res.c), np.asarray(spgemm_dense(a, b)))


def test_row_chunking_matches_unchunked():
    rng = np.random.default_rng(5)
    a = csr_from_dense(int_sparse(rng, 40, 30, 0.2))
    b = csr_from_dense(int_sparse(rng, 30, 25, 0.2))
    big = spgemm(a, b, engine="sort")
    small = spgemm(a, b, engine="sort", row_chunk=8)
    np.testing.assert_array_equal(_dense(big.c), _dense(small.c))
    np.testing.assert_array_equal(
        np.asarray(big.c.indptr), np.asarray(small.c.indptr))


# ---------------------------------------------------------------------------
# Program cache: MCL-style iterations must not re-trace
# ---------------------------------------------------------------------------

def test_compile_cache_hit_across_mcl_iterations():
    rng = np.random.default_rng(9)
    pattern = rng.random((20, 20)) < 0.25
    x1 = np.where(pattern, rng.integers(1, 5, (20, 20)), 0).astype(np.float32)
    # iteration 2: same sparsity structure, different values (a converged
    # MCL expansion keeps the support, the executor must reuse programs)
    x2 = np.where(pattern, rng.integers(1, 5, (20, 20)), 0).astype(np.float32)
    executor.clear_program_cache()
    spgemm(csr_from_dense(x1), csr_from_dense(x1), engine="sort")
    after_first = executor.cache_stats()
    assert after_first["misses"] > 0
    spgemm(csr_from_dense(x2), csr_from_dense(x2), engine="sort")
    after_second = executor.cache_stats()
    assert after_second["misses"] == after_first["misses"], (
        "second MCL iteration re-traced group programs")
    assert after_second["hits"] > after_first["hits"]


def test_cache_keys_engine_and_gather_disjoint():
    rng = np.random.default_rng(13)
    a = csr_from_dense(int_sparse(rng, 10, 10, 0.3))
    executor.clear_program_cache()
    spgemm(a, a, engine="sort", gather="xla")
    m1 = executor.cache_stats()["misses"]
    spgemm(a, a, engine="hash", gather="xla")
    m2 = executor.cache_stats()["misses"]
    spgemm(a, a, engine="sort", gather="aia")
    m3 = executor.cache_stats()["misses"]
    assert m1 < m2 < m3  # each axis value compiles its own programs


# ---------------------------------------------------------------------------
# spgemm_ell_fixed rides the public engine API
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("engine", ENGINES)
def test_ell_fixed_through_engine_registry(engine):
    rng = np.random.default_rng(4)
    x = int_sparse(rng, 12, 12, 0.25)
    e = ell_from_dense(x, k_cap=8)
    c = spgemm_ell_fixed(e, e, out_cap=12, engine=engine)
    np.testing.assert_array_equal(np.asarray(ell_to_dense(c)), x @ x)
