"""Hypothesis import shim: real hypothesis when installed, tiny fallback not.

The tier-1 suite must collect and run in environments without
``hypothesis`` (the container bakes in the jax toolchain only).  When the
real library is available it is re-exported unchanged; otherwise ``given``
degrades to a deterministic sampler that draws a handful of examples per
strategy — enough to keep the property tests exercising the code paths,
without shrinking/reporting machinery.

Usage in test modules::

    from _hypothesis_compat import given, settings, st
"""
from __future__ import annotations

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised only without hypothesis
    import functools
    import inspect
    import random

    HAVE_HYPOTHESIS = False
    _FALLBACK_EXAMPLES = 5

    class _Strategy:
        def __init__(self, draw):
            self._draw = draw

        def example(self, rng):
            return self._draw(rng)

    class _Strategies:
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(lambda rng: rng.randint(min_value, max_value))

        @staticmethod
        def floats(min_value, max_value):
            return _Strategy(lambda rng: rng.uniform(min_value, max_value))

        @staticmethod
        def sampled_from(elements):
            elements = list(elements)
            return _Strategy(lambda rng: rng.choice(elements))

        @staticmethod
        def booleans():
            return _Strategy(lambda rng: rng.random() < 0.5)

    st = _Strategies()

    def settings(*args, **kwargs):
        """No-op stand-in for ``hypothesis.settings`` (accepts any config)."""
        def deco(fn):
            return fn
        return deco

    def given(*arg_strategies, **kw_strategies):
        """Deterministic mini-``given``: a fixed-seed RNG draws
        ``_FALLBACK_EXAMPLES`` examples per test and runs them all.
        Positional strategies map to the *rightmost* parameters, matching
        real hypothesis, and everything is passed by keyword."""
        def deco(fn):
            sig = inspect.signature(fn)
            names = list(sig.parameters)
            strategies = dict(kw_strategies)
            if arg_strategies:
                for name, strat in zip(names[-len(arg_strategies):],
                                       arg_strategies):
                    strategies[name] = strat

            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                rng = random.Random(f"{fn.__module__}.{fn.__qualname__}")
                for _ in range(_FALLBACK_EXAMPLES):
                    drawn = {k: s.example(rng) for k, s in strategies.items()}
                    fn(*args, **kwargs, **drawn)

            # Hide the drawn parameters from pytest's fixture resolution
            # (the real hypothesis does the same via @impersonate).
            remaining = [p for name, p in sig.parameters.items()
                         if name not in strategies]
            wrapper.__signature__ = sig.replace(parameters=remaining)
            return wrapper
        return deco
