"""The resilient-execution layer (docs/resilience.md).

Coverage mirrors the layer's contract:

* **Fault harness** — ``fault_injection`` arms named points with
  deterministic Nth-hit/`times` schedules, disarms on exit (even on
  error), and rejects unknown points, double-arming, and bad schedules.
* **Capacity detect-and-retry** — a forced ``capacity_undersize`` fault
  through the planned/fused lane trips the device-side overflow flag,
  discards the trimmed result, and re-executes at measured capacity
  **bit-exactly** (single and batched lanes); the clean planned path
  pays zero retries AND zero blocking host syncs (the flag stays
  unread).
* **Graceful degradation** — ``on_budget="stream"`` re-routes an
  over-budget monolithic call through ``spgemm_streamed`` with derived
  ``tile_rows``, bit-identical; under budget it is inert; a single row
  beyond the budget still raises ``DeviceBudgetExceeded``; ``mcl``
  threads the knob.
* **Transient-site recovery** — ``gather_fail`` / ``stage_tile_fail``
  faults are absorbed by one idempotent re-issue, results bit-exact.
* **Serving robustness** — per-request deadlines expire queued work with
  ``DeadlineExceeded``, shed submits retry with exponential backoff
  through the injectable ``sleep``, and a poisoned micro-batch replays
  per member: innocents complete bit-exactly, the poison request is
  quarantined with its own error.
* **Satellites** — the int32 nnz-capacity boundary, the budget error
  naming ``total_ip``, ``constrain``'s counted no-mesh fallback, and the
  trainer's narrowed recovery (RuntimeError restarts, TypeError
  propagates, failures recorded).
"""
import numpy as np
import pytest

from repro.core import executor, faults
from repro.core.spgemm import spgemm, spgemm_batched
from repro.sparse.formats import csr_from_dense


def int_sparse(rng, n, m, density=0.3):
    """Small-integer sparse block — float32-exact products."""
    x = rng.integers(-4, 5, (n, m)).astype(np.float32)
    mask = rng.random((n, m)) < density
    return np.where(mask, x, 0.0).astype(np.float32)


def _pair(seed=7, n=96, k=64, m=80, density=0.25):
    rng = np.random.default_rng(seed)
    a = csr_from_dense(int_sparse(rng, n, k, density))
    b = csr_from_dense(int_sparse(rng, k, m, density))
    return a, b


@pytest.fixture(autouse=True)
def _clean_state():
    executor.clear_program_cache()
    executor.set_device_budget(None)
    yield
    executor.set_device_budget(None)


# ---------------------------------------------------------------------------
# Fault-injection harness semantics
# ---------------------------------------------------------------------------

def test_fault_registry_names_and_validation():
    with pytest.raises(ValueError, match="unknown fault point"):
        with faults.fault_injection("no_such_point"):
            pass
    for bad in (0, -1, 1.5, True):
        with pytest.raises(ValueError):
            with faults.fault_injection("gather_fail", on_hit=bad):
                pass
    with pytest.raises(ValueError):
        with faults.fault_injection("gather_fail", times=0):
            pass


def test_fault_nth_hit_schedule_and_exhaustion():
    with faults.fault_injection("gather_fail", on_hit=2, times=2):
        assert not faults.trigger("gather_fail")   # hit 1: before on_hit
        assert faults.trigger("gather_fail")       # hit 2: fires
        assert faults.trigger("gather_fail")       # hit 3: times=2
        assert not faults.trigger("gather_fail")   # exhausted
    assert not faults.armed("gather_fail")
    assert not faults.trigger("gather_fail")       # disarmed = never fires


def test_fault_fire_raises_and_disarms_on_error():
    with pytest.raises(faults.FaultInjected):
        with faults.fault_injection("gather_fail"):
            faults.fire("gather_fail")
    assert not faults.armed("gather_fail")


def test_fault_double_arm_rejected():
    with faults.fault_injection("gather_fail"):
        with pytest.raises(RuntimeError, match="already armed"):
            with faults.fault_injection("gather_fail"):
                pass
        # the rejected inner arm must not have disarmed the outer one
        assert faults.armed("gather_fail")
    assert not faults.armed("gather_fail")


# ---------------------------------------------------------------------------
# Capacity detect-and-retry (planned/fused lane)
# ---------------------------------------------------------------------------

def assert_bit_exact(c_got, c_ref):
    ipt_g, ipt_r = np.asarray(c_got.indptr), np.asarray(c_ref.indptr)
    np.testing.assert_array_equal(ipt_g, ipt_r)
    nnz = int(ipt_r[-1])
    np.testing.assert_array_equal(np.asarray(c_got.indices)[:nnz],
                                  np.asarray(c_ref.indices)[:nnz])
    np.testing.assert_array_equal(np.asarray(c_got.data)[:nnz],
                                  np.asarray(c_ref.data)[:nnz])


def test_capacity_retry_bit_exact_vs_measured():
    a, b = _pair()
    ref = spgemm(a, b, engine="fused_hash", sizing="measured")
    r0 = executor.cache_stats()["capacity_retries"]
    with faults.fault_injection("capacity_undersize"):
        res = spgemm(a, b, engine="fused_hash", sizing="planned")
    assert executor.cache_stats()["capacity_retries"] - r0 == 1
    assert_bit_exact(res.c, ref.c)


def test_capacity_clean_path_no_retries_no_syncs():
    a, b = _pair()
    spgemm(a, b, engine="fused_hash", sizing="planned")  # warm caches
    r0 = executor.cache_stats()["capacity_retries"]
    s0 = executor.cache_stats()["host_sync_count"]
    res = spgemm(a, b, engine="fused_hash", sizing="planned")
    assert executor.cache_stats()["capacity_retries"] - r0 == 0
    assert executor.cache_stats()["host_sync_count"] - s0 == 0
    ref = spgemm(a, b, engine="fused_hash", sizing="measured")
    assert_bit_exact(res.c, ref.c)


def test_capacity_retry_batched_lane_bit_exact():
    rng = np.random.default_rng(11)
    mask = rng.random((72, 72)) < 0.2
    bs = []
    for i in range(3):
        # values strictly nonzero so every member keeps the shared pattern
        vals = rng.integers(1, 5, mask.shape).astype(np.float32)
        bs.append(csr_from_dense(np.where(mask, vals, 0.0)))
    refs = [spgemm(bm, bm, engine="fused_hash", sizing="measured").c
            for bm in bs]
    r0 = executor.cache_stats()["capacity_retries"]
    with faults.fault_injection("capacity_undersize"):
        res = spgemm_batched(bs, bs, engine="fused_hash", sizing="planned")
    assert executor.cache_stats()["capacity_retries"] - r0 == 1
    for got, ref in zip(res.cs, refs):
        assert_bit_exact(got, ref)


# ---------------------------------------------------------------------------
# on_budget graceful degradation
# ---------------------------------------------------------------------------

def test_resolve_on_budget_validates():
    assert executor.resolve_on_budget("error") == "error"
    assert executor.resolve_on_budget("stream") == "stream"
    with pytest.raises(ValueError, match="on_budget"):
        executor.resolve_on_budget("retry")
    a, b = _pair()
    with pytest.raises(ValueError, match="on_budget"):
        spgemm(a, b, on_budget="explode")


def test_on_budget_stream_degrades_bit_exact():
    a, b = _pair(n=128)
    ref = spgemm(a, b)
    need = executor.estimated_device_bytes(
        ref.plan, np.dtype(np.float32).itemsize)
    with pytest.raises(executor.DeviceBudgetExceeded):
        executor.set_device_budget(need // 3)
        spgemm(a, b)  # default on_budget="error" keeps the old contract
    d0 = executor.cache_stats()["budget_degradations"]
    res = spgemm(a, b, on_budget="stream")
    assert executor.cache_stats()["budget_degradations"] - d0 == 1
    assert res.info["degraded_to_stream"] == 1
    assert res.info["n_tiles"] > 1
    assert_bit_exact(res.c, ref.c)


def test_on_budget_stream_inert_under_budget():
    a, b = _pair()
    ref = spgemm(a, b)
    need = executor.estimated_device_bytes(
        ref.plan, np.dtype(np.float32).itemsize)
    executor.set_device_budget(need * 2)
    d0 = executor.cache_stats()["budget_degradations"]
    res = spgemm(a, b, on_budget="stream")
    assert executor.cache_stats()["budget_degradations"] - d0 == 0
    assert "degraded_to_stream" not in res.info
    assert_bit_exact(res.c, ref.c)


def test_degradation_tile_rows_single_row_too_big_raises():
    a, b = _pair()
    plan = spgemm(a, b).plan
    executor.set_device_budget(1)  # below any row's estimate
    with pytest.raises(executor.DeviceBudgetExceeded, match="single row"):
        executor.derive_degradation_tile_rows(plan, a.n_rows, 4)
    executor.set_device_budget(None)
    with pytest.raises(ValueError, match="budget"):
        executor.derive_degradation_tile_rows(plan, a.n_rows, 4)


def test_mcl_threads_on_budget():
    from repro.apps.markov_clustering import mcl
    rng = np.random.default_rng(5)
    g = csr_from_dense(np.where(rng.random((64, 64)) < 0.08,
                                rng.integers(1, 5, (64, 64)), 0)
                       .astype(np.float32))
    mref = mcl(g, e=2, max_iters=2, tol=0.0)
    lo = max(i["max_ip"] for i in mref.spgemm_info) * 8
    hi = min(i["intermediate_products"] for i in mref.spgemm_info) * 8
    assert lo < hi, "graph too small to separate worst-row from total"
    executor.set_device_budget((lo + hi) // 2)
    with pytest.raises(executor.DeviceBudgetExceeded):
        mcl(g, e=2, max_iters=2, tol=0.0)
    d0 = executor.cache_stats()["budget_degradations"]
    mdeg = mcl(g, e=2, max_iters=2, tol=0.0, on_budget="stream")
    assert executor.cache_stats()["budget_degradations"] - d0 >= 1
    assert_bit_exact(mdeg.matrix, mref.matrix)
    np.testing.assert_array_equal(mdeg.clusters, mref.clusters)
    with pytest.raises(ValueError, match="on_budget"):
        mcl(g, on_budget="panic")


# ---------------------------------------------------------------------------
# Transient-site recovery: gather + tile staging
# ---------------------------------------------------------------------------

def test_gather_fail_recovered_bit_exact():
    a, b = _pair(seed=9)
    ref = spgemm(a, b)
    with faults.fault_injection("gather_fail"):
        res = spgemm(a, b)
    assert_bit_exact(res.c, ref.c)


def test_stage_tile_fail_recovered_bit_exact():
    from repro.core.spgemm import spgemm_streamed
    a, b = _pair(seed=13, n=128)
    ref = spgemm(a, b)
    with faults.fault_injection("stage_tile_fail", on_hit=2):
        res = spgemm_streamed(a, b, tile_rows=32)
    assert_bit_exact(res.c, ref.c)


# ---------------------------------------------------------------------------
# int32 capacity boundary + budget error detail (satellites)
# ---------------------------------------------------------------------------

def test_int32_nnz_capacity_boundaries():
    assert executor._int32_nnz_capacity(0) == 1
    assert executor._int32_nnz_capacity(5) == 8
    cap = executor._int32_nnz_capacity(executor._INT32_MAX)
    assert cap == executor._INT32_MAX  # pow2 would overflow; exact fit
    with pytest.raises(OverflowError):
        executor._int32_nnz_capacity(executor._INT32_MAX + 1)


def test_device_budget_error_names_total_ip():
    a, b = _pair()
    plan = spgemm(a, b).plan
    executor.set_device_budget(8)
    with pytest.raises(executor.DeviceBudgetExceeded,
                       match=str(plan.total_ip)):
        spgemm(a, b)


# ---------------------------------------------------------------------------
# constrain(): counted no-mesh fallback (satellite)
# ---------------------------------------------------------------------------

def test_constrain_outside_mesh_counts_fallback():
    import jax
    from jax.sharding import PartitionSpec
    from repro.launch.sharding import constrain

    f0 = executor.cache_stats()["sharding_fallbacks"]
    x = jax.numpy.ones((4, 4))
    y = constrain(x, PartitionSpec("x", None))
    np.testing.assert_array_equal(np.asarray(y), np.asarray(x))
    assert executor.cache_stats()["sharding_fallbacks"] - f0 == 1
    executor.clear_program_cache()
    assert executor.cache_stats()["sharding_fallbacks"] == 0


# ---------------------------------------------------------------------------
# Serving robustness: deadlines, retry-with-backoff, quarantine
# ---------------------------------------------------------------------------

class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


def _pattern_csr(mask_seed, val_seed, shape=(20, 20), density=0.3):
    rng = np.random.default_rng(mask_seed)
    mask = rng.random(shape) < density
    # values strictly nonzero: same mask seed must mean same CSR pattern
    vals = np.random.default_rng(val_seed).integers(1, 5, shape)
    return csr_from_dense(np.where(mask, vals, 0).astype(np.float32))


def _service(**kw):
    from repro.serve import SpGEMMService
    clock = FakeClock()
    slept = []
    kw.setdefault("max_batch", 4)
    kw.setdefault("max_wait", 0.05)
    kw.setdefault("max_queue", 64)
    svc = SpGEMMService(clock=clock, sleep=slept.append, **kw)
    return svc, clock, slept


def test_serve_resolvers_validate():
    from repro.serve.spgemm_service import (
        DEFAULT_BACKOFF, resolve_backoff, resolve_deadline, resolve_retries)
    assert resolve_deadline(None) is None
    assert resolve_deadline(0.5) == 0.5
    assert resolve_retries(None) == 0
    assert resolve_retries(3) == 3
    assert resolve_backoff(None) == DEFAULT_BACKOFF
    assert resolve_backoff(0.01) == 0.01
    for bad in (-1, 0, True, "soon"):
        with pytest.raises(ValueError):
            resolve_deadline(bad)
    for bad in (-1, True, 1.5):
        with pytest.raises(ValueError):
            resolve_retries(bad)
    for bad in (-0.1, 0, True):
        with pytest.raises(ValueError):
            resolve_backoff(bad)


def test_serve_deadline_expires_queued_request():
    from repro.serve import DeadlineExceeded
    svc, clock, _ = _service(max_batch=8, max_wait=0.05)
    a0, b0 = _pattern_csr(1, 10), _pattern_csr(2, 20)
    t_dead = svc.submit("t", a0, b0, deadline=0.5)
    t_live = svc.submit("t", a0, _pattern_csr(2, 21))
    clock.t = 1.0  # past t_dead's deadline, past max_wait
    svc.poll()
    assert t_dead.done and t_live.done
    with pytest.raises(DeadlineExceeded):
        t_dead.result()
    ref = spgemm(a0, _pattern_csr(2, 21))
    assert_bit_exact(t_live.result().c, ref.c)
    st = svc.stats()
    assert st["deadline_exceeded"] == 1
    assert st["requests_completed"] == 1


def test_serve_retry_backoff_exhausts_to_queue_full():
    from repro.serve import QueueFull
    svc, _, slept = _service(max_batch=8, max_wait=10.0, max_queue=2)
    svc.submit("t", _pattern_csr(1, 1), _pattern_csr(2, 2))
    svc.submit("t", _pattern_csr(3, 3), _pattern_csr(4, 4))
    with pytest.raises(QueueFull):
        svc.submit("t", _pattern_csr(5, 5), _pattern_csr(6, 6),
                   retries=2, backoff=0.1)
    assert slept == [0.1, 0.2]  # exponential: backoff * 2**attempt
    st = svc.stats()
    assert st["retries"] == 2 and st["requests_shed"] == 1


def test_serve_retry_backoff_succeeds_when_queue_drains():
    svc, clock, slept = _service(max_batch=8, max_wait=0.05, max_queue=2)
    svc.submit("t", _pattern_csr(1, 1), _pattern_csr(2, 2))
    svc.submit("t", _pattern_csr(3, 3), _pattern_csr(4, 4))

    def sleep(s):
        slept.append(s)
        clock.t += s  # sleeping past max_wait lets the retry's poll flush

    svc._sleep = sleep
    tk = svc.submit("t", _pattern_csr(5, 5), _pattern_csr(6, 6),
                    retries=3, backoff=0.1)
    assert slept == [0.1]
    st = svc.stats()
    assert st["retries"] == 1 and st["requests_shed"] == 0
    ref = spgemm(_pattern_csr(5, 5), _pattern_csr(6, 6))
    assert_bit_exact(tk.result().c, ref.c)


def test_serve_batch_failure_isolates_poison_member():
    svc, _, _ = _service(max_batch=3, max_wait=10.0)
    a_mats = [_pattern_csr(1, 100 + i) for i in range(3)]
    b_mats = [_pattern_csr(2, 200 + i) for i in range(3)]
    with faults.fault_injection("dispatch_fail", times=2):
        # 3rd same-pattern submit dispatches the batch inside the context:
        # hit 1 fails the coalesced dispatch, hit 2 fails member 0's
        # isolated replay — exactly one poison member
        tickets = [svc.submit("t", a_mats[i], b_mats[i]) for i in range(3)]
    assert all(t.done for t in tickets)
    with pytest.raises(faults.FaultInjected):
        tickets[0].result()
    for i in (1, 2):
        ref = spgemm(a_mats[i], b_mats[i])
        assert_bit_exact(tickets[i].result().c, ref.c)
    st = svc.stats()
    assert st["quarantined"] == 1
    assert st["requests_completed"] == 2


# ---------------------------------------------------------------------------
# Trainer: narrowed recovery (satellite)
# ---------------------------------------------------------------------------

def _tiny_trainer(tmpdir, failure_injector, total_steps=4):
    from typing import NamedTuple

    from repro.data.pipeline import TokenPipeline
    from repro.train.trainer import Trainer, TrainerConfig

    class State(NamedTuple):
        step: np.int64
        w: np.ndarray

    def step_fn(state, batch):
        return (State(step=np.int64(state.step) + 1, w=state.w + 1.0),
                {"loss": 0.0})

    cfg = TrainerConfig(total_steps=total_steps, checkpoint_every=1,
                        checkpoint_dir=tmpdir, max_restarts=2)
    pipe = TokenPipeline(vocab=16, seq_len=4, global_batch=1, seed=0)
    state = State(step=np.int64(0), w=np.zeros(2, np.float32))
    return Trainer(cfg, step_fn, state, pipe,
                   failure_injector=failure_injector)


def test_trainer_programming_errors_propagate(tmp_path):
    def inject(step):
        raise TypeError("not a device failure")

    tr = _tiny_trainer(str(tmp_path), inject)
    with pytest.raises(TypeError, match="not a device failure"):
        tr.run()
    assert tr.restarts == 0 and tr.failures == []


def test_trainer_records_and_logs_recovered_failures(tmp_path, caplog):
    killed = {"done": False}

    def inject(step):
        if step == 2 and not killed["done"]:
            killed["done"] = True
            raise RuntimeError("simulated preemption")

    tr = _tiny_trainer(str(tmp_path), inject)
    with caplog.at_level("WARNING", logger="repro.train.trainer"):
        state = tr.run()
    assert int(np.asarray(state.step)) == 4
    assert tr.restarts == 1
    assert tr.failures == [(2, repr(RuntimeError("simulated preemption")))]
    assert any("restart 1/2" in r.message for r in caplog.records)
