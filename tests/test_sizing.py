"""Plan-derived capacity bounds (sizing="planned") — soundness + plumbing.

The sync-free sizing path replaces the measured uniqueCount sync with
bounds from the plan's Algorithm-1 IP counts; these tests hold the bar
that makes that safe:

* **Soundness** (hypothesis property): for random CSR pairs, every
  chunk's plan-derived bound dominates the true uniqueCounts — max bound
  ≥ max nnz(C row) over the chunk and sum bound ≥ the chunk's total nnz —
  and the planned result matches the dense oracle for every engine ×
  gather combination (capacities were never silently truncated).
* **Plumbing**: ``row_ip`` survives planning and the natural-schedule
  collapse, ``resolve_sizing`` picks planned only for fused engines (and
  refuses plans without IP counts), and planned results are bit-exact vs
  measured for non-fused engines too.
"""
import dataclasses

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import executor
from repro.core.grouping import group_rows
from repro.core.ref import spgemm_dense
from repro.core.spgemm import spgemm
from repro.sparse.formats import csr_from_dense, csr_to_dense

ENGINES = ("sort", "hash", "fused_hash")
GATHERS = ("xla", "aia")


def int_sparse(rng, n, m, density=0.3):
    x = rng.integers(-4, 5, (n, m)).astype(np.float32)
    mask = rng.random((n, m)) < density
    return np.where(mask, x, 0.0).astype(np.float32)


def _dense(c):
    return np.asarray(csr_to_dense(c))


# ---------------------------------------------------------------------------
# Soundness: bound ≥ true uniqueCount, for every chunk of random pairs
# ---------------------------------------------------------------------------

@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 2**16),
       engine=st.sampled_from(ENGINES),
       gather=st.sampled_from(GATHERS))
def test_property_chunk_bounds_dominate_unique_counts(seed, engine, gather):
    rng = np.random.default_rng(seed)
    n, k, m = (int(rng.integers(4, 30)) for _ in range(3))
    da, db = rng.uniform(0.05, 0.5), rng.uniform(0.05, 0.5)
    a = csr_from_dense(int_sparse(rng, n, k, da))
    b = csr_from_dense(int_sparse(rng, k, m, db))
    plan = group_rows(a, b)
    oracle = np.asarray(spgemm_dense(a, b))
    true_counts = (oracle != 0).sum(axis=1)
    a_nnz = np.diff(np.asarray(a.indptr))
    items = executor.partition_plan(plan, a_nnz, row_chunk=8)
    for item in items:
        max_b, sum_b = executor.chunk_capacity_bounds(plan, item.rows,
                                                      b.n_cols)
        chunk_true = true_counts[item.rows]
        assert max_b >= int(chunk_true.max(initial=0)), (
            f"max bound {max_b} < true uniqueCount "
            f"{int(chunk_true.max(initial=0))} (seed={seed})")
        assert sum_b >= int(chunk_true.sum()), (
            f"sum bound {sum_b} < true chunk nnz {int(chunk_true.sum())} "
            f"(seed={seed})")
    # and the planned run really honors them: no truncation anywhere
    res = spgemm(a, b, engine=engine, gather=gather, row_chunk=8,
                 sizing="planned")
    np.testing.assert_array_equal(_dense(res.c), oracle)


# ---------------------------------------------------------------------------
# Bound plumbing + unit behavior
# ---------------------------------------------------------------------------

def test_group_rows_carries_row_ip():
    rng = np.random.default_rng(1)
    a = csr_from_dense(int_sparse(rng, 12, 10, 0.3))
    b = csr_from_dense(int_sparse(rng, 10, 8, 0.3))
    plan = group_rows(a, b)
    assert plan.row_ip is not None and len(plan.row_ip) == a.n_rows
    # IP[i] = sum of nnz(B row) over A's row i columns (Algorithm 1)
    b_nnz = np.diff(np.asarray(b.indptr))
    indptr = np.asarray(a.indptr)
    indices = np.asarray(a.indices)
    for i in range(a.n_rows):
        expect = int(b_nnz[indices[indptr[i]: indptr[i + 1]]].sum())
        assert int(plan.row_ip[i]) == expect
    # the natural-schedule collapse must keep the counts
    assert executor.ungrouped_plan(plan).row_ip is plan.row_ip


def test_chunk_capacity_bounds_clamped_by_ncols():
    rng = np.random.default_rng(2)
    a = csr_from_dense(int_sparse(rng, 10, 10, 0.9))
    b = csr_from_dense(int_sparse(rng, 10, 4, 0.9))  # only 4 columns
    plan = group_rows(a, b)
    rows = np.arange(10, dtype=np.int32)
    max_b, sum_b = executor.chunk_capacity_bounds(plan, rows, b.n_cols)
    assert max_b <= 4  # uniqueCount can never exceed n_cols(B)
    assert sum_b <= 40


def test_resolve_sizing_auto_and_validation():
    rng = np.random.default_rng(3)
    a = csr_from_dense(int_sparse(rng, 8, 8, 0.4))
    plan = group_rows(a, a)
    assert executor.resolve_sizing("auto", "fused_hash", plan) == "planned"
    assert executor.resolve_sizing("auto", "sort", plan) == "measured"
    assert executor.resolve_sizing("auto", "hash", plan) == "measured"
    assert executor.resolve_sizing("planned", "sort", plan) == "planned"
    assert executor.resolve_sizing("measured", "fused_hash", plan) \
        == "measured"
    with pytest.raises(ValueError, match="unknown sizing"):
        executor.resolve_sizing("guessed", "sort", plan)
    # a plan without Alg. 1 counts cannot serve planned sizing
    bare = dataclasses.replace(plan, row_ip=None)
    assert executor.resolve_sizing("auto", "fused_hash", bare) == "measured"
    with pytest.raises(ValueError, match="row_ip"):
        executor.resolve_sizing("planned", "sort", bare)


def test_planned_rejected_on_legacy_pipeline():
    rng = np.random.default_rng(4)
    a = csr_from_dense(int_sparse(rng, 8, 8, 0.4))
    with pytest.raises(ValueError, match="two_wave"):
        spgemm(a, a, engine="sort", pipeline="legacy", sizing="planned")


@pytest.mark.parametrize("engine", ENGINES)
def test_planned_bit_exact_vs_measured(engine):
    """Planned sizing only widens capacities — indptr and the occupied
    prefix must match the measured path bit-for-bit for every engine."""
    rng = np.random.default_rng(7)
    a = csr_from_dense(int_sparse(rng, 30, 24, 0.3))
    b = csr_from_dense(int_sparse(rng, 24, 20, 0.3))
    pl = spgemm(a, b, engine=engine, row_chunk=8, sizing="planned")
    me = spgemm(a, b, engine=engine, row_chunk=8, sizing="measured")
    nnz = me.info["nnz_c"]
    assert pl.info["nnz_c"] == nnz
    np.testing.assert_array_equal(
        np.asarray(pl.c.indptr), np.asarray(me.c.indptr))
    np.testing.assert_array_equal(
        np.asarray(pl.c.indices)[:nnz], np.asarray(me.c.indices)[:nnz])
    np.testing.assert_array_equal(
        np.asarray(pl.c.data)[:nnz], np.asarray(me.c.data)[:nnz])


def test_planned_output_is_int32_end_to_end():
    import jax.numpy as jnp

    rng = np.random.default_rng(8)
    a = csr_from_dense(int_sparse(rng, 14, 12, 0.3))
    res = spgemm(a, csr_from_dense(int_sparse(rng, 12, 10, 0.3)),
                 engine="fused_hash")
    assert res.c.indptr.dtype == jnp.int32
    assert res.c.indices.dtype == jnp.int32
    assert res.c.data.dtype == jnp.float32
