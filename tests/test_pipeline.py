"""Two-wave pipelined executor: sync budget, device epilogue, OperandCache.

Three bars from the pipelining PR:

* **Sync budget** — a multi-chunk ``execute_plan`` pays exactly **one**
  blocking allocate host sync (``cache_stats()["host_sync_count"]``) on the
  two-wave path, and one *per chunk* on the legacy path (the structure the
  pipeline removes).
* **Device epilogue** — the jitted device-side CSR reassembly
  (``phases.reassemble_device``) is bit-exact vs the legacy NumPy
  reassembly for every engine × gather combination, in-process and under
  1/2/4-device meshes (subprocess), and emits int32 ``indptr``/``indices``
  throughout with an explicit overflow guard instead of a silent downcast.
* **OperandCache** — B's replicated ELL buffers are shared across
  batched/iterative calls: the second call against the same B object
  re-replicates zero buffers (``operand_misses`` unchanged).
"""
import os
import subprocess
import sys
import textwrap

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import executor
from repro.core.ref import spgemm_dense
from repro.core.spgemm import spgemm, spgemm_batched
from repro.sparse.formats import csr_from_dense, csr_to_dense

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

ENGINES = ("sort", "hash", "fused_hash")
GATHERS = ("xla", "aia")


def run_py(body: str, n_devices: int = 4, timeout: int = 900):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    script = "import os\n" + textwrap.dedent(body)
    proc = subprocess.run([sys.executable, "-c", script], env=env,
                          capture_output=True, text=True, timeout=timeout)
    assert proc.returncode == 0, f"STDOUT:\n{proc.stdout}\nSTDERR:\n{proc.stderr}"
    return proc.stdout


def int_sparse(rng, n, m, density=0.3):
    x = rng.integers(-4, 5, (n, m)).astype(np.float32)
    mask = rng.random((n, m)) < density
    return np.where(mask, x, 0.0).astype(np.float32)


def _dense(c):
    return np.asarray(csr_to_dense(c))


def _sync_delta(fn):
    before = executor.cache_stats()["host_sync_count"]
    out = fn()
    return out, executor.cache_stats()["host_sync_count"] - before


def _fixture(seed=5, n=40, k=30, m=25):
    rng = np.random.default_rng(seed)
    a = csr_from_dense(int_sparse(rng, n, k, 0.25))
    b = csr_from_dense(int_sparse(rng, k, m, 0.25))
    return a, b


# ---------------------------------------------------------------------------
# Sync budget: one coalesced allocate sync per call, not one per chunk
# ---------------------------------------------------------------------------

def _n_work_items(res, a, row_chunk):
    nnz = np.diff(np.asarray(a.indptr))
    return len(executor.partition_plan(res.plan, nnz, row_chunk))


def test_two_wave_multichunk_single_allocate_sync():
    """The acceptance bar: a plan that splits into many group-chunks still
    performs exactly one blocking host sync on the two-wave path."""
    a, b = _fixture()
    executor.clear_program_cache()
    res, syncs = _sync_delta(lambda: spgemm(a, b, engine="sort", row_chunk=8))
    assert _n_work_items(res, a, 8) > 1, "fixture must be multi-chunk"
    assert syncs == 1, f"two-wave pipeline paid {syncs} host syncs"
    np.testing.assert_array_equal(_dense(res.c), np.asarray(spgemm_dense(a, b)))


def test_legacy_pipeline_syncs_once_per_chunk():
    a, b = _fixture()
    executor.clear_program_cache()
    res, syncs = _sync_delta(
        lambda: spgemm(a, b, engine="sort", row_chunk=8, pipeline="legacy"))
    n_items = _n_work_items(res, a, 8)
    assert n_items > 1
    assert syncs == n_items, (
        f"legacy path paid {syncs} syncs for {n_items} chunks")


def test_two_wave_batched_single_allocate_sync():
    rng = np.random.default_rng(31)
    pat = rng.random((40, 30)) < 0.25
    mats = [csr_from_dense(np.where(
        pat, rng.integers(1, 5, pat.shape), 0.0).astype(np.float32))
        for _ in range(3)]
    b = csr_from_dense(int_sparse(rng, 30, 25, 0.25))
    executor.clear_program_cache()
    res, syncs = _sync_delta(
        lambda: spgemm_batched(mats, b, engine="sort", row_chunk=8))
    assert syncs == 1, f"batched two-wave paid {syncs} host syncs"
    for i in range(3):
        np.testing.assert_array_equal(
            _dense(res.cs[i]), np.asarray(spgemm_dense(mats[i], b)))


def test_unknown_pipeline_rejected():
    a, b = _fixture()
    with pytest.raises(ValueError, match="unknown pipeline"):
        spgemm(a, b, pipeline="three_wave")


# ---------------------------------------------------------------------------
# Fused engine: zero blocking syncs under plan-derived sizing
# ---------------------------------------------------------------------------

def test_fused_two_wave_multichunk_zero_host_syncs():
    """The PR-5 acceptance bar: a fused two-wave multi-chunk call performs
    **zero** blocking host syncs — out_cap comes from the plan's Alg. 1
    bounds and the indptr is assembled on device."""
    a, b = _fixture()
    executor.clear_program_cache()
    res, syncs = _sync_delta(
        lambda: spgemm(a, b, engine="fused_hash", row_chunk=8))
    assert _n_work_items(res, a, 8) > 1, "fixture must be multi-chunk"
    assert syncs == 0, f"fused two-wave paid {syncs} host syncs"
    np.testing.assert_array_equal(_dense(res.c), np.asarray(spgemm_dense(a, b)))


def test_fused_batched_zero_host_syncs():
    rng = np.random.default_rng(41)
    pat = rng.random((40, 30)) < 0.25
    mats = [csr_from_dense(np.where(
        pat, rng.integers(1, 5, pat.shape), 0.0).astype(np.float32))
        for _ in range(3)]
    b = csr_from_dense(int_sparse(rng, 30, 25, 0.25))
    executor.clear_program_cache()
    res, syncs = _sync_delta(
        lambda: spgemm_batched(mats, b, engine="fused_hash", row_chunk=8))
    assert syncs == 0, f"fused batched two-wave paid {syncs} host syncs"
    for i in range(3):
        np.testing.assert_array_equal(
            _dense(res.cs[i]), np.asarray(spgemm_dense(mats[i], b)))


def test_fused_sizing_measured_syncs_once():
    """The escape hatch: sizing='measured' on the fused engine keeps the
    single coalesced uniqueCount sync (and exact capacities)."""
    a, b = _fixture()
    executor.clear_program_cache()
    res, syncs = _sync_delta(
        lambda: spgemm(a, b, engine="fused_hash", row_chunk=8,
                       sizing="measured"))
    assert _n_work_items(res, a, 8) > 1
    assert syncs == 1, f"measured escape hatch paid {syncs} syncs, wanted 1"
    np.testing.assert_array_equal(_dense(res.c), np.asarray(spgemm_dense(a, b)))


@pytest.mark.parametrize("gather", GATHERS)
def test_fused_bit_exact_vs_hash_engine(gather):
    """fused_hash is the same Algorithm 2/3/5 stream as the two-pass hash
    engine, so indptr, occupied indices, and values match bit-for-bit."""
    a, b = _fixture(seed=29)
    fu = spgemm(a, b, engine="fused_hash", gather=gather, row_chunk=8)
    ha = spgemm(a, b, engine="hash", gather=gather, row_chunk=8)
    nnz = fu.info["nnz_c"]
    assert nnz == ha.info["nnz_c"]
    np.testing.assert_array_equal(
        np.asarray(fu.c.indptr), np.asarray(ha.c.indptr))
    np.testing.assert_array_equal(
        np.asarray(fu.c.indices)[:nnz], np.asarray(ha.c.indices)[:nnz])
    np.testing.assert_array_equal(
        np.asarray(fu.c.data)[:nnz], np.asarray(ha.c.data)[:nnz])


# ---------------------------------------------------------------------------
# Device epilogue: bit-exact vs the legacy NumPy reassembly
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("engine", ENGINES)
@pytest.mark.parametrize("gather", GATHERS)
def test_device_epilogue_matches_numpy_reassembly(engine, gather):
    """Every engine × gather: the device-side scatter epilogue reproduces
    the legacy host-side reassembly bit-for-bit (indptr, occupied indices
    and values; the epilogue's capacity is pow2-quantized so only the
    padding tail may differ)."""
    a, b = _fixture(seed=11)
    tw = spgemm(a, b, engine=engine, gather=gather, row_chunk=8)
    lg = spgemm(a, b, engine=engine, gather=gather, row_chunk=8,
                pipeline="legacy")
    nnz = tw.info["nnz_c"]
    assert nnz == lg.info["nnz_c"]
    np.testing.assert_array_equal(
        np.asarray(tw.c.indptr), np.asarray(lg.c.indptr))
    np.testing.assert_array_equal(
        np.asarray(tw.c.indices)[:nnz], np.asarray(lg.c.indices)[:nnz])
    np.testing.assert_array_equal(
        np.asarray(tw.c.data)[:nnz], np.asarray(lg.c.data)[:nnz])
    np.testing.assert_array_equal(_dense(tw.c), np.asarray(spgemm_dense(a, b)))


@pytest.mark.parametrize("engine", ENGINES)
def test_device_epilogue_batched_matches_legacy(engine):
    rng = np.random.default_rng(13)
    pat_a = rng.random((18, 14)) < 0.3
    pat_b = rng.random((14, 16)) < 0.35
    def members(pat, k):
        return [csr_from_dense(np.where(
            pat, rng.integers(1, 5, pat.shape), 0.0).astype(np.float32))
            for _ in range(k)]
    a_mats, b_mats = members(pat_a, 3), members(pat_b, 3)
    tw = spgemm_batched(a_mats, b_mats, engine=engine, row_chunk=8)
    lg = spgemm_batched(a_mats, b_mats, engine=engine, row_chunk=8,
                        pipeline="legacy")
    nnz = tw.info["nnz_c"]
    for i in range(3):
        np.testing.assert_array_equal(
            np.asarray(tw.cs[i].indptr), np.asarray(lg.cs[i].indptr))
        np.testing.assert_array_equal(
            np.asarray(tw.cs[i].indices)[:nnz],
            np.asarray(lg.cs[i].indices)[:nnz])
        np.testing.assert_array_equal(
            np.asarray(tw.cs[i].data)[:nnz], np.asarray(lg.cs[i].data)[:nnz])


def test_epilogue_emits_int32_throughout():
    """No silent downcast at materialization: the CSR leaves the executor
    already int32 (indptr *and* indices), values in the input dtype."""
    a, b = _fixture(seed=17)
    res = spgemm(a, b, engine="sort")
    assert res.c.indptr.dtype == jnp.int32
    assert res.c.indices.dtype == jnp.int32
    assert res.c.data.dtype == jnp.float32


def test_int32_overflow_guard():
    """nnz beyond int32 must raise, not wrap; the pow2 quantum falls back
    to the exact capacity when the quantum alone would overflow."""
    with pytest.raises(OverflowError, match="int32"):
        executor._int32_nnz_capacity(2**31)
    assert executor._int32_nnz_capacity(0) == 1
    assert executor._int32_nnz_capacity(1000) == 1024
    # 2^30 quantizes to itself; 2^30+1 would quantize to 2^31 (> int32max)
    # and falls back to the exact nnz instead of downcasting.
    assert executor._int32_nnz_capacity(2**30) == 2**30
    assert executor._int32_nnz_capacity(2**30 + 1) == 2**30 + 1


# ---------------------------------------------------------------------------
# OperandCache: B replicas shared across batched/iterative calls
# ---------------------------------------------------------------------------

def test_operand_cache_zero_rereplication_across_batched_calls():
    """Two batched calls against the same B object: the second must serve
    B's replicated ELL buffers from the OperandCache (operand_misses
    unchanged = zero buffers re-replicated)."""
    rng = np.random.default_rng(23)
    pat = rng.random((20, 20)) < 0.25
    def member():
        return csr_from_dense(np.where(
            pat, rng.integers(1, 5, (20, 20)), 0.0).astype(np.float32))
    b = csr_from_dense(int_sparse(rng, 20, 18, 0.3))
    executor.clear_program_cache()
    spgemm_batched([member(), member()], b, engine="sort")
    s1 = executor.cache_stats()
    assert s1["operand_misses"] == 1 and s1["operand_hits"] == 0
    spgemm_batched([member(), member()], b, engine="sort")
    s2 = executor.cache_stats()
    assert s2["operand_misses"] == s1["operand_misses"], (
        "second batched call re-replicated B's ELL buffers")
    assert s2["operand_hits"] == s1["operand_hits"] + 1


def test_operand_cache_hits_iterative_single_matrix_calls():
    """MCL-at-fixpoint shape: same B object re-multiplied with fresh A
    values — every call after the first is an operand-cache hit, and a
    *different* B object (same contents) is a miss (identity-keyed)."""
    rng = np.random.default_rng(24)
    xb = int_sparse(rng, 16, 14, 0.3)
    b = csr_from_dense(xb)
    executor.clear_program_cache()
    for _ in range(3):
        a = csr_from_dense(int_sparse(rng, 12, 16, 0.3))
        spgemm(a, b, engine="sort")
    stats = executor.cache_stats()
    assert stats["operand_misses"] == 1 and stats["operand_hits"] == 2
    spgemm(csr_from_dense(int_sparse(rng, 12, 16, 0.3)),
           csr_from_dense(xb), engine="sort")  # new B object → miss
    assert executor.cache_stats()["operand_misses"] == 2


def test_operand_cache_never_serves_mutable_numpy_backed_b():
    """Identity keying is only sound for immutable arrays: a CSR backed by
    plain NumPy buffers must bypass the cache, so an in-place edit of B
    between calls is honored instead of served stale."""
    rng = np.random.default_rng(26)
    xa = int_sparse(rng, 12, 16, 0.3)
    xb = int_sparse(rng, 16, 14, 0.3)
    from repro.sparse.formats import CSR
    b_np = csr_from_dense(xb)
    b_np = CSR(np.asarray(b_np.indptr), np.asarray(b_np.indices),
               np.asarray(b_np.data).copy(), b_np.shape)
    a = csr_from_dense(xa)
    executor.clear_program_cache()
    r1 = spgemm(a, b_np, engine="sort")
    b_np.data[:] *= 2.0  # in-place mutation of the NumPy-backed operand
    r2 = spgemm(a, b_np, engine="sort")
    assert executor.cache_stats()["operand_hits"] == 0, (
        "mutable NumPy-backed B must never be cache-served")
    np.testing.assert_array_equal(_dense(r2.c), 2.0 * _dense(r1.c))


def test_operand_cache_lru_bound_and_clear():
    rng = np.random.default_rng(25)
    cache = executor.OperandCache(max_entries=2)
    mats = [csr_from_dense(int_sparse(rng, 10, 10, 0.4)) for _ in range(3)]
    for m in mats:
        cache.b_operands(m, 4, [None])
    assert len(cache) == 2
    cache.clear()
    assert len(cache) == 0


# ---------------------------------------------------------------------------
# Subprocess: epilogue bit-exactness under 1/2/4-device meshes
# ---------------------------------------------------------------------------

PIPELINE_MESH_BODY = """
import jax, numpy as np
from repro.core import executor
from repro.core.spgemm import spgemm
from repro.core.ref import spgemm_dense
from repro.launch.mesh import make_spgemm_mesh
from repro.sparse.formats import csr_from_dense, csr_to_dense

n_dev = {n_devices}
assert len(jax.devices()) == n_dev, jax.devices()
rng = np.random.default_rng(19)
def sp(n, m, d):
    x = rng.integers(-4, 5, (n, m)).astype(np.float32)
    return np.where(rng.random((n, m)) < d, x, 0.0).astype(np.float32)
a = csr_from_dense(sp(64, 48, 0.22))
b = csr_from_dense(sp(48, 52, 0.28))
oracle = np.asarray(spgemm_dense(a, b))
mesh = make_spgemm_mesh(n_dev)
for engine in ("sort", "hash", "fused_hash"):
    for gather in ("xla", "aia"):
        tw = spgemm(a, b, engine=engine, gather=gather, mesh=mesh,
                    row_chunk=16)
        lg = spgemm(a, b, engine=engine, gather=gather, mesh=mesh,
                    row_chunk=16, pipeline="legacy")
        nnz = tw.info["nnz_c"]
        assert nnz == lg.info["nnz_c"]
        np.testing.assert_array_equal(np.asarray(tw.c.indptr),
                                      np.asarray(lg.c.indptr))
        np.testing.assert_array_equal(np.asarray(tw.c.indices)[:nnz],
                                      np.asarray(lg.c.indices)[:nnz])
        np.testing.assert_array_equal(np.asarray(tw.c.data)[:nnz],
                                      np.asarray(lg.c.data)[:nnz])
        np.testing.assert_array_equal(np.asarray(csr_to_dense(tw.c)), oracle)
        print("EPI OK", engine, gather, n_dev)
# and the sync budget holds under the mesh: one coalesced sync per call
executor.clear_program_cache()
spgemm(a, b, engine="sort", mesh=mesh, row_chunk=16)  # warm
s0 = executor.cache_stats()["host_sync_count"]
spgemm(a, b, engine="sort", mesh=mesh, row_chunk=16)
assert executor.cache_stats()["host_sync_count"] - s0 == 1
print("SYNC OK", n_dev)
# fused zero-sync budget under the mesh (sharded epilogue included)
spgemm(a, b, engine="fused_hash", mesh=mesh, row_chunk=16)  # warm
s0 = executor.cache_stats()["host_sync_count"]
spgemm(a, b, engine="fused_hash", mesh=mesh, row_chunk=16)
assert executor.cache_stats()["host_sync_count"] - s0 == 0
print("FUSED SYNC OK", n_dev)
"""


EMPTY_SHARD_BODY = """
import jax, numpy as np
from repro.core.spgemm import spgemm
from repro.launch.mesh import make_spgemm_mesh
from repro.sparse.formats import csr_from_dense, csr_to_dense

assert len(jax.devices()) == 2, jax.devices()
rng = np.random.default_rng(3)
a = csr_from_dense(np.where(rng.random((24, 16)) < 0.4,
                            1.0, 0.0).astype(np.float32))
b = csr_from_dense(np.zeros((16, 12), np.float32))  # empty product
mesh = make_spgemm_mesh(2)
for engine in ("sort", "hash", "fused_hash"):
    res = spgemm(a, b, engine=engine, mesh=mesh, row_chunk=8)
    assert res.info["nnz_c"] == 0, (engine, res.info["nnz_c"])
    assert np.asarray(csr_to_dense(res.c)).sum() == 0
    print("EMPTY OK", engine)
"""


def test_zero_nnz_shards_under_mesh():
    """Every shard's segment capacity is 0 when the product is empty; the
    sharded epilogue must skip those shards instead of KeyError-ing."""
    out = run_py(EMPTY_SHARD_BODY, n_devices=2)
    assert out.count("EMPTY OK") == 3


@pytest.mark.parametrize("n_devices", (1, 2, 4))
def test_device_epilogue_bit_exact_under_mesh(n_devices):
    """1/2/4 forced host devices: the (sharded) device epilogue == legacy
    NumPy reassembly == dense oracle for every engine × gather combination,
    the sharded two-wave call still pays exactly one allocate sync, and the
    fused call pays zero."""
    out = run_py(PIPELINE_MESH_BODY.format(n_devices=n_devices),
                 n_devices=n_devices)
    assert out.count("EPI OK") == 6
    assert "SYNC OK" in out
    assert "FUSED SYNC OK" in out
