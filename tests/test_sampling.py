"""Matrix-based bulk sampling (paper §V-C): SpGEMM-expressed sampling."""
import numpy as np
import pytest

from repro.apps.graphs import rmat_graph
from repro.apps.sampling import (
    bulk_sample, extract, norm_rows, sample_rows, selection_matrix,
)
from repro.core.spgemm import spgemm
from repro.sparse.formats import csr_to_dense


def test_selection_matrix_extracts_rows():
    g = rmat_graph(64, 4.0, seed=0)
    rows = np.asarray([3, 10, 17])
    r = selection_matrix(rows, 64)
    got = np.asarray(csr_to_dense(spgemm(r, g, method="sort").c))
    expect = np.asarray(csr_to_dense(g))[rows]
    np.testing.assert_allclose(got, expect, rtol=1e-5)


def test_extract_submatrix_matches_dense():
    g = rmat_graph(48, 5.0, seed=1)
    rows = np.asarray([1, 5, 9])
    cols = np.asarray([0, 2, 5, 9, 30])
    sub = extract(g, rows, cols)
    expect = np.asarray(csr_to_dense(g))[np.ix_(rows, cols)]
    np.testing.assert_allclose(np.asarray(csr_to_dense(sub)), expect,
                               rtol=1e-5)


def test_norm_rows_stochastic():
    g = rmat_graph(32, 4.0, seed=2)
    q = selection_matrix(np.asarray([0, 4, 8]), 32)
    p = norm_rows(spgemm(q, g, method="sort").c)
    d = np.asarray(csr_to_dense(p))
    sums = d.sum(axis=1)
    for s in sums:
        assert s == pytest.approx(1.0, abs=1e-5) or s == pytest.approx(0.0)


def test_sample_rows_subset_and_deterministic():
    g = rmat_graph(64, 8.0, seed=3)
    q = selection_matrix(np.asarray([2, 7]), 64)
    p = norm_rows(spgemm(q, g, method="sort").c)
    s1 = sample_rows(p, 3, np.random.default_rng(0))
    s2 = sample_rows(p, 3, np.random.default_rng(0))
    np.testing.assert_array_equal(s1, s2)  # deterministic per seed
    dense = np.asarray(csr_to_dense(p))
    support = set(np.nonzero(dense.sum(0))[0].tolist())
    assert set(s1.tolist()) <= support  # sampled ⊆ neighbors


def test_bulk_sample_plan_cache_hits_on_repeat():
    """Epoch-revisited mini-batches: the second identical call's SpGEMM
    chain must be served from the PlanCache (same patterns throughout)."""
    from repro.core.spgemm import PlanCache

    g = rmat_graph(96, 5.0, seed=5)
    batch = np.asarray([1, 4, 9])
    cache = PlanCache()
    a0, f0 = bulk_sample(g, batch, fanout=2, n_layers=2, seed=3,
                         plan_cache=cache)
    misses_after_first = cache.misses
    hits_after_first = cache.hits  # P = Q·A and extract's R·A share a
    assert misses_after_first > 0  # pattern, so intra-call hits are fine
    a1, f1 = bulk_sample(g, batch, fanout=2, n_layers=2, seed=3,
                         plan_cache=cache)
    assert cache.misses == misses_after_first, "repeat call re-planned"
    assert cache.hits == 2 * hits_after_first + misses_after_first
    # cache must not change results
    for u, v in zip(f0, f1):
        np.testing.assert_array_equal(u, v)
    for u, v in zip(a0, a1):
        np.testing.assert_array_equal(
            np.asarray(csr_to_dense(u)), np.asarray(csr_to_dense(v)))


def test_bulk_sample_weight_ensemble_identity():
    """An ensemble of identical weight copies must reproduce the
    single-matrix path exactly (mean of 2 equal floats is exact), while
    routing the probability step through the batched executor."""
    from repro.core import executor

    g = rmat_graph(96, 5.0, seed=6)
    batch = np.asarray([0, 2, 5, 7])
    nnz = int(np.asarray(g.indptr)[-1])
    ws = np.stack([np.asarray(g.data)[:nnz]] * 2)
    a0, f0 = bulk_sample(g, batch, fanout=2, n_layers=2, seed=1)
    executor.clear_program_cache()
    a1, f1 = bulk_sample(g, batch, fanout=2, n_layers=2, seed=1,
                         weight_sets=ws)
    for u, v in zip(f0, f1):
        np.testing.assert_array_equal(u, v)
    for u, v in zip(a0, a1):
        np.testing.assert_array_equal(
            np.asarray(csr_to_dense(u)), np.asarray(csr_to_dense(v)))


def test_bulk_sample_weight_ensemble_reweights_probabilities():
    """A member with zeroed weights halves the averaged distribution's
    support contribution; the call must still produce valid frontiers."""
    g = rmat_graph(64, 4.0, seed=7)
    nnz = int(np.asarray(g.indptr)[-1])
    base = np.asarray(g.data)[:nnz]
    ws = np.stack([base, base * 3.0])
    adjs, frontiers = bulk_sample(g, np.asarray([0, 1]), fanout=2,
                                  n_layers=1, seed=2, weight_sets=ws)
    assert len(adjs) == 1 and len(frontiers) == 2
    g_dense = np.asarray(csr_to_dense(g))
    np.testing.assert_allclose(
        np.asarray(csr_to_dense(adjs[0])),
        g_dense[np.ix_(frontiers[0], frontiers[1])], rtol=1e-5)


def test_bulk_sample_weight_sets_shape_validated():
    g = rmat_graph(32, 3.0, seed=8)
    with pytest.raises(ValueError, match="weight_sets"):
        bulk_sample(g, np.asarray([0]), fanout=2, n_layers=1,
                    weight_sets=np.ones((2, 3), np.float32))


def test_bulk_sample_chain():
    g = rmat_graph(128, 6.0, seed=4)
    batch = np.asarray([0, 1, 2, 3])
    adjs, frontiers = bulk_sample(g, batch, fanout=2, n_layers=2, seed=0)
    assert len(adjs) == 2 and len(frontiers) == 3
    # frontiers grow monotonically and contain the batch
    assert set(batch.tolist()) <= set(frontiers[1].tolist())
    assert set(frontiers[1].tolist()) <= set(frontiers[2].tolist())
    # each A^l has shape (|Q^l|, |Q^{l+1}|) and is a true submatrix of A
    g_dense = np.asarray(csr_to_dense(g))
    for l, adj in enumerate(adjs):
        q_rows, q_cols = frontiers[l], frontiers[l + 1]
        assert adj.shape == (len(q_rows), len(q_cols))
        np.testing.assert_allclose(
            np.asarray(csr_to_dense(adj)),
            g_dense[np.ix_(q_rows, q_cols)], rtol=1e-5)
