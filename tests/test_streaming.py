"""Out-of-core streamed SpGEMM (row-block tiling).

Coverage mirrors the lane's contract:

* **Bit-exactness grid** — streamed output must be *bit-identical* to the
  monolithic ``spgemm`` for every engine × gather × pipeline combination
  (in-process, 1 device) and on forced 2/4-device host meshes
  (subprocess, same harness as ``test_sharded_executor``).
* **Tile-boundary edges** — empty tiles (all-zero row blocks), the
  ``tile_rows >= n_rows`` collapse to a single tile, and a ragged last
  tile all merge correctly.
* **Plan reuse** — repeated calls through one ``PlanCache`` hit for every
  tile (tile fingerprints are stable), the property MCL/GNN iteration
  loops rely on.
* **Knob validation** — ``resolve_tile_rows`` / ``resolve_prefetch``
  reject non-positive / non-int values up front.
* **Device budget** — ``set_device_budget`` makes the monolithic lane
  raise ``DeviceBudgetExceeded`` while the streamed lane (whose per-tile
  estimate fits) completes bit-exactly; the over-memory MCL acceptance
  run clusters a graph the monolithic expansion cannot allocate.
* **Counters** — ``tiles_streamed`` / ``tile_bytes_h2d`` /
  ``prefetch_overlap_hits`` semantics, including zero overlap at
  ``prefetch=1``.
"""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.core import executor
from repro.core.ref import spgemm_dense
from repro.core.spgemm import PlanCache, spgemm, spgemm_streamed
from repro.sparse.formats import csr_from_dense, csr_to_dense

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_py(body: str, n_devices: int = 4, timeout: int = 900):
    """Run ``body`` in a subprocess with a forced host device count."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    script = "import os\n" + textwrap.dedent(body)
    proc = subprocess.run([sys.executable, "-c", script], env=env,
                          capture_output=True, text=True, timeout=timeout)
    assert proc.returncode == 0, f"STDOUT:\n{proc.stdout}\nSTDERR:\n{proc.stderr}"
    return proc.stdout


def int_sparse(rng, n, m, density=0.3):
    """Small-integer sparse block — float32-exact products."""
    x = rng.integers(-4, 5, (n, m)).astype(np.float32)
    mask = rng.random((n, m)) < density
    return np.where(mask, x, 0.0).astype(np.float32)


def _pair(seed=7, n=150, k=64, m=90, density=0.25):
    rng = np.random.default_rng(seed)
    a = csr_from_dense(int_sparse(rng, n, k, density))
    b = csr_from_dense(int_sparse(rng, k, m, density))
    return a, b


def assert_bit_exact(c_stream, c_mono):
    """The streamed contract: identical occupied buffers, not just values.

    The monolithic lane may return capacity-padded ``indices``/``data``
    (sentinels past ``nnz``); the contract covers the ``indptr``-addressed
    prefix, which is every bit a consumer can observe.
    """
    ipt_s = np.asarray(c_stream.indptr)
    ipt_m = np.asarray(c_mono.indptr)
    np.testing.assert_array_equal(ipt_s, ipt_m)
    nnz = int(ipt_m[-1])
    np.testing.assert_array_equal(np.asarray(c_stream.indices)[:nnz],
                                  np.asarray(c_mono.indices)[:nnz])
    np.testing.assert_array_equal(np.asarray(c_stream.data)[:nnz],
                                  np.asarray(c_mono.data)[:nnz])


# ---------------------------------------------------------------------------
# bit-exactness grid (in-process, 1 device)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("engine", ["sort", "hash", "fused_hash"])
@pytest.mark.parametrize("pipeline", ["two_wave", "legacy"])
def test_streamed_bit_exact_engine_pipeline(engine, pipeline):
    a, b = _pair()
    mono = spgemm(a, b, engine=engine, pipeline=pipeline)
    res = spgemm_streamed(a, b, tile_rows=48, engine=engine,
                          pipeline=pipeline)
    assert_bit_exact(res.c, mono.c)
    assert res.info["n_tiles"] == 4  # ceil(150 / 48)
    np.testing.assert_allclose(
        np.asarray(csr_to_dense(res.c)), np.asarray(spgemm_dense(a, b)))


@pytest.mark.parametrize("gather", ["xla", "aia"])
def test_streamed_bit_exact_gather(gather):
    a, b = _pair(seed=11)
    mono = spgemm(a, b, gather=gather)
    res = spgemm_streamed(a, b, tile_rows=40, gather=gather)
    assert_bit_exact(res.c, mono.c)


def test_streamed_natural_schedule_matches():
    a, b = _pair(seed=3)
    mono = spgemm(a, b, schedule="natural")
    res = spgemm_streamed(a, b, tile_rows=64, schedule="natural")
    assert_bit_exact(res.c, mono.c)


# ---------------------------------------------------------------------------
# bit-exactness under forced multi-device meshes (subprocess)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n_devices", [2, 4])
def test_streamed_bit_exact_under_mesh(n_devices):
    run_py(f"""
        import numpy as np
        from repro.core.spgemm import spgemm, spgemm_streamed
        from repro.launch.mesh import make_spgemm_mesh
        from repro.sparse.formats import csr_from_dense

        rng = np.random.default_rng(5)
        def sp(n, m):
            x = rng.integers(-4, 5, (n, m)).astype(np.float32)
            return np.where(rng.random((n, m)) < 0.25, x, 0.0).astype(np.float32)

        a = csr_from_dense(sp(160, 64))
        b = csr_from_dense(sp(64, 96))
        mesh = make_spgemm_mesh({n_devices})
        mono = spgemm(a, b, mesh=mesh)
        res = spgemm_streamed(a, b, tile_rows=48, mesh=mesh)
        ipt = np.asarray(mono.c.indptr)
        np.testing.assert_array_equal(np.asarray(res.c.indptr), ipt)
        nnz = int(ipt[-1])
        np.testing.assert_array_equal(np.asarray(res.c.indices)[:nnz],
                                      np.asarray(mono.c.indices)[:nnz])
        np.testing.assert_array_equal(np.asarray(res.c.data)[:nnz],
                                      np.asarray(mono.c.data)[:nnz])
        print("OK", res.info["n_tiles"])
    """, n_devices=n_devices)


# ---------------------------------------------------------------------------
# tile-boundary edges
# ---------------------------------------------------------------------------

def test_tile_ranges_shapes():
    assert executor.tile_ranges(10, 4) == [(0, 4), (4, 8), (8, 10)]
    assert executor.tile_ranges(8, 4) == [(0, 4), (4, 8)]
    assert executor.tile_ranges(3, 100) == [(0, 3)]
    assert executor.tile_ranges(0, 4) == []


def test_tile_rows_ge_n_rows_collapses_to_single_tile():
    a, b = _pair(seed=9, n=60)
    mono = spgemm(a, b)
    res = spgemm_streamed(a, b, tile_rows=4096)
    assert res.info["n_tiles"] == 1
    assert_bit_exact(res.c, mono.c)


def test_empty_tiles_merge_correctly():
    # Rows 40..119 all-zero: the middle tiles plan to total_ip == 0 and
    # must contribute empty segments without dispatching any program.
    rng = np.random.default_rng(21)
    dense = int_sparse(rng, 160, 64, 0.3)
    dense[40:120] = 0.0
    a = csr_from_dense(dense)
    b = csr_from_dense(int_sparse(rng, 64, 80, 0.3))
    mono = spgemm(a, b)
    res = spgemm_streamed(a, b, tile_rows=40)
    assert res.info["n_tiles"] == 4
    assert_bit_exact(res.c, mono.c)


def test_ragged_last_tile():
    a, b = _pair(seed=13, n=200)
    mono = spgemm(a, b)
    res = spgemm_streamed(a, b, tile_rows=64)  # 64+64+64+8
    assert res.info["n_tiles"] == 4
    assert_bit_exact(res.c, mono.c)


# ---------------------------------------------------------------------------
# plan reuse across repeated tiles
# ---------------------------------------------------------------------------

def test_plan_cache_hits_across_repeated_streams():
    a, b = _pair(seed=17)
    cache = PlanCache()
    spgemm_streamed(a, b, tile_rows=48, plan=cache)
    n_tiles = 4
    assert cache.hits == 0
    assert cache.misses == n_tiles
    spgemm_streamed(a, b, tile_rows=48, plan=cache)
    assert cache.hits == n_tiles  # every tile fingerprint re-served
    assert cache.misses == n_tiles


def test_streamed_rejects_non_plancache_plan():
    a, b = _pair(seed=2, n=40)
    with pytest.raises(TypeError):
        spgemm_streamed(a, b, tile_rows=16, plan=object())


# ---------------------------------------------------------------------------
# knob validation
# ---------------------------------------------------------------------------

def test_resolve_tile_rows():
    assert executor.resolve_tile_rows(None) == executor.DEFAULT_TILE_ROWS
    assert executor.resolve_tile_rows(128) == 128
    for bad in (0, -1, 1.5, "64", True):
        with pytest.raises(ValueError):
            executor.resolve_tile_rows(bad)


def test_resolve_prefetch():
    assert executor.resolve_prefetch(None) == executor.DEFAULT_PREFETCH
    assert executor.resolve_prefetch(1) == 1
    for bad in (0, -3, 2.0, "2", False):
        with pytest.raises(ValueError):
            executor.resolve_prefetch(bad)


def test_spgemm_streamed_validates_knobs_up_front():
    a, b = _pair(seed=2, n=40)
    with pytest.raises(ValueError):
        spgemm_streamed(a, b, tile_rows=0)
    with pytest.raises(ValueError):
        spgemm_streamed(a, b, prefetch=0)


# ---------------------------------------------------------------------------
# device budget: the out-of-core acceptance bar
# ---------------------------------------------------------------------------

@pytest.fixture
def budget_guard():
    yield
    executor.set_device_budget(None)


def test_estimated_device_bytes_formula():
    a, b = _pair(seed=23, n=50)
    from repro.core.grouping import group_rows
    plan = group_rows(a, b)
    assert executor.estimated_device_bytes(plan, 4) == plan.total_ip * 8


def test_budget_rejects_monolithic_but_streamed_fits(budget_guard):
    a, b = _pair(seed=29)
    mono = spgemm(a, b)  # unbudgeted reference
    from repro.core.grouping import group_rows
    whole_ip = int(group_rows(a, b).total_ip)
    # Measure the largest single tile's demand with an unbudgeted stream,
    # then pick a budget between it and the whole product's demand.
    res_free = spgemm_streamed(a, b, tile_rows=16)
    max_tile_ip = int(res_free.info["max_tile_ip"])
    budget = (max_tile_ip * 8) + ((whole_ip * 8 - max_tile_ip * 8) // 2)
    assert max_tile_ip * 8 < budget < whole_ip * 8
    executor.set_device_budget(budget)
    assert executor.device_budget() == budget
    with pytest.raises(executor.DeviceBudgetExceeded):
        spgemm(a, b)
    res = spgemm_streamed(a, b, tile_rows=16)
    assert_bit_exact(res.c, mono.c)
    executor.set_device_budget(None)
    assert executor.device_budget() is None


def test_over_memory_mcl_completes_bit_exactly(budget_guard):
    """The issue's acceptance bar: a graph whose monolithic expansion
    exceeds the device budget still clusters end to end, bit-exactly."""
    from repro.apps.graphs import rmat_graph
    from repro.apps.markov_clustering import mcl

    g = rmat_graph(128, 8.0, seed=4)
    ref = mcl(g, max_iters=4)
    # Find the densest expansion's demand and the tightest tile demand.
    free = mcl(g, max_iters=4, stream=16)
    whole_ip = max(int(i["intermediate_products"]) for i in ref.spgemm_info)
    max_tile_ip = max(int(i["max_tile_ip"]) for i in free.spgemm_info)
    assert max_tile_ip * 8 < whole_ip * 8  # streaming actually shrinks it
    budget = (max_tile_ip * 8 + whole_ip * 8) // 2
    executor.set_device_budget(budget)
    with pytest.raises(executor.DeviceBudgetExceeded):
        mcl(g, max_iters=4)
    res = mcl(g, max_iters=4, stream=16)
    np.testing.assert_array_equal(res.clusters, ref.clusters)
    assert_bit_exact(res.matrix, ref.matrix)
    assert res.n_iterations == ref.n_iterations
    # Every expansion streamed in 8 row-block tiles of 16 rows.
    assert all(int(i["n_tiles"]) == 8 for i in res.spgemm_info)


# ---------------------------------------------------------------------------
# counters
# ---------------------------------------------------------------------------

def test_stream_counters(budget_guard):
    a, b = _pair(seed=31)
    executor.clear_program_cache()
    before = executor.cache_stats()
    assert before["tiles_streamed"] == 0
    assert before["tile_bytes_h2d"] == 0
    assert before["prefetch_overlap_hits"] == 0
    spgemm_streamed(a, b, tile_rows=48, prefetch=2)
    after = executor.cache_stats()
    assert after["tiles_streamed"] == 4
    # Every tile after the first was staged while a prior tile computed.
    assert after["prefetch_overlap_hits"] == 3
    nnz = int(np.asarray(a.indptr)[-1])
    # indptr slices + indices + data for all tiles, at least.
    assert after["tile_bytes_h2d"] >= nnz * 8
    executor.clear_program_cache()
    assert executor.cache_stats()["tiles_streamed"] == 0


def test_prefetch_one_has_no_overlap():
    a, b = _pair(seed=37)
    executor.clear_program_cache()
    spgemm_streamed(a, b, tile_rows=48, prefetch=1)
    stats = executor.cache_stats()
    assert stats["tiles_streamed"] == 4
    assert stats["prefetch_overlap_hits"] == 0
