"""Chunked parallel WKV ≡ per-token recurrence (the TPU-native RWKV6 form)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.models.rwkv6 import rwkv6_init, rwkv6_time_mix, _wkv_chunk, _wkv_chunked


def _rand_inputs(rng, b, s, h, p):
    r = jnp.asarray(rng.standard_normal((b, s, h, p)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, s, h, p)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, s, h, p)), jnp.float32)
    w = jnp.asarray(rng.uniform(0.2, 0.99, (b, s, h, p)), jnp.float32)
    u = jnp.asarray(rng.standard_normal((h, p)), jnp.float32)
    s0 = jnp.asarray(rng.standard_normal((b, h, p, p)), jnp.float32) * 0.1
    return r, k, v, w, u, s0


@pytest.mark.parametrize("chunk", [2, 4, 8, 16])
@pytest.mark.parametrize("b,s,h,p", [(2, 16, 2, 8), (1, 32, 4, 4)])
def test_chunked_wkv_matches_recurrent(chunk, b, s, h, p):
    rng = np.random.default_rng(0)
    r, k, v, w, u, s0 = _rand_inputs(rng, b, s, h, p)
    out_rec, s_rec = _wkv_chunk(r, k, v, w, u, s0)
    out_chk, s_chk = _wkv_chunked(r, k, v, w, u, s0, chunk)
    np.testing.assert_allclose(np.asarray(out_chk), np.asarray(out_rec),
                               rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(s_chk), np.asarray(s_rec),
                               rtol=1e-4, atol=1e-5)


def test_chunked_wkv_unrolled_matches():
    rng = np.random.default_rng(1)
    r, k, v, w, u, s0 = _rand_inputs(rng, 1, 16, 2, 4)
    a, sa = _wkv_chunked(r, k, v, w, u, s0, 4, unroll=False)
    b_, sb = _wkv_chunked(r, k, v, w, u, s0, 4, unroll=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b_), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(sa), np.asarray(sb), rtol=1e-6)


def test_time_mix_chunk_flag_equivalence():
    rng = np.random.default_rng(2)
    d, h = 32, 4
    params = rwkv6_init(jax.random.PRNGKey(0), d, 64, h, jnp.float32)
    x = jnp.asarray(rng.standard_normal((2, 16, d)), jnp.float32)
    o1, s1, _ = rwkv6_time_mix(params, x, n_heads=h, chunk=0)
    o2, s2, _ = rwkv6_time_mix(params, x, n_heads=h, chunk=4)
    np.testing.assert_allclose(np.asarray(o2), np.asarray(o1), rtol=2e-4,
                               atol=1e-5)
    np.testing.assert_allclose(np.asarray(s2), np.asarray(s1), rtol=2e-4,
                               atol=1e-5)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**16), chunk=st.sampled_from([2, 4, 8]))
def test_property_chunked_wkv(seed, chunk):
    rng = np.random.default_rng(seed)
    r, k, v, w, u, s0 = _rand_inputs(rng, 1, 8, 2, 4)
    out_rec, s_rec = _wkv_chunk(r, k, v, w, u, s0)
    out_chk, s_chk = _wkv_chunked(r, k, v, w, u, s0, chunk)
    np.testing.assert_allclose(np.asarray(out_chk), np.asarray(out_rec),
                               rtol=1e-3, atol=1e-4)
