"""Per-kernel shape/dtype sweeps: Pallas (interpret=True) vs ref.py oracles."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels import aia_gather as aia_k
from repro.kernels import spgemm_bsr as bsr_k
from repro.kernels import topk_spmm as topk_k


# ---------------------------------------------------------------------------
# aia_ranged_gather
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("n_blocks,r,d,n_idx", [
    (8, 1, 128, 16), (8, 2, 128, 5), (16, 4, 256, 32), (4, 8, 8, 3),
])
def test_aia_ranged_gather_sweep(dtype, n_blocks, r, d, n_idx):
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((n_blocks * r, d)), dtype)
    idx = jnp.asarray(rng.integers(0, n_blocks, n_idx), jnp.int32)
    got = aia_k.aia_ranged_gather(x, idx, r, interpret=True)
    expect = ref.aia_ranged_gather(x, idx, r)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(expect))


def test_aia_gather_repeated_and_boundary_indices():
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.standard_normal((32, 64)), jnp.float32)
    idx = jnp.asarray([0, 31, 31, 0, 15], jnp.int32)
    got = aia_k.aia_ranged_gather(x, idx, 1, interpret=True)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(x)[[0, 31, 31, 0, 15]])


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_gather_rows_manual_dma(dtype):
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.standard_normal((40, 128)), dtype)
    idx = jnp.asarray(rng.integers(0, 40, 24), jnp.int32)
    got = aia_k.gather_rows(x, idx, rows_per_block=8, interpret=True)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(x)[np.asarray(idx)])


# ---------------------------------------------------------------------------
# bsr_spmm
# ---------------------------------------------------------------------------

def _random_bsr(rng, n_brows, n_bcols, bs, avg_blocks):
    rows = [sorted(rng.choice(
        n_bcols, size=min(n_bcols, 1 + rng.integers(0, 2 * avg_blocks)),
        replace=False).tolist()) for _ in range(n_brows)]
    rowptr = np.concatenate([[0], np.cumsum([len(r) for r in rows])]).astype(np.int32)
    colidx = np.concatenate(rows).astype(np.int32)
    blocks = rng.standard_normal((len(colidx), bs, bs)).astype(np.float32)
    return rowptr, colidx, blocks


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("n_brows,n_bcols,bs,d", [
    (4, 6, 8, 16), (8, 8, 16, 32), (3, 10, 8, 128), (1, 2, 8, 8),
])
def test_bsr_spmm_sweep(dtype, n_brows, n_bcols, bs, d):
    rng = np.random.default_rng(3)
    rowptr, colidx, blocks = _random_bsr(rng, n_brows, n_bcols, bs, 2)
    b = rng.standard_normal((n_bcols * bs, d)).astype(np.float32)
    max_bpr = int((rowptr[1:] - rowptr[:-1]).max())
    got = bsr_k.bsr_spmm(
        jnp.asarray(rowptr), jnp.asarray(colidx),
        jnp.asarray(blocks, dtype), jnp.asarray(b, dtype),
        max_blocks_per_row=max_bpr, interpret=True,
    )
    expect = ref.bsr_spmm(jnp.asarray(rowptr), jnp.asarray(colidx),
                          jnp.asarray(blocks, dtype), jnp.asarray(b, dtype))
    rtol = 2e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(expect, np.float32), rtol=rtol, atol=1e-2)


def test_bsr_spmm_empty_row():
    """A block-row with zero blocks must produce zeros (ragged-tail masking)."""
    bs, d = 8, 16
    rowptr = jnp.asarray([0, 2, 2, 3], jnp.int32)  # row 1 empty
    colidx = jnp.asarray([0, 1, 1], jnp.int32)
    rng = np.random.default_rng(4)
    blocks = jnp.asarray(rng.standard_normal((3, bs, bs)), jnp.float32)
    b = jnp.asarray(rng.standard_normal((2 * bs, d)), jnp.float32)
    got = bsr_k.bsr_spmm(rowptr, colidx, blocks, b, max_blocks_per_row=2,
                         interpret=True)
    expect = ref.bsr_spmm(rowptr, colidx, blocks, b)
    np.testing.assert_allclose(np.asarray(got), np.asarray(expect), rtol=1e-5)
    assert np.abs(np.asarray(got)[bs:2 * bs]).max() == 0.0


# ---------------------------------------------------------------------------
# topk_spmm (Eq. 1)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("n,k,dff,d", [(4, 2, 16, 8), (16, 4, 64, 128), (3, 8, 32, 16)])
def test_topk_spmm_sweep(dtype, n, k, dff, d):
    rng = np.random.default_rng(5)
    vals = jnp.asarray(rng.standard_normal((n, k)), dtype)
    idx = jnp.asarray(rng.integers(0, dff, (n, k)), jnp.int32)
    w2 = jnp.asarray(rng.standard_normal((dff, d)), dtype)
    got = topk_k.topk_spmm(vals, idx, w2, interpret=True)
    expect = ref.topk_spmm(vals, idx, w2)
    rtol = 3e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(np.asarray(got), np.asarray(expect), rtol=rtol,
                               atol=1e-2)


def test_topk_spmm_duplicate_indices_accumulate():
    """Same W2 row selected twice for a token must be added twice."""
    vals = jnp.asarray([[1.0, 2.0]], jnp.float32)
    idx = jnp.asarray([[3, 3]], jnp.int32)
    w2 = jnp.asarray(np.eye(8, 4, k=-3), jnp.float32)  # row 3 -> e0
    got = topk_k.topk_spmm(vals, idx, w2, interpret=True)
    np.testing.assert_allclose(np.asarray(got), [[3.0, 0, 0, 0]])


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("n_tiles,kb,tile,block,d", [
    (2, 2, 8, 16, 32), (4, 3, 8, 128, 64), (1, 1, 8, 8, 8),
])
def test_block_topk_spmm_sweep(dtype, n_tiles, kb, tile, block, d):
    rng = np.random.default_rng(6)
    n_blocks = kb + 2
    h = jnp.asarray(rng.standard_normal((n_tiles, kb, tile, block)), dtype)
    bidx = jnp.asarray(
        np.stack([rng.choice(n_blocks, kb, replace=False) for _ in range(n_tiles)]),
        jnp.int32)
    w2 = jnp.asarray(rng.standard_normal((n_blocks * block, d)), dtype)
    got = topk_k.block_topk_spmm(h, bidx, w2, block=block, interpret=True)
    expect = ref.block_topk_spmm(h, bidx, w2, block)
    rtol = 3e-2 if dtype == jnp.bfloat16 else 1e-4
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(expect, np.float32), rtol=rtol, atol=5e-2)


# ---------------------------------------------------------------------------
# ops dispatch
# ---------------------------------------------------------------------------

def test_ops_backends_agree():
    rng = np.random.default_rng(7)
    x = jnp.asarray(rng.standard_normal((16, 32)), jnp.float32)
    idx = jnp.asarray(rng.integers(0, 16, 8), jnp.int32)
    a = ops.aia_ranged_gather(x, idx, 1, backend="xla")
    b = ops.aia_ranged_gather(x, idx, 1, backend="interpret")
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
