"""Substrate tests: optimizer, schedules, compression, checkpoint, data,
trainer fault-tolerance + straggler monitor, serving engine."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.optim import (
    adamw, sgd, apply_updates, clip_by_global_norm, global_norm,
    cosine_schedule, linear_warmup_cosine, int8_compress, int8_decompress,
)
from repro.checkpoint import (
    save_checkpoint, restore_checkpoint, latest_step, AsyncCheckpointer,
)
from repro.data.pipeline import TokenPipeline


# ---------------------------------------------------------------------------
# Optimizer
# ---------------------------------------------------------------------------

def _quad_params():
    return {"w": jnp.asarray([1.0, -2.0, 3.0]), "b": jnp.asarray(0.5)}


def test_adamw_minimizes_quadratic():
    params = _quad_params()
    opt = adamw(0.1, weight_decay=0.0)
    state = opt.init(params)
    loss = lambda p: jnp.sum(p["w"] ** 2) + p["b"] ** 2
    l0 = float(loss(params))
    for _ in range(100):
        g = jax.grad(loss)(params)
        upd, state = opt.update(g, state, params)
        params = apply_updates(params, upd)
    assert float(loss(params)) < 1e-3 * l0


def test_adamw_weight_decay_shrinks():
    params = {"w": jnp.ones(4)}
    opt = adamw(0.01, weight_decay=0.5)
    state = opt.init(params)
    zero_g = {"w": jnp.zeros(4)}
    for _ in range(10):
        upd, state = opt.update(zero_g, state, params)
        params = apply_updates(params, upd)
    assert float(jnp.max(params["w"])) < 1.0


def test_sgd_momentum():
    params = {"w": jnp.asarray([4.0])}
    opt = sgd(0.05, momentum=0.9)
    state = opt.init(params)
    loss = lambda p: jnp.sum(p["w"] ** 2)
    for _ in range(100):
        g = jax.grad(loss)(params)
        upd, state = opt.update(g, state, params)
        params = apply_updates(params, upd)
    assert abs(float(params["w"][0])) < 0.1


def test_clip_by_global_norm():
    g = {"a": jnp.full(4, 10.0), "b": jnp.full(9, 10.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert abs(float(global_norm(clipped)) - 1.0) < 1e-5
    assert float(norm) > 1.0
    small = {"a": jnp.full(4, 0.01), "b": jnp.full(9, 0.01)}
    clipped2, _ = clip_by_global_norm(small, 1.0)
    np.testing.assert_allclose(np.asarray(clipped2["a"]),
                               np.asarray(small["a"]), rtol=1e-5)


def test_schedules():
    cos = cosine_schedule(1.0, 100)
    assert abs(float(cos(jnp.asarray(0)))) > 0.99
    assert float(cos(jnp.asarray(100))) == pytest.approx(0.1, abs=1e-5)
    wc = linear_warmup_cosine(1.0, 10, 110)
    assert float(wc(jnp.asarray(5))) == pytest.approx(0.5, abs=1e-6)
    assert float(wc(jnp.asarray(10))) == pytest.approx(1.0, abs=1e-4)


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 2**16), scale=st.floats(1e-3, 1e3))
def test_property_int8_compression_error_bound(seed, scale):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal(64).astype(np.float32) * scale)
    q, s = int8_compress(x)
    y = int8_decompress(q, s)
    # error bounded by half a quantization step
    amax = float(jnp.max(jnp.abs(x)))
    assert float(jnp.max(jnp.abs(y - x))) <= amax / 127.0 * 0.5 + 1e-7


# ---------------------------------------------------------------------------
# Checkpoint
# ---------------------------------------------------------------------------

def _tree():
    return {"a": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
            "nested": {"b": jnp.asarray([1, 2, 3], jnp.int32)},
            "scalar": jnp.asarray(7, jnp.int32)}


def test_checkpoint_roundtrip():
    with tempfile.TemporaryDirectory() as d:
        t = _tree()
        save_checkpoint(d, 5, t)
        assert latest_step(d) == 5
        restored = restore_checkpoint(d, 5, jax.tree.map(jnp.zeros_like, t))
        for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(restored)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_corruption_detected():
    with tempfile.TemporaryDirectory() as d:
        save_checkpoint(d, 1, _tree())
        # corrupt a leaf file
        path = os.path.join(d, "step_1", "leaf_0.npy")
        with open(path, "r+b") as f:
            f.seek(-1, 2)
            f.write(b"\x00")
        with pytest.raises(IOError):
            restore_checkpoint(d, 1, _tree())


def test_async_checkpointer_overlap():
    with tempfile.TemporaryDirectory() as d:
        ck = AsyncCheckpointer(d)
        ck.save(3, _tree())
        ck.wait()
        assert latest_step(d) == 3


def test_checkpoint_latest_ignores_partial():
    with tempfile.TemporaryDirectory() as d:
        save_checkpoint(d, 2, _tree())
        os.makedirs(os.path.join(d, "step_9"))  # no manifest -> partial
        assert latest_step(d) == 2


# ---------------------------------------------------------------------------
# Data pipeline determinism
# ---------------------------------------------------------------------------

def test_pipeline_deterministic_per_step():
    p1 = TokenPipeline(vocab=100, seq_len=16, global_batch=4, seed=7)
    p2 = TokenPipeline(vocab=100, seq_len=16, global_batch=4, seed=7)
    for step in (0, 3, 17):
        b1, b2 = p1.batch_at(step), p2.batch_at(step)
        np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
        np.testing.assert_array_equal(b1["labels"], b2["labels"])
    # different steps differ
    assert not np.array_equal(p1.batch_at(0)["tokens"], p1.batch_at(1)["tokens"])


def test_pipeline_local_slice():
    p = TokenPipeline(vocab=50, seq_len=8, global_batch=8, seed=1,
                      local_slice=slice(2, 4))
    b = p.batch_at(0)
    assert b["tokens"].shape == (2, 8)
    full = TokenPipeline(vocab=50, seq_len=8, global_batch=8, seed=1).batch_at(0)
    np.testing.assert_array_equal(b["tokens"], full["tokens"][2:4])


def test_pipeline_labels_shifted():
    p = TokenPipeline(vocab=50, seq_len=8, global_batch=2, seed=0)
    b = p.batch_at(0)
    # labels are the next-token stream: shifted view of the same sequence
    assert b["tokens"].shape == b["labels"].shape
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


# ---------------------------------------------------------------------------
# Trainer: fault tolerance + straggler monitor
# ---------------------------------------------------------------------------

def _tiny_trainer(tmpdir, failure_injector=None, total_steps=12):
    from repro.configs import smoke_config
    from repro.train import Trainer, TrainerConfig, make_train_step, init_train_state
    import dataclasses as dc
    cfg = dc.replace(smoke_config("granite-3-2b"), n_layers=2)
    state = init_train_state(cfg, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(cfg))
    pipe = TokenPipeline(vocab=cfg.vocab, seq_len=16, global_batch=2, seed=3)
    tc = TrainerConfig(total_steps=total_steps, checkpoint_every=4,
                       checkpoint_dir=tmpdir, max_restarts=3)
    return Trainer(tc, step, state, pipe, failure_injector=failure_injector)


def test_trainer_runs_and_checkpoints():
    with tempfile.TemporaryDirectory() as d:
        tr = _tiny_trainer(d)
        state = tr.run()
        assert int(np.asarray(state.step)) == 12
        assert latest_step(d) == 12
        losses = [m["loss"] for m in tr.history]
        assert all(np.isfinite(losses))


def test_trainer_recovers_from_failure_bit_identically():
    """Kill step 6 once; final state must equal the no-failure run."""
    with tempfile.TemporaryDirectory() as d1:
        clean = _tiny_trainer(d1).run()
    killed = {"done": False}

    def inject(step):
        if step == 6 and not killed["done"]:
            killed["done"] = True
            raise RuntimeError("simulated device failure")

    with tempfile.TemporaryDirectory() as d2:
        tr = _tiny_trainer(d2, failure_injector=inject)
        recovered = tr.run()
        assert tr.restarts == 1
    for a, b in zip(jax.tree.leaves(clean.params),
                    jax.tree.leaves(recovered.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_straggler_monitor():
    from repro.train import StragglerMonitor
    m = StragglerMonitor(alpha=0.5, threshold=2.0)
    for i in range(5):
        assert not m.observe(i, 1.0)
    assert m.observe(5, 10.0)  # 10x slower than EWMA -> flagged
    assert m.flagged == [5]


# ---------------------------------------------------------------------------
# Serving engine
# ---------------------------------------------------------------------------

def test_serve_engine_batched_requests():
    import dataclasses as dc
    from repro.configs import smoke_config
    from repro.models.transformer import init_transformer
    from repro.serve import ServeEngine
    from repro.serve.engine import Request
    cfg = dc.replace(smoke_config("granite-3-2b"), n_layers=2)
    params, _ = init_transformer(cfg, jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, params, batch_slots=3, max_seq=32)
    rng = np.random.default_rng(0)
    for ln in (3, 5, 2):
        eng.submit(Request(prompt=rng.integers(0, cfg.vocab, ln),
                           max_new_tokens=4))
    done = eng.run()
    assert len(done) == 3
    for r in done:
        assert len(r.out_tokens) == 4
        assert all(0 <= t < cfg.vocab for t in r.out_tokens)


def test_greedy_generate_matches_engine():
    import dataclasses as dc
    from repro.configs import smoke_config
    from repro.models.transformer import init_transformer
    from repro.serve import greedy_generate
    cfg = dc.replace(smoke_config("granite-3-2b"), n_layers=2)
    params, _ = init_transformer(cfg, jax.random.PRNGKey(1))
    prompt = np.asarray([5, 9, 2], np.int32)
    out1 = greedy_generate(cfg, params, prompt, 5, max_seq=16)
    out2 = greedy_generate(cfg, params, prompt, 5, max_seq=16)
    np.testing.assert_array_equal(out1, out2)  # deterministic
