"""Pallas Algorithm-4 kernel vs the JAX hash engine (Algorithm 4 oracle)."""
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import phases
from repro.kernels.hash_accum import hash_accumulate, hash_accumulate_sorted


def _as_sorted_pairs(cols, vals, count):
    """Order-independent comparison: (col, val) pairs of the valid prefix."""
    out = []
    for r in range(cols.shape[0]):
        occ = cols[r] >= 0
        pairs = sorted(zip(cols[r][occ].tolist(),
                           np.round(vals[r][occ], 4).tolist()))
        assert len(pairs) == count[r]
        out.append(pairs)
    return out


def _random_stream(rng, r, ip_cap, n_cols):
    keys = rng.integers(0, n_cols, (r, ip_cap)).astype(np.int32)
    pad = rng.random((r, ip_cap)) < 0.3
    keys = np.where(pad, -1, keys)
    vals = np.where(pad, 0, rng.standard_normal((r, ip_cap))).astype(np.float32)
    return keys, vals


@pytest.mark.parametrize("r,ip_cap,n_cols,table_cap", [
    (4, 16, 8, 16), (2, 32, 64, 64), (8, 8, 4, 8), (1, 64, 16, 32),
])
def test_hash_accum_kernel_matches_jax_engine(r, ip_cap, n_cols, table_cap):
    rng = np.random.default_rng(0)
    keys, vals = _random_stream(rng, r, ip_cap, n_cols)
    kc, kv, kn = hash_accumulate(jnp.asarray(keys), jnp.asarray(vals),
                                 table_cap, interpret=True)
    jc, jv, jn = phases.accumulate_hash(jnp.asarray(keys), jnp.asarray(vals),
                                        table_cap)
    got = _as_sorted_pairs(np.asarray(kc), np.asarray(kv), np.asarray(kn))
    # jax engine emits sorted prefix; rebuild pairs the same way
    expect = []
    jc, jv, jn = np.asarray(jc), np.asarray(jv), np.asarray(jn)
    for i in range(r):
        expect.append(sorted(zip(jc[i, :jn[i]].tolist(),
                                 np.round(jv[i, :jn[i]], 4).tolist())))
    assert got == expect


@pytest.mark.parametrize("r,ip_cap,n_cols,table_cap,out_cap", [
    (4, 16, 8, 16, 8), (2, 32, 64, 64, 32), (8, 8, 4, 8, 4),
])
def test_hash_accum_sorted_matches_scan_engine(r, ip_cap, n_cols, table_cap,
                                               out_cap):
    """The fused-engine kernel branch (kernel table + XLA sort + trim) is
    bit-identical to the scan engine's sorted trimmed output — the
    contract `phases.fused_hash_sorted` relies on when the backend
    resolves to pallas/interpret (TPU)."""
    rng = np.random.default_rng(5)
    keys, vals = _random_stream(rng, r, ip_cap, n_cols)
    kc, kv, kn = hash_accumulate_sorted(jnp.asarray(keys), jnp.asarray(vals),
                                        table_cap, out_cap, interpret=True)
    jc, jv, jn = phases.fused_hash_sorted(jnp.asarray(keys),
                                          jnp.asarray(vals),
                                          table_cap, out_cap, kernel="xla")
    np.testing.assert_array_equal(np.asarray(kn), np.asarray(jn))
    np.testing.assert_array_equal(np.asarray(kc), np.asarray(jc))
    np.testing.assert_allclose(np.asarray(kv), np.asarray(jv),
                               rtol=1e-6, atol=1e-6)


def test_fused_spgemm_through_interpret_kernel(monkeypatch):
    """End-to-end: REPRO_KERNEL_BACKEND=interpret routes the fused engine
    through the Pallas Algorithm-4 kernel (the TPU branch, interpreted on
    CPU) — results must stay bit-exact vs the two-pass hash engine."""
    from repro.core import executor
    from repro.core.spgemm import spgemm
    from repro.sparse.formats import csr_from_dense

    monkeypatch.setenv("REPRO_KERNEL_BACKEND", "interpret")
    assert executor._fused_kernel_mode(np.dtype(np.float32).str) \
        == "interpret"
    rng = np.random.default_rng(7)
    x = np.where(rng.random((12, 12)) < 0.3,
                 rng.integers(1, 5, (12, 12)), 0).astype(np.float32)
    a = csr_from_dense(x)
    fu = spgemm(a, a, engine="fused_hash", row_chunk=8)
    monkeypatch.delenv("REPRO_KERNEL_BACKEND")
    ha = spgemm(a, a, engine="hash", row_chunk=8)
    nnz = fu.info["nnz_c"]
    assert nnz == ha.info["nnz_c"]
    np.testing.assert_array_equal(
        np.asarray(fu.c.indptr), np.asarray(ha.c.indptr))
    np.testing.assert_array_equal(
        np.asarray(fu.c.indices)[:nnz], np.asarray(ha.c.indices)[:nnz])
    np.testing.assert_array_equal(
        np.asarray(fu.c.data)[:nnz], np.asarray(ha.c.data)[:nnz])


def test_hash_accum_kernel_duplicate_keys_accumulate():
    keys = jnp.asarray([[3, 3, 3, -1]], jnp.int32)
    vals = jnp.asarray([[1.0, 2.0, 4.0, 9.0]], jnp.float32)
    cols, out, cnt = hash_accumulate(keys, vals, 8, interpret=True)
    assert int(cnt[0]) == 1
    occ = np.asarray(cols[0]) >= 0
    np.testing.assert_allclose(np.asarray(out[0])[occ], [7.0])


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**16))
def test_property_hash_accum_equals_segment_sum(seed):
    """Unordered (col → Σ val) content equals a segment-sum ground truth."""
    rng = np.random.default_rng(seed)
    keys, vals = _random_stream(rng, 2, 16, 8)
    cols, out, cnt = hash_accumulate(jnp.asarray(keys), jnp.asarray(vals),
                                     16, interpret=True)
    for r in range(2):
        truth = {}
        for k, v in zip(keys[r], vals[r]):
            if k >= 0:
                truth[int(k)] = truth.get(int(k), 0.0) + float(v)
        occ = np.asarray(cols[r]) >= 0
        got = dict(zip(np.asarray(cols[r])[occ].tolist(),
                       np.asarray(out[r])[occ].tolist()))
        assert set(got) == set(truth)
        for k in truth:
            np.testing.assert_allclose(got[k], truth[k], rtol=1e-5, atol=1e-5)
