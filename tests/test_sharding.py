"""Direct unit coverage for the SpGEMM shard-placement helpers in
``repro.launch.sharding`` — shard enumeration, device placement, the merge
point, and the footprint-gathered operand block (the communication-avoiding
alternative to full B replication)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.mesh import make_spgemm_mesh
from repro.launch.sharding import (
    merge_device, place_operand_block, replicate_to, shard_devices,
)


def test_shard_devices_none_mesh_is_single_logical_shard():
    assert shard_devices(None) == [None]


def test_shard_devices_flattens_mesh():
    n = jax.device_count()
    mesh = make_spgemm_mesh(n)
    devices = shard_devices(mesh)
    assert len(devices) == n
    assert set(devices) == set(np.asarray(mesh.devices).reshape(-1))


def test_replicate_to_none_is_identity():
    x = jnp.arange(4)
    assert replicate_to(x, None) is x


def test_replicate_to_places_on_device():
    dev = jax.devices()[-1]
    x = replicate_to(jnp.arange(4), dev)
    assert list(x.devices()) == [dev]
    np.testing.assert_array_equal(np.asarray(x), np.arange(4))


def test_merge_device_first_shard_or_none():
    assert merge_device([]) is None
    assert merge_device([None]) is None
    devs = jax.devices()
    assert merge_device(devs) is devs[0]


@pytest.mark.parametrize("device", [None, "last"])
def test_place_operand_block_gathers_rows_and_remaps(device):
    dev = jax.devices()[-1] if device == "last" else None
    b_idx = jnp.asarray(np.arange(12, dtype=np.int32).reshape(6, 2))
    b_val = jnp.asarray(np.arange(12, dtype=np.float32).reshape(6, 2) * 10)
    rows = np.array([1, 3, 4], dtype=np.int64)
    idx_blk, val_blk, remap = place_operand_block(b_idx, b_val, rows, dev)

    np.testing.assert_array_equal(np.asarray(idx_blk),
                                  np.asarray(b_idx)[rows])
    np.testing.assert_array_equal(np.asarray(val_blk),
                                  np.asarray(b_val)[rows])
    # remap: global row id -> block-local position, -1 for absent rows
    expect = np.array([-1, 0, -1, 1, 2, -1], dtype=np.int32)
    np.testing.assert_array_equal(np.asarray(remap), expect)
    assert remap.dtype == jnp.int32
    if dev is not None:
        for x in (idx_blk, val_blk, remap):
            assert list(x.devices()) == [dev]


def test_place_operand_block_full_footprint_is_permutation_free():
    """All rows selected in order: the block equals the replica and remap
    is the identity — the degenerate case the threshold fast path skips."""
    b_idx = jnp.asarray(np.arange(8, dtype=np.int32).reshape(4, 2))
    b_val = jnp.ones((4, 2), jnp.float32)
    idx_blk, val_blk, remap = place_operand_block(
        b_idx, b_val, np.arange(4, dtype=np.int64), None)
    np.testing.assert_array_equal(np.asarray(idx_blk), np.asarray(b_idx))
    np.testing.assert_array_equal(np.asarray(remap), np.arange(4))


def test_place_operand_block_remap_feeds_remap_columns():
    """End-to-end with the executor's column remapping: global A-columns
    remapped through the block's remap hit the same B rows the full
    replica would serve, and padding (-1) stays -1."""
    from repro.core.phases import remap_columns

    b_idx = jnp.asarray(np.arange(10, dtype=np.int32).reshape(5, 2))
    b_val = jnp.asarray(np.random.default_rng(0)
                        .random((5, 2)).astype(np.float32))
    rows = np.array([0, 2, 3], dtype=np.int64)
    idx_blk, _, remap = place_operand_block(b_idx, b_val, rows, None)

    cols = jnp.asarray(np.array([2, -1, 0, 3], dtype=np.int32))
    local = remap_columns(cols, remap)
    np.testing.assert_array_equal(np.asarray(local), [1, -1, 0, 2])
    # gathering the block at the local ids == gathering B at the globals
    valid = np.asarray(cols) >= 0
    np.testing.assert_array_equal(
        np.asarray(jnp.take(idx_blk, local, axis=0))[valid],
        np.asarray(b_idx)[np.asarray(cols)[valid]])
