"""hillclimb.py's offline measurement loop behind ``engine="auto"``.

``measure_bin_engines`` with an injected ``measure`` stub: full candidate
coverage of every non-empty bin, cache convergence identical to the
executor's incremental in-band rounds, argmin assignment, and the swept
cache serving ``engine="auto"`` as pure hits — all without timing a single
real kernel."""
import numpy as np
import pytest

from benchmarks.hillclimb import measure_bin_engines
from repro.core import executor
from repro.core.grouping import group_rows
from repro.sparse.formats import csr_from_dense


def int_sparse(rng, n, m, density=0.3):
    x = rng.integers(-4, 5, (n, m)).astype(np.float32)
    mask = rng.random((n, m)) < density
    return np.where(mask, x, 0.0).astype(np.float32)


@pytest.fixture()
def fixture():
    """Operands spanning three Table-I groups (single-nnz rows → group 0,
    0.25-density rows → group 1, full rows → group 2)."""
    rng = np.random.default_rng(2)
    xa = np.zeros((64, 48), np.float32)
    for i in range(24):
        xa[i, rng.integers(0, 48)] = float(rng.integers(1, 5))
    xa[24:48] = int_sparse(rng, 24, 48, 0.25)
    xa[48:] = rng.integers(1, 5, (16, 48)).astype(np.float32)
    a = csr_from_dense(xa)
    b = csr_from_dense(int_sparse(rng, 48, 52, 0.25))
    plan = group_rows(a, b)
    assert sum(s > 0 for s in plan.group_sizes) >= 3, plan.group_sizes
    return a, b, plan


def test_sweep_covers_every_populated_bin_and_engine(fixture):
    a, b, plan = fixture
    calls = []
    cache = executor.AutotuneCache()
    record = measure_bin_engines(
        a, b, plan=plan, cache=cache,
        measure=lambda g, e: calls.append((g, e)) or 100.0)
    populated = [g for g in range(4) if plan.group_sizes[g] > 0]
    expected = {(g, e) for g in populated
                for e in executor.available_engines()}
    assert set(calls) == expected and len(calls) == len(expected)
    assert record["group_sizes"] == list(plan.group_sizes)
    assert record["converged"]
    for g in populated:
        assert set(record["timings_us"][str(g)]) == \
            set(executor.available_engines())


def test_sweep_converges_cache_to_argmin(fixture):
    """Recording every candidate converges the entry exactly as the
    in-band rounds would, picking the per-bin argmin."""
    a, b, plan = fixture
    cache = executor.AutotuneCache()
    names = executor.available_engines()
    winner = {g: names[g % len(names)] for g in range(4)}
    record = measure_bin_engines(
        a, b, plan=plan, cache=cache,
        measure=lambda g, e: 10.0 if e == winner[g] else 100.0)
    key = executor.autotune_key(a, b, plan)
    assert cache.converged(key)
    seed = executor.static_bin_engines()
    for g in range(4):
        expect = winner[g] if plan.group_sizes[g] > 0 else seed[g]
        assert record["assignment"][g] == expect


def test_swept_cache_serves_auto_as_pure_hits(fixture):
    """The sweep's whole point: engine="auto" against a swept cache never
    measures in-band — first call included."""
    from repro.core.spgemm import spgemm
    from repro.core.ref import spgemm_dense
    from repro.sparse.formats import csr_to_dense

    a, b, plan = fixture
    cache = executor.AutotuneCache()
    measured = []
    measure_bin_engines(a, b, plan=plan, cache=cache,
                        measure=lambda g, e: measured.append((g, e)) or 50.0)
    n_swept = len(measured)
    assert cache.stats()["hits"] == 0
    res = spgemm(a, b, engine="auto", plan=plan, autotune=cache)
    assert cache.stats()["hits"] == 1 and cache.stats()["misses"] == 0
    assert len(measured) == n_swept, "auto re-measured after a full sweep"
    np.testing.assert_array_equal(
        np.asarray(csr_to_dense(res.c)), np.asarray(spgemm_dense(a, b)))


def test_sweep_restricted_engine_list(fixture):
    a, b, plan = fixture
    calls = []
    record = measure_bin_engines(
        a, b, plan=plan, engines=("sort",),
        cache=executor.AutotuneCache(candidates=("sort",)),
        measure=lambda g, e: calls.append(e) or 75.0)
    assert set(calls) == {"sort"}
    assert record["converged"]
    assert all(e == "sort" for g, e in enumerate(record["assignment"])
               if plan.group_sizes[g] > 0)


def test_sweep_defaults_plan_and_module_cache():
    """plan=None derives group_rows(a, b); cache=None folds into the
    executor module cache (the one engine="auto" reads by default)."""
    rng = np.random.default_rng(5)
    a = csr_from_dense(int_sparse(rng, 20, 16, 0.3))
    executor.clear_program_cache()  # reset the module autotune cache
    record = measure_bin_engines(a, a, measure=lambda g, e: 60.0)
    plan = group_rows(a, a)
    assert record["group_sizes"] == list(plan.group_sizes)
    key = executor.autotune_key(a, a, plan)
    assert executor.default_autotune_cache().converged(key)
    executor.clear_program_cache()
