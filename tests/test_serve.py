"""SpGEMMService: coalescing, shedding, tenant isolation, bit-exactness."""
import numpy as np
import pytest

from repro.core.spgemm import spgemm
from repro.serve import QueueFull, ServeKnobs, SpGEMMService
from repro.sparse.formats import csr_from_dense


def _pattern(seed, shape=(20, 20), density=0.25):
    return np.random.default_rng(seed).random(shape) < density


def _csr(mask, seed):
    vals = np.random.default_rng(seed).standard_normal(mask.shape)
    return csr_from_dense((mask * vals).astype(np.float32))


class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


def _service(**kw):
    clock = FakeClock()
    kw.setdefault("max_batch", 4)
    kw.setdefault("max_wait", 1.0)
    kw.setdefault("max_queue", 64)
    return SpGEMMService(clock=clock, **kw), clock


def test_coalesced_batch_bit_exact_vs_per_request():
    svc, _ = _service(max_batch=4)
    mask_a, mask_b = _pattern(1), _pattern(2)
    b_mats = [_csr(mask_b, 100 + i) for i in range(4)]
    a_mats = [_csr(mask_a, 200 + i) for i in range(4)]
    tickets = [svc.submit(f"t{i % 2}", a_mats[i], b_mats[i])
               for i in range(4)]
    stats = svc.stats()
    assert stats["batched_dispatches"] == 1
    assert stats["singleton_dispatches"] == 0
    assert stats["coalescing_ratio"] == 4.0
    for i, tk in enumerate(tickets):
        assert tk.done and tk.coalesced_with == 4
        ref = spgemm(a_mats[i], b_mats[i]).c
        got = tk.result().c
        np.testing.assert_array_equal(np.asarray(got.indptr),
                                      np.asarray(ref.indptr))
        np.testing.assert_array_equal(np.asarray(got.indices),
                                      np.asarray(ref.indices))
        np.testing.assert_array_equal(np.asarray(got.data),
                                      np.asarray(ref.data))


def test_singleton_pattern_falls_back_to_single_spgemm():
    svc, clock = _service(max_batch=8, max_wait=0.5)
    tk = svc.submit("solo", _csr(_pattern(3), 1), _csr(_pattern(4), 2))
    assert not tk.done and svc.queue_depth() == 1
    clock.t = 1.0
    assert svc.poll() == 1
    assert tk.done and tk.coalesced_with == 1
    stats = svc.stats()
    assert stats["singleton_dispatches"] == 1
    assert stats["batched_dispatches"] == 0
    ref = spgemm(_csr(_pattern(3), 1), _csr(_pattern(4), 2)).c
    np.testing.assert_array_equal(np.asarray(tk.result().c.data),
                                  np.asarray(ref.data))


def test_result_forces_dispatch_of_pending_group():
    svc, _ = _service(max_batch=8)
    tk = svc.submit("t", _csr(_pattern(5), 1), _csr(_pattern(6), 2))
    assert not tk.done
    res = tk.result()
    assert tk.done and res is not None and svc.queue_depth() == 0


def test_queue_full_sheds_and_counts():
    svc, _ = _service(max_batch=100, max_queue=3)
    b = _csr(_pattern(7), 0)
    for i in range(3):
        svc.submit("t", _csr(_pattern(10 + i), i), b)
    with pytest.raises(QueueFull):
        svc.submit("t", _csr(_pattern(20), 9), b)
    stats = svc.stats()
    assert stats["requests_shed"] == 1
    assert stats["queue_depth"] == 3
    assert stats["tenants"]["t"]["shed"] == 1
    # shed request never completes, queued ones still can
    assert svc.flush() == 3
    assert svc.stats()["requests_completed"] == 3


def test_max_wait_flush_on_submit_path():
    svc, clock = _service(max_batch=8, max_wait=0.5)
    tk = svc.submit("t", _csr(_pattern(8), 1), _csr(_pattern(9), 2))
    clock.t = 0.6
    # a later submit (different pattern) polls overdue groups on entry
    svc.submit("t", _csr(_pattern(30), 3), _csr(_pattern(31), 4))
    assert tk.done


def test_per_tenant_quota_eviction_is_isolated():
    svc, _ = _service(max_batch=1, tenant_plan_quota=2)
    b = _csr(_pattern(40), 0)
    # tenant A warms two patterns, tenant B churns through four
    for i in range(2):
        svc.submit("A", _csr(_pattern(50 + i), i), b)
    for i in range(4):
        svc.submit("B", _csr(_pattern(60 + i), i), b)
    ten = svc.stats()["tenants"]
    assert ten["B"]["plan_entries"] == 2  # quota enforced on B
    assert ten["A"]["plan_entries"] == 2  # A untouched by B's churn
    # resubmitting A's patterns hits A's cache
    for i in range(2):
        svc.submit("A", _csr(_pattern(50 + i), 100 + i), b)
    assert svc.stats()["tenants"]["A"]["plan_hits"] == 2


def test_cross_tenant_batch_accounts_plan_in_both_caches():
    svc, _ = _service(max_batch=2)
    mask_a, mask_b = _pattern(70), _pattern(71)
    svc.submit("lead", _csr(mask_a, 1), _csr(mask_b, 2))
    svc.submit("rider", _csr(mask_a, 3), _csr(mask_b, 4))
    ten = svc.stats()["tenants"]
    assert ten["lead"]["plan_entries"] == 1
    assert ten["rider"]["plan_entries"] == 1
    assert svc.stats()["batched_dispatches"] == 1


def test_knob_signature_splits_groups_and_validates():
    svc, _ = _service(max_batch=2)
    mask_a, mask_b = _pattern(80), _pattern(81)
    svc.submit("t", _csr(mask_a, 1), _csr(mask_b, 2), engine="sort")
    svc.submit("t", _csr(mask_a, 3), _csr(mask_b, 4), engine="hash")
    assert svc.stats()["queued_groups"] == 2  # knobs differ -> no coalesce
    with pytest.raises(ValueError):
        svc.submit("t", _csr(mask_a, 5), _csr(mask_b, 6), engine="nope")
    with pytest.raises(ValueError):
        svc.submit("t", _csr(mask_a, 5), _csr(mask_b, 6), sizing="nope")
    svc.flush()


def test_stats_latency_percentiles_use_injected_clock():
    svc, clock = _service(max_batch=4)
    mask_a, mask_b = _pattern(90), _pattern(91)
    b = _csr(mask_b, 0)
    for i in range(3):
        svc.submit("t", _csr(mask_a, i), b)
        clock.t += 0.1
    svc.flush()
    s = svc.stats()
    assert s["latency_p50_ms"] >= 100.0  # oldest waited 0.3s, median 0.2s
    assert s["latency_p99_ms"] >= s["latency_p50_ms"]
    assert s["requests_completed"] == 3


def test_serve_knobs_signature_stable():
    k1, k2 = ServeKnobs(engine="hash"), ServeKnobs(engine="hash")
    assert k1.signature() == k2.signature()
    assert ServeKnobs(engine="sort").signature() != k1.signature()
