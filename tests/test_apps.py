"""Paper applications: MCL, Graph Contraction, GNN+TopK training."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.apps import (
    rmat_graph, uniform_graph, mcl, graph_contraction,
    GNNConfig, init_gnn, gnn_forward, train_gnn,
)
from repro.apps.graph_contraction import label_matrix
from repro.apps.gnn import normalize_adjacency
from repro.sparse.formats import csr_to_dense, csr_from_dense
from repro.sparse.ops import csr_column_sums


def test_generators_shapes_and_stats():
    g = rmat_graph(256, 8.0, seed=1)
    assert g.shape == (256, 256)
    nnz = int(np.asarray(g.nnz))
    assert 256 * 4 < nnz <= 256 * 8  # dedup/self-loop removal shrinks a bit
    u = uniform_graph(256, 4.0, seed=1)
    deg = np.asarray(u.row_nnz())
    assert deg.max() < 20  # flat distribution


# ---------------------------------------------------------------------------
# Graph contraction — Algorithm 7 invariants
# ---------------------------------------------------------------------------

def test_contraction_matches_dense_oracle():
    rng = np.random.default_rng(0)
    n, m = 30, 5
    g = uniform_graph(n, 3.0, seed=2)
    labels = rng.integers(0, m, n)
    c, infos = graph_contraction(g, labels)
    s_dense = np.zeros((m, n), np.float32)
    s_dense[labels, np.arange(n)] = 1.0
    g_dense = np.asarray(csr_to_dense(g))
    expect = s_dense @ g_dense @ s_dense.T
    np.testing.assert_allclose(np.asarray(csr_to_dense(c)), expect,
                               rtol=1e-4, atol=1e-4)
    assert c.shape == (m, m)
    assert len(infos) == 2


def test_contraction_preserves_total_weight():
    """Merging nodes must conserve Σ edge weights (S has exactly one 1/col)."""
    g = rmat_graph(64, 4.0, seed=3)
    labels = np.random.default_rng(1).integers(0, 7, 64)
    c, _ = graph_contraction(g, labels)
    total_g = float(np.asarray(csr_to_dense(g)).sum())
    total_c = float(np.asarray(csr_to_dense(c)).sum())
    np.testing.assert_allclose(total_c, total_g, rtol=1e-4)


def test_label_matrix_structure():
    labels = np.array([2, 0, 1, 0])
    s = label_matrix(labels)
    d = np.asarray(csr_to_dense(s))
    assert d.shape == (3, 4)
    np.testing.assert_array_equal(d.sum(axis=0), np.ones(4))


# ---------------------------------------------------------------------------
# MCL — Algorithm 6 invariants
# ---------------------------------------------------------------------------

def test_mcl_two_blocks():
    """Two dense blocks + one bridge edge -> exactly two clusters."""
    n = 16
    x = np.zeros((n, n), np.float32)
    x[:8, :8] = 1.0
    x[8:, 8:] = 1.0
    np.fill_diagonal(x, 0)
    x[7, 8] = x[8, 7] = 0.1  # weak bridge
    g = csr_from_dense(x)
    res = mcl(g, e=2, r=2.0, k=16, max_iters=12)
    labels = res.clusters
    assert len(np.unique(labels[:8])) == 1
    assert len(np.unique(labels[8:])) == 1
    assert labels[0] != labels[8]


def test_mcl_column_stochastic_invariant():
    """After every iteration the matrix stays column-stochastic."""
    g = rmat_graph(48, 3.0, seed=4)
    res = mcl(g, e=2, r=2.0, k=16, max_iters=3, tol=0.0)
    s = np.asarray(csr_column_sums(res.matrix))
    nonzero = s > 1e-9
    np.testing.assert_allclose(s[nonzero], 1.0, rtol=1e-4)


def test_mcl_runs_spgemm_per_iteration():
    g = rmat_graph(32, 3.0, seed=5)
    res = mcl(g, e=2, max_iters=3, tol=0.0)
    assert len(res.spgemm_info) == res.n_iterations
    for info in res.spgemm_info:
        assert info["flops"] == 2 * info["intermediate_products"]


# ---------------------------------------------------------------------------
# GNN + TopK (Eq. 1–3)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", ["gcn", "gin", "sage"])
def test_gnn_forward_shapes(arch):
    g = rmat_graph(64, 4.0, seed=6)
    a = normalize_adjacency(g)
    cfg = GNNConfig(arch=arch, d_in=16, d_hidden=32, n_classes=5, topk=8)
    params = init_gnn(cfg, jax.random.PRNGKey(0))
    x = np.random.default_rng(0).standard_normal((64, 16)).astype(np.float32)
    logits = gnn_forward(cfg, params, a, jnp.asarray(x))
    assert logits.shape == (64, 5)
    assert not np.isnan(np.asarray(logits)).any()


@pytest.mark.parametrize("arch", ["gcn", "gin", "sage"])
def test_gnn_training_loss_decreases(arch):
    rng = np.random.default_rng(7)
    n = 96
    g = rmat_graph(n, 5.0, seed=7)
    a = normalize_adjacency(g)
    x = rng.standard_normal((n, 16)).astype(np.float32)
    labels = rng.integers(0, 4, n)
    cfg = GNNConfig(arch=arch, d_in=16, d_hidden=32, n_classes=4, topk=8)
    _, hist = train_gnn(cfg, a, x, labels, n_steps=25, lr=5e-3)
    assert hist[-1] < hist[0] * 0.9, hist


@pytest.mark.parametrize("arch", ["gcn", "gin", "sage"])
def test_gnn_minibatch_training_runs_and_amortizes(arch):
    """Mini-batch path: finite losses, and epoch-revisited batches hit the
    shared PlanCache (the sampler's probability patterns repeat)."""
    from repro.apps.gnn import train_gnn_minibatch

    rng = np.random.default_rng(11)
    n = 48
    g = normalize_adjacency(rmat_graph(n, 4.0, seed=11))
    x = rng.standard_normal((n, 8)).astype(np.float32)
    labels = rng.integers(0, 3, n)
    cfg = GNNConfig(arch=arch, n_layers=2, d_in=8, d_hidden=16,
                    n_classes=3, topk=8)
    params, hist, stats = train_gnn_minibatch(
        cfg, g, x, labels, batch_size=16, n_epochs=2, fanout=3, seed=2)
    assert len(hist) == 2 * 3  # 2 epochs × ceil(48/16) batches
    assert np.isfinite(hist).all()
    assert stats["plan_cache_hits"] > 0, stats
    for k, v in params.items():
        assert np.isfinite(np.asarray(v)).all(), k


def test_gnn_minibatch_forward_shapes_and_weight_ensemble():
    from repro.apps.gnn import gnn_forward_minibatch, init_gnn
    from repro.apps.sampling import bulk_sample

    rng = np.random.default_rng(12)
    n = 64
    g = normalize_adjacency(rmat_graph(n, 4.0, seed=12))
    cfg = GNNConfig(arch="sage", n_layers=2, d_in=8, d_hidden=16,
                    n_classes=4, topk=8)
    params = init_gnn(cfg, jax.random.PRNGKey(0))
    x = jnp.asarray(rng.standard_normal((n, 8)).astype(np.float32))
    batch = np.asarray([3, 7, 11])
    adjs, frontiers = bulk_sample(g, batch, fanout=2, n_layers=2, seed=4)
    logits = gnn_forward_minibatch(cfg, params, adjs, frontiers, x)
    assert logits.shape == (len(batch), 4)
    assert np.isfinite(np.asarray(logits)).all()
    # the edge-weight ensemble path produces the same shapes
    nnz = int(np.asarray(g.indptr)[-1])
    ws = np.stack([np.asarray(g.data)[:nnz]] * 2)
    adjs2, frontiers2 = bulk_sample(g, batch, fanout=2, n_layers=2, seed=4,
                                    weight_sets=ws)
    logits2 = gnn_forward_minibatch(cfg, params, adjs2, frontiers2, x)
    np.testing.assert_allclose(np.asarray(logits2), np.asarray(logits),
                               rtol=1e-5, atol=1e-5)


def test_gnn_topk_vs_dense_agree_when_k_full():
    """k = d_hidden makes TopK the identity: sparse path == dense path."""
    rng = np.random.default_rng(8)
    n = 48
    g = rmat_graph(n, 4.0, seed=8)
    a = normalize_adjacency(g)
    x = jnp.asarray(rng.standard_normal((n, 12)).astype(np.float32))
    key = jax.random.PRNGKey(3)
    cfg_s = GNNConfig(arch="gcn", d_in=12, d_hidden=24, n_classes=3,
                      topk=24, sparse_mode="topk")
    cfg_d = dataclasses_replace(cfg_s, sparse_mode="dense")
    params = init_gnn(cfg_s, key)
    ls = gnn_forward(cfg_s, params, a, x)
    ld = gnn_forward(cfg_d, params, a, x)
    np.testing.assert_allclose(np.asarray(ls), np.asarray(ld), rtol=1e-5)


def dataclasses_replace(cfg, **kw):
    import dataclasses
    return dataclasses.replace(cfg, **kw)
