"""Benchmark-harness correctness: locality simulator, roofline math,
regression-gate record comparison, and the CI contract gates."""
import json

import pytest

from benchmarks import assert_ci
from benchmarks.bench_locality import simulate
from benchmarks.check_regression import (
    compare, record_drift, write_step_summary,
)
from benchmarks.roofline import (
    Roofline, model_flops, wire_bytes_per_chip, roofline_from_record,
    PEAK_FLOPS_BF16, HBM_BW,
)
from repro.apps.graphs import rmat_graph
from repro.configs import get_config, SHAPE_SETS


def test_locality_aia_improves_hit_ratio_and_round_trips():
    # cage15-like regime (the benchmark's): dense-ish uniform rows, cache
    # under capacity pressure — where AIA's consolidation+grouping pays.
    from repro.apps.graphs import uniform_graph
    a = uniform_graph(2048, 19.2, seed=0)
    r = simulate(a, cache_kib=128)
    assert r["with_aia_hit_pct"] >= r["without_aia_hit_pct"]
    assert r["with_aia_round_trips"] < r["without_aia_round_trips"]
    assert r["round_trip_reduction"] > 1.5  # ≥ avg row len × 2 consolidation


def test_locality_round_trips_always_reduce():
    """The Fig. 2 round-trip consolidation is shape-independent."""
    a = rmat_graph(512, 8.0, seed=0)
    r = simulate(a, cache_kib=32)
    assert r["with_aia_round_trips"] < r["without_aia_round_trips"]


def _recs(**kw):
    return {k: {"name": k, "us": v} for k, v in kw.items()}


def test_check_regression_flags_only_real_regressions():
    base = _recs(a=100.0, b=100.0, zero=0.0)
    cur = _recs(a=150.0, b=250.0, zero=0.0)
    regs = compare(cur, base, max_ratio=2.0)
    assert [r[0] for r in regs] == ["b"]
    name, cur_us, base_us, ratio = regs[0]
    assert (cur_us, base_us, ratio) == (250.0, 100.0, 2.5)


def test_check_regression_skips_zero_and_missing_records():
    base = _recs(a=100.0, gone=80.0, zero=0.0)
    cur = _recs(a=120.0, new=999999.0, zero=0.0)
    # 'new' has no baseline, 'gone' no current, 'zero' is a counter row:
    # none of them can regress — drift is reported separately as warnings.
    assert compare(cur, base, max_ratio=2.0) == []
    new, missing = record_drift(cur, base)
    assert new == ["new"] and missing == ["gone"]


def test_check_regression_drift_empty_when_sets_match():
    base = _recs(a=1.0, b=2.0)
    cur = _recs(a=1.0, b=2.0)
    assert record_drift(cur, base) == ([], [])


def test_check_regression_min_us_noise_floor():
    """Records with both sides under the floor are jitter-dominated and
    skipped; a record *crossing* the floor (tiny baseline, blown-up
    current — the re-tracing signature) still gates."""
    base = _recs(tiny=30.0, crossed=30.0, big=1000.0)
    cur = _recs(tiny=90.0, crossed=900.0, big=2500.0)
    # no floor: all three 2x+ blowups flagged
    assert [r[0] for r in compare(cur, base, max_ratio=2.0)] == \
        ["big", "crossed", "tiny"]
    regs = compare(cur, base, max_ratio=2.0, min_us=200.0)
    assert [r[0] for r in regs] == ["big", "crossed"]
    # floor above everything: only records with a side >= floor gate
    assert compare(cur, base, max_ratio=2.0, min_us=1e9) == []


def test_roofline_terms_and_dominance():
    r = Roofline(arch="x", shape="train_4k", mesh={"data": 16, "model": 16},
                 t_compute=2.0, t_memory=1.0, t_collective=0.5,
                 model_flops_per_chip=1.97e14 * 1.5,  # 1.5s of ideal compute
                 hlo_flops_per_chip=2.0 * PEAK_FLOPS_BF16)
    assert r.dominant == "compute"
    assert r.bound_seconds == 2.0
    assert abs(r.useful_ratio - 0.75) < 1e-9
    assert abs(r.roofline_fraction - 0.75) < 1e-9


def test_model_flops_train_vs_decode():
    cfg = get_config("granite-3-2b")
    shapes = {s.name: s for s in SHAPE_SETS}
    f_train = model_flops(cfg, shapes["train_4k"])
    f_decode = model_flops(cfg, shapes["decode_32k"])
    # train: 6·N·D with D = 1M tokens; decode: 2·N·B + cache reads
    assert f_train > 100 * f_decode
    n = cfg.n_params()
    assert abs(f_train - 6 * n * 256 * 4096) / f_train < 1e-9


def test_moe_active_params_used():
    cfg = get_config("llama4-scout-17b-a16e")
    assert cfg.n_active_params() < 0.35 * cfg.n_params()
    shapes = {s.name: s for s in SHAPE_SETS}
    f = model_flops(cfg, shapes["train_4k"])
    assert abs(f - 6 * cfg.n_active_params() * 256 * 4096) / f < 1e-9


def test_wire_bytes_weighting():
    coll = {"all-reduce": 100.0, "all-gather": 100.0}
    w = wire_bytes_per_chip(coll, {"data": 16, "model": 16})
    # AR: 2·15/16·100 = 187.5 ; AG: 15/16·100 = 93.75
    assert abs(w - (187.5 + 93.75)) < 1e-6


def test_roofline_from_record():
    cfg = get_config("granite-3-2b")
    shapes = {s.name: s for s in SHAPE_SETS}
    rec = {
        "arch": "granite-3-2b", "shape": "train_4k",
        "mesh": {"data": 16, "model": 16},
        "flops_per_device": 1e13,
        "bytes_accessed_per_device": 1e11,
        "collective_bytes": {"all-reduce": 1e9},
    }
    r = roofline_from_record(rec, cfg, shapes["train_4k"])
    assert r.t_compute == 1e13 / PEAK_FLOPS_BF16
    assert r.t_memory == 1e11 / HBM_BW
    assert r.dominant == "memory"


# ---------------------------------------------------------------------------
# assert_ci: the tested replacement for ci.yml's inline assert heredocs.
# ---------------------------------------------------------------------------

def _doc(records=None, **meta):
    return {"records": [{"name": k, "us": v}
                        for k, v in (records or {}).items()],
            "meta": meta}


def _good_ci_doc():
    return _doc(
        records={"ci_batched_sort": 100.0, "ci_batched_loop_sort": 300.0,
                 "ci_selfprod_pipelined": 50.0, "ci_selfprod_legacy": 80.0,
                 "ci_selfprod_fused": 40.0, "ci_selfprod_fused_hash": 45.0},
        cache_stats={"plan_hits": 3},
        pipeline_probe={"host_syncs_pipelined": 1, "host_syncs_legacy": 4},
        fused_probe={"host_syncs_fused": 0},
        operand_probe={"n_shards": 2, "bytes_replicated": 1000,
                       "bytes_footprint": 400, "rows_footprint": 300,
                       "rows_total": 512},
    )


def test_assert_ci_all_ci_contracts_pass():
    names = ["plan_hits", "batched_beats_looped", "sync_budget",
             "fused_zero_sync", "operand_gate"]
    assert assert_ci.run_checks(_good_ci_doc(), names) == []


def test_assert_ci_plan_hits():
    assert assert_ci.check_plan_hits(_doc(cache_stats={"plan_hits": 0}))
    assert assert_ci.check_plan_hits(_doc())  # meta missing entirely


def test_assert_ci_batched_beats_looped():
    ok = _doc(records={"ci_batched_sort": 100.0,
                       "ci_batched_loop_sort": 101.0})
    assert assert_ci.check_batched_beats_looped(ok) == []
    tie = _doc(records={"ci_batched_sort": 100.0,
                        "ci_batched_loop_sort": 100.0})
    assert assert_ci.check_batched_beats_looped(tie)
    assert assert_ci.check_batched_beats_looped(_doc())  # records missing


def test_assert_ci_sync_budget():
    doc = _good_ci_doc()
    assert assert_ci.check_sync_budget(doc) == []
    doc["meta"]["pipeline_probe"]["host_syncs_pipelined"] = 3
    assert any("per wave" in e for e in assert_ci.check_sync_budget(doc))
    doc["meta"]["pipeline_probe"] = {"host_syncs_pipelined": 1,
                                     "host_syncs_legacy": 1}
    assert any("multiple chunks" in e
               for e in assert_ci.check_sync_budget(doc))


def test_assert_ci_fused_zero_sync():
    doc = _good_ci_doc()
    assert assert_ci.check_fused_zero_sync(doc) == []
    doc["meta"]["fused_probe"]["host_syncs_fused"] = 1
    assert assert_ci.check_fused_zero_sync(doc)


def test_assert_ci_operand_gate():
    doc = _good_ci_doc()
    assert assert_ci.check_operand_gate(doc) == []
    # footprint == replicated is a FAIL: placement must be strictly smaller
    doc["meta"]["operand_probe"]["bytes_footprint"] = 1000
    assert any("strictly below" in e
               for e in assert_ci.check_operand_gate(doc))
    doc = _good_ci_doc()
    doc["meta"]["operand_probe"]["n_shards"] = 1
    assert any("2 shards" in e for e in assert_ci.check_operand_gate(doc))
    assert assert_ci.check_operand_gate(_doc()) == ["operand_probe meta "
                                                    "missing"]


def _good_serve_doc():
    return _doc(
        records={"ci_serve_coalesced": 1000.0,
                 "ci_serve_per_request": 2500.0},
        serve_probe={"batched_dispatches": 3, "coalescing_ratio": 4.0,
                     "coalesced_s": 0.001, "per_request_s": 0.0025,
                     "quota_respected": True, "requests_shed": 0},
    )


def test_assert_ci_serve_gate_passes_good_doc():
    assert assert_ci.check_serve_gate(_good_serve_doc()) == []


def test_assert_ci_serve_gate_requires_coalescing():
    doc = _good_serve_doc()
    doc["meta"]["serve_probe"]["batched_dispatches"] = 0
    assert any("spgemm_batched" in e
               for e in assert_ci.check_serve_gate(doc))
    doc = _good_serve_doc()
    doc["meta"]["serve_probe"]["coalescing_ratio"] = 1.0
    assert any("ratio" in e for e in assert_ci.check_serve_gate(doc))


def test_assert_ci_serve_gate_speedup_and_tolerance():
    doc = _good_serve_doc()
    doc["meta"]["serve_probe"]["coalesced_s"] = 0.003  # slower than 0.0025
    assert any("did not beat" in e
               for e in assert_ci.check_serve_gate(doc))
    assert assert_ci.check_serve_gate(doc, tolerance=1.5) == []


def test_assert_ci_serve_gate_quota_shed_and_missing():
    doc = _good_serve_doc()
    doc["meta"]["serve_probe"]["quota_respected"] = False
    assert any("quota" in e for e in assert_ci.check_serve_gate(doc))
    doc = _good_serve_doc()
    doc["meta"]["serve_probe"]["requests_shed"] = 2
    assert any("shed" in e for e in assert_ci.check_serve_gate(doc))
    assert assert_ci.check_serve_gate(_doc()) == ["serve_probe meta missing"]
    doc = _good_serve_doc()
    doc["records"] = []
    assert any("missing" in e for e in assert_ci.check_serve_gate(doc))


def test_assert_ci_main_serve_gate_flag(tmp_path):
    art = tmp_path / "BENCH_ci.json"
    art.write_text(json.dumps(_good_serve_doc()))
    assert assert_ci.main([str(art), "--serve-gate"]) == 0
    bad = _good_serve_doc()
    bad["meta"]["serve_probe"]["coalesced_s"] = 0.01
    art.write_text(json.dumps(bad))
    assert assert_ci.main([str(art), "--serve-gate"]) == 1
    assert assert_ci.main([str(art), "--serve-gate",
                           "--serve-tolerance", "10.0"]) == 0


def _good_stream_doc():
    return _doc(
        records={"ci_selfprod_streamed": 220.0,
                 "ci_selfprod_stream_mono": 100.0},
        stream_probe={"bit_exact": True,
                      "streamed_record": "ci_selfprod_streamed",
                      "monolithic_record": "ci_selfprod_stream_mono",
                      "tiles_streamed": 4, "tile_bytes_h2d": 123456,
                      "prefetch_overlap_hits": 3},
    )


def test_assert_ci_stream_gate_passes_good_doc():
    assert assert_ci.check_stream_gate(_good_stream_doc()) == []


def test_assert_ci_stream_gate_requires_bit_exactness():
    doc = _good_stream_doc()
    doc["meta"]["stream_probe"]["bit_exact"] = False
    assert any("diverged" in e for e in assert_ci.check_stream_gate(doc))


def test_assert_ci_stream_gate_overhead_tolerance():
    doc = _good_stream_doc()
    doc["records"][0]["us"] = 300.0  # 3x the monolithic 100us
    assert any("exceeded" in e for e in assert_ci.check_stream_gate(doc))
    assert assert_ci.check_stream_gate(doc, tolerance=4.0) == []


def test_assert_ci_stream_gate_requires_real_tiling():
    doc = _good_stream_doc()
    doc["meta"]["stream_probe"]["tiles_streamed"] = 1
    assert any("tile" in e for e in assert_ci.check_stream_gate(doc))
    doc = _good_stream_doc()
    doc["meta"]["stream_probe"]["prefetch_overlap_hits"] = 0
    assert any("overlap" in e for e in assert_ci.check_stream_gate(doc))
    doc = _good_stream_doc()
    doc["meta"]["stream_probe"]["tile_bytes_h2d"] = 0
    assert any("host-to-device" in e
               for e in assert_ci.check_stream_gate(doc))


def test_assert_ci_stream_gate_missing_probe_and_records():
    assert assert_ci.check_stream_gate(_doc()) == ["stream_probe meta "
                                                   "missing"]
    doc = _good_stream_doc()
    doc["records"] = []
    assert any("missing" in e for e in assert_ci.check_stream_gate(doc))


def test_assert_ci_main_stream_gate_flag(tmp_path):
    art = tmp_path / "BENCH_ci.json"
    art.write_text(json.dumps(_good_stream_doc()))
    assert assert_ci.main([str(art), "--stream-gate"]) == 0
    bad = _good_stream_doc()
    bad["records"][0]["us"] = 5000.0
    art.write_text(json.dumps(bad))
    assert assert_ci.main([str(art), "--stream-gate"]) == 1
    assert assert_ci.main([str(art), "--stream-gate",
                           "--stream-tolerance", "100.0"]) == 0


def _good_resilience_doc():
    return _doc(
        records={"ci_chaos_capacity_retry": 400.0,
                 "ci_chaos_degraded": 900.0},
        resilience_probe={"capacity_retries_forced": 1,
                          "capacity_retry_bit_exact": True,
                          "capacity_retries_clean": 0,
                          "host_syncs_clean": 0,
                          "budget_degradations": 2,
                          "degraded_bit_exact": True},
    )


def test_assert_ci_resilience_gate_passes_good_doc():
    assert assert_ci.check_resilience_gate(_good_resilience_doc()) == []


def test_assert_ci_resilience_gate_requires_forced_retry():
    doc = _good_resilience_doc()
    doc["meta"]["resilience_probe"]["capacity_retries_forced"] = 0
    assert any("did not trigger" in e
               for e in assert_ci.check_resilience_gate(doc))


def test_assert_ci_resilience_gate_requires_bit_exact_recovery():
    doc = _good_resilience_doc()
    doc["meta"]["resilience_probe"]["capacity_retry_bit_exact"] = False
    assert any("diverged from measured" in e
               for e in assert_ci.check_resilience_gate(doc))
    doc = _good_resilience_doc()
    doc["meta"]["resilience_probe"]["degraded_bit_exact"] = False
    assert any("diverged from the monolithic" in e
               for e in assert_ci.check_resilience_gate(doc))


def test_assert_ci_resilience_gate_clean_path_must_stay_free():
    doc = _good_resilience_doc()
    doc["meta"]["resilience_probe"]["capacity_retries_clean"] = 1
    assert any("clean planned run paid capacity retries" in e
               for e in assert_ci.check_resilience_gate(doc))
    doc = _good_resilience_doc()
    doc["meta"]["resilience_probe"]["host_syncs_clean"] = 1
    assert any("blocking host syncs" in e
               for e in assert_ci.check_resilience_gate(doc))


def test_assert_ci_resilience_gate_requires_degradation():
    doc = _good_resilience_doc()
    doc["meta"]["resilience_probe"]["budget_degradations"] = 0
    assert any("did not degrade" in e
               for e in assert_ci.check_resilience_gate(doc))


def test_assert_ci_resilience_gate_missing_probe_and_records():
    assert assert_ci.check_resilience_gate(_doc()) == [
        "resilience_probe meta missing"]
    doc = _good_resilience_doc()
    doc["records"] = []
    assert any("missing" in e for e in assert_ci.check_resilience_gate(doc))


def test_assert_ci_main_resilience_gate_flag(tmp_path):
    art = tmp_path / "BENCH_ci.json"
    art.write_text(json.dumps(_good_resilience_doc()))
    assert assert_ci.main([str(art), "--resilience-gate"]) == 0
    bad = _good_resilience_doc()
    bad["meta"]["resilience_probe"]["capacity_retry_bit_exact"] = False
    art.write_text(json.dumps(bad))
    assert assert_ci.main([str(art), "--resilience-gate"]) == 1


# ---------------------------------------------------------------------------
# check_docs: the knobs.md docs-vs-code drift gate.
# ---------------------------------------------------------------------------

def test_check_docs_live_knobs_md_matches_code():
    from benchmarks import check_docs
    with open("docs/knobs.md") as f:
        assert check_docs.check(f.read()) == []


def test_check_docs_parses_tables_and_flags_drift():
    from benchmarks import check_docs
    text = ("## `engine`\n\n| Choice | x |\n|---|---|\n| `sort` | a |\n"
            "| `hash` | b |\n\n## not-a-knob heading\n| `zzz` | c |\n")
    tables = check_docs.parse_knob_tables(text)
    assert tables == {"engine": {"sort", "hash"}}
    errs = check_docs.check(text)
    # fused_hash/auto undocumented + the other five knob tables absent
    assert any("`engine` table drift" in e and "fused_hash" in e
               for e in errs)
    assert any("no table for `sizing`" in e for e in errs)


def test_check_docs_rejects_choices_the_resolver_rejects():
    from benchmarks import check_docs
    with open("docs/knobs.md") as f:
        text = f.read()
    text = text.replace("| `replicate` |", "| `bogus` |")
    errs = check_docs.check(text)
    assert any("resolver rejects" in e and "bogus" in e for e in errs)
    assert any("`operands` table drift" in e for e in errs)


def test_check_docs_main_cli(tmp_path, capsys):
    from benchmarks import check_docs
    good = tmp_path / "knobs.md"
    with open("docs/knobs.md") as f:
        good.write_text(f.read())
    assert check_docs.main([str(good)]) == 0
    assert "match the code" in capsys.readouterr().out
    good.write_text("# nothing here\n")
    assert check_docs.main([str(good)]) == 1
    assert "FAIL" in capsys.readouterr().err


def _good_medium_doc():
    return _doc(
        records={"medium_selfprod_sort": 900.0, "medium_selfprod_hash": 700.0,
                 "medium_selfprod_fused_hash": 600.0,
                 "medium_selfprod_auto": 650.0,
                 "medium_selfprod_pipelined": 500.0,
                 "medium_selfprod_legacy": 520.0},
        autotune_probe={"autotune_hits_converged": 4,
                        "autotune_misses_converged": 0},
        operand_probe={"n_shards": 2, "bytes_replicated": 9000,
                       "bytes_footprint": 5000, "rows_footprint": 800,
                       "rows_total": 1024},
    )


def test_assert_ci_autotune():
    doc = _good_medium_doc()
    assert assert_ci.check_autotune(doc) == []
    # auto is 650 vs best 600: a 1.05 tolerance rejects it
    assert any("not within" in e
               for e in assert_ci.check_autotune(doc, tolerance=1.05))
    doc["meta"]["autotune_probe"]["autotune_misses_converged"] = 2
    assert any("still measuring" in e for e in assert_ci.check_autotune(doc))
    assert assert_ci.check_autotune(_doc())  # all records missing


def test_assert_ci_pipelined_beats_legacy():
    doc = _good_medium_doc()
    assert assert_ci.check_pipelined_beats_legacy(doc) == []
    doc["records"][-2]["us"] = 600.0  # pipelined 600 vs legacy 520 > 1.1x
    assert assert_ci.check_pipelined_beats_legacy(doc)
    assert assert_ci.check_pipelined_beats_legacy(doc, tolerance=2.0) == []


def test_assert_ci_run_checks_prefixes_and_accumulates():
    doc = _doc()  # everything missing -> every check fails
    fails = assert_ci.run_checks(doc, ["plan_hits", "operand_gate"])
    assert len(fails) >= 2
    assert fails[0].startswith("[plan_hits]")
    assert any(f.startswith("[operand_gate]") for f in fails)


def test_assert_ci_main_cli(tmp_path, capsys):
    art = tmp_path / "BENCH_ci.json"
    art.write_text(json.dumps(_good_ci_doc()))
    flags = ["--plan-hits", "--batched-beats-looped", "--sync-budget",
             "--fused-zero-sync", "--operand-gate"]
    assert assert_ci.main([str(art)] + flags) == 0
    assert "5 contracts OK" in capsys.readouterr().out

    bad = _good_ci_doc()
    bad["meta"]["cache_stats"]["plan_hits"] = 0
    art.write_text(json.dumps(bad))
    assert assert_ci.main([str(art), "--plan-hits"]) == 1
    assert "FAIL [plan_hits]" in capsys.readouterr().err

    with pytest.raises(SystemExit):  # no contract flags selected
        assert_ci.main([str(art)])


def test_assert_ci_main_tolerance_flags(tmp_path):
    art = tmp_path / "BENCH_medium.json"
    art.write_text(json.dumps(_good_medium_doc()))
    assert assert_ci.main([str(art), "--autotune",
                           "--pipelined-beats-legacy"]) == 0
    # auto (650us) vs best single engine (600us) fails a 1.01x bound
    assert assert_ci.main([str(art), "--autotune",
                           "--auto-tolerance", "1.01"]) == 1


def test_write_step_summary_markdown_table(tmp_path):
    base = _recs(a=100.0, b=100.0, gone=50.0)
    cur = _recs(a=150.0, b=250.0, new=40.0)
    shared = ["a", "b"]
    regs = compare(cur, base, max_ratio=2.0)
    new, missing = record_drift(cur, base)
    out = tmp_path / "summary.md"
    out.write_text("previous step content\n")
    write_step_summary(cur, base, shared, regs, new, missing,
                       max_ratio=2.0, min_us=0.0, path=str(out))
    text = out.read_text()
    assert text.startswith("previous step content\n")  # appended, not clobbered
    assert "| record | baseline µs | current µs | ratio |" in text
    assert "| a | 100 | 150 | 1.50x | ✅ |" in text
    assert "| b | 100 | 250 | 2.50x | ❌ > 2.0x |" in text
    assert "no baseline" in text and "missing from run" in text
    assert "**FAIL**" in text


def test_hillclimb_append_log_creates_results_dir(tmp_path, monkeypatch):
    """Regression: --spgemm-bins wrote results/autotune_log.json into a
    directory that doesn't exist on a fresh checkout."""
    from benchmarks.hillclimb import append_log
    path = tmp_path / "results" / "autotune_log.json"
    assert not path.parent.exists()
    append_log(str(path), {"run": 1})
    log = append_log(str(path), {"run": 2})
    assert log == [{"run": 1}, {"run": 2}]
    assert json.loads(path.read_text()) == log
    # bare relative filename: empty dirname must not trip makedirs
    monkeypatch.chdir(tmp_path)
    assert append_log("flat.json", {"run": 3}) == [{"run": 3}]


def test_write_step_summary_ok_verdict(tmp_path):
    base = _recs(a=100.0)
    cur = _recs(a=110.0)
    out = tmp_path / "summary.md"
    write_step_summary(cur, base, ["a"], [], [], [],
                       max_ratio=2.0, min_us=0.0, path=str(out))
    text = out.read_text()
    assert "**OK**" in text and "1 record(s)" in text
