"""Benchmark-harness correctness: locality simulator, roofline math,
regression-gate record comparison."""
from benchmarks.bench_locality import simulate
from benchmarks.check_regression import compare, record_drift
from benchmarks.roofline import (
    Roofline, model_flops, wire_bytes_per_chip, roofline_from_record,
    PEAK_FLOPS_BF16, HBM_BW,
)
from repro.apps.graphs import rmat_graph
from repro.configs import get_config, SHAPE_SETS


def test_locality_aia_improves_hit_ratio_and_round_trips():
    # cage15-like regime (the benchmark's): dense-ish uniform rows, cache
    # under capacity pressure — where AIA's consolidation+grouping pays.
    from repro.apps.graphs import uniform_graph
    a = uniform_graph(2048, 19.2, seed=0)
    r = simulate(a, cache_kib=128)
    assert r["with_aia_hit_pct"] >= r["without_aia_hit_pct"]
    assert r["with_aia_round_trips"] < r["without_aia_round_trips"]
    assert r["round_trip_reduction"] > 1.5  # ≥ avg row len × 2 consolidation


def test_locality_round_trips_always_reduce():
    """The Fig. 2 round-trip consolidation is shape-independent."""
    a = rmat_graph(512, 8.0, seed=0)
    r = simulate(a, cache_kib=32)
    assert r["with_aia_round_trips"] < r["without_aia_round_trips"]


def _recs(**kw):
    return {k: {"name": k, "us": v} for k, v in kw.items()}


def test_check_regression_flags_only_real_regressions():
    base = _recs(a=100.0, b=100.0, zero=0.0)
    cur = _recs(a=150.0, b=250.0, zero=0.0)
    regs = compare(cur, base, max_ratio=2.0)
    assert [r[0] for r in regs] == ["b"]
    name, cur_us, base_us, ratio = regs[0]
    assert (cur_us, base_us, ratio) == (250.0, 100.0, 2.5)


def test_check_regression_skips_zero_and_missing_records():
    base = _recs(a=100.0, gone=80.0, zero=0.0)
    cur = _recs(a=120.0, new=999999.0, zero=0.0)
    # 'new' has no baseline, 'gone' no current, 'zero' is a counter row:
    # none of them can regress — drift is reported separately as warnings.
    assert compare(cur, base, max_ratio=2.0) == []
    new, missing = record_drift(cur, base)
    assert new == ["new"] and missing == ["gone"]


def test_check_regression_drift_empty_when_sets_match():
    base = _recs(a=1.0, b=2.0)
    cur = _recs(a=1.0, b=2.0)
    assert record_drift(cur, base) == ([], [])


def test_check_regression_min_us_noise_floor():
    """Records with both sides under the floor are jitter-dominated and
    skipped; a record *crossing* the floor (tiny baseline, blown-up
    current — the re-tracing signature) still gates."""
    base = _recs(tiny=30.0, crossed=30.0, big=1000.0)
    cur = _recs(tiny=90.0, crossed=900.0, big=2500.0)
    # no floor: all three 2x+ blowups flagged
    assert [r[0] for r in compare(cur, base, max_ratio=2.0)] == \
        ["big", "crossed", "tiny"]
    regs = compare(cur, base, max_ratio=2.0, min_us=200.0)
    assert [r[0] for r in regs] == ["big", "crossed"]
    # floor above everything: only records with a side >= floor gate
    assert compare(cur, base, max_ratio=2.0, min_us=1e9) == []


def test_roofline_terms_and_dominance():
    r = Roofline(arch="x", shape="train_4k", mesh={"data": 16, "model": 16},
                 t_compute=2.0, t_memory=1.0, t_collective=0.5,
                 model_flops_per_chip=1.97e14 * 1.5,  # 1.5s of ideal compute
                 hlo_flops_per_chip=2.0 * PEAK_FLOPS_BF16)
    assert r.dominant == "compute"
    assert r.bound_seconds == 2.0
    assert abs(r.useful_ratio - 0.75) < 1e-9
    assert abs(r.roofline_fraction - 0.75) < 1e-9


def test_model_flops_train_vs_decode():
    cfg = get_config("granite-3-2b")
    shapes = {s.name: s for s in SHAPE_SETS}
    f_train = model_flops(cfg, shapes["train_4k"])
    f_decode = model_flops(cfg, shapes["decode_32k"])
    # train: 6·N·D with D = 1M tokens; decode: 2·N·B + cache reads
    assert f_train > 100 * f_decode
    n = cfg.n_params()
    assert abs(f_train - 6 * n * 256 * 4096) / f_train < 1e-9


def test_moe_active_params_used():
    cfg = get_config("llama4-scout-17b-a16e")
    assert cfg.n_active_params() < 0.35 * cfg.n_params()
    shapes = {s.name: s for s in SHAPE_SETS}
    f = model_flops(cfg, shapes["train_4k"])
    assert abs(f - 6 * cfg.n_active_params() * 256 * 4096) / f < 1e-9


def test_wire_bytes_weighting():
    coll = {"all-reduce": 100.0, "all-gather": 100.0}
    w = wire_bytes_per_chip(coll, {"data": 16, "model": 16})
    # AR: 2·15/16·100 = 187.5 ; AG: 15/16·100 = 93.75
    assert abs(w - (187.5 + 93.75)) < 1e-6


def test_roofline_from_record():
    cfg = get_config("granite-3-2b")
    shapes = {s.name: s for s in SHAPE_SETS}
    rec = {
        "arch": "granite-3-2b", "shape": "train_4k",
        "mesh": {"data": 16, "model": 16},
        "flops_per_device": 1e13,
        "bytes_accessed_per_device": 1e11,
        "collective_bytes": {"all-reduce": 1e9},
    }
    r = roofline_from_record(rec, cfg, shapes["train_4k"])
    assert r.t_compute == 1e13 / PEAK_FLOPS_BF16
    assert r.t_memory == 1e11 / HBM_BW
    assert r.dominant == "memory"
