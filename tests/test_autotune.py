"""engine="auto" per-bin adaptive dispatch: validation, cache, bit-exactness.

Four layers of coverage:

* ``resolve_engine`` — the one validation path every façade/app entry point
  now routes through (typo → immediate error naming valid choices).
* ``AutotuneCache`` — hit on same-support/different-values operands,
  invalidation on index mutation, LRU bound: the same bars as the
  ``PlanCache`` tests in test_executor.py, keyed the same way.
* Convergence — an unconverged key measures one candidate per bin per
  call; once the queue drains every call is a pure hit with ZERO
  re-measurement (the contract the medium bench tier gates in CI).
* Bit-exactness — ``engine="auto"`` (measured assignment AND forced-mixed
  per-bin assignments via ``plan.group_engines``) matches the dense oracle
  for every gather × schedule × pipeline combination, single and batched.
"""
import dataclasses

import numpy as np
import pytest

from repro.core import executor
from repro.core.grouping import group_rows
from repro.core.ref import spgemm_dense
from repro.core.spgemm import spgemm, spgemm_batched, spgemm_ell_fixed
from repro.sparse.formats import (
    csr_from_dense, csr_to_dense, ell_from_dense,
)

GATHERS = ("xla", "aia")
SCHEDULES = ("grouped", "natural")
PIPELINES = ("two_wave", "legacy")


def int_sparse(rng, n, m, density=0.3):
    x = rng.integers(-4, 5, (n, m)).astype(np.float32)
    mask = rng.random((n, m)) < density
    return np.where(mask, x, 0.0).astype(np.float32)


def _dense(c):
    return np.asarray(csr_to_dense(c))


def _operands(seed=7, n=18, k=14, m=16):
    rng = np.random.default_rng(seed)
    a = csr_from_dense(int_sparse(rng, n, k, 0.25))
    b = csr_from_dense(int_sparse(rng, k, m, 0.35))
    return a, b


def _multibin_operands():
    """Operands whose plan populates several Table-I groups — the fixture
    every forced-mixed test needs.  A mixes single-nnz rows (IP < 32 →
    group 0), ~0.25-density rows (IP ≈ 150 → group 1) and full rows
    (IP ≈ nnz(B) ≈ 620 → group 2)."""
    rng = np.random.default_rng(2)
    xa = np.zeros((64, 48), np.float32)
    for i in range(24):
        xa[i, rng.integers(0, 48)] = float(rng.integers(1, 5))
    xa[24:48] = int_sparse(rng, 24, 48, 0.25)
    xa[48:] = rng.integers(1, 5, (16, 48)).astype(np.float32)
    a = csr_from_dense(xa)
    b = csr_from_dense(int_sparse(rng, 48, 52, 0.25))
    plan = group_rows(a, b)
    assert sum(s > 0 for s in plan.group_sizes) >= 3, plan.group_sizes
    return a, b, plan


def _stub_measure(timings=None, calls=None):
    """measure(group, engine) stub: record calls, serve canned µs."""
    def measure(group, engine):
        if calls is not None:
            calls.append((group, engine))
        if timings is not None:
            return timings[(group, engine)]
        return 100.0
    return measure


# ---------------------------------------------------------------------------
# resolve_engine: the single validation chokepoint
# ---------------------------------------------------------------------------

def test_resolve_engine_accepts_registered_and_auto():
    for name in executor.available_engines():
        assert executor.resolve_engine(name) == name
    assert executor.resolve_engine("auto") == "auto"
    assert executor.resolve_engine(None) == "sort"          # default
    assert executor.resolve_engine(None, method="hash") == "hash"
    assert executor.resolve_engine("hash", method="hash") == "hash"


def test_resolve_engine_typo_names_valid_choices():
    with pytest.raises(ValueError) as e:
        executor.resolve_engine("osrt")
    msg = str(e.value)
    assert "unknown engine 'osrt'" in msg
    for name in executor.available_engines():
        assert name in msg
    assert "auto" in msg


def test_resolve_engine_rejects_conflicting_alias():
    with pytest.raises(ValueError, match="conflicting method"):
        executor.resolve_engine("sort", method="hash")


def test_facades_validate_engine_up_front():
    a, b = _operands()
    with pytest.raises(ValueError, match="unknown engine"):
        spgemm(a, b, engine="osrt")
    with pytest.raises(ValueError, match="unknown engine"):
        spgemm_batched([a], b, engine="osrt")
    with pytest.raises(ValueError, match="conflicting method"):
        spgemm(a, b, engine="sort", method="hash")


def test_ell_fixed_rejects_auto():
    rng = np.random.default_rng(4)
    e = ell_from_dense(int_sparse(rng, 12, 12, 0.25), k_cap=8)
    with pytest.raises(ValueError, match="Table-I bins"):
        spgemm_ell_fixed(e, e, out_cap=12, engine="auto")
    with pytest.raises(ValueError, match="unknown engine"):
        spgemm_ell_fixed(e, e, out_cap=12, engine="osrt")


def test_static_bin_engines_backend_seed():
    assert executor.static_bin_engines("tpu") == ("fused_hash",) * 4
    assert executor.static_bin_engines("cpu") == ("sort",) * 4
    assert executor.static_bin_engines("gpu") == ("sort",) * 4
    seed = executor.static_bin_engines()  # live backend
    assert len(seed) == 4 and all(e in executor.ENGINES for e in seed)


# ---------------------------------------------------------------------------
# Sizing rule: planned only when every non-empty bin resolved fused
# ---------------------------------------------------------------------------

def test_resolve_sizing_auto_with_per_bin_assignment():
    a, b = _operands()
    plan = group_rows(a, b)
    fused = ("fused_hash",) * 4
    mixed = tuple("sort" if plan.group_sizes[g] > 0 else "fused_hash"
                  for g in range(4))
    assert executor.resolve_sizing("auto", "auto", plan, fused) == "planned"
    assert executor.resolve_sizing("auto", "auto", plan, mixed) == "measured"
    # an all-fused assignment on the empty bins only: the non-empty bins
    # drive the rule, so a single non-fused populated bin forces measured
    one_sort = list(fused)
    populated = next(g for g in range(4) if plan.group_sizes[g] > 0)
    one_sort[populated] = "sort"
    assert executor.resolve_sizing(
        "auto", "auto", plan, tuple(one_sort)) == "measured"


def test_engines_in_use_restricts_to_populated_bins():
    a, b = _operands()
    plan = group_rows(a, b)
    ge = tuple("hash" if plan.group_sizes[g] > 0 else "sort"
               for g in range(4))
    assert set(executor._engines_in_use("auto", plan, ge)) == {"hash"}
    assert executor._engines_in_use("sort", plan, None) == ("sort",)


def test_forced_all_fused_auto_pays_zero_host_syncs():
    """plan.group_engines all-fused under engine="auto" takes the planned
    sizing lane: the whole call dispatches with zero blocking syncs."""
    a, b = _operands()
    forced = dataclasses.replace(group_rows(a, b),
                                 group_engines=("fused_hash",) * 4)
    spgemm(a, b, engine="auto", plan=forced)  # warm
    s0 = executor.cache_stats()["host_sync_count"]
    res = spgemm(a, b, engine="auto", plan=forced)
    assert executor.cache_stats()["host_sync_count"] == s0
    np.testing.assert_array_equal(_dense(res.c), np.asarray(spgemm_dense(a, b)))


# ---------------------------------------------------------------------------
# AutotuneCache: PlanCache's bars, same key discipline
# ---------------------------------------------------------------------------

def test_autotune_cache_hits_on_same_support_different_values():
    rng = np.random.default_rng(21)
    pattern = rng.random((24, 24)) < 0.25
    m1, m2 = [csr_from_dense(np.where(
        pattern, rng.integers(1, 5, (24, 24)), 0.0).astype(np.float32))
        for _ in range(2)]
    plan = group_rows(m1, m1)
    cache = executor.AutotuneCache(candidates=("sort",))
    calls = []
    cache.assignment_for(executor.autotune_key(m1, m1, plan), plan,
                         _stub_measure(calls=calls))
    assert cache.stats()["misses"] == 1 and calls  # measured the seed
    n_calls = len(calls)
    # same support, different values → same key → converged pure hit
    asg = cache.assignment_for(executor.autotune_key(m2, m2, plan), plan,
                               _stub_measure(calls=calls))
    assert cache.stats() == {"hits": 1, "misses": 1, "entries": 1}
    assert len(calls) == n_calls, "converged lookup re-measured"
    assert asg == ("sort",) * 4


def test_autotune_cache_invalidated_by_index_mutation():
    """Same nnz, one column index changed → different fingerprint → the
    mutated pattern measures from scratch (its binning may differ)."""
    import jax.numpy as jnp
    from repro.sparse.formats import CSR

    rng = np.random.default_rng(22)
    a = csr_from_dense(int_sparse(rng, 16, 16, 0.3))
    b = csr_from_dense(int_sparse(rng, 16, 12, 0.3))
    cache = executor.AutotuneCache(candidates=("sort",))
    plan = group_rows(a, b)
    cache.assignment_for(executor.autotune_key(a, b, plan), plan,
                         _stub_measure())
    ind = np.asarray(a.indices).copy()
    row0 = np.asarray(a.indptr)[:2]
    assert row0[1] > row0[0]
    ind[row0[0]] = (ind[row0[0]] + 1) % a.n_cols
    mutated = CSR(a.indptr, jnp.asarray(ind), a.data, a.shape)
    mplan = group_rows(mutated, b)
    cache.assignment_for(executor.autotune_key(mutated, b, mplan), mplan,
                         _stub_measure())
    assert cache.stats() == {"hits": 0, "misses": 2, "entries": 2}


def test_autotune_cache_keys_on_bin_signature():
    """Same pattern, different binning (ungrouped single-bin plan) → a
    separate entry: per-bin timings don't transfer across bin layouts."""
    a, b = _operands()
    grouped = group_rows(a, b)
    natural = executor.ungrouped_plan(grouped)
    cache = executor.AutotuneCache(candidates=("sort",))
    cache.assignment_for(executor.autotune_key(a, b, grouped), grouped,
                         _stub_measure())
    cache.assignment_for(executor.autotune_key(a, b, natural), natural,
                         _stub_measure())
    assert cache.stats()["entries"] == 2 and cache.misses == 2


def test_autotune_cache_lru_bound():
    rng = np.random.default_rng(24)
    cache = executor.AutotuneCache(max_entries=2, candidates=("sort",))
    mats = [csr_from_dense(int_sparse(rng, 10, 10, 0.4)) for _ in range(3)]
    keys = []
    for m in mats:
        plan = group_rows(m, m)
        key = executor.autotune_key(m, m, plan)
        keys.append((key, plan))
        cache.assignment_for(key, plan, _stub_measure())
    assert len(cache) == 2
    assert not cache.converged(keys[0][0])  # evicted
    cache.assignment_for(*keys[0], _stub_measure())  # re-measures: a miss
    assert cache.misses == 4 and cache.hits == 0


def test_autotune_argmin_beats_seed():
    """Measured timings override the static seed: the per-bin argmin wins
    even when the seed engine was measured first."""
    a, b, plan = _multibin_operands()
    cache = executor.AutotuneCache()
    seed = executor.static_bin_engines()
    best = next(e for e in executor.available_engines() if e != seed[0])
    timings = {(g, e): (50.0 if e == best else 100.0)
               for g in range(4) for e in executor.available_engines()}
    key = executor.autotune_key(a, b, plan)
    for _ in range(len(executor.available_engines())):
        asg = cache.assignment_for(key, plan, _stub_measure(timings))
    assert cache.converged(key)
    for g in range(4):
        assert asg[g] == (best if plan.group_sizes[g] > 0 else seed[g])


def test_autotune_stats_fold_into_cache_stats():
    a, b = _operands()
    plan = group_rows(a, b)
    executor.clear_program_cache()
    assert executor.cache_stats()["autotune_hits"] == 0
    assert executor.cache_stats()["autotune_misses"] == 0
    cache = executor.AutotuneCache(candidates=("sort",))
    key = executor.autotune_key(a, b, plan)
    cache.assignment_for(key, plan, _stub_measure())
    cache.assignment_for(key, plan, _stub_measure())
    stats = executor.cache_stats()
    assert stats["autotune_misses"] == 1 and stats["autotune_hits"] == 1


# ---------------------------------------------------------------------------
# Convergence through the public engine="auto" path
# ---------------------------------------------------------------------------

def test_auto_converges_then_serves_pure_hits():
    """The iterative-workload contract: after one in-band measurement round
    per candidate, every further call is a hit with zero re-measurement,
    and the converged result stays bit-exact."""
    a, b = _operands()
    oracle = np.asarray(spgemm_dense(a, b))
    tuner = executor.AutotuneCache()
    n_rounds = len(executor.available_engines())
    for _ in range(n_rounds):
        res = spgemm(a, b, engine="auto", autotune=tuner)
        np.testing.assert_array_equal(_dense(res.c), oracle)
    assert tuner.misses == n_rounds
    key = executor.autotune_key(a, b, res.plan)
    assert tuner.converged(key)
    hits0, misses0 = tuner.hits, tuner.misses
    res = spgemm(a, b, engine="auto", autotune=tuner)
    assert (tuner.hits, tuner.misses) == (hits0 + 1, misses0)
    np.testing.assert_array_equal(_dense(res.c), oracle)
    # every populated bin measured every candidate
    [entry] = tuner._entries.values()
    plan = res.plan
    for g in range(4):
        if plan.group_sizes[g] > 0:
            assert set(entry.timings[g]) == set(executor.available_engines())


def test_auto_summary_is_json_friendly():
    import json

    a, b = _operands()
    tuner = executor.AutotuneCache(candidates=("sort",))
    spgemm(a, b, engine="auto", autotune=tuner)
    [summary] = tuner.summary()
    json.dumps(summary)  # no numpy scalars / tuples leaking through
    assert summary["group_sizes"] and len(summary["assignment"]) == 4


# ---------------------------------------------------------------------------
# Bit-exactness grid: auto == dense oracle on every axis combination
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("gather", GATHERS)
@pytest.mark.parametrize("schedule", SCHEDULES)
@pytest.mark.parametrize("pipeline", PIPELINES)
def test_auto_grid_matches_oracle(gather, schedule, pipeline):
    a, b = _operands()
    res = spgemm(a, b, engine="auto", gather=gather, schedule=schedule,
                 pipeline=pipeline, autotune=executor.AutotuneCache())
    np.testing.assert_array_equal(_dense(res.c), np.asarray(spgemm_dense(a, b)))


@pytest.mark.parametrize("pipeline", PIPELINES)
def test_forced_mixed_assignment_matches_oracle(pipeline):
    """plan.group_engines with *different* engines on different populated
    bins — the dispatch shape the autotuner will pick on real hardware —
    stays bit-exact on both sync structures."""
    a, b, plan = _multibin_operands()
    populated = [g for g in range(4) if plan.group_sizes[g] > 0]
    names = executor.available_engines()
    ge = ["sort"] * 4
    for i, g in enumerate(populated):
        ge[g] = names[i % len(names)]
    assert len({ge[g] for g in populated}) >= 2  # genuinely mixed
    forced = dataclasses.replace(plan, group_engines=tuple(ge))
    res = spgemm(a, b, engine="auto", plan=forced, pipeline=pipeline)
    np.testing.assert_array_equal(_dense(res.c), np.asarray(spgemm_dense(a, b)))
    # forced assignment wins over the call-level engine too
    res2 = spgemm(a, b, engine="sort", plan=forced, pipeline=pipeline)
    np.testing.assert_array_equal(_dense(res2.c), _dense(res.c))


def test_setup_execution_rejects_unknown_group_engine():
    a, b, plan = _multibin_operands()
    forced = dataclasses.replace(plan, group_engines=("sort", "osrt",
                                                      "sort", "sort"))
    with pytest.raises(ValueError, match="unknown engine"):
        spgemm(a, b, plan=forced)


def test_batched_auto_matches_per_member_oracle():
    rng = np.random.default_rng(31)
    pat_a = rng.random((18, 14)) < 0.3
    a_mats = [csr_from_dense(np.where(
        pat_a, rng.integers(1, 5, (18, 14)), 0.0).astype(np.float32))
        for _ in range(3)]
    b = csr_from_dense(int_sparse(rng, 14, 16, 0.35))
    tuner = executor.AutotuneCache()
    res = spgemm_batched(a_mats, b, engine="auto", autotune=tuner)
    for i in range(3):
        np.testing.assert_array_equal(
            _dense(res.cs[i]), np.asarray(spgemm_dense(a_mats[i], b)))
    # the batch shares one pattern → exactly one autotune entry
    assert len(tuner) == 1


# ---------------------------------------------------------------------------
# Measurement plumbing: bin_subplan + measure_group_engine
# ---------------------------------------------------------------------------

def test_bin_subplan_isolates_one_group():
    a, b, plan = _multibin_operands()
    populated = [g for g in range(4) if plan.group_sizes[g] > 0]
    for g in populated:
        sub = executor.bin_subplan(plan, g)
        assert sub.group_sizes[g] == plan.group_sizes[g]
        assert sum(sub.group_sizes) == plan.group_sizes[g]
        np.testing.assert_array_equal(
            np.sort(sub.map_rows), np.sort(plan.rows_of_group(g)))
        # the subplan must execute through the normal pipeline
        c, _ = executor.execute_plan(a, b, sub, engine="sort")
        assert c.indptr.shape[0] == a.n_rows + 1


def test_measure_group_engine_rejects_auto_and_times_with_stub_timer():
    a, b, plan = _multibin_operands()
    g = next(i for i in range(4) if plan.group_sizes[i] > 0)
    with pytest.raises(ValueError, match="unknown engine"):
        executor.measure_group_engine(a, b, plan, g, "auto")
    ticks = iter(range(100))
    us = executor.measure_group_engine(a, b, plan, g, "sort",
                                       timer=lambda: float(next(ticks)))
    assert us > 0  # monotone stub timer → positive measured µs
