"""Format round-trips and conversions (unit + hypothesis property tests)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.sparse import (
    csr_from_dense, csr_to_dense, ell_from_dense, ell_to_dense,
    csr_to_ell, ell_to_csr, bsr_from_dense, bsr_to_dense, csr_from_coo,
    csr_transpose, csr_spmm, csr_spmv, csr_permute_rows,
    csr_column_normalize, csr_column_sums, csr_hadamard_power,
    topk_rows, topk_mask, topk_rows_st, block_topk_rows,
)

jax.config.update("jax_enable_x64", False)


def random_sparse(rng, n, m, density=0.2):
    x = rng.standard_normal((n, m)).astype(np.float32)
    mask = rng.random((n, m)) < density
    return np.where(mask, x, 0.0).astype(np.float32)


@pytest.mark.parametrize("n,m,density", [(1, 1, 1.0), (7, 5, 0.3), (16, 16, 0.1),
                                         (10, 40, 0.05), (33, 9, 0.9)])
def test_csr_roundtrip(n, m, density):
    rng = np.random.default_rng(0)
    x = random_sparse(rng, n, m, density)
    a = csr_from_dense(x, capacity=max(int((x != 0).sum()), 1) + 7)  # extra pad
    np.testing.assert_allclose(np.asarray(csr_to_dense(a)), x)


@pytest.mark.parametrize("n,m", [(5, 8), (12, 12), (3, 20)])
def test_ell_roundtrip(n, m):
    rng = np.random.default_rng(1)
    x = random_sparse(rng, n, m, 0.3)
    e = ell_from_dense(x)
    np.testing.assert_allclose(np.asarray(ell_to_dense(e)), x)


def test_csr_ell_csr_roundtrip():
    rng = np.random.default_rng(2)
    x = random_sparse(rng, 9, 13, 0.4)
    a = csr_from_dense(x)
    kmax = int((x != 0).sum(1).max())
    e = csr_to_ell(a, kmax)
    np.testing.assert_allclose(np.asarray(ell_to_dense(e)), x)
    a2 = ell_to_csr(e)
    np.testing.assert_allclose(np.asarray(csr_to_dense(a2)), x)


def test_bsr_roundtrip():
    rng = np.random.default_rng(3)
    x = random_sparse(rng, 16, 24, 0.2)
    b = bsr_from_dense(x, (4, 8))
    np.testing.assert_allclose(np.asarray(bsr_to_dense(b)), x)


def test_csr_from_coo_merges_duplicates():
    rows = [0, 0, 1, 0]
    cols = [1, 1, 2, 3]
    vals = [1.0, 2.0, 5.0, 4.0]
    a = csr_from_coo(rows, cols, vals, (2, 4))
    d = np.asarray(csr_to_dense(a))
    expect = np.zeros((2, 4), np.float32)
    expect[0, 1] = 3.0
    expect[0, 3] = 4.0
    expect[1, 2] = 5.0
    np.testing.assert_allclose(d, expect)


def test_transpose():
    rng = np.random.default_rng(4)
    x = random_sparse(rng, 11, 7, 0.3)
    a = csr_from_dense(x, capacity=int((x != 0).sum()) + 5)
    at = csr_transpose(a)
    np.testing.assert_allclose(np.asarray(csr_to_dense(at)), x.T)


def test_spmm_spmv():
    rng = np.random.default_rng(5)
    x = random_sparse(rng, 10, 14, 0.25)
    a = csr_from_dense(x)
    d = rng.standard_normal((14, 6)).astype(np.float32)
    np.testing.assert_allclose(np.asarray(csr_spmm(a, jnp.asarray(d))), x @ d,
                               rtol=1e-5, atol=1e-5)
    v = rng.standard_normal(14).astype(np.float32)
    np.testing.assert_allclose(np.asarray(csr_spmv(a, jnp.asarray(v))), x @ v,
                               rtol=1e-5, atol=1e-5)


def test_permute_rows():
    rng = np.random.default_rng(6)
    x = random_sparse(rng, 8, 9, 0.4)
    a = csr_from_dense(x, capacity=int((x != 0).sum()) + 3)
    perm = rng.permutation(8).astype(np.int32)
    ap = csr_permute_rows(a, jnp.asarray(perm))
    np.testing.assert_allclose(np.asarray(csr_to_dense(ap)), x[perm])
    back = csr_permute_rows(ap, jnp.asarray(perm), inverse=True)
    np.testing.assert_allclose(np.asarray(csr_to_dense(back)), x)


def test_column_normalize():
    rng = np.random.default_rng(7)
    x = np.abs(random_sparse(rng, 9, 9, 0.5))
    a = csr_from_dense(x)
    an = csr_column_normalize(a)
    s = np.asarray(csr_column_sums(an))
    nonzero_cols = (x.sum(0) > 0)
    np.testing.assert_allclose(s[nonzero_cols], 1.0, rtol=1e-5)


def test_hadamard_power():
    rng = np.random.default_rng(8)
    x = np.abs(random_sparse(rng, 6, 6, 0.5))
    a = csr_from_dense(x)
    a2 = csr_hadamard_power(a, 2.0)
    np.testing.assert_allclose(np.asarray(csr_to_dense(a2)), x * x, rtol=1e-5)


def test_topk_rows():
    rng = np.random.default_rng(9)
    x = rng.standard_normal((5, 12)).astype(np.float32)
    t = topk_rows(jnp.asarray(x), 3)
    dense = np.asarray(t.to_dense())
    # each row keeps exactly its top-3 |values|
    for i in range(5):
        kept = np.nonzero(dense[i])[0]
        top = np.argsort(-np.abs(x[i]))[:3]
        assert set(kept) == set(top)
        np.testing.assert_allclose(dense[i, kept], x[i, kept])


def test_topk_st_gradient_matches_eq3():
    """Eq. (3): gradient is the mask ⊙ upstream (winner-take-all)."""
    rng = np.random.default_rng(10)
    x = jnp.asarray(rng.standard_normal((4, 10)).astype(np.float32))
    k = 4
    f = lambda x: jnp.sum(topk_rows_st(x, k) ** 2)
    g = jax.grad(f)(x)
    m = topk_mask(x, k)
    expect = np.where(np.asarray(m), 2 * np.asarray(x), 0)
    np.testing.assert_allclose(np.asarray(g), expect, rtol=1e-5)


def test_block_topk():
    rng = np.random.default_rng(11)
    x = jnp.asarray(rng.standard_normal((3, 32)).astype(np.float32))
    t = block_topk_rows(x, k_blocks=2, block=8)
    assert t.values.shape == (3, 16)
    assert t.indices.shape == (3, 2)
    xb = np.asarray(x).reshape(3, 4, 8)
    energy = (xb ** 2).sum(-1)
    for i in range(3):
        top2 = set(np.argsort(-energy[i])[:2])
        assert set(np.asarray(t.indices)[i]) == top2


@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(1, 12), m=st.integers(1, 12),
    seed=st.integers(0, 2**16), density=st.floats(0.0, 1.0),
)
def test_property_csr_roundtrip_and_transpose(n, m, seed, density):
    rng = np.random.default_rng(seed)
    x = random_sparse(rng, n, m, density)
    cap = max(int((x != 0).sum()), 1)
    a = csr_from_dense(x, capacity=cap)
    np.testing.assert_allclose(np.asarray(csr_to_dense(a)), x)
    np.testing.assert_allclose(np.asarray(csr_to_dense(csr_transpose(a))), x.T)


@settings(max_examples=20, deadline=None)
@given(n=st.integers(1, 8), m=st.integers(2, 16), seed=st.integers(0, 2**16))
def test_property_topk_mask_card(n, m, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((n, m)).astype(np.float32))
    k = min(3, m)
    mask = np.asarray(topk_mask(x, k))
    assert (mask.sum(axis=1) == k).all()
