"""End-to-end LM training driver: ~100M-param model, a few hundred steps,
with checkpoints, fault-tolerant trainer, and the paper's TopK-SpGEMM FFN.

    PYTHONPATH=src python examples/train_lm.py --steps 300 [--ffn-mode topk]

This is deliverable (b)'s end-to-end driver at CPU-feasible scale; the same
stack (configs → train_step → trainer) is what launch/train.py runs on the
production mesh.
"""
import argparse
import tempfile

import jax

from repro.configs.base import ArchConfig
from repro.data.pipeline import TokenPipeline
from repro.optim import adamw, linear_warmup_cosine
from repro.train import Trainer, TrainerConfig, init_train_state, make_train_step


def model_100m(ffn_mode="dense") -> ArchConfig:
    # ~100M params: 20L × d512 × ff2048, vocab 8192  (≈92M)
    return ArchConfig(
        name="repro-100m", family="dense", n_layers=20, d_model=512,
        n_heads=8, n_kv_heads=8, d_ff=2048, vocab=8192, head_dim=64,
        ffn_mode=ffn_mode, topk_k=256 if ffn_mode != "dense" else 0,
        dtype="float32", remat="none", loss_chunks=4,
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ffn-mode", default="dense",
                    choices=["dense", "topk", "block_topk"])
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()

    cfg = model_100m(args.ffn_mode)
    n_params = cfg.n_params()
    print(f"model: {cfg.name} ffn={cfg.ffn_mode} ~{n_params/1e6:.0f}M params")

    opt = adamw(linear_warmup_cosine(3e-4, 20, args.steps))
    state = init_train_state(cfg, jax.random.PRNGKey(0), opt)
    step = jax.jit(make_train_step(cfg, opt))
    pipe = TokenPipeline(vocab=cfg.vocab, seq_len=args.seq,
                         global_batch=args.batch, seed=0)
    ckpt_dir = args.ckpt_dir or tempfile.mkdtemp(prefix="repro_lm_")
    tcfg = TrainerConfig(total_steps=args.steps, checkpoint_every=100,
                         checkpoint_dir=ckpt_dir)
    trainer = Trainer(tcfg, step, state, pipe)
    trainer.run()
    losses = [m["loss"] for m in trainer.history]
    print(f"loss: {losses[0]:.3f} -> {losses[-1]:.3f} "
          f"({len(losses)} steps; ckpts in {ckpt_dir})")
    if trainer.monitor.flagged:
        print(f"straggler steps flagged: {trainer.monitor.flagged}")
    assert losses[-1] < losses[0], "loss did not decrease"


if __name__ == "__main__":
    main()
