"""GNN training with TopK structured pruning (paper §V-C, Eq. 1–3).

    PYTHONPATH=src python examples/gnn_training.py

Trains GCN/GIN/GraphSAGE with the pruning layer that turns SpMM into
SpGEMM, and compares against the dense baseline — the paper's Fig. 10
experiment at example scale.
"""
import time

import numpy as np

from repro.apps import GNNConfig, train_gnn, rmat_graph
from repro.apps.gnn import normalize_adjacency


def main():
    n = 1024
    g = rmat_graph(n, 16.0, seed=0)
    a = normalize_adjacency(g)
    rng = np.random.default_rng(0)
    x = rng.standard_normal((n, 64)).astype(np.float32)
    labels = rng.integers(0, 8, n)

    for arch in ("gcn", "gin", "sage"):
        row = [arch]
        for mode in ("topk", "dense"):
            cfg = GNNConfig(arch=arch, d_in=64, d_hidden=64, n_classes=8,
                            topk=16, sparse_mode=mode)
            t0 = time.perf_counter()
            _, hist = train_gnn(cfg, a, x, labels, n_steps=15)
            dt = time.perf_counter() - t0
            row.append(f"{mode}: {dt:.2f}s loss {hist[0]:.3f}->{hist[-1]:.3f}")
        print(" | ".join(row))
    print("(TopK keeps 16/64 features per node -> aggregation is the")
    print(" paper's SpGEMM; backward uses the Eq. 3 winner-take-all mask)")


if __name__ == "__main__":
    main()
