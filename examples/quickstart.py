"""Quickstart: the paper's SpGEMM pipeline end-to-end in 60 seconds.

    PYTHONPATH=src python examples/quickstart.py

Walks the three phases (Algorithm 1 → Table-I grouping → allocation →
accumulation) on a small power-law graph, checks the result against the
dense oracle, and shows the AIA kernel serving the same gather pattern.
"""
import numpy as np
import jax.numpy as jnp

from repro.apps.graphs import rmat_graph
from repro.core import intermediate_products, group_rows, spgemm
from repro.core.ref import spgemm_dense
from repro.kernels import ops
from repro.sparse.formats import csr_to_dense


def main():
    # A power-law graph like the paper's Table II workloads
    a = rmat_graph(512, 8.0, seed=0)
    print(f"A: {a.shape}, nnz={int(np.asarray(a.nnz))}")

    # Phase 1 — Algorithm 1: intermediate products + Table-I grouping
    ip = intermediate_products(a, a)
    plan = group_rows(a, a)
    print(f"total IP = {plan.total_ip} (paper FLOPs = {2*plan.total_ip})")
    print(f"Table-I groups: sizes={plan.group_sizes} "
          f"capacities={plan.table_capacities}")

    # Phases 2+3 — allocation + accumulation; every registered engine
    # (sort, hash, fused_hash) plus engine="auto" (per-bin adaptive
    # dispatch) agrees with the dense oracle
    c_dense = np.asarray(spgemm_dense(a, a))
    results = {}
    for engine in ("sort", "hash", "fused_hash", "auto"):
        results[engine] = spgemm(a, a, engine=engine)
        got = np.asarray(csr_to_dense(results[engine].c))
        np.testing.assert_allclose(got, c_dense, rtol=1e-4, atol=1e-4)
    res = results["sort"]
    print(f"C = A·A: nnz={res.info['nnz_c']}, "
          f"compression={res.info['compression_ratio']:.2f} "
          f"(sort/hash/fused_hash/auto engines verified vs dense oracle)")

    # The current knob surface: explicit gather backend, sync-free planned
    # sizing on the fused lane, and the operand placement policy (a no-op
    # without mesh=, but validated at entry like every knob)
    res_planned = spgemm(a, a, engine="fused_hash", gather="xla",
                         sizing="planned", operands="auto")
    np.testing.assert_allclose(np.asarray(csr_to_dense(res_planned.c)),
                               c_dense, rtol=1e-4, atol=1e-4)
    print("fused_hash + sizing='planned' (zero blocking host syncs): OK")

    # The AIA primitive: ranged indirect gather via scalar-prefetch DMA
    x = jnp.arange(64 * 8, dtype=jnp.float32).reshape(64, 8)
    idx = jnp.asarray([3, 0, 7, 7, 1], jnp.int32)
    out = ops.aia_ranged_gather(x, idx, r=1, backend="interpret")
    np.testing.assert_array_equal(np.asarray(out), np.asarray(x)[[3, 0, 7, 7, 1]])
    print("AIA ranged gather (Pallas, interpret mode): OK")


if __name__ == "__main__":
    main()
