"""Graph analytics with SpGEMM: Markov Clustering + Graph Contraction
(paper §V-A/B, Fig. 7/8 workloads).

    PYTHONPATH=src python examples/graph_analytics.py
"""
import numpy as np

from repro.apps import mcl, graph_contraction, rmat_graph
from repro.sparse.formats import csr_from_dense


def main():
    # ---- MCL on a planted two-cluster graph ----
    n = 24
    x = np.zeros((n, n), np.float32)
    x[:12, :12] = np.random.default_rng(0).random((12, 12)) > 0.3
    x[12:, 12:] = np.random.default_rng(1).random((12, 12)) > 0.3
    np.fill_diagonal(x, 0)
    x[11, 12] = x[12, 11] = 0.05  # weak bridge
    g = csr_from_dense(x.astype(np.float32))
    res = mcl(g, e=2, r=2.0, k=16, max_iters=10)
    print(f"MCL: {res.n_iterations} iterations, "
          f"{len(np.unique(res.clusters))} clusters found")
    print(f"  cluster of node 0:  {sorted(np.where(res.clusters == res.clusters[0])[0])[:12]}")
    print(f"  cluster of node 23: {sorted(np.where(res.clusters == res.clusters[23])[0])[:12]}")
    total_ip = sum(i['intermediate_products'] for i in res.spgemm_info)
    print(f"  SpGEMM work: {total_ip} intermediate products over "
          f"{len(res.spgemm_info)} expansions")

    # ---- Graph contraction: 512 nodes -> 16 super-nodes ----
    g2 = rmat_graph(512, 6.0, seed=2)
    labels = np.random.default_rng(3).integers(0, 16, 512)
    c, infos = graph_contraction(g2, labels)
    print(f"Contraction: {g2.shape} -> {c.shape}, "
          f"nnz {int(np.asarray(g2.nnz))} -> {int(np.asarray(c.nnz))}")
    w_before = float(np.asarray(g2.data).sum())
    w_after = float(np.asarray(c.data).sum())
    print(f"  total edge weight preserved: {w_before:.1f} -> {w_after:.1f}")


if __name__ == "__main__":
    main()
