"""Out-of-core streamed SpGEMM: the row-block lane end-to-end.

    PYTHONPATH=src python examples/streaming.py

Streams a power-law graph's self-product in row-block tiles
(``spgemm_streamed``), asserts bit-exactness against the monolithic
``spgemm``, prints the streaming counter deltas (``tiles_streamed``,
``tile_bytes_h2d``, ``prefetch_overlap_hits``), and then replays the
out-of-core story: a device budget that makes the monolithic lane raise
``DeviceBudgetExceeded`` while the streamed lane completes the same
product under it.  See docs/streaming.md for the memory model.
"""
import numpy as np

from repro.apps.graphs import rmat_graph
from repro.core import executor
from repro.core.spgemm import PlanCache, spgemm, spgemm_streamed


def stream_vs_monolithic(a, tile_rows=64):
    """One streamed self-product vs the monolithic lane, bit-compared."""
    mono = spgemm(a, a)

    executor.clear_program_cache()  # zeroed counters → readable deltas
    before = executor.cache_stats()
    cache = PlanCache()
    res = spgemm_streamed(a, a, tile_rows=tile_rows, plan=cache)
    after = executor.cache_stats()

    # Bit-exactness: identical indptr and identical occupied buffers.
    ipt = np.asarray(mono.c.indptr)
    nnz = int(ipt[-1])
    np.testing.assert_array_equal(np.asarray(res.c.indptr), ipt)
    np.testing.assert_array_equal(np.asarray(res.c.indices)[:nnz],
                                  np.asarray(mono.c.indices)[:nnz])
    np.testing.assert_array_equal(np.asarray(res.c.data)[:nnz],
                                  np.asarray(mono.c.data)[:nnz])

    print(f"streamed == monolithic, bit-exact (nnz_c={nnz})")
    print(f"  n_tiles={res.info['n_tiles']} tile_rows={tile_rows} "
          f"prefetch={res.info['prefetch']}")
    print(f"  total_ip={res.info['total_ip']} "
          f"max_tile_ip={res.info['max_tile_ip']} "
          f"(device peak shrank {res.info['total_ip'] / res.info['max_tile_ip']:.1f}x)")
    for key in ("tiles_streamed", "tile_bytes_h2d", "prefetch_overlap_hits"):
        print(f"  {key}: {before[key]} -> {after[key]}")

    # Repeat through the same PlanCache: every tile is a plan hit.
    spgemm_streamed(a, a, tile_rows=tile_rows, plan=cache)
    print(f"  repeat call: plan hits={cache.hits} misses={cache.misses}")
    return res, mono


def over_budget_demo(a, res, tile_rows=64):
    """A budget the monolithic product exceeds but every tile fits."""
    itemsize = np.dtype(np.float32).itemsize
    whole = int(res.info["total_ip"]) * (4 + itemsize)
    largest_tile = int(res.info["max_tile_ip"]) * (4 + itemsize)
    budget = (whole + largest_tile) // 2
    print(f"\ndevice budget demo: monolithic needs ~{whole} bytes, "
          f"largest tile ~{largest_tile}, budget={budget}")

    executor.set_device_budget(budget)
    try:
        try:
            spgemm(a, a)
            raise AssertionError("monolithic lane should have exceeded "
                                 "the budget")
        except executor.DeviceBudgetExceeded as e:
            print(f"  monolithic: DeviceBudgetExceeded ({e})")
        streamed = spgemm_streamed(a, a, tile_rows=tile_rows)
        print(f"  streamed: completed under the same budget "
              f"({streamed.info['n_tiles']} tiles)")
    finally:
        executor.set_device_budget(None)


def main():
    """Run the streamed-vs-monolithic walkthrough."""
    a = rmat_graph(512, 8.0, seed=0)
    print(f"A: {a.shape}, nnz={int(np.asarray(a.indptr)[-1])}")
    res, _mono = stream_vs_monolithic(a, tile_rows=64)
    over_budget_demo(a, res, tile_rows=64)
    print("\nstreaming example OK")


if __name__ == "__main__":
    main()
