"""Serving example: the multi-tenant pattern-coalescing SpGEMMService.

    PYTHONPATH=src python examples/serving.py

Two tenants issue same-structure queries (the production shape: per-user
subgraph inference, repeated MCL steps).  The service fingerprints each
operand pattern, coalesces same-pattern requests — across tenants — into
one ``spgemm_batched`` dispatch, and keeps per-tenant plan/operand/
autotune cache quotas.  See docs/serving.md for the full reference.
"""
import numpy as np

from repro.core.spgemm import spgemm
from repro.serve import QueueFull, SpGEMMService
from repro.sparse.formats import csr_from_dense


def main():
    rng = np.random.default_rng(0)
    n = 128
    # one shared sparsity pattern, per-request value sets — the
    # "same-structure queries" traffic the micro-batcher coalesces
    mask = rng.random((n, n)) < 0.05
    b = csr_from_dense((mask * rng.standard_normal((n, n)))
                       .astype(np.float32))

    def query():
        vals = rng.standard_normal((n, n)).astype(np.float32)
        return csr_from_dense((mask * vals).astype(np.float32))

    svc = SpGEMMService(max_batch=4, max_wait=0.05, max_queue=64,
                        tenant_plan_quota=8)

    # 4 same-pattern requests from 2 tenants -> ONE batched dispatch
    queries = [query() for _ in range(4)]
    tickets = [svc.submit(f"tenant-{i % 2}", q, b) for i, q in
               enumerate(queries)]
    assert all(t.done for t in tickets)  # group hit max_batch -> dispatched
    print(f"4 requests coalesced into {svc.stats()['dispatches']} "
          f"dispatch(es), coalescing ratio "
          f"{svc.stats()['coalescing_ratio']:.1f}")

    # bit-exact vs calling spgemm per request
    for q, t in zip(queries, tickets):
        ref = spgemm(q, b).c
        got = t.result().c
        np.testing.assert_array_equal(np.asarray(got.data),
                                      np.asarray(ref.data))
    print("coalesced results bit-exact vs per-request spgemm: OK")

    # a cold (singleton) pattern falls back to plain spgemm on flush
    solo_mask = rng.random((n, n)) < 0.05
    solo = csr_from_dense((solo_mask * rng.standard_normal((n, n)))
                          .astype(np.float32))
    tk = svc.submit("tenant-0", solo, b)
    svc.flush()
    print(f"singleton pattern dispatched alone "
          f"(coalesced_with={tk.coalesced_with})")

    # bounded queue: overload sheds loudly instead of silently growing
    tiny = SpGEMMService(max_batch=100, max_wait=1e9, max_queue=2)
    tiny.submit("t", query(), b)
    tiny.submit("t", query(), b)
    try:
        tiny.submit("t", query(), b)
    except QueueFull:
        print(f"queue bound enforced: "
              f"{tiny.stats()['requests_shed']} request shed")
    tiny.flush()

    s = svc.stats()
    print(f"stats: p50={s['latency_p50_ms']:.1f}ms "
          f"p99={s['latency_p99_ms']:.1f}ms; per-tenant plan hit rates: "
          + ", ".join(f"{tid}={t['plan_hit_rate']:.2f}"
                      for tid, t in s["tenants"].items()))


if __name__ == "__main__":
    main()
