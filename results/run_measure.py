import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import json
from repro.configs import ARCH_IDS, SHAPE_SETS, get_config
from repro.launch import specs as sp
from repro.launch.dryrun import measure_cell
from repro.launch.mesh import make_production_mesh

mesh = make_production_mesh()
records = []
for arch in ARCH_IDS:
    cfg = get_config(arch)
    for shape in SHAPE_SETS:
        ok, why = sp.cell_is_runnable(cfg, shape)
        if not ok:
            records.append({"arch": arch, "shape": shape.name, "skipped": why})
            continue
        try:
            records.append(measure_cell(cfg, shape, mesh))
        except Exception as e:  # record and continue; fix later
            print(f"[measure] FAIL {arch} x {shape.name}: {type(e).__name__}: {e}")
            records.append({"arch": arch, "shape": shape.name,
                            "error": f"{type(e).__name__}: {e}"})
        with open("/root/repo/results/measure_single.json", "w") as f:
            json.dump(records, f, indent=1, default=str)
print("DONE", len(records))
